//! Discrete-event machinery: the time-ordered event queue.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::coordinator::task::{DeviceId, TaskId};
use crate::sim::netsim::FlowId;
use crate::time::SimTime;
use crate::util::slab::SlotRef;

/// A fixed-capacity, inline batch of task ids. Low-priority requests are
/// at most [`IdBatch::CAP`] tasks (the trace alphabet is −1..=4, enforced
/// at generation and at trace load), so carrying the ids inline keeps
/// event construction allocation-free on the requeue/re-offer hot paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct IdBatch {
    len: u8,
    ids: [TaskId; Self::CAP],
}

impl IdBatch {
    /// Maximum low-priority tasks per frame (paper, Fig. 1).
    pub const CAP: usize = 4;

    pub fn new() -> Self {
        Self::default()
    }

    /// A single-id batch (requeue / re-offer events).
    pub fn one(id: TaskId) -> Self {
        let mut b = Self::new();
        b.push(id);
        b
    }

    pub fn push(&mut self, id: TaskId) {
        assert!((self.len as usize) < Self::CAP, "IdBatch overflow (> {} tasks)", Self::CAP);
        self.ids[self.len as usize] = id;
        self.len += 1;
    }

    pub fn as_slice(&self) -> &[TaskId] {
        &self.ids[..self.len as usize]
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Everything that can happen in the simulated system.
///
/// `HpFinish` / `LpFinish` / `TransferStart` carry the [`SlotRef`] of the
/// placement they were scheduled under: a task that is cancelled and
/// later re-placed (preemption victim, churn eviction, crash re-offer)
/// is re-slotted with a fresh slab generation, so events queued against
/// the dead placement stop resolving and are dropped instead of
/// finishing or transferring the new placement at the old placement's
/// times. (This folds the old explicit `gen: u64` placement counter into
/// the slab's generation word.)
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// The conveyor produces frame `index` of the trace (all devices).
    TraceFrame { index: usize },
    /// A high-priority scheduling request reaches the controller.
    HpArrive { task: TaskId },
    /// A high-priority task finishes on its device.
    HpFinish { task: SlotRef },
    /// A low-priority batch request reaches the controller.
    LpArrive { tasks: IdBatch, realloc: bool },
    /// A low-priority task finishes on its device.
    LpFinish { task: SlotRef },
    /// An offloaded task's input transfer begins on the medium.
    TransferStart { task: SlotRef },
    /// The medium predicts flow completion (stale if epoch mismatches).
    MediumComplete { flow: FlowId, epoch: u64 },
    /// A bandwidth probe round begins (host device chosen at fire time).
    ProbeStart,
    /// Background traffic burst toggles.
    TrafficToggle { active: bool },
    /// A device joins the fleet mid-run (scenario churn schedule).
    DeviceJoin { device: DeviceId },
    /// A device leaves the fleet; its live tasks are evicted.
    DeviceLeave { device: DeviceId },
    /// A device crashes (fault plan): unlike a graceful leave, its
    /// in-flight tasks are *lost* and their medium flows aborted.
    DeviceCrash { device: DeviceId },
    /// A crashed device recovers with fresh, empty availability.
    DeviceRecover { device: DeviceId },
    /// Crash-lost low-priority tasks re-enter scheduling via
    /// [`crate::coordinator::scheduler::SchedEvent::Reoffer`].
    Reoffer { tasks: IdBatch },
    /// The background-traffic regime changes mid-run (scenario schedule).
    /// The f64 rate/duty are carried as `to_bits` so the event stays `Eq`.
    RegimeChange { bg_bps_bits: u64, duty_bits: u64 },
}

/// A scheduled event: ordered by time, then insertion sequence (FIFO among
/// simultaneous events) for full determinism.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Scheduled {
    pub at: SimTime,
    pub seq: u64,
    pub event: Event,
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want earliest first.
        other.at.cmp(&self.at).then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic time-ordered queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, at: SimTime, event: Event) {
        self.seq += 1;
        self.heap.push(Scheduled { at, seq: self.seq, event });
    }

    pub fn pop(&mut self) -> Option<Scheduled> {
        self.heap.pop()
    }

    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(300, Event::ProbeStart);
        q.push(100, Event::TraceFrame { index: 0 });
        q.push(200, Event::TrafficToggle { active: true });
        assert_eq!(q.pop().unwrap().at, 100);
        assert_eq!(q.pop().unwrap().at, 200);
        assert_eq!(q.pop().unwrap().at, 300);
        assert!(q.pop().is_none());
    }

    #[test]
    fn id_batch_holds_up_to_cap_inline() {
        let mut b = IdBatch::new();
        assert!(b.is_empty());
        for id in 1..=IdBatch::CAP as u64 {
            b.push(id);
        }
        assert_eq!(b.len(), IdBatch::CAP);
        assert_eq!(b.as_slice(), &[1, 2, 3, 4]);
        assert_eq!(IdBatch::one(9).as_slice(), &[9]);
    }

    #[test]
    #[should_panic(expected = "IdBatch overflow")]
    fn id_batch_rejects_overflow() {
        let mut b = IdBatch::new();
        for id in 0..=IdBatch::CAP as u64 {
            b.push(id);
        }
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut q = EventQueue::new();
        q.push(50, Event::HpArrive { task: 1 });
        q.push(50, Event::HpArrive { task: 2 });
        q.push(50, Event::HpArrive { task: 3 });
        let order: Vec<_> = (0..3)
            .map(|_| match q.pop().unwrap().event {
                Event::HpArrive { task } => task,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 2, 3]);
    }
}
