//! Discrete-event machinery: the time-ordered event queue.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::coordinator::task::{DeviceId, TaskId};
use crate::sim::netsim::FlowId;
use crate::time::SimTime;
use crate::util::slab::SlotRef;

/// An inline-plus-spill batch of task ids (a small-vector). Conveyor
/// low-priority requests are at most [`IdBatch::INLINE`] tasks (the trace
/// alphabet is −1..=4, enforced at generation and at trace load), so the
/// common case stays allocation-free on the requeue/re-offer hot paths —
/// but generative workloads ([`crate::workload::gen`]) emit arbitrary
/// batch sizes, which spill to the heap instead of truncating or
/// panicking.
#[derive(Debug, Clone)]
enum IdBatchRepr {
    Inline { len: u8, ids: [TaskId; IdBatch::INLINE] },
    Spilled(Vec<TaskId>),
}

#[derive(Debug, Clone)]
pub struct IdBatch(IdBatchRepr);

impl Default for IdBatch {
    fn default() -> Self {
        Self(IdBatchRepr::Inline { len: 0, ids: [0; Self::INLINE] })
    }
}

impl IdBatch {
    /// Ids stored inline before spilling to the heap (the conveyor
    /// workload's maximum low-priority tasks per frame, paper Fig. 1).
    pub const INLINE: usize = 4;

    pub fn new() -> Self {
        Self::default()
    }

    /// A single-id batch (requeue / re-offer events).
    pub fn one(id: TaskId) -> Self {
        let mut b = Self::new();
        b.push(id);
        b
    }

    pub fn push(&mut self, id: TaskId) {
        match &mut self.0 {
            IdBatchRepr::Inline { len, ids } => {
                if (*len as usize) < Self::INLINE {
                    ids[*len as usize] = id;
                    *len += 1;
                } else {
                    // Boundary crossing: move the inline ids to the heap
                    // and append — larger batches grow like a Vec.
                    let mut v = Vec::with_capacity(Self::INLINE * 2);
                    v.extend_from_slice(&ids[..]);
                    v.push(id);
                    self.0 = IdBatchRepr::Spilled(v);
                }
            }
            IdBatchRepr::Spilled(v) => v.push(id),
        }
    }

    pub fn as_slice(&self) -> &[TaskId] {
        match &self.0 {
            IdBatchRepr::Inline { len, ids } => &ids[..*len as usize],
            IdBatchRepr::Spilled(v) => v,
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the ids spilled to the heap (diagnostics/tests).
    pub fn is_spilled(&self) -> bool {
        matches!(self.0, IdBatchRepr::Spilled(_))
    }
}

/// Content equality: representation (inline vs spilled) is invisible.
impl PartialEq for IdBatch {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for IdBatch {}

/// Everything that can happen in the simulated system.
///
/// `HpFinish` / `LpFinish` / `TransferStart` carry the [`SlotRef`] of the
/// placement they were scheduled under: a task that is cancelled and
/// later re-placed (preemption victim, churn eviction, crash re-offer)
/// is re-slotted with a fresh slab generation, so events queued against
/// the dead placement stop resolving and are dropped instead of
/// finishing or transferring the new placement at the old placement's
/// times. (This folds the old explicit `gen: u64` placement counter into
/// the slab's generation word.)
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// The conveyor produces frame `index` of the trace (all devices).
    TraceFrame { index: usize },
    /// A generative-workload arrival fires: `index` into the compiled
    /// arrival plan ([`crate::workload::gen::GenWorkload`]). Independent
    /// of the conveyor frame clock — this is how open-loop load reaches
    /// the engine.
    GenArrive { index: usize },
    /// A high-priority scheduling request reaches the controller.
    HpArrive { task: TaskId },
    /// A high-priority task finishes on its device.
    HpFinish { task: SlotRef },
    /// A low-priority batch request reaches the controller.
    LpArrive { tasks: IdBatch, realloc: bool },
    /// A low-priority task finishes on its device.
    LpFinish { task: SlotRef },
    /// An offloaded task's input transfer begins on the medium.
    TransferStart { task: SlotRef },
    /// The medium predicts flow completion (stale if epoch mismatches).
    MediumComplete { flow: FlowId, epoch: u64 },
    /// A bandwidth probe round begins (host device chosen at fire time).
    ProbeStart,
    /// Background traffic burst toggles.
    TrafficToggle { active: bool },
    /// A device joins the fleet mid-run (scenario churn schedule).
    DeviceJoin { device: DeviceId },
    /// A device leaves the fleet; its live tasks are evicted.
    DeviceLeave { device: DeviceId },
    /// A device crashes (fault plan): unlike a graceful leave, its
    /// in-flight tasks are *lost* and their medium flows aborted.
    DeviceCrash { device: DeviceId },
    /// A crashed device recovers with fresh, empty availability.
    DeviceRecover { device: DeviceId },
    /// Crash-lost low-priority tasks re-enter scheduling via
    /// [`crate::coordinator::scheduler::SchedEvent::Reoffer`].
    Reoffer { tasks: IdBatch },
    /// The background-traffic regime changes mid-run (scenario schedule).
    /// The f64 rate/duty are carried as `to_bits` so the event stays `Eq`.
    RegimeChange { bg_bps_bits: u64, duty_bits: u64 },
    /// The cloud tier's WAN medium predicts an upload completion (stale
    /// if the WAN epoch mismatches). Only pushed when the cloud tier is
    /// enabled — edge-only runs never see it.
    WanComplete { flow: FlowId, epoch: u64 },
    /// A device's battery is predicted to hit zero under its current
    /// draw ([`crate::energy::FleetEnergy`]): stale if the device's
    /// power changed since (epoch mismatch). Only pushed when a battery
    /// is configured — unbatteried runs never see it.
    BatteryDeplete { device: DeviceId, epoch: u64 },
}

/// A scheduled event: ordered by time, then insertion sequence (FIFO among
/// simultaneous events) for full determinism.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Scheduled {
    pub at: SimTime,
    pub seq: u64,
    pub event: Event,
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want earliest first.
        other.at.cmp(&self.at).then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic time-ordered queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, at: SimTime, event: Event) {
        self.seq += 1;
        self.heap.push(Scheduled { at, seq: self.seq, event });
    }

    pub fn pop(&mut self) -> Option<Scheduled> {
        self.heap.pop()
    }

    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(300, Event::ProbeStart);
        q.push(100, Event::TraceFrame { index: 0 });
        q.push(200, Event::TrafficToggle { active: true });
        assert_eq!(q.pop().unwrap().at, 100);
        assert_eq!(q.pop().unwrap().at, 200);
        assert_eq!(q.pop().unwrap().at, 300);
        assert!(q.pop().is_none());
    }

    #[test]
    fn id_batch_holds_up_to_inline_without_allocating() {
        let mut b = IdBatch::new();
        assert!(b.is_empty());
        for id in 1..=IdBatch::INLINE as u64 {
            b.push(id);
        }
        assert_eq!(b.len(), IdBatch::INLINE);
        assert_eq!(b.as_slice(), &[1, 2, 3, 4]);
        assert!(!b.is_spilled(), "at the inline capacity the batch must stay inline");
        assert_eq!(IdBatch::one(9).as_slice(), &[9]);
    }

    #[test]
    fn id_batch_spills_at_the_boundary_instead_of_panicking() {
        // The boundary: INLINE ids stay inline, the (INLINE+1)-th spills —
        // contents and order are preserved exactly across the crossing.
        let mut b = IdBatch::new();
        for id in 1..=IdBatch::INLINE as u64 {
            b.push(id);
        }
        b.push(5);
        assert!(b.is_spilled());
        assert_eq!(b.as_slice(), &[1, 2, 3, 4, 5]);
        // Keep growing well past the old cap (generative batch sizes).
        for id in 6..=100u64 {
            b.push(id);
        }
        assert_eq!(b.len(), 100);
        assert_eq!(b.as_slice()[99], 100);
        assert!(b.as_slice().windows(2).all(|w| w[0] + 1 == w[1]));
    }

    #[test]
    fn id_batch_equality_ignores_representation() {
        let mut inline = IdBatch::new();
        let mut spilled = IdBatch::new();
        for id in 1..=3u64 {
            inline.push(id);
        }
        for id in 1..=6u64 {
            spilled.push(id);
        }
        // Same content compares equal regardless of storage...
        assert_eq!(inline.clone(), inline);
        assert_eq!(spilled.clone(), spilled);
        // ...and different content does not.
        assert_ne!(inline, spilled);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut q = EventQueue::new();
        q.push(50, Event::HpArrive { task: 1 });
        q.push(50, Event::HpArrive { task: 2 });
        q.push(50, Event::HpArrive { task: 3 });
        let order: Vec<_> = (0..3)
            .map(|_| match q.pop().unwrap().event {
                Event::HpArrive { task } => task,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 2, 3]);
    }
}
