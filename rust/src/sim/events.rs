//! Discrete-event machinery: the time-ordered event queue.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::coordinator::task::{DeviceId, TaskId};
use crate::sim::netsim::FlowId;
use crate::time::SimTime;
use crate::util::slab::SlotRef;

/// An inline-plus-spill batch of task ids (a small-vector). Conveyor
/// low-priority requests are at most [`IdBatch::INLINE`] tasks (the trace
/// alphabet is −1..=4, enforced at generation and at trace load), so the
/// common case stays allocation-free on the requeue/re-offer hot paths —
/// but generative workloads ([`crate::workload::gen`]) emit arbitrary
/// batch sizes, which spill to the heap instead of truncating or
/// panicking.
#[derive(Debug, Clone)]
enum IdBatchRepr {
    Inline { len: u8, ids: [TaskId; IdBatch::INLINE] },
    Spilled(Vec<TaskId>),
}

#[derive(Debug, Clone)]
pub struct IdBatch(IdBatchRepr);

impl Default for IdBatch {
    fn default() -> Self {
        Self(IdBatchRepr::Inline { len: 0, ids: [0; Self::INLINE] })
    }
}

impl IdBatch {
    /// Ids stored inline before spilling to the heap (the conveyor
    /// workload's maximum low-priority tasks per frame, paper Fig. 1).
    pub const INLINE: usize = 4;

    pub fn new() -> Self {
        Self::default()
    }

    /// A single-id batch (requeue / re-offer events).
    pub fn one(id: TaskId) -> Self {
        let mut b = Self::new();
        b.push(id);
        b
    }

    pub fn push(&mut self, id: TaskId) {
        match &mut self.0 {
            IdBatchRepr::Inline { len, ids } => {
                if (*len as usize) < Self::INLINE {
                    ids[*len as usize] = id;
                    *len += 1;
                } else {
                    // Boundary crossing: move the inline ids to the heap
                    // and append — larger batches grow like a Vec.
                    let mut v = Vec::with_capacity(Self::INLINE * 2);
                    v.extend_from_slice(&ids[..]);
                    v.push(id);
                    self.0 = IdBatchRepr::Spilled(v);
                }
            }
            IdBatchRepr::Spilled(v) => v.push(id),
        }
    }

    pub fn as_slice(&self) -> &[TaskId] {
        match &self.0 {
            IdBatchRepr::Inline { len, ids } => &ids[..*len as usize],
            IdBatchRepr::Spilled(v) => v,
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the ids spilled to the heap (diagnostics/tests).
    pub fn is_spilled(&self) -> bool {
        matches!(self.0, IdBatchRepr::Spilled(_))
    }
}

/// Content equality: representation (inline vs spilled) is invisible.
impl PartialEq for IdBatch {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for IdBatch {}

/// Everything that can happen in the simulated system.
///
/// `HpFinish` / `LpFinish` / `TransferStart` carry the [`SlotRef`] of the
/// placement they were scheduled under: a task that is cancelled and
/// later re-placed (preemption victim, churn eviction, crash re-offer)
/// is re-slotted with a fresh slab generation, so events queued against
/// the dead placement stop resolving and are dropped instead of
/// finishing or transferring the new placement at the old placement's
/// times. (This folds the old explicit `gen: u64` placement counter into
/// the slab's generation word.)
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// The conveyor produces frame `index` of the trace (all devices).
    TraceFrame { index: usize },
    /// A generative-workload arrival fires: `index` into the compiled
    /// arrival plan ([`crate::workload::gen::GenWorkload`]). Independent
    /// of the conveyor frame clock — this is how open-loop load reaches
    /// the engine.
    GenArrive { index: usize },
    /// A high-priority scheduling request reaches the controller.
    HpArrive { task: TaskId },
    /// A high-priority task finishes on its device.
    HpFinish { task: SlotRef },
    /// A low-priority batch request reaches the controller.
    LpArrive { tasks: IdBatch, realloc: bool },
    /// A low-priority task finishes on its device.
    LpFinish { task: SlotRef },
    /// An offloaded task's input transfer begins on the medium.
    TransferStart { task: SlotRef },
    /// The medium predicts flow completion (stale if epoch mismatches).
    MediumComplete { flow: FlowId, epoch: u64 },
    /// A bandwidth probe round begins (host device chosen at fire time).
    ProbeStart,
    /// Background traffic burst toggles.
    TrafficToggle { active: bool },
    /// A device joins the fleet mid-run (scenario churn schedule).
    DeviceJoin { device: DeviceId },
    /// A device leaves the fleet; its live tasks are evicted.
    DeviceLeave { device: DeviceId },
    /// A device crashes (fault plan): unlike a graceful leave, its
    /// in-flight tasks are *lost* and their medium flows aborted.
    DeviceCrash { device: DeviceId },
    /// A crashed device recovers with fresh, empty availability.
    DeviceRecover { device: DeviceId },
    /// Crash-lost low-priority tasks re-enter scheduling via
    /// [`crate::coordinator::scheduler::SchedEvent::Reoffer`].
    Reoffer { tasks: IdBatch },
    /// The background-traffic regime changes mid-run (scenario schedule).
    /// The f64 rate/duty are carried as `to_bits` so the event stays `Eq`.
    RegimeChange { bg_bps_bits: u64, duty_bits: u64 },
    /// The cloud tier's WAN medium predicts an upload completion (stale
    /// if the WAN epoch mismatches). Only pushed when the cloud tier is
    /// enabled — edge-only runs never see it.
    WanComplete { flow: FlowId, epoch: u64 },
    /// A device's battery is predicted to hit zero under its current
    /// draw ([`crate::energy::FleetEnergy`]): stale if the device's
    /// power changed since (epoch mismatch). Only pushed when a battery
    /// is configured — unbatteried runs never see it.
    BatteryDeplete { device: DeviceId, epoch: u64 },
    /// A device becomes unreachable-but-alive (fault plan partition):
    /// its medium flows stall (captured, not aborted) and compute
    /// results are held undeliverable until the partition heals.
    PartitionStart { device: DeviceId },
    /// A partitioned device becomes reachable again: stalled flows
    /// resume from their captured progress, held results deliver.
    PartitionHeal { device: DeviceId },
    /// An offloaded placement's timeout window expired (recovery layer):
    /// if the placement is still live, cancel and retry with backoff or
    /// abandon past the retry limit. Dead if the `SlotRef` went stale.
    /// Only pushed when `offload_timeout_s > 0`.
    OffloadTimeout { task: SlotRef },
    /// A hedged-duplicate window expired for a still-running offloaded
    /// placement: launch a duplicate, first completion wins. Dead if the
    /// `SlotRef` went stale. Only pushed when `hedge_timeout_s > 0`.
    HedgeLaunch { task: SlotRef },
    /// A running staged low-priority execution crossed the boundary
    /// after anytime stage `stage` (1-based). If a truncation was armed
    /// at or below this stage the task finishes *now* with partial
    /// accuracy; otherwise execution continues into the next stage. All
    /// boundary events of an execution are pushed when it starts; a
    /// cancelled placement leaves them to die via the stale `SlotRef`.
    /// Only pushed for rungs carrying a stage plan — monolithic runs
    /// never see it.
    LpStageBoundary { task: SlotRef, stage: u8 },
    /// The deadline-pressure controller wakes up: survey running staged
    /// executions and offer the scheduler a truncation decision
    /// ([`crate::coordinator::scheduler::SchedEvent::Pressure`]).
    /// Periodic chain, only seeded when `pressure_check_s > 0`.
    PressureCheck,
}

/// A scheduled event: ordered by time, then insertion sequence (FIFO among
/// simultaneous events) for full determinism.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Scheduled {
    pub at: SimTime,
    pub seq: u64,
    pub event: Event,
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want earliest first.
        other.at.cmp(&self.at).then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Bucket width of the calendar ring, microseconds. With
/// [`EventQueue::N_BUCKETS`] buckets the ring spans ~33.5 s — wide
/// enough that the engine's periodic chains (frame period ~18.9 s,
/// probe interval 30 s) land inside the ring instead of the far heap.
const BUCKET_WIDTH_US: SimTime = 1 << 16;

/// Deterministic time-ordered queue: a two-level calendar.
///
/// Events near the cursor live in a ring of [`EventQueue::N_BUCKETS`]
/// unsorted buckets of [`BUCKET_WIDTH_US`] each; the *current* bucket is
/// staged into a small binary heap (exact `(at, seq)` order), and events
/// past the ring's horizon wait in a far heap that drains into the ring
/// as the cursor advances. Every operation is `O(log bucket)` instead of
/// `O(log total)`, and a bad width guess degenerates to the old single
/// binary heap — never worse.
///
/// Pop order is **identical** to the old `BinaryHeap<Scheduled>`:
/// earliest `at` first, FIFO (`seq`) among simultaneous events.
///
/// Epoch-guarded events (medium/WAN predictions, battery depletions,
/// slab-stale finishes) die in place when superseded; the owner reports
/// them via [`EventQueue::note_stale`] / [`EventQueue::note_popped_stale`]
/// and triggers [`EventQueue::compact`] when [`EventQueue::should_compact`]
/// says the dead fraction crossed ½, so the queue's footprint tracks
/// *live* events under heavy preemption, churn, and battery re-arming.
#[derive(Debug)]
pub struct EventQueue {
    /// Current bucket, heapified: the only totally-ordered region.
    staged: BinaryHeap<Scheduled>,
    /// Ring of future buckets (unsorted), disjoint time ranges.
    ring: Vec<Vec<Scheduled>>,
    /// Events at or past `horizon()`.
    far: BinaryHeap<Scheduled>,
    /// Events in `ring` (excluding `staged`).
    in_ring: usize,
    len: usize,
    /// Start time of the staged bucket's range.
    cursor_start: SimTime,
    /// Ring slot currently staged.
    cursor: usize,
    seq: u64,
    /// Estimated dead (superseded) events still queued.
    stale: usize,
    /// Compaction sweeps performed over this queue's lifetime
    /// (deterministic hot-path gauge; surfaced as `queue_compactions`).
    compactions: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self {
            staged: BinaryHeap::new(),
            ring: (0..Self::N_BUCKETS).map(|_| Vec::new()).collect(),
            far: BinaryHeap::new(),
            in_ring: 0,
            len: 0,
            cursor_start: 0,
            cursor: 0,
            seq: 0,
            stale: 0,
            compactions: 0,
        }
    }
}

impl EventQueue {
    /// Ring size (slots). Power of two so the modulo is a mask.
    pub const N_BUCKETS: usize = 512;

    pub fn new() -> Self {
        Self::default()
    }

    /// End of the ring's coverage; later events wait in the far heap.
    fn horizon(&self) -> SimTime {
        self.cursor_start + Self::N_BUCKETS as SimTime * BUCKET_WIDTH_US
    }

    /// File an event into staged / ring / far by its time.
    fn route(&mut self, s: Scheduled) {
        // `at` below the staged range is legal (safety, not used by the
        // engine): the staged heap orders it correctly anyway.
        let offset = s.at.saturating_sub(self.cursor_start) / BUCKET_WIDTH_US;
        if offset == 0 {
            self.staged.push(s);
        } else if (offset as usize) < Self::N_BUCKETS {
            let slot = (self.cursor + offset as usize) % Self::N_BUCKETS;
            self.ring[slot].push(s);
            self.in_ring += 1;
        } else {
            self.far.push(s);
        }
    }

    pub fn push(&mut self, at: SimTime, event: Event) {
        self.seq += 1;
        let seq = self.seq;
        self.len += 1;
        self.route(Scheduled { at, seq, event });
    }

    /// Move the cursor one bucket forward: stage the next slot and pull
    /// far events that the advancing horizon made near.
    fn advance_bucket(&mut self) {
        debug_assert!(self.staged.is_empty());
        self.cursor = (self.cursor + 1) % Self::N_BUCKETS;
        self.cursor_start += BUCKET_WIDTH_US;
        let bucket = std::mem::take(&mut self.ring[self.cursor]);
        self.in_ring -= bucket.len();
        self.staged = BinaryHeap::from(bucket);
        let horizon = self.horizon();
        while self.far.peek().is_some_and(|s| s.at < horizon) {
            let s = self.far.pop().unwrap();
            self.route(s);
        }
    }

    pub fn pop(&mut self) -> Option<Scheduled> {
        loop {
            if let Some(s) = self.staged.pop() {
                self.len -= 1;
                return Some(s);
            }
            if self.in_ring > 0 {
                self.advance_bucket();
            } else if let Some(next) = self.far.peek().map(|s| s.at) {
                // The ring is empty: jump the cursor straight to the far
                // heap's minimum instead of stepping bucket by bucket.
                self.cursor_start = (next / BUCKET_WIDTH_US) * BUCKET_WIDTH_US;
                let horizon = self.horizon();
                while self.far.peek().is_some_and(|s| s.at < horizon) {
                    let s = self.far.pop().unwrap();
                    self.route(s);
                }
            } else {
                return None;
            }
        }
    }

    pub fn peek_time(&self) -> Option<SimTime> {
        if let Some(s) = self.staged.peek() {
            return Some(s.at);
        }
        // Buckets hold disjoint ranges in cursor order: the first
        // nonempty slot contains the global near-minimum.
        for offset in 1..Self::N_BUCKETS {
            let slot = (self.cursor + offset) % Self::N_BUCKETS;
            if let Some(t) = self.ring[slot].iter().map(|s| s.at).min() {
                return Some(t);
            }
        }
        self.far.peek().map(|s| s.at)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    // ---- stale-entry accounting -----------------------------------------

    /// Report `n` queued events as superseded (epoch bumped, placement
    /// cancelled): they will be ignored when popped, and count toward the
    /// compaction trigger until then.
    pub fn note_stale(&mut self, n: usize) {
        self.stale = (self.stale + n).min(self.len);
    }

    /// A superseded event was popped (and ignored): it no longer bloats
    /// the queue.
    pub fn note_popped_stale(&mut self) {
        self.stale = self.stale.saturating_sub(1);
    }

    /// Estimated dead entries currently queued (diagnostics/tests).
    pub fn stale_estimate(&self) -> usize {
        self.stale
    }

    /// True when dead entries dominate: more than half the queue is
    /// superseded (and the queue is big enough for a sweep to pay off).
    pub fn should_compact(&self) -> bool {
        self.len >= 512 && self.stale * 2 > self.len
    }

    /// Compaction sweeps performed so far (diagnostics/metrics).
    pub fn compactions(&self) -> u64 {
        self.compactions
    }

    /// Drop every queued event the predicate rejects, keeping original
    /// `(at, seq)` order for survivors (seq values are preserved, so FIFO
    /// ties replay identically). Resets the stale estimate.
    pub fn compact(&mut self, mut live: impl FnMut(&Event) -> bool) {
        self.compactions += 1;
        let mut all: Vec<Scheduled> =
            Vec::with_capacity(self.staged.len() + self.in_ring + self.far.len());
        all.extend(std::mem::take(&mut self.staged));
        for slot in &mut self.ring {
            all.append(slot);
        }
        all.extend(std::mem::take(&mut self.far));
        all.retain(|s| live(&s.event));
        self.in_ring = 0;
        self.len = all.len();
        self.stale = 0;
        for s in all {
            self.route(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(300, Event::ProbeStart);
        q.push(100, Event::TraceFrame { index: 0 });
        q.push(200, Event::TrafficToggle { active: true });
        assert_eq!(q.pop().unwrap().at, 100);
        assert_eq!(q.pop().unwrap().at, 200);
        assert_eq!(q.pop().unwrap().at, 300);
        assert!(q.pop().is_none());
    }

    #[test]
    fn id_batch_holds_up_to_inline_without_allocating() {
        let mut b = IdBatch::new();
        assert!(b.is_empty());
        for id in 1..=IdBatch::INLINE as u64 {
            b.push(id);
        }
        assert_eq!(b.len(), IdBatch::INLINE);
        assert_eq!(b.as_slice(), &[1, 2, 3, 4]);
        assert!(!b.is_spilled(), "at the inline capacity the batch must stay inline");
        assert_eq!(IdBatch::one(9).as_slice(), &[9]);
    }

    #[test]
    fn id_batch_spills_at_the_boundary_instead_of_panicking() {
        // The boundary: INLINE ids stay inline, the (INLINE+1)-th spills —
        // contents and order are preserved exactly across the crossing.
        let mut b = IdBatch::new();
        for id in 1..=IdBatch::INLINE as u64 {
            b.push(id);
        }
        b.push(5);
        assert!(b.is_spilled());
        assert_eq!(b.as_slice(), &[1, 2, 3, 4, 5]);
        // Keep growing well past the old cap (generative batch sizes).
        for id in 6..=100u64 {
            b.push(id);
        }
        assert_eq!(b.len(), 100);
        assert_eq!(b.as_slice()[99], 100);
        assert!(b.as_slice().windows(2).all(|w| w[0] + 1 == w[1]));
    }

    #[test]
    fn id_batch_equality_ignores_representation() {
        let mut inline = IdBatch::new();
        let mut spilled = IdBatch::new();
        for id in 1..=3u64 {
            inline.push(id);
        }
        for id in 1..=6u64 {
            spilled.push(id);
        }
        // Same content compares equal regardless of storage...
        assert_eq!(inline.clone(), inline);
        assert_eq!(spilled.clone(), spilled);
        // ...and different content does not.
        assert_ne!(inline, spilled);
    }

    /// The calendar queue must replay the exact `(at, seq)` order of a
    /// plain `BinaryHeap<Scheduled>` under an adversarial mix of near
    /// pushes (same-time bursts), in-ring pushes, and far-horizon pushes
    /// interleaved with pops — the property the engine's determinism
    /// rests on.
    #[test]
    fn calendar_matches_reference_heap_order() {
        let mut q = EventQueue::new();
        let mut model: BinaryHeap<Scheduled> = BinaryHeap::new();
        let mut seq = 0u64;
        // Small deterministic LCG: no external RNG in this crate's tests.
        let mut state = 0x2545_f491_4f6c_dd1du64;
        let mut rand = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state >> 33
        };
        let mut now = 0u64;
        for round in 0..5000u64 {
            let op = rand() % 10;
            if op < 6 {
                // Push: near (same bucket), mid-ring, or far beyond the
                // horizon — including exact time collisions for FIFO.
                let delta = match rand() % 4 {
                    0 => 0,
                    1 => rand() % 1000,
                    2 => rand() % (BUCKET_WIDTH_US * 64),
                    _ => BUCKET_WIDTH_US * EventQueue::N_BUCKETS as u64 + rand() % (1 << 28),
                };
                let at = now + delta;
                seq += 1;
                q.push(at, Event::TraceFrame { index: round as usize });
                model.push(Scheduled {
                    at,
                    seq,
                    event: Event::TraceFrame { index: round as usize },
                });
            } else {
                let got = q.pop();
                let want = model.pop();
                assert_eq!(got, want, "divergence at round {round}");
                if let Some(s) = got {
                    assert!(s.at >= now, "time went backwards");
                    now = s.at;
                }
            }
            assert_eq!(q.len(), model.len());
            assert_eq!(q.peek_time(), model.peek().map(|s| s.at));
        }
        while let Some(want) = model.pop() {
            assert_eq!(q.pop(), Some(want));
        }
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn far_horizon_events_pop_in_order() {
        // Events far beyond the ring's span (probe chains, late churn)
        // must come back in exact time order across the far→ring drain.
        let mut q = EventQueue::new();
        let span = BUCKET_WIDTH_US * EventQueue::N_BUCKETS as u64;
        q.push(7 * span, Event::ProbeStart);
        q.push(3, Event::TraceFrame { index: 0 });
        q.push(2 * span + 17, Event::TrafficToggle { active: true });
        q.push(7 * span, Event::ProbeStart); // same time: FIFO by seq
        assert_eq!(q.peek_time(), Some(3));
        assert_eq!(q.pop().unwrap().at, 3);
        assert_eq!(q.pop().unwrap().at, 2 * span + 17);
        let a = q.pop().unwrap();
        let b = q.pop().unwrap();
        assert_eq!((a.at, b.at), (7 * span, 7 * span));
        assert!(a.seq < b.seq, "simultaneous far events must stay FIFO");
        assert!(q.pop().is_none());
    }

    #[test]
    fn stale_accounting_triggers_and_compaction_shrinks() {
        let mut q = EventQueue::new();
        for i in 0..1000u64 {
            // Even indices simulate epoch-stale predictions.
            q.push(i * 100, Event::MediumComplete { flow: i, epoch: i % 2 });
        }
        assert!(!q.should_compact());
        q.note_stale(400);
        assert!(!q.should_compact(), "400/1000 dead is below the ½ trigger");
        q.note_stale(200);
        assert!(q.should_compact(), "600/1000 dead must trigger");
        q.compact(|ev| !matches!(ev, Event::MediumComplete { epoch: 0, .. }));
        assert_eq!(q.len(), 500);
        assert_eq!(q.stale_estimate(), 0);
        assert_eq!(q.compactions(), 1, "the sweep gauge counts each compaction");
        assert!(!q.should_compact());
        // Survivors still pop in exact time order with odd epochs only.
        let mut last = 0;
        let mut n = 0;
        while let Some(s) = q.pop() {
            assert!(s.at >= last);
            last = s.at;
            assert!(matches!(s.event, Event::MediumComplete { epoch: 1, .. }));
            n += 1;
        }
        assert_eq!(n, 500);
        // The estimate clamps to the queue size and drains saturating.
        q.push(1, Event::ProbeStart);
        q.note_stale(99);
        assert_eq!(q.stale_estimate(), 1);
        q.note_popped_stale();
        q.note_popped_stale();
        assert_eq!(q.stale_estimate(), 0);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut q = EventQueue::new();
        q.push(50, Event::HpArrive { task: 1 });
        q.push(50, Event::HpArrive { task: 2 });
        q.push(50, Event::HpArrive { task: 3 });
        let order: Vec<_> = (0..3)
            .map(|_| match q.pop().unwrap().event {
                Event::HpArrive { task } => task,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 2, 3]);
    }
}
