//! Ground-truth shared-medium simulator (substrate for the paper's 802.11n
//! WiFi link).
//!
//! The controller *models* the link with its discretisation; this module is
//! what the link actually *does*. A fluid processor-sharing model: all
//! active flows (image transfers + bandwidth probes) share the capacity
//! left over by background traffic equally. Congestion therefore delays
//! transfers beyond what the controller planned — the placement-error
//! mechanism the paper's congestion experiments (Fig. 8) study — and probe
//! flows measure the *contended* share, reproducing the bandwidth
//! under-estimation effect of frequent probing (Fig. 6/7).
//!
//! ## Incremental accounting
//!
//! Because every flow drains at the *same* share, one accumulator
//! (`drained`: bits removed from each flow since the medium last became
//! busy) advances the whole fluid model in O(1); a flow's remaining bits
//! are `deficit - drained`, where `deficit` was fixed at admission. The
//! earliest-completing flow and the total remaining bits are cached and
//! invalidated only on add/remove/rate-change epochs, so
//! [`Medium::next_completion`] — called by the engine after *every*
//! medium mutation — no longer rescans the flow table per event.

use crate::time::SimTime;
use crate::util::Rng;

/// Identifies a flow on the medium. Task transfers use the task id; probe
/// flows use ids above [`PROBE_FLOW_BASE`].
pub type FlowId = u64;

/// Probe flows are namespaced away from task ids.
pub const PROBE_FLOW_BASE: FlowId = 1 << 60;

#[derive(Debug, Clone, Copy)]
struct Flow {
    id: FlowId,
    /// Bits this flow still owed when admitted, *plus* the accumulator
    /// value at admission: true remaining = `deficit - drained`, clamped
    /// at zero (the clamp only matters in the ≤1 µs rounding window
    /// between a flow hitting zero and its completion event firing).
    deficit: f64,
}

/// The shared wireless medium.
#[derive(Debug, Clone)]
pub struct Medium {
    /// Raw link capacity, bits/s.
    pub link_bps: f64,
    /// Bandwidth consumed by background traffic while a burst is active.
    pub bg_bps: f64,
    bg_active: bool,
    /// Active flows, sorted by id: deterministic ascending iteration
    /// (the engine's crash orphan scan relies on it) and binary-search
    /// lookup. Flow counts are small — a handful of transfers plus at
    /// most one probe — so sorted-insert beats hashing.
    flows: Vec<Flow>,
    last_update: SimTime,
    /// Bumped on every rate-changing mutation; completion events carry the
    /// epoch they were computed under so stale ones can be discarded.
    pub epoch: u64,
    /// Per-flow bits drained since `flows` last became non-empty.
    drained: f64,
    /// Σ deficit over active flows (cached total, see
    /// [`Medium::total_remaining_bits`]).
    sum_deficit: f64,
    /// Earliest-completing flow as `(deficit, id)` — the same flow a full
    /// rescan over live (unclamped) flows would pick: minimum remaining
    /// bits, ties to the lower id, since `remaining = deficit - drained`
    /// is order-preserving until the clamp. Maintained on
    /// add/remove/complete; `Some` iff flows is non-empty.
    min_flow: Option<(f64, FlowId)>,
    /// Fluid-model advances that did real work (deterministic hot-path
    /// gauge; surfaced as `medium_drain_ops` in the metrics).
    pub drain_ops: u64,
}

impl Medium {
    pub fn new(link_bps: f64, bg_bps: f64) -> Self {
        Self {
            link_bps,
            bg_bps,
            bg_active: false,
            flows: Vec::new(),
            last_update: 0,
            epoch: 0,
            drained: 0.0,
            sum_deficit: 0.0,
            min_flow: None,
            drain_ops: 0,
        }
    }

    fn find(&self, id: FlowId) -> Result<usize, usize> {
        self.flows.binary_search_by(|f| f.id.cmp(&id))
    }

    /// Recompute the cached minimum (only needed when the current
    /// minimum leaves or is overwritten).
    fn rescan_min(&mut self) {
        self.min_flow = self
            .flows
            .iter()
            .map(|f| (f.deficit, f.id))
            .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    }

    /// Offer a candidate for the cached minimum.
    fn offer_min(&mut self, deficit: f64, id: FlowId) {
        match self.min_flow {
            Some((d, mid)) if d < deficit || (d == deficit && mid < id) => {}
            _ => self.min_flow = Some((deficit, id)),
        }
    }

    /// Drop a flow by position, maintaining every cache. Returns its id.
    fn remove_at(&mut self, pos: usize) -> FlowId {
        let f = self.flows.remove(pos);
        self.sum_deficit -= f.deficit;
        if self.flows.is_empty() {
            // Idle medium: reset the accumulator so it cannot grow (and
            // lose float precision) over a long run.
            self.drained = 0.0;
            self.sum_deficit = 0.0;
            self.min_flow = None;
        } else if self.min_flow.map(|(_, mid)| mid == f.id).unwrap_or(false) {
            self.rescan_min();
        }
        f.id
    }

    /// Capacity currently shared by foreground flows, bits/s.
    pub fn available_bps(&self) -> f64 {
        let avail = if self.bg_active {
            self.link_bps - self.bg_bps
        } else {
            self.link_bps
        };
        avail.max(self.link_bps * 0.02) // the medium never fully starves
    }

    /// Per-flow share right now, bits/s.
    pub fn per_flow_bps(&self) -> f64 {
        if self.flows.is_empty() {
            return self.available_bps();
        }
        self.available_bps() / self.flows.len() as f64
    }

    /// Advance the fluid model to `now`. All flows share equally, so one
    /// accumulator bump advances every flow — O(1), no rescan. Must be
    /// called (internally) before any mutation.
    fn drain_to(&mut self, now: SimTime) {
        debug_assert!(now >= self.last_update);
        if now == self.last_update || self.flows.is_empty() {
            self.last_update = now;
            return;
        }
        let dt_s = (now - self.last_update) as f64 / 1e6;
        self.drained += self.per_flow_bps() * dt_s;
        self.last_update = now;
        self.drain_ops += 1;
    }

    /// Start a transfer of `bytes` at `now`.
    pub fn add_flow(&mut self, now: SimTime, id: FlowId, bytes: u64) {
        self.drain_to(now);
        let deficit = bytes as f64 * 8.0 + self.drained;
        match self.find(id) {
            Ok(pos) => {
                // Same replace-on-collision semantics the old map had
                // (never hit by the engine: task and probe ids are unique).
                self.sum_deficit += deficit - self.flows[pos].deficit;
                self.flows[pos].deficit = deficit;
                self.rescan_min();
            }
            Err(pos) => {
                self.flows.insert(pos, Flow { id, deficit });
                self.sum_deficit += deficit;
                self.offer_min(deficit, id);
            }
        }
        self.epoch += 1;
    }

    /// Remove a flow (cancelled transfer). Returns whether it existed.
    pub fn remove_flow(&mut self, now: SimTime, id: FlowId) -> bool {
        self.drain_to(now);
        match self.find(id) {
            Ok(pos) => {
                self.remove_at(pos);
                self.epoch += 1;
                true
            }
            Err(_) => false,
        }
    }

    /// Toggle background traffic (the duty-cycled burst generator).
    pub fn set_background(&mut self, now: SimTime, active: bool) {
        if self.bg_active != active {
            self.drain_to(now);
            self.bg_active = active;
            self.epoch += 1;
        }
    }

    /// Change the background burst rate mid-run (congestion regime change).
    /// Drains the fluid model first so in-flight transfers keep the share
    /// they actually had until `now`.
    pub fn set_background_rate(&mut self, now: SimTime, bg_bps: f64) {
        if (self.bg_bps - bg_bps).abs() > f64::EPSILON {
            self.drain_to(now);
            self.bg_bps = bg_bps;
            self.epoch += 1;
        }
    }

    pub fn background_active(&self) -> bool {
        self.bg_active
    }

    /// Predict the earliest flow completion from `now` under current
    /// rates. Returns `(finish_time, flow_id)`. O(1): the minimum is
    /// cached across calls and only invalidated by mutation epochs.
    pub fn next_completion(&mut self, now: SimTime) -> Option<(SimTime, FlowId)> {
        self.drain_to(now);
        let (deficit, id) = self.min_flow?;
        let share = self.per_flow_bps();
        let remaining = (deficit - self.drained).max(0.0);
        let dt_us = (remaining / share * 1e6).ceil() as u64;
        Some((now + dt_us, id))
    }

    /// Pop a flow that has (within fluid tolerance) finished by `now`.
    pub fn complete_flow(&mut self, now: SimTime, id: FlowId) -> bool {
        self.drain_to(now);
        match self.find(id) {
            // One share-microsecond of tolerance for integer rounding.
            Ok(pos)
                if (self.flows[pos].deficit - self.drained).max(0.0)
                    <= self.per_flow_bps() / 1e5 + 1.0 =>
            {
                self.remove_at(pos);
                self.epoch += 1;
                true
            }
            _ => false,
        }
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Whether `id` is still transferring (no time advance).
    pub fn has_flow(&self, id: FlowId) -> bool {
        self.find(id).is_ok()
    }

    /// Active flow ids in ascending order (task flows before probe
    /// flows). The engine's crash orphan scan iterates this instead of
    /// sorting a scratch copy of its runtime table.
    pub fn flow_ids(&self) -> impl Iterator<Item = FlowId> + '_ {
        self.flows.iter().map(|f| f.id)
    }

    /// Remaining bits of flow `id` after draining the fluid model to
    /// `now`. Diagnostic/test hook.
    pub fn remaining_bits(&mut self, now: SimTime, id: FlowId) -> Option<f64> {
        self.drain_to(now);
        self.find(id).ok().map(|pos| (self.flows[pos].deficit - self.drained).max(0.0))
    }

    /// Total remaining bits across all flows after draining to `now`.
    /// O(1) via the cached deficit sum while no flow sits at zero; falls
    /// back to a scan only inside a completion's rounding window.
    pub fn total_remaining_bits(&mut self, now: SimTime) -> f64 {
        self.drain_to(now);
        if self.flows.is_empty() {
            return 0.0;
        }
        if let Some((d, _)) = self.min_flow {
            if d - self.drained > 0.0 {
                return (self.sum_deficit - self.flows.len() as f64 * self.drained).max(0.0);
            }
        }
        self.flows.iter().map(|f| (f.deficit - self.drained).max(0.0)).sum()
    }
}

/// The cloud tier: a high-capacity executor behind a WAN [`Medium`].
///
/// The third placement target (after local and edge-offload): inputs are
/// uploaded over a dedicated WAN uplink — fluid processor-sharing, like
/// the edge link, so concurrent uploads contend — then the task runs for
/// its deterministic `Task::cloud_us` service time after a fixed
/// propagation delay (`rtt_us` covers request up + result back; the
/// bandwidth-limited upload itself is simulated, not folded into the
/// RTT). The executor is provisioned: there is no queueing and no load
/// jitter on the cloud side, which is exactly why it changes which
/// deadline/accuracy trades are reachable under overload.
///
/// The tier carries **its own bandwidth estimator**: instead of the edge
/// link's probe trains, every completed upload contributes its achieved
/// goodput to an EWMA (same α as the edge estimator). The schedulers'
/// cloud-feasibility check plans with this estimate, so WAN congestion
/// from concurrent uploads feeds back into placement the same way probe
/// under-estimation does at the edge.
#[derive(Debug, Clone)]
pub struct CloudTier {
    /// The WAN uplink shared by in-flight uploads.
    pub wan: Medium,
    /// Fixed round-trip propagation delay, µs.
    pub rtt_us: SimTime,
    /// EWMA of achieved upload goodput, bits/s.
    est_bps: f64,
    alpha: f64,
    /// In-flight uploads: `(flow id, start time, payload bytes)`. Small
    /// (bounded by concurrent cloud placements), scanned linearly.
    uploads: Vec<(FlowId, SimTime, u64)>,
}

impl CloudTier {
    /// Build from config; `None` when the cloud tier is disabled
    /// (`cloud_wan_bps == 0`, the default).
    pub fn from_config(cfg: &crate::config::SystemConfig) -> Option<Self> {
        if cfg.cloud_wan_bps <= 0.0 {
            return None;
        }
        Some(Self {
            wan: Medium::new(cfg.cloud_wan_bps, 0.0),
            rtt_us: crate::time::millis(cfg.cloud_rtt_ms.max(0.0)),
            est_bps: cfg.cloud_wan_bps,
            alpha: cfg.ewma_alpha,
            uploads: Vec::new(),
        })
    }

    /// Current WAN bandwidth estimate the schedulers plan with, bits/s.
    pub fn estimate_bps(&self) -> f64 {
        self.est_bps
    }

    /// Start uploading `bytes` for task-flow `id` at `now`.
    pub fn begin_upload(&mut self, now: SimTime, id: FlowId, bytes: u64) {
        self.wan.add_flow(now, id, bytes);
        self.uploads.push((id, now, bytes));
    }

    /// Earliest predicted upload completion (see [`Medium::next_completion`]).
    pub fn next_completion(&mut self, now: SimTime) -> Option<(SimTime, FlowId)> {
        self.wan.next_completion(now)
    }

    /// An upload completion event fired: pop the flow if it really is
    /// done, feed the achieved goodput into the estimator, and return
    /// the payload size. `None` if the prediction went stale.
    pub fn complete_upload(&mut self, now: SimTime, id: FlowId) -> Option<u64> {
        if !self.wan.complete_flow(now, id) {
            return None;
        }
        let pos = self.uploads.iter().position(|&(f, _, _)| f == id)?;
        let (_, start, bytes) = self.uploads.swap_remove(pos);
        let dt_s = now.saturating_sub(start) as f64 / 1e6;
        if dt_s > 0.0 {
            let sample = bytes as f64 * 8.0 / dt_s;
            self.est_bps = self.alpha * sample + (1.0 - self.alpha) * self.est_bps;
        }
        Some(bytes)
    }

    /// Abort an in-flight upload (source crashed / placement cancelled).
    /// Returns whether it existed.
    pub fn abort_upload(&mut self, now: SimTime, id: FlowId) -> bool {
        let existed = self.wan.remove_flow(now, id);
        if let Some(pos) = self.uploads.iter().position(|&(f, _, _)| f == id) {
            self.uploads.swap_remove(pos);
        }
        existed
    }

    /// Whether task-flow `id` is currently uploading.
    pub fn has_upload(&self, id: FlowId) -> bool {
        self.wan.has_flow(id)
    }

    /// Uploads currently in flight.
    pub fn inflight(&self) -> usize {
        self.uploads.len()
    }

    /// In-flight upload flow ids, ascending (crash orphan scan).
    pub fn upload_ids(&self) -> impl Iterator<Item = FlowId> + '_ {
        self.wan.flow_ids()
    }
}

/// MTU-sized packet the loss model samples over (1500 B Ethernet-class
/// frames, matching the paper's Packet_MMAP traffic generator).
pub const PACKET_BYTES: u64 = 1500;

/// A [`Medium`] with per-packet loss and retransmission inflation: the
/// lost fraction of every transfer is re-queued as extra bits, so a lossy
/// link doesn't just *slow* transfers the way congestion does — it makes
/// their airtime demand grow, which is what erodes the controller's
/// communication-window plans. Probe pings are *not* retransmitted (a
/// lost ping is a lost sample), so under `probe_loss` a
/// [`crate::coordinator::bandwidth::ProbeRound`] comes back partial or
/// empty — see [`LossyMedium::probe_survivors`].
///
/// All loss draws come from the embedded seed-deterministic RNG, never
/// ambient randomness, and with both rates at zero the RNG is untouched:
/// an ideal `LossyMedium` is bit-identical to the bare [`Medium`].
///
/// Derefs to [`Medium`] for everything that isn't loss-aware.
#[derive(Debug, Clone)]
pub struct LossyMedium {
    inner: Medium,
    /// Per-packet loss probability on task transfers.
    pub loss_rate: f64,
    /// Per-ping loss probability on probe rounds.
    pub probe_loss: f64,
    rng: Rng,
    /// Extra bits re-queued by retransmission (diagnostics).
    pub retransmitted_bits: f64,
}

impl std::ops::Deref for LossyMedium {
    type Target = Medium;
    fn deref(&self) -> &Medium {
        &self.inner
    }
}

impl std::ops::DerefMut for LossyMedium {
    fn deref_mut(&mut self) -> &mut Medium {
        &mut self.inner
    }
}

impl LossyMedium {
    pub fn new(inner: Medium, loss_rate: f64, probe_loss: f64, seed: u64) -> Self {
        Self {
            inner,
            loss_rate: loss_rate.clamp(0.0, crate::fault::MAX_LOSS_RATE),
            probe_loss: probe_loss.clamp(0.0, crate::fault::MAX_LOSS_RATE),
            rng: Rng::seed_from_u64(seed),
            retransmitted_bits: 0.0,
        }
    }

    /// An ideal (lossless) medium — behaves exactly like the inner one.
    pub fn ideal(inner: Medium) -> Self {
        Self::new(inner, 0.0, 0.0, 0)
    }

    /// Start a transfer of `bytes` at `now`. On a lossy link the lost
    /// packets are re-queued (and can be lost again), inflating the flow;
    /// probe flows are exempt — ping loss drops samples, not airtime.
    pub fn add_flow(&mut self, now: SimTime, id: FlowId, bytes: u64) {
        let bytes = if self.loss_rate > 0.0 && id < PROBE_FLOW_BASE {
            let extra = self.retransmit_packets(bytes.div_ceil(PACKET_BYTES));
            self.retransmitted_bits += (extra * PACKET_BYTES * 8) as f64;
            bytes + extra * PACKET_BYTES
        } else {
            bytes
        };
        self.inner.add_flow(now, id, bytes);
    }

    /// Rounds of re-queued packets until everything got through. Expected
    /// total inflation is p/(1−p) of the original packet count; the cap
    /// on `loss_rate` bounds it.
    fn retransmit_packets(&mut self, packets: u64) -> u64 {
        let mut extra = 0u64;
        let mut pending = packets;
        while pending > 0 {
            let lost = self.rng.gen_binomial(pending, self.loss_rate);
            extra += lost;
            pending = lost;
        }
        extra
    }

    /// How many of a probe round's `pings` survive the lossy link. With
    /// `probe_loss` at zero this returns `pings` without touching the
    /// RNG (the ideal path stays bit-identical).
    pub fn probe_survivors(&mut self, pings: u64) -> u64 {
        if self.probe_loss <= 0.0 {
            return pings;
        }
        pings - self.rng.gen_binomial(pings, self.probe_loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_gets_full_capacity() {
        let mut m = Medium::new(40e6, 0.0);
        m.add_flow(0, 1, 150_000); // 1.2 Mbit at 40 Mb/s = 30 ms
        let (t, id) = m.next_completion(0).unwrap();
        assert_eq!(id, 1);
        assert_eq!(t, 30_000);
        assert!(m.complete_flow(t, 1));
        assert_eq!(m.active_flows(), 0);
    }

    #[test]
    fn two_flows_share_capacity() {
        let mut m = Medium::new(40e6, 0.0);
        m.add_flow(0, 1, 150_000);
        m.add_flow(0, 2, 150_000);
        let (t, _) = m.next_completion(0).unwrap();
        assert_eq!(t, 60_000); // halved share → doubled time
    }

    #[test]
    fn background_traffic_slows_transfers() {
        let mut m = Medium::new(40e6, 20e6);
        m.add_flow(0, 1, 150_000);
        m.set_background(0, true);
        let (t, _) = m.next_completion(0).unwrap();
        assert_eq!(t, 60_000); // 20 Mb/s left → 60 ms
        m.set_background(30_000, false);
        // Half the bits drained in 30 ms at 20 Mb/s; the rest at 40 Mb/s.
        let (t2, _) = m.next_completion(30_000).unwrap();
        assert_eq!(t2, 30_000 + 15_000);
    }

    #[test]
    fn late_joiner_delays_earlier_flow() {
        let mut m = Medium::new(40e6, 0.0);
        m.add_flow(0, 1, 150_000);
        // At 15 ms, half transferred; a second flow joins.
        m.add_flow(15_000, 2, 150_000);
        let (t, id) = m.next_completion(15_000).unwrap();
        assert_eq!(id, 1);
        assert_eq!(t, 15_000 + 30_000); // remaining 600 kbit at 20 Mb/s
    }

    #[test]
    fn epoch_bumps_invalidate_predictions() {
        let mut m = Medium::new(40e6, 0.0);
        m.add_flow(0, 1, 150_000);
        let e0 = m.epoch;
        m.add_flow(1_000, 2, 150_000);
        assert!(m.epoch > e0);
        // Original completion (30 ms) is now stale: flow 1 isn't done.
        assert!(!m.complete_flow(30_000, 1));
    }

    #[test]
    fn medium_never_starves_completely() {
        let mut m = Medium::new(40e6, 45e6); // bg demand above capacity
        m.set_background(0, true);
        assert!(m.available_bps() > 0.0);
        m.add_flow(0, 1, 1000);
        assert!(m.next_completion(0).is_some());
    }

    #[test]
    fn remove_flow_cancels() {
        let mut m = Medium::new(40e6, 0.0);
        m.add_flow(0, 1, 150_000);
        assert!(m.remove_flow(10_000, 1));
        assert!(!m.remove_flow(10_000, 1));
        assert!(m.next_completion(10_000).is_none());
    }

    #[test]
    fn ideal_lossy_medium_is_bit_identical_to_bare() {
        let mut bare = Medium::new(40e6, 0.0);
        let mut lossy = LossyMedium::ideal(Medium::new(40e6, 0.0));
        for (t, id, bytes) in [(0, 1, 150_000u64), (5_000, 2, 90_000), (20_000, 3, 10_000)] {
            bare.add_flow(t, id, bytes);
            lossy.add_flow(t, id, bytes);
        }
        assert_eq!(bare.next_completion(25_000), lossy.next_completion(25_000));
        assert_eq!(lossy.retransmitted_bits, 0.0);
    }

    #[test]
    fn lossy_link_inflates_transfers() {
        let mut lossy = LossyMedium::new(Medium::new(40e6, 0.0), 0.2, 0.0, 1234);
        lossy.add_flow(0, 1, 1_100_000);
        let inflated = lossy.remaining_bits(0, 1).unwrap();
        // 20% loss re-queues roughly p/(1−p) = 25% extra bits.
        assert!(inflated > 1_100_000.0 * 8.0 * 1.10, "too little inflation: {inflated}");
        assert!(inflated < 1_100_000.0 * 8.0 * 1.60, "implausible inflation: {inflated}");
        assert!(lossy.retransmitted_bits > 0.0);
        // Probe flows are exempt from retransmission inflation.
        let before = lossy.retransmitted_bits;
        lossy.add_flow(0, PROBE_FLOW_BASE, 84_000);
        assert_eq!(lossy.retransmitted_bits, before);
        assert_eq!(lossy.remaining_bits(0, PROBE_FLOW_BASE), Some(84_000.0 * 8.0));
    }

    #[test]
    fn cloud_tier_gates_on_config_and_estimates_from_uploads() {
        use crate::config::SystemConfig;
        assert!(
            CloudTier::from_config(&SystemConfig::default()).is_none(),
            "cloud tier must default OFF"
        );
        let cfg = SystemConfig { cloud_wan_bps: 20e6, cloud_rtt_ms: 50.0, ..Default::default() };
        let mut c = CloudTier::from_config(&cfg).unwrap();
        assert_eq!(c.rtt_us, 50_000);
        assert_eq!(c.estimate_bps(), 20e6);
        // A solo 1.1 MB upload at 20 Mb/s finishes in 440 ms and its
        // achieved goodput equals the link rate: the EWMA stays put.
        c.begin_upload(0, 7, 1_100_000);
        assert_eq!(c.inflight(), 1);
        let (t, id) = c.next_completion(0).unwrap();
        assert_eq!(id, 7);
        assert_eq!(t, 440_000);
        assert_eq!(c.complete_upload(t, 7), Some(1_100_000));
        assert_eq!(c.inflight(), 0);
        assert!((c.estimate_bps() - 20e6).abs() < 20e6 * 0.01, "est {}", c.estimate_bps());
        // Two concurrent uploads halve the share: the survivor's sample
        // drags the estimate below the raw link rate.
        c.begin_upload(1_000_000, 8, 1_100_000);
        c.begin_upload(1_000_000, 9, 1_100_000);
        let (t2, first) = c.next_completion(1_000_000).unwrap();
        assert_eq!(c.complete_upload(t2, first), Some(1_100_000));
        let (t3, second) = c.next_completion(t2).unwrap();
        assert_eq!(c.complete_upload(t3, second), Some(1_100_000));
        assert!(c.estimate_bps() < 20e6 * 0.95, "contended est {}", c.estimate_bps());
        // Aborts drop the flow and the record.
        c.begin_upload(t3, 10, 500_000);
        assert!(c.has_upload(10));
        assert!(c.abort_upload(t3 + 1_000, 10));
        assert!(!c.abort_upload(t3 + 1_000, 10));
        assert_eq!(c.inflight(), 0);
    }

    #[test]
    fn probe_survivors_shrink_under_loss_and_are_deterministic() {
        let mut a = LossyMedium::new(Medium::new(40e6, 0.0), 0.0, 0.5, 7);
        let mut b = LossyMedium::new(Medium::new(40e6, 0.0), 0.0, 0.5, 7);
        let mut total = 0u64;
        for _ in 0..50 {
            let s = a.probe_survivors(30);
            assert_eq!(s, b.probe_survivors(30), "same seed, same survivors");
            assert!(s <= 30);
            total += s;
        }
        // 50 rounds × 30 pings at 50% loss ≈ 750 survivors.
        assert!((500..1000).contains(&total), "survivor mass off: {total}");
        // Lossless probes never touch the RNG.
        let mut ideal = LossyMedium::ideal(Medium::new(40e6, 0.0));
        assert_eq!(ideal.probe_survivors(30), 30);
    }
}
