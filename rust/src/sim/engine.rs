//! The discrete-event simulation engine: drives a [`Scheduler`] through a
//! workload trace over the simulated devices and shared medium, collecting
//! the metrics the paper's figures report.
//!
//! ## Latency model
//!
//! The controller is a single server: requests queue behind one another
//! and behind bandwidth-update rebuilds (`busy_until`). Each scheduling
//! call's *operation count* converts to virtual processing time at
//! `op_cost_us`; the perceived scheduling latency of a task is
//! queueing + processing (what Fig. 5 plots), and decisions only take
//! effect after it elapses — so scheduler cost genuinely burns deadline
//! slack, the feedback loop at the heart of the paper.
//!
//! ## Execution model
//!
//! Devices honour allocations: a task starts at its allocated start time
//! or when its input arrives (offloads wait for the real transfer on the
//! shared medium, which congestion can delay beyond the reserved window),
//! whichever is later, and runs for its fixed processing time. A task that
//! finishes past its deadline is a violation and invalidates its frame.
//!
//! ## Hot-path storage
//!
//! Steady-state event handling is allocation-free and index-based:
//!
//! * Tasks live in a generational [`Slab`] ([`crate::util::slab`]); a dense
//!   `TaskId → SlotRef` vector (ids are monotone from 1) replaces the old
//!   `HashMap`s, so per-event lookup is two array indexes and no hashing.
//! * The old explicit placement-generation counter is folded into the
//!   slab's generation word: cancelling a placement re-slots the task
//!   (same index, next generation, thanks to the LIFO free list), and
//!   every finish/transfer event queued under the dead placement carries
//!   a [`SlotRef`] that simply stops resolving.
//! * Frame state is a dense vector indexed by `FrameId` (frame ids are
//!   `row × n_devices + device` by construction).
//! * Batch events carry ids inline up to [`IdBatch::INLINE`] (spilling to
//!   the heap only for larger generative batches), scheduler dispatch
//!   borrows `&Task` straight out of the slab (stack array of refs), and
//!   the probe/orphan scans reuse scratch buffers held on the engine.
//!
//! Terminal tasks (completed, violated, rejected, dropped) release their
//! slot for reuse, so live slab size tracks in-flight work rather than
//! the whole run history.
//!
//! ## Degraded inference
//!
//! Task classes may carry model-variant ladders
//! ([`crate::workload::gen::variants`]): batch dispatches expose the
//! tasks' remaining ladder, the schedulers may step down to a cheaper
//! DNN variant instead of rejecting, and the engine commits the choice —
//! rewriting the slab tasks' input/stage costs to the chosen rung
//! ([`Engine::apply_variant`]) and crediting the rung's accuracy to the
//! delivered-accuracy metrics at completion. Ladder-free runs take none
//! of these paths and stay byte-identical to the pre-ladder engine.

use std::sync::Arc;

use crate::config::SystemConfig;
use crate::coordinator::bandwidth::{BandwidthEstimator, ProbeRound};
use crate::coordinator::fleet::CellMap;
use crate::coordinator::scheduler::{
    Decision, Ops, Outcome, PressureCandidate, SchedEvent, Scheduler,
};
use crate::coordinator::task::{
    Allocation, DeviceId, FrameId, StagePlan, Task, TaskId, VariantRung, MAX_RUNGS,
};
use crate::energy::{EnergyModel, FleetEnergy};
use crate::fault::detector::{Belief, SuspicionDetector};
use crate::metrics::Metrics;
use crate::obs::{FlightRecorder, Phase, PhaseTimers, TraceEvent, TraceSink};
use crate::sim::events::{Event, EventQueue, IdBatch};
use crate::sim::netsim::{CloudTier, FlowId, LossyMedium, Medium, PROBE_FLOW_BASE};
use crate::time::{SimDuration, SimTime};
use crate::util::slab::{Slab, SlotRef};
use crate::util::Rng;
use crate::workload::gen::GenWorkload;
use crate::workload::trace::Trace;

/// Scenario-level extras beyond the paper's fixed homogeneous testbed.
/// `Default` reproduces the paper's setup exactly (and byte-identically:
/// the default path makes the same RNG draws and event pushes as before
/// these knobs existed).
#[derive(Debug, Clone, Default)]
pub struct RunExtras {
    /// Per-device processing-time multiplier (1.0 = the paper's Pi 2B;
    /// 1.3 = 30 % slower than the controller's homogeneous plan). Shorter
    /// than the fleet ⇒ remaining devices run at 1.0.
    pub device_speed: Vec<f64>,
    /// Fleet churn schedule: (time, device, join?). Leaves evict the
    /// device's live tasks; joins (re-)activate a device slot.
    pub churn: Vec<(SimTime, DeviceId, bool)>,
    /// Congestion regime changes: (time, bg_bps, duty_cycle). Overrides
    /// the config's static burst generator from that point on.
    pub regimes: Vec<(SimTime, f64, f64)>,
    /// Fault schedule: (time, device, recover?). Crashes lose in-flight
    /// work (flows aborted, survivors re-offered), unlike graceful churn.
    /// Compile a [`crate::fault::FaultPlan`] to fill this.
    pub faults: Vec<(SimTime, DeviceId, bool)>,
    /// Per-packet loss probability on task transfers (retransmission
    /// inflation on the medium; 0 = the paper's ideal link).
    pub loss_rate: f64,
    /// Per-ping loss probability on probe rounds (partial/empty rounds).
    pub probe_loss: f64,
    /// Compiled generative workload ([`crate::workload::gen`]): arrival
    /// events independent of the conveyor frame clock. Composes with a
    /// trace (both feed the same queue); `None` leaves the paper's
    /// trace-only path untouched.
    pub gen: Option<GenWorkload>,
    /// Compiled model-variant ladder for the conveyor's low-priority
    /// (stage-3) class. Empty = no ladder: the paper's single-model path,
    /// bit-identical to the pre-ladder engine. A one-rung ladder never
    /// degrades either (and at accuracy 1.0 is byte-identical too);
    /// deeper ladders let the schedulers trade accuracy for deadlines.
    /// Generative classes carry their own ladders in the compiled plan.
    pub lp_ladder: Vec<VariantRung>,
    /// Anytime stage plans for the conveyor LP ladder, parallel to
    /// `lp_ladder` (rung k runs under plan k; missing/short entries mean
    /// monolithic). Empty = no stage plans: no boundary events exist,
    /// the pressure controller has nothing to survey, and the run stays
    /// byte-identical to the pre-anytime engine. Generative classes
    /// carry their own plans in the compiled plan
    /// ([`crate::workload::gen::GenClass::stage_plans`]).
    pub lp_stage_plans: Vec<StagePlan>,
    /// Per-device power model ([`crate::energy`]): integrated at every
    /// state transition the engine observes. `None` = energy accounting
    /// off — no extra events, no extra RNG draws, byte-identical output.
    pub energy: Option<EnergyModel>,
    /// Per-device battery capacity, joules (needs `energy`). Depletion
    /// routes through the crash path — in-flight work lost or
    /// re-offered — and a drained device never recovers.
    pub battery_j: Option<f64>,
    /// Partition schedule: (time, device, heal?). A partitioned device is
    /// unreachable-but-alive: its flows stall (resuming on heal with the
    /// bits already sent preserved), in-progress compute finishes but the
    /// result is held undeliverable until heal. Distinct from crash, which
    /// loses work. Compile a [`crate::fault::FaultPlan`] to fill this.
    pub partitions: Vec<(SimTime, DeviceId, bool)>,
    /// Flight-recorder ring capacity, records ([`crate::obs`]). 0 = off
    /// (the default): the engine carries no recorder, the schedulers
    /// never build [`crate::obs::DecisionRecord`]s, and every hook site
    /// is a skipped `Option` check — zero events, zero RNG draws,
    /// byte-identical output (locked by the `zero_trace_knob` golden).
    pub trace_capacity: usize,
    /// Per-phase wall-clock timing ([`crate::obs::PhaseTimers`]), off by
    /// default. Wall time is inherently non-deterministic, so the
    /// determinism/golden grids must leave this knob off; the timers
    /// never feed the simulation, only the `phase_*_ns` gauges.
    pub timing: bool,
}

/// Runtime state of a placed task. Staleness is carried by the slab
/// generation (a cancelled placement re-slots the task), so no explicit
/// `cancelled`/`gen` fields remain.
#[derive(Debug, Clone)]
struct TaskRuntime {
    alloc: Allocation,
    realloc: bool,
    /// Placed through a crash re-offer (fault accounting).
    reoffered: bool,
}

/// One live task in the engine's slab: identity plus (optional)
/// placement. `rt` is `None` until the scheduler places the task, and
/// again between a cancellation and its re-placement.
#[derive(Debug, Clone)]
struct TaskSlot {
    task: Task,
    rt: Option<TaskRuntime>,
    /// Index into the engine's ladder table (0 = no ladder: the task's
    /// single model at implicit accuracy 1.0).
    ladder: u16,
    /// Current ladder rung the task runs at (0 = full accuracy). Bumped
    /// when a placement degrades; `task.input_bytes` / `task.proc_us`
    /// are rewritten to the rung at the same moment, so re-placements
    /// and transfers always see the spec that was actually scheduled.
    rung: u8,
    /// Offload attempts consumed by the timeout/retry policy (each retry
    /// doubles the timeout — exponential backoff).
    tries: u8,
    /// For a hedge duplicate: the primary task it shadows.
    hedge_of: Option<TaskId>,
    /// For a hedged primary: the duplicate racing it (first terminal
    /// outcome wins; the loser is cancelled without accounting).
    hedged_by: Option<TaskId>,
    /// Anytime execution window: `Some((eff_start, total))` while a
    /// *staged* LP execution runs on an edge device — the committed
    /// start and actual total duration, from which the engine predicts
    /// stage-boundary and finish times for the pressure survey. `None`
    /// for monolithic executions (the default path).
    exec: Option<(SimTime, SimDuration)>,
    /// Next uncommitted stage boundary of the running staged execution
    /// (1-based; starts at the plan's mandatory prefix, advances as
    /// boundaries fire). Meaningless while `exec` is `None`.
    next_stage: u8,
    /// Armed truncation: complete at the first boundary at or past this
    /// stage instead of running to full depth (`u8::MAX` = no cut).
    /// Also doubles as the completion stage of a truncated result held
    /// behind a partition, so the heal re-delivers the same cut.
    cut_stage: u8,
}

/// Per-frame pipeline bookkeeping (Fig. 1's three stages), stored densely
/// by `FrameId`. `tracked` is false for frame slots whose trace cell was
/// empty (no object on the belt) or whose device was out of the fleet.
#[derive(Debug, Clone, Default)]
struct FrameState {
    tracked: bool,
    /// DNN tasks this frame will generate after its HP task (trace value).
    lp_expected: u32,
    lp_done: u32,
    hp_done: bool,
    failed: bool,
    counted: bool,
    deadline: SimTime,
}

/// An in-flight probe round (under probe loss, `bytes` reflects only the
/// surviving pings; lost-ping counts live in the metrics).
#[derive(Debug, Clone)]
struct ProbeFlight {
    started: SimTime,
    bytes: u64,
    host: usize,
    /// Pings that survived probe loss (trace-export payload).
    survivors: u64,
}

/// The simulator.
pub struct Engine {
    pub cfg: SystemConfig,
    sched: Box<dyn Scheduler>,
    medium: LossyMedium,
    estimator: BandwidthEstimator,
    queue: EventQueue,
    now: SimTime,
    /// Controller single-server queue.
    busy_until: SimTime,
    /// Live tasks (identity + placement), slot-recycled.
    tasks: Slab<TaskSlot>,
    /// `TaskId → SlotRef` (dense: ids are monotone from 1). NULL entries
    /// are tasks that reached a terminal state and released their slot.
    task_index: Vec<SlotRef>,
    /// Frame pipeline state, dense by `FrameId`.
    frames: Vec<FrameState>,
    /// In-flight probe rounds (at most a couple at a time — linear scan).
    probes: Vec<(FlowId, ProbeFlight)>,
    pub metrics: Metrics,
    rng: Rng,
    next_task_id: TaskId,
    next_probe_id: FlowId,
    trace: Arc<Trace>,
    /// No new probe/traffic events after this time (lets the queue drain).
    end_of_input: SimTime,
    /// Fleet membership as the engine sees it (trace frames for inactive
    /// devices are dropped; scheduler keeps its own mirror).
    active_devices: Vec<bool>,
    /// Per-device processing-time multiplier (scenario heterogeneity).
    device_speed: Vec<f64>,
    /// Current burst duty cycle (regime changes override the config's).
    duty_cycle: f64,
    /// Whether the traffic-toggle event chain is alive.
    traffic_on: bool,
    /// Crash time per device (`Some` while down; recovery latency metric).
    crashed_at: Vec<Option<SimTime>>,
    /// Scratch: active-device list for probe rounds (reused per round).
    scratch_devices: Vec<DeviceId>,
    /// Scratch: crash orphan collection (reused per crash).
    scratch_orphans: Vec<(TaskId, FrameId)>,
    /// Compiled generative workload (None for trace-only runs).
    gen: Option<GenWorkload>,
    /// Model-variant ladder table. Index 0 is the empty "no ladder"
    /// sentinel; the conveyor LP ladder and every laddered generative
    /// class register their rungs here once at construction.
    ladders: Vec<Vec<VariantRung>>,
    /// Ladder index for conveyor low-priority tasks (0 = none).
    conveyor_ladder: u16,
    /// Ladder index per generative class (parallel to `gen.classes`).
    gen_ladders: Vec<u16>,
    /// Anytime stage-plan table, in lockstep with `ladders`: entry
    /// `[l][r]` is rung `r`'s plan in ladder `l` (`StagePlan::NONE` =
    /// monolithic). Index 0 is the same empty sentinel, so a slot's
    /// `(ladder, rung)` pair resolves both tables.
    stage_plans: Vec<Vec<StagePlan>>,
    /// Slab handles of staged LP executions in flight — the pressure
    /// survey's worklist. Entries go stale when their execution ends
    /// (handle dies or `exec` clears) and are swept on the next survey;
    /// empty whenever no ladder carries stage plans.
    staged_execs: Vec<SlotRef>,
    /// Scratch: pressure-survey candidates (reused per check).
    pressure_cands: Vec<PressureCandidate>,
    /// Scratch: slab handle per survey candidate, same order (maps the
    /// scheduler's `TruncateCut::index` back to a slot).
    pressure_slots: Vec<SlotRef>,
    /// Per-device energy integrator (`None` = accounting off: every
    /// hook site is behind an `Option` check and pushes no events).
    fleet: Option<FleetEnergy>,
    /// Cloud tier behind the WAN (`None` unless `cloud_wan_bps > 0`).
    cloud: Option<CloudTier>,
    /// Scratch: battery levels relayed to the scheduler.
    scratch_levels: Vec<f64>,
    /// Device-cell span of the TraceFrame event chains (one chain head
    /// per cell lives in the queue at a time).
    trace_span: usize,
    /// Epoch of the latest armed medium-completion prediction
    /// (`u64::MAX` = none armed). Re-arming under a newer epoch marks
    /// the superseded queued event stale for compaction accounting.
    armed_medium: u64,
    /// Same, for the WAN upload-completion prediction.
    armed_wan: u64,
    /// Per-device epoch of the latest armed battery-depletion event.
    armed_battery: Vec<u64>,
    /// Imperfect failure detector fed by probe rounds (belief, not truth;
    /// disabled — zero overhead, no events — when `suspect_after == 0`).
    detector: SuspicionDetector,
    /// Partition truth per device (unreachable-but-alive).
    partitioned: Vec<bool>,
    /// When the device's current outage (crash or partition) began —
    /// detection-lag accounting for the suspicion detector.
    down_since: Vec<Option<SimTime>>,
    /// Flows stalled by a partition: (task, remaining bits). Re-added to
    /// the medium when both endpoints are reachable again.
    stalled_flows: Vec<(TaskId, f64)>,
    /// Finished-but-undeliverable results held behind a partition; the
    /// heal re-fires their `LpFinish` (deadline re-checked then). The
    /// second field is the anytime completion stage (`u8::MAX` = ran to
    /// full depth), so a truncated result re-delivers the same cut.
    held_finishes: Vec<(TaskId, u8)>,
    /// Optional flight recorder ([`crate::obs`]): `None` = tracing off —
    /// every hook is a skipped `Option` check, no events, no RNG draws.
    /// Boxed so the disabled engine pays one pointer, not a ring header.
    recorder: Option<Box<FlightRecorder>>,
    /// Optional per-phase wall-clock timers (`None` = timing off).
    timers: Option<Box<PhaseTimers>>,
}

impl Engine {
    /// The paper's fixed testbed: no churn, homogeneous devices, the
    /// config's static congestion regime. `trace` may be owned or an
    /// [`Arc`] shared across runs (twin runs, sweep grids).
    pub fn new(
        cfg: SystemConfig,
        sched: Box<dyn Scheduler>,
        trace: impl Into<Arc<Trace>>,
        label: &str,
    ) -> Self {
        Self::with_extras(cfg, sched, trace, label, RunExtras::default())
    }

    /// Full scenario constructor (what [`crate::scenario::Scenario`]
    /// compiles to).
    pub fn with_extras(
        cfg: SystemConfig,
        sched: Box<dyn Scheduler>,
        trace: impl Into<Arc<Trace>>,
        label: &str,
        extras: RunExtras,
    ) -> Self {
        let trace: Arc<Trace> = trace.into();
        let end_of_input = (trace.entries.len() as u64 + 1) * cfg.frame_period();
        let mut queue = EventQueue::new();
        // Each device samples its own conveyor belt: frame phases are
        // staggered across devices (offset d·T/n). This is what makes
        // offloading interesting — a host device's high-priority work
        // arrives mid-way through guest tasks' processing windows — and it
        // is where the paper's preemption/reallocation traffic comes from.
        //
        // Only one chain head per device *cell* enters the queue; each
        // fired frame chains its successor (the cell's next device in the
        // same row — phases ascend with the device index — then the
        // cell's head in the next row). Every frame still fires at
        // exactly i·T + d·T/n, but queue occupancy is O(cells), not
        // O(rows × devices) — pre-pushing a 100k-device trace used to
        // hold millions of pending frames up front.
        let trace_span = CellMap::new(cfg.cell_size, cfg.n_devices).span();
        if !trace.entries.is_empty() {
            let mut d = 0;
            while d < cfg.n_devices {
                let phase = d as u64 * cfg.frame_period() / cfg.n_devices as u64;
                queue.push(phase, Event::TraceFrame { index: d });
                d += trace_span;
            }
        }
        // First probe after one interval (the baseline estimate covers
        // start-up, as with the paper's initial iperf3 test).
        queue.push(cfg.bandwidth_interval(), Event::ProbeStart);
        if cfg.duty_cycle > 0.0 {
            queue.push(0, Event::TrafficToggle { active: true });
        }
        // Scenario schedules: fleet churn and congestion regime changes.
        for &(at, device, join) in &extras.churn {
            let ev = if join { Event::DeviceJoin { device } } else { Event::DeviceLeave { device } };
            queue.push(at, ev);
        }
        for &(at, bg_bps, duty) in &extras.regimes {
            queue.push(
                at,
                Event::RegimeChange { bg_bps_bits: bg_bps.to_bits(), duty_bits: duty.to_bits() },
            );
        }
        // Fault schedule: crashes lose work, recoveries restore capacity.
        for &(at, device, recover) in &extras.faults {
            let ev = if recover {
                Event::DeviceRecover { device }
            } else {
                Event::DeviceCrash { device }
            };
            queue.push(at, ev);
        }
        // Partition schedule: unreachable-but-alive intervals.
        for &(at, device, heal) in &extras.partitions {
            let ev = if heal {
                Event::PartitionHeal { device }
            } else {
                Event::PartitionStart { device }
            };
            queue.push(at, ev);
        }
        // Generative workload: only the plan's head enters the queue —
        // each fired arrival chains the next (the plan is time-sorted),
        // so the queue stays O(live events) instead of holding millions
        // of pending arrivals up front. The input horizon stretches to
        // cover the plan so probes/traffic keep running until the last
        // arrival.
        let mut end_of_input = end_of_input;
        if let Some(gen) = &extras.gen {
            if let Some(first) = gen.arrivals.first() {
                queue.push(first.at, Event::GenArrive { index: 0 });
            }
            end_of_input = end_of_input.max(gen.last_arrival() + cfg.frame_period());
        }
        let mut device_speed = extras.device_speed;
        if device_speed.len() < cfg.n_devices {
            device_speed.resize(cfg.n_devices, 1.0);
        }
        // Ladder table: index 0 is the "no ladder" sentinel. The conveyor
        // LP ladder and every laddered generative class register once
        // here; tasks carry only the u16 index, so the hot path never
        // clones rung vectors. Anytime stage plans ride in lockstep —
        // the same push order fills both tables, padded with
        // `StagePlan::NONE` so every rung has an entry.
        let mut ladders: Vec<Vec<VariantRung>> = vec![Vec::new()];
        let mut stage_plans: Vec<Vec<StagePlan>> = vec![Vec::new()];
        let conveyor_ladder = if extras.lp_ladder.is_empty() {
            0u16
        } else {
            let mut plans = extras.lp_stage_plans.clone();
            plans.resize(extras.lp_ladder.len(), StagePlan::NONE);
            ladders.push(extras.lp_ladder.clone());
            stage_plans.push(plans);
            (ladders.len() - 1) as u16
        };
        let mut gen_ladders: Vec<u16> = Vec::new();
        if let Some(g) = &extras.gen {
            for c in &g.classes {
                if c.rungs.is_empty() {
                    gen_ladders.push(0);
                } else {
                    let mut plans = c.stage_plans.clone();
                    plans.resize(c.rungs.len(), StagePlan::NONE);
                    ladders.push(c.rungs.clone());
                    stage_plans.push(plans);
                    gen_ladders.push((ladders.len() - 1) as u16);
                }
            }
        }
        // Anytime pressure controller: one periodic survey chain, alive
        // only while the knob is set — the off default pushes nothing
        // and the run stays byte-identical.
        if cfg.pressure_check_s > 0.0 {
            queue.push(crate::time::secs(cfg.pressure_check_s), Event::PressureCheck);
        }
        let estimator = BandwidthEstimator::new(&cfg, cfg.link_bps);
        let n_cells = trace.entries.len() * cfg.n_devices;
        let fleet =
            extras.energy.map(|m| FleetEnergy::new(m, extras.battery_j, cfg.n_devices));
        let cloud = CloudTier::from_config(&cfg);
        // Attaching a recorder implies explainability: the schedulers
        // start building DecisionRecords, drained into the ring after
        // every handled event. With capacity 0 the scheduler is never
        // told and the run stays byte-identical to a recorder-less one.
        let mut sched = sched;
        let recorder = if extras.trace_capacity > 0 {
            sched.set_explain(true);
            Some(Box::new(FlightRecorder::new(extras.trace_capacity)))
        } else {
            None
        };
        let timers = extras.timing.then(|| Box::new(PhaseTimers::default()));
        Self {
            active_devices: vec![true; cfg.n_devices],
            device_speed,
            duty_cycle: cfg.duty_cycle,
            traffic_on: cfg.duty_cycle > 0.0,
            medium: LossyMedium::new(
                Medium::new(cfg.link_bps, cfg.bg_bps),
                extras.loss_rate,
                extras.probe_loss,
                cfg.seed ^ 0x4c4f_5353, // "LOSS"
            ),
            estimator,
            queue,
            now: 0,
            busy_until: 0,
            tasks: Slab::with_capacity(64),
            // ≤ 1 HP + ≤ IdBatch::INLINE LP tasks per conveyor frame cell:
            // reserving up front keeps arrival-path growth out of steady
            // state (generative ids grow the index lazily).
            task_index: Vec::with_capacity(n_cells * (1 + IdBatch::INLINE) + 8),
            frames: vec![FrameState::default(); n_cells],
            probes: Vec::with_capacity(4),
            metrics: Metrics::new(label),
            rng: Rng::seed_from_u64(cfg.seed ^ 0x454e47), // "ENG"
            next_task_id: 1,
            next_probe_id: PROBE_FLOW_BASE,
            trace,
            end_of_input,
            crashed_at: vec![None; cfg.n_devices],
            scratch_devices: Vec::with_capacity(cfg.n_devices),
            scratch_orphans: Vec::with_capacity(16),
            gen: extras.gen,
            ladders,
            conveyor_ladder,
            gen_ladders,
            stage_plans,
            staged_execs: Vec::new(),
            pressure_cands: Vec::new(),
            pressure_slots: Vec::new(),
            fleet,
            cloud,
            scratch_levels: Vec::new(),
            trace_span,
            armed_medium: u64::MAX,
            armed_wan: u64::MAX,
            armed_battery: vec![u64::MAX; cfg.n_devices],
            detector: SuspicionDetector::new(cfg.n_devices, cfg.suspect_after, cfg.confirm_after),
            partitioned: vec![false; cfg.n_devices],
            down_since: vec![None; cfg.n_devices],
            stalled_flows: Vec::new(),
            held_finishes: Vec::new(),
            recorder,
            timers,
            cfg,
            sched,
        }
    }

    /// Process the next queued event. Returns `false` once the queue has
    /// drained (benches use this to meter per-event cost; normal drivers
    /// call [`Engine::run`]).
    pub fn step(&mut self) -> bool {
        let Some(s) = self.queue.pop() else { return false };
        debug_assert!(s.at >= self.now, "time went backwards");
        self.now = s.at;
        let t0 = self.phase_start();
        self.handle(s.event);
        self.phase_end(t0, Phase::Dispatch);
        // Single decision-drain point: whatever DecisionRecords the
        // handled event's scheduler calls produced enter the ring here,
        // in event order, timestamped with the event's sim-time.
        if self.recorder.is_some() {
            let now = self.now;
            let decisions = self.sched.drain_decisions();
            if let Some(r) = self.recorder.as_mut() {
                for d in decisions {
                    r.record(now, TraceEvent::Decision(d));
                }
            }
        }
        // Lazy compaction: epoch-guarded predictions and finishes of dead
        // placements die in place when superseded; once they dominate the
        // queue, one sweep drops them all so the footprint tracks *live*
        // events under heavy preemption, churn, and battery re-arming.
        if self.queue.should_compact() {
            let t0 = self.phase_start();
            let mut q = std::mem::take(&mut self.queue);
            q.compact(|ev| self.event_live(ev));
            self.queue = q;
            self.phase_end(t0, Phase::Compact);
        }
        true
    }

    // ---- observability ---------------------------------------------------

    /// Start a wall-clock phase measurement. `None` (timing off, the
    /// default) costs one branch — no clock read on the hot path.
    #[inline]
    fn phase_start(&self) -> Option<std::time::Instant> {
        self.timers.as_ref().map(|_| std::time::Instant::now())
    }

    /// Fold a measurement started by [`Engine::phase_start`].
    #[inline]
    fn phase_end(&mut self, t0: Option<std::time::Instant>, phase: Phase) {
        if let (Some(t0), Some(t)) = (t0, self.timers.as_mut()) {
            t.add(phase, t0.elapsed().as_nanos() as u64);
        }
    }

    /// Feed the flight recorder, if one is attached. With tracing off
    /// (the default) this is a skipped `Option` check: no allocation, no
    /// RNG, no events. Hot-path callers whose event needs extra lookups
    /// gate construction on [`Engine::tracing`] first.
    #[inline]
    fn trace(&mut self, event: TraceEvent) {
        if let Some(r) = self.recorder.as_mut() {
            r.record(self.now, event);
        }
    }

    /// Like [`Engine::trace`] with an explicit timestamp — exec windows
    /// open at their allocated start, not at the decision event.
    #[inline]
    fn trace_at(&mut self, at: SimTime, event: TraceEvent) {
        if let Some(r) = self.recorder.as_mut() {
            r.record(at, event);
        }
    }

    /// Whether a flight recorder is attached.
    #[inline]
    fn tracing(&self) -> bool {
        self.recorder.is_some()
    }

    /// The attached flight recorder (`None` = tracing off). The chaos
    /// campaign dumps this when an invariant trips.
    pub fn recorder(&self) -> Option<&FlightRecorder> {
        self.recorder.as_deref()
    }

    /// Chrome-trace/Perfetto JSON of the recorded run (`None` = tracing
    /// off). See [`FlightRecorder::perfetto_json`].
    pub fn trace_json(&self) -> Option<String> {
        self.recorder.as_ref().map(|r| r.perfetto_json(self.cfg.n_devices))
    }

    /// Number of events currently queued. Scale tests assert occupancy
    /// stays O(cells + live work), not O(trace length × fleet size).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Can this queued event still do work when it fires? The compaction
    /// predicate: superseded epoch-guarded predictions and finish /
    /// transfer events whose placement died (slab handle re-slotted) are
    /// dead weight the sweep may drop.
    fn event_live(&self, ev: &Event) -> bool {
        match ev {
            Event::HpFinish { task } | Event::LpFinish { task } | Event::TransferStart { task } => {
                self.tasks.get(*task).map_or(false, |s| s.rt.is_some())
            }
            Event::OffloadTimeout { task } | Event::HedgeLaunch { task } => {
                self.tasks.get(*task).map_or(false, |s| s.rt.is_some())
            }
            Event::LpStageBoundary { task, .. } => {
                self.tasks.get(*task).map_or(false, |s| s.rt.is_some())
            }
            Event::MediumComplete { epoch, .. } => *epoch == self.medium.epoch,
            Event::WanComplete { epoch, .. } => {
                self.cloud.as_ref().map_or(false, |c| c.wan.epoch == *epoch)
            }
            Event::BatteryDeplete { device, epoch } => self
                .fleet
                .as_ref()
                .map_or(false, |f| f.pred_epoch(*device) == Some(*epoch)),
            _ => true,
        }
    }

    /// Run to completion and return the collected metrics.
    pub fn run(mut self) -> Metrics {
        self.drain();
        self.metrics
    }

    /// Run to completion in place, leaving the engine inspectable — the
    /// chaos campaign audits the slab ([`Self::live_tasks`]) after the
    /// drain, which a consuming [`Self::run`] cannot offer.
    pub fn drain(&mut self) -> &Metrics {
        while self.step() {}
        self.flush_partition_remnants();
        self.metrics.final_bandwidth_estimate_bps = self.sched.bandwidth_estimate();
        self.metrics.bw_stale_us = self.estimator.stale_us(self.now);
        self.metrics.reject_reasons = self.sched.reject_diag();
        self.metrics.retransmitted_mbits = self.medium.retransmitted_bits / 1e6;
        // Hot-path gauges: the deterministic op counters always land;
        // trace/timing gauges stay 0 unless their knobs were on.
        self.metrics.medium_drain_ops = self.medium.drain_ops;
        self.metrics.queue_compactions = self.queue.compactions();
        if let Some(r) = self.recorder.as_ref() {
            self.metrics.trace_events = r.total_seen();
        }
        if let Some(t) = self.timers.as_ref() {
            self.metrics.phase_dispatch_ns = t.dispatch_ns;
            self.metrics.phase_sched_ns = t.sched_ns;
            self.metrics.phase_medium_ns = t.medium_ns;
            self.metrics.phase_compact_ns = t.compact_ns;
        }
        if let Some(f) = self.fleet.as_mut() {
            // Fold the trailing idle draw, then bank the fleet totals.
            f.settle_all(self.now);
            let (idle, active, tx, rx, total) = f.totals();
            self.metrics.energy_idle_j = idle;
            self.metrics.energy_active_j = active;
            self.metrics.energy_tx_j = tx;
            self.metrics.energy_rx_j = rx;
            self.metrics.energy_total_j = total;
            self.metrics.battery_final_j = f.battery_final_j();
        }
        self.metrics.debug_audit();
        &self.metrics
    }

    fn fresh_task_id(&mut self) -> TaskId {
        let id = self.next_task_id;
        self.next_task_id += 1;
        id
    }

    // ---- slab plumbing ---------------------------------------------------

    /// Current slab handle for `id` (NULL for terminal/unknown tasks).
    fn slot_of(&self, id: TaskId) -> SlotRef {
        self.task_index.get(id as usize).copied().unwrap_or(SlotRef::NULL)
    }

    /// Borrow a task that the caller knows is live (arrival/requeue paths
    /// guarantee liveness by construction; a panic here is an engine bug,
    /// not a recoverable state).
    fn task(&self, id: TaskId) -> &Task {
        &self.tasks.get(self.slot_of(id)).expect("task must be live").task
    }

    /// Insert a fresh task (rung 0 of `ladder`; 0 = no ladder).
    fn insert_task(&mut self, task: Task, ladder: u16) -> SlotRef {
        let id = task.id as usize;
        let h = self.tasks.insert(TaskSlot {
            task,
            rt: None,
            ladder,
            rung: 0,
            tries: 0,
            hedge_of: None,
            hedged_by: None,
            exec: None,
            next_stage: 0,
            cut_stage: u8::MAX,
        });
        if self.task_index.len() <= id {
            self.task_index.resize(id + 1, SlotRef::NULL);
        }
        self.task_index[id] = h;
        h
    }

    /// Commit a degradation decision: bump each task's rung and rewrite
    /// its spec to the chosen variant, so the transfer (input bytes) and
    /// any future re-placement (remaining ladder tail) see the model
    /// that was actually scheduled. `variant` is relative to the ladder
    /// tail the dispatch exposed, i.e. to the tasks' current rung.
    fn apply_variant(&mut self, ids: &[TaskId], variant: Option<u8>) {
        let Some(k) = variant else { return };
        if k == 0 {
            return;
        }
        for &id in ids {
            let h = self.slot_of(id);
            let slot = self.tasks.get_mut(h).expect("degraded task live");
            slot.rung += k;
            let rung = &self.ladders[slot.ladder as usize][slot.rung as usize];
            // Same respec the degradation policy planned the allocation
            // with — never a hand-rolled copy that could drift from it.
            slot.task = slot.task.at_rung(rung);
            self.metrics.degraded_placements = self.metrics.degraded_placements.saturating_add(1);
        }
    }

    /// Release a terminal task's slot (completed, violated, rejected, or
    /// dropped — nothing will reference it again; any event still in the
    /// queue carries a handle that no longer resolves).
    fn free_task(&mut self, id: TaskId) {
        let h = self.slot_of(id);
        if self.tasks.remove(h).is_some() {
            self.task_index[id as usize] = SlotRef::NULL;
        }
    }

    /// Kill a task's current placement: abort its medium flow and re-slot
    /// it (same index, next slab generation via the LIFO free list), so
    /// every finish/transfer event queued under the dead placement goes
    /// stale. The task itself stays live for requeue/re-offer.
    fn cancel_placement(&mut self, task: TaskId) {
        let h = self.slot_of(task);
        // (device, power-config index, source) of the dead placement —
        // the energy integrator must stop charging what was cancelled.
        let mut ended: Option<(DeviceId, usize, DeviceId)> = None;
        if let Some(mut slot) = self.tasks.remove(h) {
            if let Some(rt) = slot.rt.take() {
                ended = Some((rt.alloc.device, rt.alloc.config.index(), slot.task.source));
            }
            if slot.exec.take().is_some() {
                // Unfired stage boundaries of the dead staged execution
                // can never resolve under the new slab generation.
                let plan = self.stage_plan(slot.ladder as usize, slot.rung as usize);
                self.queue.note_stale(plan.n_stages.saturating_sub(slot.next_stage) as usize);
                slot.next_stage = 0;
                slot.cut_stage = u8::MAX;
            }
            let nh = self.tasks.insert(slot);
            self.task_index[task as usize] = nh;
        }
        let lan_flow = self.medium.remove_flow(self.now, task);
        self.arm_medium();
        // A cancelled placement's partition bookkeeping dies with it:
        // stalled transfers are not resumed, held results not delivered.
        if let Some(pos) = self.stalled_flows.iter().position(|&(id, _)| id == task) {
            self.stalled_flows.remove(pos);
        }
        if let Some(pos) = self.held_finishes.iter().position(|&(id, _)| id == task) {
            self.held_finishes.remove(pos);
        }
        if let Some((device, cfg_idx, source)) = ended {
            // The finish event queued under the dead placement will never
            // resolve — report it so compaction accounting sees it.
            self.queue.note_stale(1);
            // A cloud placement's upload rides the WAN, not the LAN.
            let wan_flow = device >= self.cfg.n_devices
                && self.cloud.as_mut().map_or(false, |c| c.abort_upload(self.now, task));
            if wan_flow {
                self.arm_wan();
            }
            self.energy_task_end(device, cfg_idx);
            if lan_flow || wan_flow {
                self.energy_transfer_end(source, device);
            }
        }
    }

    // ---- frame plumbing --------------------------------------------------

    fn frame_mut(&mut self, frame: FrameId) -> Option<&mut FrameState> {
        self.frames.get_mut(frame as usize).filter(|f| f.tracked)
    }

    /// Charge a scheduling call: queueing behind `busy_until`, then
    /// `ops`-proportional processing. Returns (decision_time, latency
    /// perceived since `arrival`).
    fn charge(&mut self, arrival: SimTime, ops: Ops) -> (SimTime, SimDuration) {
        let service_start = self.busy_until.max(arrival);
        let proc = (ops as f64 * self.cfg.op_cost_us).round() as SimDuration;
        let done = service_start + proc;
        self.busy_until = done;
        self.metrics.controller_busy_us = self.metrics.controller_busy_us.saturating_add(proc);
        (done, done - arrival)
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::TraceFrame { index } => self.on_trace_frame(index),
            Event::GenArrive { index } => self.on_gen_arrive(index),
            Event::HpArrive { task } => self.on_hp_arrive(task),
            Event::HpFinish { task } => self.on_hp_finish(task),
            Event::LpArrive { tasks, realloc } => self.on_lp_arrive(tasks, realloc),
            Event::LpFinish { task } => self.on_lp_finish(task),
            Event::TransferStart { task } => self.on_transfer_start(task),
            Event::MediumComplete { flow, epoch } => self.on_medium_complete(flow, epoch),
            Event::ProbeStart => self.on_probe_start(),
            Event::TrafficToggle { active } => self.on_traffic_toggle(active),
            Event::DeviceJoin { device } => self.on_device_join(device),
            Event::DeviceLeave { device } => self.on_device_leave(device),
            Event::DeviceCrash { device } => self.on_device_crash(device),
            Event::DeviceRecover { device } => self.on_device_recover(device),
            Event::Reoffer { tasks } => self.on_reoffer(tasks),
            Event::RegimeChange { bg_bps_bits, duty_bits } => {
                self.on_regime_change(f64::from_bits(bg_bps_bits), f64::from_bits(duty_bits))
            }
            Event::WanComplete { flow, epoch } => self.on_wan_complete(flow, epoch),
            Event::BatteryDeplete { device, epoch } => self.on_battery_deplete(device, epoch),
            Event::PartitionStart { device } => self.on_partition_start(device),
            Event::PartitionHeal { device } => self.on_partition_heal(device),
            Event::OffloadTimeout { task } => self.on_offload_timeout(task),
            Event::HedgeLaunch { task } => self.on_hedge_launch(task),
            Event::LpStageBoundary { task, stage } => self.on_lp_stage_boundary(task, stage),
            Event::PressureCheck => self.on_pressure_check(),
        }
    }

    fn device_active(&self, device: DeviceId) -> bool {
        self.active_devices.get(device).copied().unwrap_or(false)
    }

    // ---- energy accounting ----------------------------------------------
    //
    // Every hook below no-ops (no event pushes, no arithmetic) when the
    // run carries no [`EnergyModel`] — the default path stays
    // byte-identical. Each fleet transition returns a fresh battery
    // depletion prediction (`None` on mains) that replaces the previous
    // one via the epoch guard.

    /// Arm the battery-depletion prediction a fleet hook returned.
    fn arm_battery(&mut self, device: DeviceId, pred: Option<(u64, u64)>) {
        if let Some((epoch, delta_us)) = pred {
            if let Some(armed) = self.armed_battery.get_mut(device) {
                if *armed != u64::MAX && *armed != epoch {
                    self.queue.note_stale(1); // superseded prediction
                }
                *armed = epoch;
            }
            self.queue
                .push(self.now.saturating_add(delta_us), Event::BatteryDeplete { device, epoch });
        }
    }

    /// A committed allocation starts powering its device (commitment =
    /// active: the engine has no "actually started" event, see
    /// [`crate::energy`]). Cloud placements no-op (mains powered).
    fn energy_task_start(&mut self, device: DeviceId, cfg_idx: usize) {
        let now = self.now;
        let pred = self.fleet.as_mut().and_then(|f| f.task_start(now, device, cfg_idx));
        self.arm_battery(device, pred);
    }

    fn energy_task_end(&mut self, device: DeviceId, cfg_idx: usize) {
        let now = self.now;
        let pred = self.fleet.as_mut().and_then(|f| f.task_end(now, device, cfg_idx));
        self.arm_battery(device, pred);
    }

    fn energy_transfer_start(&mut self, src: DeviceId, dst: DeviceId) {
        let now = self.now;
        let Some(preds) = self.fleet.as_mut().map(|f| f.transfer_start(now, src, dst)) else {
            return;
        };
        self.arm_battery(src, preds[0]);
        self.arm_battery(dst, preds[1]);
    }

    fn energy_transfer_end(&mut self, src: DeviceId, dst: DeviceId) {
        let now = self.now;
        let Some(preds) = self.fleet.as_mut().map(|f| f.transfer_end(now, src, dst)) else {
            return;
        };
        self.arm_battery(src, preds[0]);
        self.arm_battery(dst, preds[1]);
    }

    fn energy_set_online(&mut self, device: DeviceId, online: bool) {
        let now = self.now;
        let pred = self.fleet.as_mut().and_then(|f| f.set_online(now, device, online));
        self.arm_battery(device, pred);
    }

    /// A predicted battery depletion fired. Stale epochs (the device's
    /// power changed since, which re-armed a fresh prediction) are
    /// ignored; a genuine depletion routes through the crash path —
    /// in-flight work is lost or re-offered — and the recover guard
    /// keeps the device down for the rest of the run.
    fn on_battery_deplete(&mut self, device: DeviceId, epoch: u64) {
        if self.armed_battery.get(device).copied() == Some(epoch) {
            self.armed_battery[device] = u64::MAX;
        }
        let now = self.now;
        let drained = self.fleet.as_mut().map_or(false, |f| f.on_deplete(now, device, epoch));
        if !drained {
            self.queue.note_popped_stale();
            return;
        }
        self.metrics.battery_depletions = self.metrics.battery_depletions.saturating_add(1);
        self.trace(TraceEvent::BatteryDeplete { device });
        self.on_device_crash(device);
    }

    // ---- workload generation -------------------------------------------

    fn on_trace_frame(&mut self, index: usize) {
        // `index` encodes (trace row, device): one event per device frame.
        let n = self.cfg.n_devices;
        let (row, device) = (index / n, index % n);
        // Chain the successor first, unconditionally — the conveyor must
        // keep rolling even when this frame is dropped (device out of the
        // fleet, empty belt cell). Within a row the cell's members fire
        // in device order (phases ascend with the index); the cell's last
        // member chains the cell head in the next row.
        let next = device + 1;
        if next < n && next % self.trace_span != 0 {
            let phase = next as u64 * self.cfg.frame_period() / n as u64;
            self.queue
                .push(row as u64 * self.cfg.frame_period() + phase, Event::TraceFrame { index: index + 1 });
        } else if row + 1 < self.trace.entries.len() {
            let head = (device / self.trace_span) * self.trace_span;
            let phase = head as u64 * self.cfg.frame_period() / n as u64;
            self.queue.push(
                (row as u64 + 1) * self.cfg.frame_period() + phase,
                Event::TraceFrame { index: (row + 1) * n + head },
            );
        }
        if !self.device_active(device) {
            return; // the device has left the fleet: no camera, no frames
        }
        let load = self.trace.entries[row].loads[device];
        if load < 0 {
            return; // no object on the belt
        }
        let frame_id = index as FrameId;
        self.trace(TraceEvent::FrameArrive { index });
        self.metrics.frames_total = self.metrics.frames_total.saturating_add(1);
        self.metrics.hp_generated = self.metrics.hp_generated.saturating_add(1);
        self.frames[index] = FrameState {
            tracked: true,
            lp_expected: load as u32,
            lp_done: 0,
            hp_done: false,
            failed: false,
            counted: false,
            deadline: self.now + self.cfg.frame_period(),
        };
        let id = self.fresh_task_id();
        let task = Task::high(id, frame_id, device, self.now, &self.cfg);
        self.insert_task(task, 0);
        // Request travels to the controller.
        self.queue.push(self.now + self.cfg.control_latency(), Event::HpArrive { task: id });
    }

    /// A generative arrival fires: admit (or drop) one batch of one task
    /// class from the compiled plan. Each admitted arrival is its own
    /// pipeline unit — a fresh frame slot appended past the conveyor's
    /// dense region — so frame-completion accounting covers generative
    /// work with no special cases downstream.
    fn on_gen_arrive(&mut self, index: usize) {
        let Some(gen) = &self.gen else { return };
        let arrival = gen.arrivals[index];
        // Copy the flat class fields only — never clone the class: its
        // ladder Vec lives once in the engine's ladder table, and this
        // path fires once per arrival of a potentially million-arrival
        // plan.
        let (priority, deadline_us, input_bytes, proc_us, cloud_us, batch) = {
            let c = &gen.classes[arrival.class as usize];
            (c.priority, c.deadline_us, c.input_bytes, c.proc_us, c.cloud_us, c.batch)
        };
        let ladder = self.gen_ladders.get(arrival.class as usize).copied().unwrap_or(0);
        let cap = gen.admission_cap;
        // Chain the next planned arrival first, unconditionally — the
        // plan must keep unrolling even when this arrival is dropped.
        if let Some(next) = gen.arrivals.get(index + 1) {
            let at = next.at;
            self.queue.push(at, Event::GenArrive { index: index + 1 });
        }
        let count = if priority == crate::coordinator::task::Priority::High {
            1
        } else {
            batch.max(1)
        };
        // Offered-load accounting happens before any drop: the
        // denominator of every drop/completion rate is what the
        // generator *asked* for, outages included.
        self.metrics.gen_arrivals = self.metrics.gen_arrivals.saturating_add(1);
        self.metrics.offered_tasks = self.metrics.offered_tasks.saturating_add(count as u64);
        self.metrics.offered_mbits += count as f64 * input_bytes as f64 * 8.0 / 1e6;
        self.trace(TraceEvent::GenArrive { index });
        if !self.device_active(arrival.source) {
            // The client's device is out of the fleet (churn/crash
            // outage): the work is offered but has nowhere to originate.
            self.metrics.offline_dropped = self.metrics.offline_dropped.saturating_add(count as u64);
            self.trace(TraceEvent::AdmissionDrop { tasks: count as usize });
            return;
        }
        if cap > 0 && self.tasks.len() + count as usize > cap {
            self.metrics.admission_dropped = self.metrics.admission_dropped.saturating_add(count as u64);
            self.trace(TraceEvent::AdmissionDrop { tasks: count as usize });
            return;
        }
        let frame_id = self.frames.len() as FrameId;
        let is_hp = priority == crate::coordinator::task::Priority::High;
        self.frames.push(FrameState {
            tracked: true,
            lp_expected: if is_hp { 0 } else { count },
            lp_done: 0,
            // LP-only units have no detector stage to wait for.
            hp_done: !is_hp,
            failed: false,
            counted: false,
            deadline: self.now + deadline_us,
        });
        self.metrics.frames_total = self.metrics.frames_total.saturating_add(1);
        if is_hp {
            self.metrics.hp_generated = self.metrics.hp_generated.saturating_add(1);
            let id = self.fresh_task_id();
            let task = Task::of_class(
                id,
                frame_id,
                arrival.source,
                self.now,
                priority,
                deadline_us,
                input_bytes,
                proc_us,
                cloud_us,
            );
            self.insert_task(task, 0);
            self.queue.push(self.now + self.cfg.control_latency(), Event::HpArrive { task: id });
        } else {
            self.metrics.lp_generated = self.metrics.lp_generated.saturating_add(count as u64);
            let mut ids = IdBatch::new();
            for _ in 0..count {
                let id = self.fresh_task_id();
                let task = Task::of_class(
                    id,
                    frame_id,
                    arrival.source,
                    self.now,
                    priority,
                    deadline_us,
                    input_bytes,
                    proc_us,
                    cloud_us,
                );
                self.insert_task(task, ladder);
                ids.push(id);
            }
            let at = self.now + self.cfg.control_latency();
            self.queue.push(at, Event::LpArrive { tasks: ids, realloc: false });
        }
    }

    // ---- high-priority path --------------------------------------------

    fn on_hp_arrive(&mut self, task_id: TaskId) {
        let arrival = self.now;
        let service_start = self.busy_until.max(arrival);
        let h = self.slot_of(task_id);
        let frame = self.tasks.get(h).expect("hp task live at arrival").task.frame;
        // Borrow the task straight out of the slab for the dispatch — the
        // scheduler sees `&Task`, nothing is cloned.
        let t0 = self.phase_start();
        let Decision { outcome, ops, .. } = {
            let task = &self.tasks.get(h).expect("hp task live at arrival").task;
            self.sched.on_event(service_start, SchedEvent::HighPriority { task })
        };
        self.phase_end(t0, Phase::Sched);
        let (decision, lat) = self.charge(arrival, ops);
        match outcome {
            Outcome::HpAllocated { alloc, victims } => {
                if victims.is_empty() {
                    self.metrics.hp_allocated_no_preempt = self.metrics.hp_allocated_no_preempt.saturating_add(1);
                    self.metrics.lat_hp_alloc.record(lat);
                } else {
                    self.metrics.hp_allocated_with_preempt = self.metrics.hp_allocated_with_preempt.saturating_add(1);
                    self.metrics.lat_hp_preempt.record(lat);
                }
                self.trace(TraceEvent::HpPlace {
                    task: task_id,
                    device: alloc.device,
                    cores: alloc.cores as u8,
                });
                // "Reallocation can only begin once the high-priority task
                // has completed pre-emption": victims re-enter after the
                // decision, plus the control round.
                self.requeue_preempted(victims, decision);
                self.start_local(alloc, decision, false, false);
            }
            Outcome::HpRejected { victims } => {
                self.metrics.hp_rejected = self.metrics.hp_rejected.saturating_add(1);
                self.trace(TraceEvent::HpReject { task: task_id });
                self.fail_frame(frame);
                // Tasks evicted by a preemption attempt that ultimately
                // failed still get their reallocation chance.
                self.requeue_preempted(victims, decision);
                self.free_task(task_id);
            }
            other => unreachable!("HP event must yield an HP outcome, got {other:?}"),
        }
    }

    /// Cancel preemption victims and queue their low-priority re-entry.
    fn requeue_preempted(&mut self, victims: Vec<Allocation>, decision: SimTime) {
        for v in victims {
            self.trace(TraceEvent::Preempt { task: v.task, device: v.device });
            self.cancel_placement(v.task);
            self.metrics.lp_preempted = self.metrics.lp_preempted.saturating_add(1);
            self.metrics.lp_realloc_attempts = self.metrics.lp_realloc_attempts.saturating_add(1);
            self.queue.push(
                decision + self.cfg.control_latency(),
                Event::LpArrive { tasks: IdBatch::one(v.task), realloc: true },
            );
        }
    }

    /// Actual on-device duration for an allocation. The scheduler planned
    /// `mean + padding`; the Raspberry Pi takes `mean + |N(0, σ)|`
    /// (Section V: the padding is the benchmark standard deviation). The
    /// overshoot beyond the padding is what erodes thin placement margins.
    fn actual_duration(&mut self, alloc: &Allocation) -> SimDuration {
        // Scenario heterogeneity: the controller plans for the homogeneous
        // testbed; a slower device (factor > 1) silently overshoots the
        // plan, eroding placement margins exactly like jitter does.
        let slow = self.device_speed.get(alloc.device).copied().unwrap_or(1.0);
        let planned = alloc.end - alloc.start;
        if alloc.config == crate::coordinator::task::TaskConfig::HighPriority {
            // HP runtimes are not padded in the paper.
            return (planned as f64 * slow).round() as SimDuration;
        }
        let pad = crate::time::secs(self.cfg.proc_padding_s);
        let mean = planned.saturating_sub(pad);
        let sigma = self.cfg.proc_jitter_s;
        let jitter = (self.rng.gen_gauss().abs() * sigma).min(3.0 * sigma);
        (mean as f64 * slow).round() as SimDuration + crate::time::secs(jitter)
    }

    /// Start a task that needs no transfer: runs on its device from
    /// max(allocated start, decision + control latency).
    fn start_local(&mut self, alloc: Allocation, decision: SimTime, realloc: bool, reoffered: bool) {
        let eff_start = alloc.start.max(decision + self.cfg.control_latency());
        let proc = self.actual_duration(&alloc);
        let finish = eff_start + proc;
        let task = alloc.task;
        let is_hp = alloc.config == crate::coordinator::task::TaskConfig::HighPriority;
        let (device, cfg_idx) = (alloc.device, alloc.config.index());
        let h = self.slot_of(task);
        self.tasks.get_mut(h).expect("placing a live task").rt =
            Some(TaskRuntime { alloc, realloc, reoffered });
        self.trace_at(eff_start, TraceEvent::ExecStart { task, device });
        self.energy_task_start(device, cfg_idx);
        if is_hp {
            self.queue.push(finish, Event::HpFinish { task: h });
        } else {
            self.begin_lp_exec(h, eff_start, proc);
        }
    }

    // ---- anytime execution ----------------------------------------------
    //
    // Imprecise-computation model: a rung may carry a [`StagePlan`]
    // splitting its execution into a mandatory prefix plus optional
    // refinement stages, each contributing a slice of processing time
    // and accuracy. A running staged execution is a chain of
    // stage-boundary events; the pressure controller may arm a cut so
    // the next boundary completes the task early at partial accuracy.
    // Every hook below no-ops for plan-less rungs (the default): no
    // extra events, no extra RNG draws, byte-identical runs.

    /// Per-rung anytime plan (`StagePlan::NONE` for plan-less rungs).
    fn stage_plan(&self, ladder: usize, rung: usize) -> StagePlan {
        self.stage_plans.get(ladder).and_then(|v| v.get(rung)).copied().unwrap_or(StagePlan::NONE)
    }

    /// Commit a low-priority edge execution's finish chain. Monolithic
    /// rungs push exactly the one `LpFinish` the engine always pushed.
    /// A cuttable plan additionally predicts every optional stage
    /// boundary from the *same* already-sampled duration: boundary k
    /// lands at `eff_start + total·frac_after(k)` (the final stage's
    /// boundary coincides with the finish, so it is never pushed), and
    /// truncating at k simply delivers the finish at that earlier point.
    fn begin_lp_exec(&mut self, h: SlotRef, eff_start: SimTime, total: SimDuration) {
        let (plan, ok) = {
            let slot = self.tasks.get(h).expect("starting a live LP exec");
            let plan = self.stage_plan(slot.ladder as usize, slot.rung as usize);
            (plan, plan.cuttable())
        };
        if ok {
            {
                let slot = self.tasks.get_mut(h).expect("starting a live LP exec");
                slot.exec = Some((eff_start, total));
                slot.next_stage = plan.mandatory;
                slot.cut_stage = u8::MAX;
            }
            for k in plan.mandatory..plan.n_stages {
                let at = eff_start + (total as f64 * plan.frac_after(k)).round() as SimDuration;
                self.queue.push(at, Event::LpStageBoundary { task: h, stage: k });
            }
            self.staged_execs.push(h);
        }
        self.queue.push(eff_start + total, Event::LpFinish { task: h });
    }

    /// A staged LP execution crossed stage boundary `stage`: either the
    /// armed cut lands here — the task completes now at partial depth —
    /// or the execution keeps refining toward the next boundary.
    fn on_lp_stage_boundary(&mut self, h: SlotRef, stage: u8) {
        let (task_id, device, cut_stage) = {
            let Some(slot) = self.tasks.get(h) else {
                self.queue.note_popped_stale();
                return;
            };
            let (Some(rt), Some(_)) = (slot.rt.as_ref(), slot.exec) else {
                self.queue.note_popped_stale();
                return;
            };
            (slot.task.id, rt.alloc.device, slot.cut_stage)
        };
        self.trace(TraceEvent::StageBoundary { task: task_id, device, stage });
        if cut_stage <= stage {
            self.finish_lp(h, stage);
        } else {
            self.tasks.get_mut(h).expect("live staged exec").next_stage = stage + 1;
        }
    }

    /// Periodic deadline-pressure survey: collect every staged execution
    /// that still has an optional boundary ahead, predict its cut/full
    /// finish times from the already-sampled duration (pure arithmetic,
    /// zero RNG), and let the scheduler's rescue policy arm cuts. The
    /// chain re-pushes itself until end-of-input so it never keeps an
    /// otherwise-drained queue alive.
    fn on_pressure_check(&mut self) {
        if self.now > self.end_of_input {
            return;
        }
        let period = crate::time::secs(self.cfg.pressure_check_s);
        self.queue.push(self.now + period, Event::PressureCheck);
        let now = self.now;
        let mut execs = std::mem::take(&mut self.staged_execs);
        let mut cands = std::mem::take(&mut self.pressure_cands);
        let mut slots = std::mem::take(&mut self.pressure_slots);
        cands.clear();
        slots.clear();
        execs.retain(|&h| {
            // Sweep: executions that finished (slot freed or re-slotted,
            // or `exec` cleared), were already cut, or are past their
            // last optional boundary leave the worklist for good.
            let Some(slot) = self.tasks.get(h) else { return false };
            let (Some(rt), Some((eff_start, total))) = (slot.rt.as_ref(), slot.exec) else {
                return false;
            };
            let plan = self.stage_plan(slot.ladder as usize, slot.rung as usize);
            let next = slot.next_stage;
            if slot.cut_stage != u8::MAX || next >= plan.n_stages {
                return false;
            }
            let device = rt.alloc.device;
            // A device predicted to die before the full-depth finish
            // makes truncation an energy rescue, not just a deadline one.
            let full_finish = eff_start + total;
            let battery_doomed = self
                .fleet
                .as_ref()
                .and_then(|f| f.depletion_eta_us(now, device))
                .map_or(false, |eta| now + eta < full_finish);
            cands.push(PressureCandidate {
                task: slot.task.id,
                device,
                cut_stage: next,
                n_stages: plan.n_stages,
                cut_finish: eff_start
                    + (total as f64 * plan.frac_after(next)).round() as SimDuration,
                full_finish,
                deadline: slot.task.deadline,
                accuracy_loss: plan.accuracy_after(plan.n_stages) - plan.accuracy_after(next),
                battery_doomed,
            });
            slots.push(h);
            true
        });
        if !cands.is_empty() {
            self.metrics.pressure_events = self.metrics.pressure_events.saturating_add(1);
            let escalate = self.cfg.pressure_backlog > 0
                && self.tasks.len() >= self.cfg.pressure_backlog as usize;
            let d = self.sched.on_event(now, SchedEvent::Pressure { candidates: &cands, escalate });
            self.charge_control(d.ops);
            if let Outcome::Truncate { cuts } = d.outcome {
                for cut in cuts {
                    // Synchronous dispatch over a just-built survey: the
                    // index maps straight back to a live slot, and the
                    // cut targets a boundary still in the queue.
                    let h = slots[cut.index as usize];
                    if let Some(slot) = self.tasks.get_mut(h) {
                        slot.cut_stage = cut.at_stage;
                        self.metrics.pressure_cuts = self.metrics.pressure_cuts.saturating_add(1);
                    }
                }
            }
        }
        self.staged_execs = execs;
        self.pressure_cands = cands;
        self.pressure_slots = slots;
    }

    fn on_hp_finish(&mut self, h: SlotRef) {
        // A non-resolving handle is an event from a dead placement.
        let Some(slot) = self.tasks.get(h) else {
            self.queue.note_popped_stale();
            return;
        };
        let Some(rt) = slot.rt.as_ref() else {
            self.queue.note_popped_stale();
            return;
        };
        let frame = rt.alloc.frame;
        let (device, cfg_idx) = (rt.alloc.device, rt.alloc.config.index());
        let task_id = slot.task.id;
        let deadline = slot.task.deadline;
        let source = slot.task.source;
        let created_at = slot.task.created_at;
        self.energy_task_end(device, cfg_idx);
        if self.now > deadline {
            self.metrics.hp_violations = self.metrics.hp_violations.saturating_add(1);
            self.trace(TraceEvent::Complete {
                task: task_id,
                device,
                high_priority: true,
                violated: true,
            });
            self.trace(TraceEvent::Violation { task: task_id });
            self.sched.on_event(self.now, SchedEvent::Violation { task: task_id });
            self.fail_frame(frame);
            self.free_task(task_id);
            return;
        }
        self.metrics.hp_completed = self.metrics.hp_completed.saturating_add(1);
        self.trace(TraceEvent::Complete {
            task: task_id,
            device,
            high_priority: true,
            violated: false,
        });
        self.metrics.lat_hp_e2e.record(self.now - created_at);
        self.sched.on_event(self.now, SchedEvent::Complete { task: task_id });
        let (lp_expected, frame_deadline) = {
            let f = self.frame_mut(frame).expect("frame tracked");
            f.hp_done = true;
            (f.lp_expected, f.deadline)
        };
        // Stage 2 found recyclable waste: spawn the low-priority request.
        if lp_expected > 0 {
            let mut ids = IdBatch::new();
            let ladder = self.conveyor_ladder;
            for _ in 0..lp_expected {
                let id = self.fresh_task_id();
                let t = Task::low(id, frame, source, self.now, frame_deadline, &self.cfg);
                self.insert_task(t, ladder);
                ids.push(id);
            }
            self.metrics.lp_generated = self.metrics.lp_generated.saturating_add(lp_expected as u64);
            self.queue
                .push(self.now + self.cfg.control_latency(), Event::LpArrive { tasks: ids, realloc: false });
        }
        self.check_frame(frame);
        self.free_task(task_id);
    }

    // ---- low-priority path ---------------------------------------------

    /// Dispatch a batch-shaped event with slab borrows — no clones, and
    /// no allocation for batches up to twice the conveyor's inline cap
    /// (a stack array; larger generative batches borrow through one
    /// temporary `Vec`). Every id must be live: arrival/requeue/re-offer
    /// paths guarantee it. `realloc: Some(r)` dispatches
    /// [`SchedEvent::LowPriorityBatch`]; `None` dispatches
    /// [`SchedEvent::Reoffer`]. The event exposes the batch's remaining
    /// model-variant ladder (the tail from the tasks' current rung), so
    /// the scheduler's shared degradation policy can step down instead
    /// of rejecting; a returned `Decision::variant` is relative to that
    /// tail and applied through [`Engine::apply_variant`].
    fn dispatch_batch(
        &mut self,
        service_start: SimTime,
        ids: &[TaskId],
        realloc: Option<bool>,
    ) -> Decision {
        const STACK: usize = 2 * IdBatch::INLINE;
        // Battery-aware planning: refresh the scheduler's battery-level
        // snapshot before the batch lands. Free dispatch (the arms ack 0
        // ops, so it never touches `busy_until` or latency accounting),
        // and only battery-backed fleets take the path at all.
        if self.fleet.as_ref().map_or(false, |f| f.has_battery()) {
            let now = self.now;
            let mut levels = std::mem::take(&mut self.scratch_levels);
            if let Some(f) = self.fleet.as_mut() {
                f.settle_all(now);
                f.levels(&mut levels);
            }
            let _ =
                self.sched.on_event(service_start, SchedEvent::BatteryLevels { levels: &levels });
            self.scratch_levels = levels;
        }
        let first_slot = self.tasks.get(self.slot_of(ids[0])).expect("batch task live");
        let (lidx, cur_rung) = (first_slot.ladder as usize, first_slot.rung as usize);
        debug_assert!(
            ids.iter().all(|&id| {
                let s = self.tasks.get(self.slot_of(id)).expect("batch task live");
                (s.ladder as usize, s.rung as usize) == (lidx, cur_rung)
            }),
            "batch members must share one ladder and rung (one arrival = one class)"
        );
        let ladder: &[VariantRung] =
            if lidx == 0 { &[] } else { &self.ladders[lidx][cur_rung..] };
        let first = &first_slot.task;
        let mut stack: [&Task; STACK] = [first; STACK];
        let mut heap: Vec<&Task> = Vec::new();
        let tasks: &[&Task] = if ids.len() <= STACK {
            for (i, &id) in ids.iter().enumerate() {
                stack[i] = &self.tasks.get(self.slot_of(id)).expect("batch task live").task;
            }
            &stack[..ids.len()]
        } else {
            heap.reserve_exact(ids.len());
            for &id in ids {
                heap.push(&self.tasks.get(self.slot_of(id)).expect("batch task live").task);
            }
            &heap
        };
        let ev = match realloc {
            Some(realloc) => SchedEvent::LowPriorityBatch { tasks, realloc, ladder },
            None => SchedEvent::Reoffer { tasks, ladder },
        };
        let t0 = self.phase_start();
        let d = self.sched.on_event(service_start, ev);
        self.phase_end(t0, Phase::Sched);
        d
    }

    fn on_lp_arrive(&mut self, batch: IdBatch, realloc: bool) {
        debug_assert!(!batch.as_slice().is_empty(), "LpArrive batches are never empty");
        // Recovery-policy re-placements can race a hedge settlement: the
        // partner may have won (and freed this task) while the retry sat
        // in the queue. Dead ids are silently skipped — on the default
        // path every queued id is still live and this filter keeps all.
        let mut live = IdBatch::new();
        for &id in batch.as_slice() {
            if self.tasks.get(self.slot_of(id)).is_some() {
                live.push(id);
            }
        }
        if live.is_empty() {
            return;
        }
        let batch = live;
        let ids = batch.as_slice();
        let arrival = self.now;
        let service_start = self.busy_until.max(arrival);
        let Decision { outcome, ops, variant } = self.dispatch_batch(service_start, ids, Some(realloc));
        let (decision, lat) = self.charge(arrival, ops);
        if realloc {
            self.metrics.lat_lp_realloc.record(lat);
        } else {
            self.metrics.lat_lp_alloc.record(lat);
        }
        match outcome {
            Outcome::LpAllocated { allocs } => {
                // A degraded placement re-specs the tasks before the
                // transfer/start machinery reads them.
                self.apply_variant(ids, variant);
                self.place_lp_allocs(allocs, decision, realloc, false)
            }
            Outcome::LpRejected => {
                if !realloc {
                    self.metrics.lp_alloc_failures = self.metrics.lp_alloc_failures.saturating_add(batch.len() as u64);
                }
                self.trace(TraceEvent::LpReject { tasks: batch.len() });
                for &id in ids {
                    if self.hedge_dissolve_on_loss(id) {
                        continue;
                    }
                    let frame = self.task(id).frame;
                    self.metrics.lp_lost = self.metrics.lp_lost.saturating_add(1);
                    self.fail_frame(frame);
                    self.free_task(id);
                }
            }
            other => unreachable!("LP event must yield an LP outcome, got {other:?}"),
        }
    }

    /// Commit a batch of low-priority allocations decided at `decision`:
    /// counters, then either the transfer kick-off (offloads) or the
    /// local start. Shared by initial/realloc placement and crash
    /// re-offers.
    fn place_lp_allocs(&mut self, allocs: Vec<Allocation>, decision: SimTime, realloc: bool, reoffered: bool) {
        for alloc in allocs {
            if alloc.device >= self.cfg.n_devices {
                // Cloud placement: counted on its own axis — the core-mix
                // counters describe the edge fleet only, so the identity
                // becomes two + four + cloud = initial + realloc.
                self.metrics.cloud_offloads = self.metrics.cloud_offloads.saturating_add(1);
            } else {
                match alloc.config {
                    crate::coordinator::task::TaskConfig::LowTwoCore => self.metrics.two_core_allocs = self.metrics.two_core_allocs.saturating_add(1),
                    crate::coordinator::task::TaskConfig::LowFourCore => self.metrics.four_core_allocs = self.metrics.four_core_allocs.saturating_add(1),
                    _ => {}
                }
            }
            if realloc {
                self.metrics.lp_realloc_success = self.metrics.lp_realloc_success.saturating_add(1);
            } else {
                self.metrics.lp_allocated_initial = self.metrics.lp_allocated_initial.saturating_add(1);
            }
            if reoffered {
                self.metrics.crash_reoffer_placed = self.metrics.crash_reoffer_placed.saturating_add(1);
            }
            if self.tracing() {
                // The committed rung lives on the slab task (rewritten by
                // `apply_variant` before this commit path runs).
                let rung =
                    self.tasks.get(self.slot_of(alloc.task)).map_or(0, |s| s.rung as usize);
                self.trace(TraceEvent::LpPlace {
                    task: alloc.task,
                    device: alloc.device,
                    cores: alloc.cores as u8,
                    rung,
                });
            }
            if alloc.offloaded {
                self.metrics.offloaded_total = self.metrics.offloaded_total.saturating_add(1);
                // The device ships the input image when the
                // reserved communication window opens.
                let comm_start = alloc.comm.map(|(c1, _)| c1).unwrap_or(decision);
                let at = comm_start.max(decision + self.cfg.control_latency());
                let task = alloc.task;
                let (device, cfg_idx) = (alloc.device, alloc.config.index());
                let h = self.slot_of(task);
                let slot = self.tasks.get_mut(h).expect("placing a live task");
                slot.rt = Some(TaskRuntime { alloc, realloc, reoffered });
                let tries = slot.tries;
                let hedgeable = slot.hedge_of.is_none() && slot.hedged_by.is_none();
                self.queue.push(at, Event::TransferStart { task: h });
                // Recovery policy (both knobs default off — no events, no
                // behavior change): a per-placement timeout with
                // exponential backoff, and a hedged duplicate launch for
                // placements still unfinished past the hedge horizon.
                if self.cfg.offload_timeout_s > 0.0 {
                    let timeout = (self.cfg.offload_timeout_s * 1e6).round() as u64;
                    let deadline_at = at.saturating_add(timeout << tries.min(16));
                    self.queue.push(deadline_at, Event::OffloadTimeout { task: h });
                }
                if self.cfg.hedge_timeout_s > 0.0 && hedgeable {
                    let horizon = (self.cfg.hedge_timeout_s * 1e6).round() as u64;
                    self.queue.push(decision.saturating_add(horizon), Event::HedgeLaunch { task: h });
                }
                // Commitment powers the destination (a cloud destination
                // is mains powered and no-ops inside the integrator).
                self.energy_task_start(device, cfg_idx);
            } else {
                self.start_local(alloc, decision, realloc, reoffered);
            }
        }
    }

    fn on_transfer_start(&mut self, h: SlotRef) {
        let Some(slot) = self.tasks.get(h) else {
            self.queue.note_popped_stale();
            return;
        };
        let Some(rt) = slot.rt.as_ref() else {
            self.queue.note_popped_stale();
            return;
        };
        let (id, bytes) = (slot.task.id, slot.task.input_bytes);
        let (src, dst) = (slot.task.source, rt.alloc.device);
        if dst >= self.cfg.n_devices {
            // Cloud placement: the input rides the WAN uplink, not the
            // fleet's shared 802.11 medium.
            self.trace(TraceEvent::CloudUploadStart { task: id });
            if let Some(c) = self.cloud.as_mut() {
                c.begin_upload(self.now, id, bytes);
            }
            self.arm_wan();
        } else {
            self.trace(TraceEvent::TransferStart { task: id, device: dst });
            self.medium.add_flow(self.now, id, bytes);
            self.arm_medium();
        }
        // An endpoint behind a partition stalls the transfer on the spot
        // (the flow is added first so loss inflation draws stay on the
        // one shared code path, then pulled off the air until heal).
        if self.is_partitioned(src) || self.is_partitioned(dst) {
            self.stall_flow(id, dst);
        }
        // Radio power: tx on the source, rx on the destination (the
        // cloud side no-ops — it is not in the fleet).
        self.energy_transfer_start(src, dst);
    }

    fn on_lp_finish(&mut self, h: SlotRef) {
        self.finish_lp(h, u8::MAX);
    }

    /// Terminal LP delivery. `cut == u8::MAX` is the full-depth finish
    /// (the only case before stage plans existed); `cut == k` is a
    /// truncated completion landing on stage boundary k — the result
    /// delivers k stages' partial accuracy now instead of full accuracy
    /// later, and the dead tail of the event chain (the unfired
    /// boundaries plus the full-depth `LpFinish`) goes stale in place.
    fn finish_lp(&mut self, h: SlotRef, cut: u8) {
        let Some(slot) = self.tasks.get(h) else {
            self.queue.note_popped_stale();
            return;
        };
        let Some(rt) = slot.rt.as_ref() else {
            self.queue.note_popped_stale();
            return;
        };
        let (frame, offloaded, realloc, reoffered) =
            (rt.alloc.frame, rt.alloc.offloaded, rt.realloc, rt.reoffered);
        let (device, cfg_idx) = (rt.alloc.device, rt.alloc.config.index());
        let task_id = slot.task.id;
        let source = slot.task.source;
        let deadline = slot.task.deadline;
        let created_at = slot.task.created_at;
        let (lidx, rung) = (slot.ladder as usize, slot.rung as usize);
        let (hedge_of, hedged_by) = (slot.hedge_of, slot.hedged_by);
        // Partition hold: the compute finished but the result cannot
        // reach its source across the partition. The task stays live and
        // undelivered until the heal re-fires this event (the deadline is
        // re-checked then — a long partition turns the hold into a
        // violation). Local completions deliver locally, never held. A
        // truncated result remembers its cut so the heal re-delivers the
        // same partial depth.
        if offloaded && (self.is_partitioned(source) || self.is_partitioned(device)) {
            if !self.held_finishes.iter().any(|&(id, _)| id == task_id) {
                self.held_finishes.push((task_id, cut));
                self.metrics.partition_held_results = self.metrics.partition_held_results.saturating_add(1);
            }
            return;
        }
        let plan = self.stage_plan(lidx, rung);
        if cut != u8::MAX {
            // The execution ends here: the full-depth `LpFinish` and any
            // boundary past the cut are now dead weight for compaction.
            // (A held-then-healed cut over-counts — harmless: staleness
            // is a sweep heuristic, not accounting the audit checks.)
            self.queue.note_stale(plan.n_stages.saturating_sub(cut) as usize);
            self.trace(TraceEvent::Truncate { task: task_id, device, stage: cut });
        }
        self.energy_task_end(device, cfg_idx);
        if self.now > deadline {
            // Hedge settlement on a late finish: the partner may still
            // deliver in time, so a late half never fails the frame — it
            // hands the logical task to the survivor and exits silently.
            if let Some(primary) = hedge_of {
                self.metrics.hedges_wasted = self.metrics.hedges_wasted.saturating_add(1);
                let ph = self.slot_of(primary);
                if let Some(ps) = self.tasks.get_mut(ph) {
                    ps.hedged_by = None;
                }
                self.sched.on_event(self.now, SchedEvent::Violation { task: task_id });
                self.free_task(task_id);
                return;
            }
            if let Some(clone) = hedged_by {
                let ch = self.slot_of(clone);
                if let Some(cs) = self.tasks.get_mut(ch) {
                    cs.hedge_of = None;
                }
                self.sched.on_event(self.now, SchedEvent::Violation { task: task_id });
                self.free_task(task_id);
                return;
            }
            self.metrics.lp_violations = self.metrics.lp_violations.saturating_add(1);
            self.trace(TraceEvent::Complete {
                task: task_id,
                device,
                high_priority: false,
                violated: true,
            });
            self.trace(TraceEvent::Violation { task: task_id });
            self.sched.on_event(self.now, SchedEvent::Violation { task: task_id });
            self.fail_frame(frame);
            self.free_task(task_id);
            return;
        }
        // First-completion-wins duplicate suppression: exactly one half
        // of a hedge pair ever reaches the accounting below; the loser's
        // placement is cancelled without any completion/violation credit.
        if let Some(primary) = hedge_of {
            self.metrics.hedges_won = self.metrics.hedges_won.saturating_add(1);
            self.cancel_placement(primary);
            self.sched.on_event(self.now, SchedEvent::Violation { task: primary });
            self.free_task(primary);
        } else if let Some(clone) = hedged_by {
            self.metrics.hedges_wasted = self.metrics.hedges_wasted.saturating_add(1);
            self.cancel_placement(clone);
            self.sched.on_event(self.now, SchedEvent::Violation { task: clone });
            self.free_task(clone);
        }
        self.trace(TraceEvent::Complete {
            task: task_id,
            device,
            high_priority: false,
            violated: false,
        });
        self.metrics.lat_lp_e2e.record(self.now - created_at);
        if realloc {
            self.metrics.lp_completed_realloc = self.metrics.lp_completed_realloc.saturating_add(1);
        } else {
            self.metrics.lp_completed_initial = self.metrics.lp_completed_initial.saturating_add(1);
        }
        if offloaded {
            self.metrics.offloaded_completed = self.metrics.offloaded_completed.saturating_add(1);
            if device >= self.cfg.n_devices {
                // The three-tier acceptance metric: cloud placements
                // that actually delivered within deadline.
                self.metrics.cloud_completions = self.metrics.cloud_completions.saturating_add(1);
            }
        }
        // Delivered-accuracy accounting: a completion delivers its
        // rung's inference accuracy (1.0 for ladder-less tasks —
        // identical to an explicit one-rung ladder at accuracy 1.0, so
        // the no-degradation path stays byte-identical); a truncated
        // completion delivers the plan's cumulative credit through its
        // cut stage. Violations and drops deliver nothing and are never
        // counted here, so `accuracy_sum` is exactly the fleet's
        // delivered-inference ledger.
        let accuracy = if cut != u8::MAX {
            // Only deliveries that beat the deadline count as truncated
            // *completions* — a cut result healing in late is a plain
            // violation and was accounted above.
            self.metrics.truncated_completions =
                self.metrics.truncated_completions.saturating_add(1);
            self.metrics.stages_skipped = self
                .metrics
                .stages_skipped
                .saturating_add(plan.n_stages.saturating_sub(cut) as u64);
            plan.accuracy_after(cut)
        } else if lidx == 0 {
            1.0
        } else {
            self.ladders[lidx][rung].accuracy
        };
        self.metrics.accuracy_sum += accuracy;
        self.metrics.rung_completions[rung.min(MAX_RUNGS - 1)] += 1;
        if rung > 0 {
            self.metrics.degraded_completions = self.metrics.degraded_completions.saturating_add(1);
        }
        if reoffered {
            // A crash-lost task made it back inside its original deadline.
            self.metrics.crash_recovered_in_deadline = self.metrics.crash_recovered_in_deadline.saturating_add(1);
        }
        self.sched.on_event(self.now, SchedEvent::Complete { task: task_id });
        if let Some(f) = self.frame_mut(frame) {
            f.lp_done += 1;
        }
        self.check_frame(frame);
        self.free_task(task_id);
    }

    // ---- medium / probes / traffic --------------------------------------

    /// (Re-)arm the next medium completion event under the current epoch.
    fn arm_medium(&mut self) {
        let t0 = self.phase_start();
        if let Some((t, flow)) = self.medium.next_completion(self.now) {
            let epoch = self.medium.epoch;
            if self.armed_medium != u64::MAX && self.armed_medium != epoch {
                self.queue.note_stale(1); // superseded prediction
            }
            self.armed_medium = epoch;
            self.queue.push(t, Event::MediumComplete { flow, epoch });
        }
        self.phase_end(t0, Phase::Medium);
    }

    fn on_medium_complete(&mut self, flow: FlowId, epoch: u64) {
        if self.armed_medium == epoch {
            self.armed_medium = u64::MAX; // the tracked event left the queue
        }
        if epoch != self.medium.epoch {
            self.queue.note_popped_stale();
            return; // stale prediction; a newer event is armed
        }
        if !self.medium.complete_flow(self.now, flow) {
            self.arm_medium();
            return;
        }
        if flow >= PROBE_FLOW_BASE {
            self.on_probe_end(flow);
        } else {
            // Transfer done: the offloaded task may start processing.
            let h = self.slot_of(flow);
            let placed = self
                .tasks
                .get(h)
                .and_then(|s| s.rt.as_ref().map(|rt| (rt.alloc, s.task.source)));
            if let Some((alloc, source)) = placed {
                let eff_start = alloc.start.max(self.now);
                let proc = self.actual_duration(&alloc);
                self.trace(TraceEvent::TransferDone { task: flow });
                self.trace_at(eff_start, TraceEvent::ExecStart { task: flow, device: alloc.device });
                self.begin_lp_exec(h, eff_start, proc);
                self.energy_transfer_end(source, alloc.device);
            }
        }
        self.arm_medium();
    }

    // ---- cloud tier ------------------------------------------------------

    /// (Re-)arm the next WAN upload completion under the WAN epoch.
    fn arm_wan(&mut self) {
        let Some(c) = self.cloud.as_mut() else { return };
        if let Some((t, flow)) = c.next_completion(self.now) {
            let epoch = c.wan.epoch;
            if self.armed_wan != u64::MAX && self.armed_wan != epoch {
                self.queue.note_stale(1); // superseded prediction
            }
            self.armed_wan = epoch;
            self.queue.push(t, Event::WanComplete { flow, epoch });
        }
    }

    /// A cloud upload is predicted complete. On a genuine completion the
    /// task runs for its *deterministic* `cloud_us` service time (the
    /// cloud tier is not a jittery Raspberry Pi — and crucially, this
    /// path draws no RNG, so enabling the cloud perturbs nothing else),
    /// finishing one WAN round-trip plus the service time later. The
    /// refreshed goodput EWMA goes back to the schedulers as a zero-cost
    /// [`SchedEvent::CloudBandwidthUpdate`].
    fn on_wan_complete(&mut self, flow: FlowId, epoch: u64) {
        if self.armed_wan == epoch {
            self.armed_wan = u64::MAX; // the tracked event left the queue
        }
        let now = self.now;
        let Some(c) = self.cloud.as_mut() else { return };
        if epoch != c.wan.epoch {
            self.queue.note_popped_stale();
            return; // stale prediction; a newer event is armed
        }
        let rtt_us = c.rtt_us;
        let completed = c.complete_upload(now, flow);
        let bps = c.estimate_bps();
        if completed.is_none() {
            self.arm_wan();
            return;
        }
        let h = self.slot_of(flow);
        let done = self
            .tasks
            .get(h)
            .and_then(|s| s.rt.as_ref().map(|rt| (rt.alloc.device, s.task.source, s.task.cloud_us)));
        if let Some((device, source, cloud_us)) = done {
            self.trace(TraceEvent::CloudUploadDone { task: flow });
            // The source's radio goes quiet the moment the upload lands.
            self.energy_transfer_end(source, device);
            self.queue.push(now + rtt_us + cloud_us, Event::LpFinish { task: h });
        }
        let _ = self.sched.on_event(now, SchedEvent::CloudBandwidthUpdate { bps });
        self.arm_wan();
    }

    fn on_probe_start(&mut self) {
        if self.now > self.end_of_input {
            return; // drain phase: no new probes
        }
        // Probing runs over the devices that are actually in the fleet:
        // a departed device neither hosts a round nor answers pings.
        // (With the full fleet active this draws the exact same RNG value
        // as indexing 0..n_devices — the default path stays bit-identical.)
        // The device list is a scratch buffer reused across rounds.
        let mut active = std::mem::take(&mut self.scratch_devices);
        active.clear();
        // Partitioned devices are unreachable: they cannot host or answer
        // pings (with no partitions scheduled this filter keeps everyone
        // and the host draw is unchanged).
        active.extend(
            (0..self.active_devices.len())
                .filter(|&d| self.active_devices[d] && !self.is_partitioned(d)),
        );
        let host = if active.len() >= 2 {
            Some((active[self.rng.index(active.len())], active.len()))
        } else {
            None
        };
        self.scratch_devices = active;
        let Some((host, n_active)) = host else {
            // Nobody to ping: skip the round but keep the clock running.
            self.queue.push(self.now + self.estimator.interval, Event::ProbeStart);
            return;
        };
        // A random device hosts the round (Section V) and pings every
        // other device: ping_count × (n−1) × 1400 B, out and back.
        // Under probe loss some pings never make it back; the round's
        // airtime (and sample count) shrinks with them. A fully lost
        // round is a probe failure: no traffic, no estimator update — but
        // the attempt still consumes its slot in the probe cadence.
        let pings = self.cfg.ping_count as u64 * (n_active as u64 - 1);
        let survivors = self.medium.probe_survivors(pings);
        self.metrics.probe_pings_lost = self.metrics.probe_pings_lost.saturating_add(pings - survivors);
        if survivors == 0 {
            self.trace(TraceEvent::ProbeStart { device: host });
            self.trace(TraceEvent::ProbeEnd { device: host, survivors: 0 });
            self.metrics.probe_rounds_lost = self.metrics.probe_rounds_lost.saturating_add(1);
            let was_stale = self.estimator.is_stale(self.now);
            let _ = self.estimator.apply(self.now, &ProbeRound { host, samples_bps: vec![] });
            if !was_stale && self.estimator.is_stale(self.now) {
                self.emit_bandwidth_stale();
            }
            // A fully lost round reaches nobody: every expected heartbeat
            // is a miss — the detector's false-positive mechanism (the
            // lost round is seed-deterministic through the probe-loss
            // RNG, so false suspicions replay exactly).
            self.feed_detector(false);
            self.queue.push(self.now + self.estimator.interval, Event::ProbeStart);
            return;
        }
        // The surviving round will reach every reachable device; devices
        // that are down (crashed) or unreachable (partitioned) miss their
        // heartbeat either way.
        self.feed_detector(true);
        // Payload of the surviving round (out + back per ping), inflated
        // by the small-frame airtime factor — the medium is occupied for
        // much longer than the raw bytes suggest.
        let bytes =
            (survivors * self.cfg.ping_bytes * 2) as f64 * self.cfg.probe_airtime_factor;
        let bytes = bytes as u64;
        let id = self.next_probe_id;
        self.next_probe_id += 1;
        self.trace(TraceEvent::ProbeStart { device: host });
        self.probes.push((id, ProbeFlight { started: self.now, bytes, host, survivors }));
        self.medium.add_flow(self.now, id, bytes);
        self.arm_medium();
        // Next round is interval-periodic regardless of this round's
        // duration (the paper's fixed invocation rate).
        self.queue.push(self.now + self.estimator.interval, Event::ProbeStart);
    }

    fn on_probe_end(&mut self, flow: FlowId) {
        let Some(pos) = self.probes.iter().position(|(f, _)| *f == flow) else { return };
        let (_, p) = self.probes.swap_remove(pos);
        let dur_us = (self.now - p.started).max(1);
        // Achieved throughput of the probe flow — pings measured the
        // *contended* share, exactly like the paper's RTT-derived samples.
        // The airtime the probe flow achieved per second of wall time *is*
        // the share a bulk transfer would get — exactly what the devices'
        // RTT→b/s conversion estimates (an idle link reads as the full
        // link rate; a congested one as the contended share). The
        // estimator folds the round *mean*, so per-ping multiplicity is
        // immaterial — one sample carries it; a partial round under probe
        // loss differs only through its shrunken airtime (and the
        // survivor counts already tracked in the metrics).
        let achieved_bps = p.bytes as f64 * 8.0 / (dur_us as f64 / 1e6);
        let round = ProbeRound { host: p.host, samples_bps: vec![achieved_bps] };
        self.trace(TraceEvent::ProbeEnd { device: p.host, survivors: p.survivors });
        let was_stale = self.estimator.is_stale(self.now);
        if let Some(new_est) = self.estimator.apply(self.now, &round) {
            self.metrics.bandwidth_updates = self.metrics.bandwidth_updates.saturating_add(1);
            self.trace(TraceEvent::BandwidthUpdate { est_bps: new_est });
            // The scheduler rebuilds its link representation; the
            // controller is busy for the duration (no allocations can be
            // made while the data structure regenerates).
            let ops = self
                .sched
                .on_event(self.now, SchedEvent::BandwidthUpdate { bps: new_est })
                .ops;
            self.metrics.link_rebuild_ops = self.metrics.link_rebuild_ops.saturating_add(ops);
            let proc = (ops as f64 * self.cfg.op_cost_us).round() as SimDuration;
            self.busy_until = self.busy_until.max(self.now) + proc;
            self.metrics.controller_busy_us = self.metrics.controller_busy_us.saturating_add(proc);
        }
        if !was_stale && self.estimator.is_stale(self.now) {
            self.emit_bandwidth_stale();
        }
    }

    fn on_traffic_toggle(&mut self, active: bool) {
        if self.now > self.end_of_input {
            self.medium.set_background(self.now, false);
            self.traffic_on = false;
            return;
        }
        if self.duty_cycle <= 0.0 {
            // A regime change turned the generator off: let the chain die.
            self.medium.set_background(self.now, false);
            self.traffic_on = false;
            return;
        }
        self.medium.set_background(self.now, active);
        self.arm_medium();
        let period = self.cfg.bandwidth_interval();
        let duty = self.duty_cycle.clamp(0.0, 1.0);
        if active {
            // Burst lasts duty × period, then the line goes quiet.
            let on_for = (period as f64 * duty).round() as SimDuration;
            self.queue.push(self.now + on_for, Event::TrafficToggle { active: false });
        } else {
            // Quiet for (1 − duty) × period on average, with ±50 % phase
            // jitter: real background traffic is not phase-locked to the
            // controller's probe clock, and without the jitter every probe
            // would sample the exact same point of the burst cycle.
            let off_base = (period as f64 * (1.0 - duty)).max(1.0);
            let off_for = (off_base * (0.5 + self.rng.gen_f64())).round() as SimDuration;
            self.queue.push(self.now + off_for.max(1), Event::TrafficToggle { active: true });
        }
    }

    // ---- scenario schedule: churn + congestion regimes -------------------

    fn on_device_join(&mut self, device: DeviceId) {
        while self.active_devices.len() <= device {
            self.active_devices.push(false);
            self.device_speed.push(1.0);
        }
        if self.active_devices[device] {
            return; // already in the fleet
        }
        self.active_devices[device] = true;
        self.metrics.churn_joins = self.metrics.churn_joins.saturating_add(1);
        self.trace(TraceEvent::DeviceJoin { device });
        // A (re-)join is announced: any stale suspicion resets silently
        // (the join path clears it scheduler-side too).
        let _ = self.detector.heartbeat(device);
        self.energy_set_online(device, true);
        let _ = self.sched.on_event(self.now, SchedEvent::DeviceJoined { device });
    }

    fn on_device_leave(&mut self, device: DeviceId) {
        if !self.device_active(device) {
            return;
        }
        self.active_devices[device] = false;
        self.metrics.churn_leaves = self.metrics.churn_leaves.saturating_add(1);
        self.trace(TraceEvent::DeviceLeave { device });
        // Settle the departing device's draw first: eviction hooks below
        // then no-op on it (its run counters are force-cleared) while
        // still releasing live counterparts on surviving devices.
        self.energy_set_online(device, false);
        let decision = self.sched.on_event(self.now, SchedEvent::DeviceLeft { device });
        let Outcome::Ack { evicted } = decision.outcome else {
            unreachable!("DeviceLeft must be acknowledged");
        };
        for a in evicted {
            self.cancel_placement(a.task);
            self.metrics.churn_evicted = self.metrics.churn_evicted.saturating_add(1);
            let source = self.task(a.task).source;
            let hp = a.config == crate::coordinator::task::TaskConfig::HighPriority;
            if hp || source == device || !self.device_active(source) {
                // The task (or the device holding its input image) is
                // gone: the frame cannot complete.
                if self.hedge_dissolve_on_loss(a.task) {
                    continue;
                }
                if !hp {
                    self.metrics.lp_lost = self.metrics.lp_lost.saturating_add(1);
                }
                self.fail_frame(a.frame);
                self.free_task(a.task);
            } else {
                // Guest task on the departed device: its source still has
                // the input, so it re-enters low-priority scheduling like a
                // preemption victim.
                self.metrics.lp_realloc_attempts = self.metrics.lp_realloc_attempts.saturating_add(1);
                self.queue.push(
                    self.now + self.cfg.control_latency(),
                    Event::LpArrive { tasks: IdBatch::one(a.task), realloc: true },
                );
            }
        }
    }

    // ---- fault injection: crashes, recoveries, re-offers -----------------

    /// A device crashes: unlike a graceful leave, everything it was
    /// running is *lost* — flows aborted on the medium, no completions.
    /// Lost guest tasks whose source (and its input image) survive are
    /// re-offered to the scheduler on their remaining deadline budget.
    fn on_device_crash(&mut self, device: DeviceId) {
        if !self.device_active(device) {
            return; // already down (or never joined): nothing to lose
        }
        self.active_devices[device] = false;
        self.metrics.device_crashes = self.metrics.device_crashes.saturating_add(1);
        self.trace(TraceEvent::DeviceCrash { device });
        if self.crashed_at.len() <= device {
            self.crashed_at.resize(device + 1, None);
        }
        self.crashed_at[device] = Some(self.now);
        if let Some(x) = self.down_since.get_mut(device) {
            x.get_or_insert(self.now);
        }
        self.energy_set_online(device, false);
        let decision = self.sched.on_event(self.now, SchedEvent::DeviceCrashed { device });
        let Outcome::Ack { evicted } = decision.outcome else {
            unreachable!("DeviceCrashed must be acknowledged");
        };
        for a in evicted {
            self.cancel_placement(a.task); // aborts the medium flow too
            self.metrics.crash_tasks_lost = self.metrics.crash_tasks_lost.saturating_add(1);
            let source = self.task(a.task).source;
            let hp = a.config == crate::coordinator::task::TaskConfig::HighPriority;
            if hp || source == device || !self.device_active(source) {
                // The work (or the device holding its input image) died
                // with the crash: the frame cannot complete.
                if self.hedge_dissolve_on_loss(a.task) {
                    continue;
                }
                if !hp {
                    self.metrics.lp_lost = self.metrics.lp_lost.saturating_add(1);
                }
                self.fail_frame(a.frame);
                self.free_task(a.task);
            } else {
                // The source still holds the input: re-offer the lost
                // task. Its deadline is unchanged — the time burned
                // before the crash is gone for good.
                self.metrics.crash_tasks_reoffered = self.metrics.crash_tasks_reoffered.saturating_add(1);
                self.metrics.lp_realloc_attempts = self.metrics.lp_realloc_attempts.saturating_add(1);
                self.queue.push(
                    self.now + self.cfg.control_latency(),
                    Event::Reoffer { tasks: IdBatch::one(a.task) },
                );
            }
        }
        // In-flight input transfers *from* the crashed device die with
        // it: a guest task placed elsewhere whose image was still
        // crossing the medium can never start. The medium's flow table is
        // id-sorted, so iterating it visits orphans in ascending TaskId
        // order — no sort needed (determinism assertion below).
        let mut orphans = std::mem::take(&mut self.scratch_orphans);
        orphans.clear();
        for id in self.medium.flow_ids() {
            if id >= PROBE_FLOW_BASE {
                break; // probe flows are namespaced above all task ids
            }
            let Some(slot) = self.tasks.get(self.slot_of(id)) else { continue };
            let Some(rt) = slot.rt.as_ref() else { continue };
            if rt.alloc.offloaded && rt.alloc.device != device && slot.task.source == device {
                orphans.push((id, rt.alloc.frame));
            }
        }
        debug_assert!(
            orphans.windows(2).all(|w| w[0].0 < w[1].0),
            "crash orphan scan must visit tasks in ascending id order (determinism)"
        );
        for &(id, frame) in orphans.iter() {
            self.cancel_placement(id);
            // Free the placement the scheduler still holds for it.
            let _ = self.sched.on_event(self.now, SchedEvent::Violation { task: id });
            if self.hedge_dissolve_on_loss(id) {
                continue;
            }
            self.metrics.crash_tasks_lost = self.metrics.crash_tasks_lost.saturating_add(1);
            self.metrics.lp_lost = self.metrics.lp_lost.saturating_add(1);
            self.fail_frame(frame);
            self.free_task(id);
        }
        // In-flight *cloud* uploads from the crashed device die the same
        // way (the WAN flow table is id-sorted too, so the scan is
        // deterministic for the same reason as above).
        orphans.clear();
        if let Some(c) = self.cloud.as_ref() {
            for id in c.upload_ids() {
                let Some(slot) = self.tasks.get(self.slot_of(id)) else { continue };
                let Some(rt) = slot.rt.as_ref() else { continue };
                if slot.task.source == device {
                    orphans.push((id, rt.alloc.frame));
                }
            }
        }
        for &(id, frame) in orphans.iter() {
            self.cancel_placement(id); // aborts the WAN upload too
            let _ = self.sched.on_event(self.now, SchedEvent::Violation { task: id });
            if self.hedge_dissolve_on_loss(id) {
                continue;
            }
            self.metrics.crash_tasks_lost = self.metrics.crash_tasks_lost.saturating_add(1);
            self.metrics.lp_lost = self.metrics.lp_lost.saturating_add(1);
            self.fail_frame(frame);
            self.free_task(id);
        }
        orphans.clear();
        self.scratch_orphans = orphans;
        // Held results and stalled transfers whose *source* crashed die
        // too (entries touching the crashed compute device were already
        // purged through the scheduler eviction above).
        self.kill_partition_remnants_of(device);
    }

    /// A crashed device comes back with fresh, empty availability. Only
    /// devices that actually crashed recover: a `recover_at` with no
    /// preceding crash (e.g. the crash no-oped because the device had
    /// already gracefully left) is a no-op, never a spurious revival —
    /// graceful returns go through `join_at`.
    fn on_device_recover(&mut self, device: DeviceId) {
        if self.fleet.as_ref().map_or(false, |f| f.depleted(device)) {
            return; // a drained battery never comes back
        }
        let Some(crashed) = self.crashed_at.get_mut(device).and_then(Option::take) else {
            return; // no crash on record: nothing to recover from
        };
        if self.device_active(device) {
            return; // already revived (a graceful join beat the recovery)
        }
        self.active_devices[device] = true;
        self.metrics.device_recoveries = self.metrics.device_recoveries.saturating_add(1);
        self.trace(TraceEvent::DeviceRecover { device });
        self.metrics.lat_crash_recovery.record(self.now - crashed);
        // `DeviceRecovered` already re-admits the device scheduler-side
        // (it routes through the join path, which drops any suspicion),
        // so the detector resets silently — no separate `DeviceCleared`.
        let _ = self.detector.heartbeat(device);
        self.refresh_down(device);
        self.energy_set_online(device, true);
        let _ = self.sched.on_event(self.now, SchedEvent::DeviceRecovered { device });
    }

    /// Crash-lost tasks re-enter scheduling. The scheduler re-places them
    /// on whatever deadline budget remains or rejects (drop-by-deadline);
    /// tasks whose frame already failed are dropped without a dispatch.
    fn on_reoffer(&mut self, batch: IdBatch) {
        let mut live = IdBatch::new();
        for &id in batch.as_slice() {
            // The task may have settled since the re-offer was queued
            // (its hedge partner won): dead ids are skipped silently.
            if self.tasks.get(self.slot_of(id)).is_none() {
                continue;
            }
            let (frame, source) = {
                let t = self.task(id);
                (t.frame, t.source)
            };
            let frame_alive = self
                .frames
                .get(frame as usize)
                .map(|f| f.tracked && !f.failed)
                .unwrap_or(false);
            if frame_alive && self.device_active(source) {
                live.push(id);
            } else {
                if self.hedge_dissolve_on_loss(id) {
                    continue;
                }
                self.metrics.crash_reoffer_dropped = self.metrics.crash_reoffer_dropped.saturating_add(1);
                self.metrics.lp_lost = self.metrics.lp_lost.saturating_add(1);
                if frame_alive {
                    // The source (and its input image) died between the
                    // crash and the re-offer: the frame can never finish.
                    self.fail_frame(frame);
                }
                self.free_task(id);
            }
        }
        if live.is_empty() {
            return;
        }
        self.trace(TraceEvent::Reoffer { tasks: live.as_slice().len() });
        let ids = live.as_slice();
        let arrival = self.now;
        let service_start = self.busy_until.max(arrival);
        let Decision { outcome, ops, variant } = self.dispatch_batch(service_start, ids, None);
        let (decision, lat) = self.charge(arrival, ops);
        self.metrics.lat_lp_realloc.record(lat);
        match outcome {
            Outcome::LpAllocated { allocs } => {
                self.apply_variant(ids, variant);
                self.place_lp_allocs(allocs, decision, true, true)
            }
            Outcome::LpRejected => {
                self.trace(TraceEvent::LpReject { tasks: ids.len() });
                for &id in ids {
                    if self.hedge_dissolve_on_loss(id) {
                        continue;
                    }
                    self.metrics.crash_reoffer_dropped = self.metrics.crash_reoffer_dropped.saturating_add(1);
                    self.metrics.lp_lost = self.metrics.lp_lost.saturating_add(1);
                    let frame = self.task(id).frame;
                    self.fail_frame(frame);
                    self.free_task(id);
                }
            }
            other => unreachable!("Reoffer must yield an LP outcome, got {other:?}"),
        }
    }

    fn on_regime_change(&mut self, bg_bps: f64, duty: f64) {
        self.medium.set_background_rate(self.now, bg_bps);
        self.arm_medium();
        self.duty_cycle = duty;
        if duty > 0.0 && !self.traffic_on && self.now <= self.end_of_input {
            // Revive the toggle chain (it dies whenever duty drops to 0).
            self.traffic_on = true;
            self.queue.push(self.now, Event::TrafficToggle { active: true });
        }
        if duty <= 0.0 {
            self.medium.set_background(self.now, false);
            self.arm_medium();
        }
    }

    // ---- robustness: partitions, failure detection, recovery policy ------
    //
    // Everything below is gated behind the PR 8 knobs (all default off)
    // or behind partition schedules (default empty): the zero-knob path
    // pushes no events, makes no RNG draws, and dispatches no scheduler
    // events — byte-identical output to the oracle-only engine.

    fn is_partitioned(&self, device: DeviceId) -> bool {
        self.partitioned.get(device).copied().unwrap_or(false)
    }

    /// Clear the outage timestamp once the device is both alive and
    /// reachable again (crash and partition can overlap; the timestamp
    /// tracks the earliest still-active outage).
    fn refresh_down(&mut self, device: DeviceId) {
        if self.device_active(device) && !self.is_partitioned(device) {
            if let Some(x) = self.down_since.get_mut(device) {
                *x = None;
            }
        }
    }

    /// Charge scheduler ops incurred outside a placement call (suspicion
    /// fan-out, staleness rebuilds) to the controller's single server.
    fn charge_control(&mut self, ops: Ops) {
        let proc = (ops as f64 * self.cfg.op_cost_us).round() as SimDuration;
        self.busy_until = self.busy_until.max(self.now) + proc;
        self.metrics.controller_busy_us = self.metrics.controller_busy_us.saturating_add(proc);
    }

    /// The estimator crossed into staleness: the schedulers switch to
    /// conservative planning until the next successful probe round.
    fn emit_bandwidth_stale(&mut self) {
        self.trace(TraceEvent::BandwidthStale);
        let ops = self.sched.on_event(self.now, SchedEvent::BandwidthStale).ops;
        self.metrics.link_rebuild_ops = self.metrics.link_rebuild_ops.saturating_add(ops);
        self.charge_control(ops);
    }

    /// Feed one probe round's evidence to the suspicion detector.
    /// `delivered` = the round survived probe loss (its pings will reach
    /// every reachable device). Devices that are crashed or partitioned
    /// answer nothing either way; gracefully departed devices deregister
    /// and owe no heartbeat. Heartbeats are credited at round start —
    /// one probe interval of granularity, deterministic and cheap.
    fn feed_detector(&mut self, delivered: bool) {
        if !self.detector.enabled() {
            return;
        }
        for d in 0..self.cfg.n_devices {
            let reachable = self.device_active(d) && !self.is_partitioned(d);
            if reachable && delivered {
                if self.detector.heartbeat(d) {
                    self.metrics.devices_cleared = self.metrics.devices_cleared.saturating_add(1);
                    self.trace(TraceEvent::DetectorClear { device: d });
                    let ops =
                        self.sched.on_event(self.now, SchedEvent::DeviceCleared { device: d }).ops;
                    self.charge_control(ops);
                }
            } else {
                // Gracefully departed devices deregistered: no heartbeat
                // owed. Everyone else (crashed, partitioned, or unlucky
                // in a fully lost round) missed one.
                let deregistered = !self.device_active(d)
                    && self.crashed_at.get(d).map_or(true, |c| c.is_none())
                    && !self.is_partitioned(d);
                if !deregistered {
                    self.note_miss(d);
                }
            }
        }
    }

    /// One missed heartbeat: escalate belief and fan out a suspicion the
    /// moment the threshold trips. A suspicion of a genuinely down
    /// device records its detection lag; one of a live device is a false
    /// positive (probe loss) — the work it strands is the detector's
    /// accuracy cost.
    fn note_miss(&mut self, device: DeviceId) {
        match self.detector.miss(device) {
            Some(Belief::Suspected) => {
                self.metrics.devices_suspected = self.metrics.devices_suspected.saturating_add(1);
                self.trace(TraceEvent::DetectorSuspect { device, confirmed: false });
                match self.down_since.get(device).copied().flatten() {
                    Some(since) => self.metrics.lat_detection.record(self.now - since),
                    None => self.metrics.false_suspicions = self.metrics.false_suspicions.saturating_add(1),
                }
                let ops = self
                    .sched
                    .on_event(self.now, SchedEvent::DeviceSuspected { device })
                    .ops;
                self.charge_control(ops);
            }
            // Confirmation is a metrics-grade escalation only: the
            // scheduler already stopped placing at suspicion.
            Some(Belief::Confirmed) => {
                self.trace(TraceEvent::DetectorSuspect { device, confirmed: true });
            }
            Some(Belief::Alive) | None => {}
        }
    }

    /// Pull a task's in-flight transfer off the air (LAN or WAN),
    /// preserving the bits already delivered for the heal-time resume.
    fn stall_flow(&mut self, id: TaskId, dst: DeviceId) {
        if dst >= self.cfg.n_devices {
            let bits = self
                .cloud
                .as_mut()
                .and_then(|c| c.wan.remaining_bits(self.now, id))
                .unwrap_or(0.0);
            if self.cloud.as_mut().map_or(false, |c| c.abort_upload(self.now, id)) {
                self.stalled_flows.push((id, bits));
                self.metrics.partition_stalled_flows = self.metrics.partition_stalled_flows.saturating_add(1);
                self.arm_wan();
            }
        } else if let Some(bits) = self.medium.remaining_bits(self.now, id) {
            self.medium.remove_flow(self.now, id);
            self.stalled_flows.push((id, bits));
            self.metrics.partition_stalled_flows = self.metrics.partition_stalled_flows.saturating_add(1);
            self.arm_medium();
        }
    }

    /// A device becomes unreachable-but-alive: flows touching it stall
    /// (bits preserved), its in-progress compute keeps running, and any
    /// result it finishes is held undeliverable until the heal.
    fn on_partition_start(&mut self, device: DeviceId) {
        if device >= self.partitioned.len() || self.partitioned[device] {
            return; // unknown device or already partitioned
        }
        if !self.device_active(device) {
            return; // already down: a crash dominates a partition
        }
        self.partitioned[device] = true;
        self.metrics.partitions_started = self.metrics.partitions_started.saturating_add(1);
        self.trace(TraceEvent::PartitionStart { device });
        if let Some(x) = self.down_since.get_mut(device) {
            x.get_or_insert(self.now);
        }
        // Stall every LAN task flow with an endpoint behind the
        // partition. The flow table is id-sorted, so the scan visits
        // tasks in ascending id order (determinism, as in the crash
        // orphan scan).
        let mut hit: Vec<(TaskId, DeviceId)> = Vec::new();
        for id in self.medium.flow_ids() {
            if id >= PROBE_FLOW_BASE {
                break;
            }
            let Some(slot) = self.tasks.get(self.slot_of(id)) else { continue };
            let Some(rt) = slot.rt.as_ref() else { continue };
            if slot.task.source == device || rt.alloc.device == device {
                hit.push((id, rt.alloc.device));
            }
        }
        // WAN uploads *from* the partitioned device stall the same way.
        if let Some(c) = self.cloud.as_ref() {
            for id in c.upload_ids() {
                let Some(slot) = self.tasks.get(self.slot_of(id)) else { continue };
                let Some(rt) = slot.rt.as_ref() else { continue };
                if slot.task.source == device {
                    hit.push((id, rt.alloc.device));
                }
            }
        }
        for (id, dst) in hit {
            self.stall_flow(id, dst);
        }
    }

    /// The partition heals: stalled flows whose endpoints are all
    /// reachable again resume with their remaining bits, and held
    /// results re-fire their finish (deadline re-checked there).
    fn on_partition_heal(&mut self, device: DeviceId) {
        if device >= self.partitioned.len() || !self.partitioned[device] {
            return;
        }
        self.partitioned[device] = false;
        self.metrics.partitions_healed = self.metrics.partitions_healed.saturating_add(1);
        self.trace(TraceEvent::PartitionHeal { device });
        self.refresh_down(device);
        let stalled = std::mem::take(&mut self.stalled_flows);
        let mut keep = Vec::new();
        let (mut resumed_lan, mut resumed_wan) = (false, false);
        for (id, bits) in stalled {
            let Some(slot) = self.tasks.get(self.slot_of(id)) else { continue };
            let Some(rt) = slot.rt.as_ref() else { continue };
            let (src, dst) = (slot.task.source, rt.alloc.device);
            if self.is_partitioned(src) || self.is_partitioned(dst) {
                keep.push((id, bits)); // still cut off by another partition
                continue;
            }
            let bytes = (bits / 8.0).ceil() as u64;
            if dst >= self.cfg.n_devices {
                if let Some(c) = self.cloud.as_mut() {
                    c.begin_upload(self.now, id, bytes);
                    resumed_wan = true;
                }
            } else {
                // Raw `Medium` re-add through the deref: the stalled bits
                // already carry their loss inflation from the original
                // `add_flow` — re-inflating (and re-drawing the loss RNG)
                // would double-count it.
                let m: &mut Medium = &mut self.medium;
                m.add_flow(self.now, id, bytes);
                resumed_lan = true;
            }
        }
        self.stalled_flows = keep;
        if resumed_lan {
            self.arm_medium();
        }
        if resumed_wan {
            self.arm_wan();
        }
        let held = std::mem::take(&mut self.held_finishes);
        let mut keep = Vec::new();
        for (id, cut) in held {
            let h = self.slot_of(id);
            let Some(slot) = self.tasks.get(h) else { continue };
            let Some(rt) = slot.rt.as_ref() else { continue };
            let (src, dst) = (slot.task.source, rt.alloc.device);
            if self.is_partitioned(src) || self.is_partitioned(dst) {
                keep.push((id, cut));
            } else if cut == u8::MAX {
                self.queue.push(self.now, Event::LpFinish { task: h });
            } else {
                // A truncated result re-delivers through its boundary so
                // the same cut (and its partial accuracy) lands; the
                // slot's armed `cut_stage` routes it back to `finish_lp`.
                self.queue.push(self.now, Event::LpStageBoundary { task: h, stage: cut });
            }
        }
        self.held_finishes = keep;
    }

    /// Held results and stalled transfers whose source (input image and
    /// result consumer) crashed can never deliver: lose them now so the
    /// slab drains. Entries whose *compute* device crashed were already
    /// purged via the scheduler eviction in the crash path.
    fn kill_partition_remnants_of(&mut self, device: DeviceId) {
        let mut doomed: Vec<TaskId> = Vec::new();
        for &(id, _) in self.held_finishes.iter() {
            if let Some(slot) = self.tasks.get(self.slot_of(id)) {
                if slot.task.source == device {
                    doomed.push(id);
                }
            }
        }
        for &(id, _) in self.stalled_flows.iter() {
            if let Some(slot) = self.tasks.get(self.slot_of(id)) {
                if slot.task.source == device {
                    doomed.push(id);
                }
            }
        }
        for id in doomed {
            let frame = self
                .tasks
                .get(self.slot_of(id))
                .and_then(|s| s.rt.as_ref().map(|rt| rt.alloc.frame));
            let _ = self.sched.on_event(self.now, SchedEvent::Violation { task: id });
            self.cancel_placement(id); // purges the held/stalled record
            if self.hedge_dissolve_on_loss(id) {
                continue;
            }
            self.metrics.crash_tasks_lost = self.metrics.crash_tasks_lost.saturating_add(1);
            self.metrics.lp_lost = self.metrics.lp_lost.saturating_add(1);
            if let Some(f) = frame {
                self.fail_frame(f);
            }
            self.free_task(id);
        }
    }

    /// Post-drain sweep: a partition that never healed leaves held
    /// results and stalled transfers behind — they are lost, and the
    /// slab must still come out empty (the chaos campaign's invariant).
    fn flush_partition_remnants(&mut self) {
        let held = std::mem::take(&mut self.held_finishes);
        let stalled = std::mem::take(&mut self.stalled_flows);
        for id in held.into_iter().map(|(id, _)| id).chain(stalled.into_iter().map(|(id, _)| id)) {
            let Some(slot) = self.tasks.get(self.slot_of(id)) else { continue };
            let frame = slot.rt.as_ref().map(|rt| rt.alloc.frame);
            let _ = self.sched.on_event(self.now, SchedEvent::Violation { task: id });
            self.cancel_placement(id);
            if self.hedge_dissolve_on_loss(id) {
                continue;
            }
            self.metrics.lp_lost = self.metrics.lp_lost.saturating_add(1);
            if let Some(f) = frame {
                self.fail_frame(f);
            }
            self.free_task(id);
        }
    }

    /// If `task` is half of a hedge pair, dissolve the pair: the partner
    /// carries the logical task alone from here, and `task` is freed
    /// with no frame/loss accounting (exactly one half may ever reach a
    /// terminal counter). Returns whether the dissolution happened.
    fn hedge_dissolve_on_loss(&mut self, task: TaskId) -> bool {
        let Some(slot) = self.tasks.get(self.slot_of(task)) else { return false };
        let (hedge_of, hedged_by) = (slot.hedge_of, slot.hedged_by);
        let Some(partner) = hedge_of.or(hedged_by) else { return false };
        if hedge_of.is_some() {
            self.metrics.hedges_wasted = self.metrics.hedges_wasted.saturating_add(1); // a lost duplicate never wins
        }
        if let Some(ps) = self.tasks.get_mut(self.slot_of(partner)) {
            ps.hedge_of = None;
            ps.hedged_by = None;
        }
        self.free_task(task);
        true
    }

    /// A placement's offload timeout fired. Only an undelivered input
    /// counts — once the transfer lands, compute runs deterministically
    /// and retrying would only waste work. Within the retry budget the
    /// placement is cancelled and re-enters scheduling (the next timeout
    /// doubles: exponential backoff); past it the task is lost.
    fn on_offload_timeout(&mut self, h: SlotRef) {
        let Some(slot) = self.tasks.get(h) else {
            self.queue.note_popped_stale();
            return;
        };
        let Some(rt) = slot.rt.as_ref() else {
            self.queue.note_popped_stale();
            return;
        };
        if !rt.alloc.offloaded {
            return;
        }
        let id = slot.task.id;
        let (frame, source, tries) = (rt.alloc.frame, slot.task.source, slot.tries);
        let in_flight = self.medium.has_flow(id)
            || self.stalled_flows.iter().any(|&(f, _)| f == id)
            || self.cloud.as_ref().map_or(false, |c| c.upload_ids().any(|u| u == id));
        if !in_flight {
            return; // input delivered (or result already held): no timeout
        }
        if !self.device_active(source) {
            return; // source down: the crash path owns this task's fate
        }
        let _ = self.sched.on_event(self.now, SchedEvent::Violation { task: id });
        self.cancel_placement(id);
        if (tries as u32) < self.cfg.retry_limit {
            self.metrics.retries = self.metrics.retries.saturating_add(1);
            self.trace(TraceEvent::Retry { task: id, attempt: tries as u32 + 1 });
            if let Some(s) = self.tasks.get_mut(self.slot_of(id)) {
                s.tries = tries.saturating_add(1);
            }
            self.metrics.lp_realloc_attempts = self.metrics.lp_realloc_attempts.saturating_add(1);
            self.queue.push(
                self.now + self.cfg.control_latency(),
                Event::LpArrive { tasks: IdBatch::one(id), realloc: true },
            );
        } else {
            if self.hedge_dissolve_on_loss(id) {
                return;
            }
            self.metrics.lp_lost = self.metrics.lp_lost.saturating_add(1);
            self.fail_frame(frame);
            self.free_task(id);
        }
    }

    /// The hedge horizon passed with the primary still unfinished: race
    /// a duplicate placement against it. The duplicate is a full clone
    /// (same frame, deadline, and input) under a fresh id, dispatched on
    /// the re-placement path; first terminal outcome wins and the loser
    /// is suppressed without double credit.
    fn on_hedge_launch(&mut self, h: SlotRef) {
        let Some(slot) = self.tasks.get(h) else {
            self.queue.note_popped_stale();
            return;
        };
        let Some(rt) = slot.rt.as_ref() else {
            self.queue.note_popped_stale();
            return;
        };
        if slot.hedge_of.is_some() || slot.hedged_by.is_some() || !rt.alloc.offloaded {
            return;
        }
        if self.now > slot.task.deadline {
            return; // no budget left to hedge with
        }
        let primary_id = slot.task.id;
        let (ladder, rung) = (slot.ladder, slot.rung);
        let mut task = slot.task.clone();
        let clone_id = self.fresh_task_id();
        task.id = clone_id;
        let ch = self.insert_task(task, ladder);
        self.tasks.get_mut(ch).expect("fresh clone is live").rung = rung;
        let arrival = self.now;
        let service_start = self.busy_until.max(arrival);
        let ids = [clone_id];
        let Decision { outcome, ops, variant } =
            self.dispatch_batch(service_start, &ids, Some(true));
        let (decision, lat) = self.charge(arrival, ops);
        self.metrics.lat_lp_realloc.record(lat);
        self.metrics.lp_realloc_attempts = self.metrics.lp_realloc_attempts.saturating_add(1);
        match outcome {
            Outcome::LpAllocated { allocs } => {
                self.metrics.hedges_launched = self.metrics.hedges_launched.saturating_add(1);
                if self.tracing() {
                    let dev = allocs.first().map_or(0, |a| a.device);
                    self.trace(TraceEvent::HedgeLaunch { task: clone_id, device: dev });
                }
                self.apply_variant(&ids, variant);
                // Link before placement so neither half re-hedges.
                if let Some(ps) = self.tasks.get_mut(self.slot_of(primary_id)) {
                    ps.hedged_by = Some(clone_id);
                }
                if let Some(cs) = self.tasks.get_mut(self.slot_of(clone_id)) {
                    cs.hedge_of = Some(primary_id);
                }
                self.place_lp_allocs(allocs, decision, true, false);
            }
            Outcome::LpRejected => {
                // Nowhere to hedge to: the primary keeps running alone.
                self.free_task(clone_id);
            }
            other => unreachable!("hedge dispatch must yield an LP outcome, got {other:?}"),
        }
    }

    // ---- frame bookkeeping ----------------------------------------------

    fn fail_frame(&mut self, frame: FrameId) {
        if let Some(f) = self.frame_mut(frame) {
            f.failed = true;
        }
    }

    fn check_frame(&mut self, frame: FrameId) {
        if let Some(f) = self.frame_mut(frame) {
            if !f.counted && !f.failed && f.hp_done && f.lp_done >= f.lp_expected {
                f.counted = true;
                self.metrics.frames_completed = self.metrics.frames_completed.saturating_add(1);
            }
        }
    }

    /// Live tasks currently tracked (diagnostic/bench hook: with slot
    /// recycling this tracks in-flight work, not run history).
    pub fn live_tasks(&self) -> usize {
        self.tasks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::ras_sched::RasScheduler;
    use crate::coordinator::scheduler::wps::WpsScheduler;
    use crate::workload::trace::{Trace, TraceSpec};

    fn run(sched_is_ras: bool, spec: TraceSpec, frames: usize, seed: u64) -> Metrics {
        let mut cfg = SystemConfig::default();
        cfg.seed = seed;
        let trace = Trace::generate(spec, cfg.n_devices, frames, seed);
        let sched: Box<dyn Scheduler> = if sched_is_ras {
            Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps))
        } else {
            Box::new(WpsScheduler::new(&cfg, 0, cfg.link_bps))
        };
        Engine::new(cfg, sched, trace, if sched_is_ras { "RAS" } else { "WPS" }).run()
    }

    #[test]
    fn light_load_mostly_completes() {
        for ras in [true, false] {
            let m = run(ras, TraceSpec::Weighted(1), 12, 3);
            assert!(m.frames_total > 0);
            assert!(
                m.frame_completion_rate() > 0.7,
                "{}: light load should mostly complete, got {:.2} ({m:?})",
                m.label,
                m.frame_completion_rate()
            );
        }
    }

    #[test]
    fn accounting_identities_hold() {
        for ras in [true, false] {
            let m = run(ras, TraceSpec::Weighted(3), 15, 11);
            // Every generated HP task is allocated (±preemption) or rejected.
            assert_eq!(
                m.hp_generated,
                m.hp_allocated_no_preempt + m.hp_allocated_with_preempt + m.hp_rejected,
                "{}: hp accounting", m.label
            );
            // Completions never exceed allocations.
            assert!(m.hp_completed <= m.hp_allocated_no_preempt + m.hp_allocated_with_preempt);
            assert!(m.lp_completed_initial + m.lp_violations <= m.lp_allocated_initial + m.lp_realloc_success);
            assert!(m.offloaded_completed <= m.offloaded_total);
            assert!(m.frames_completed <= m.frames_total);
            // Core mix (plus the cloud axis) only counts successful
            // allocations; edge-only runs keep cloud_offloads at 0.
            assert_eq!(
                m.two_core_allocs + m.four_core_allocs + m.cloud_offloads,
                m.lp_allocated_initial + m.lp_realloc_success,
                "{}: core mix accounting", m.label
            );
            assert_eq!(m.cloud_offloads, 0, "{}: no cloud tier configured", m.label);
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run(true, TraceSpec::Weighted(2), 10, 5);
        let b = run(true, TraceSpec::Weighted(2), 10, 5);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn probes_fire_at_interval() {
        let m = run(true, TraceSpec::Weighted(1), 10, 7);
        // 10 frames × 18.86 s ≈ 188 s → ~6 probe rounds at 30 s.
        assert!(m.bandwidth_updates >= 4, "expected probe rounds, got {}", m.bandwidth_updates);
        assert!(m.link_rebuild_ops > 0);
    }

    #[test]
    fn slab_frees_terminal_tasks() {
        // The engine's slab recycles slots: after a drained run every
        // task reached a terminal state, so nothing may stay live.
        let mut cfg = SystemConfig::default();
        cfg.seed = 21;
        let trace = Trace::generate(TraceSpec::Weighted(3), cfg.n_devices, 10, 21);
        let sched: Box<dyn Scheduler> = Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps));
        let mut eng = Engine::new(cfg, sched, trace, "slab");
        let mut peak = 0usize;
        while eng.step() {
            peak = peak.max(eng.live_tasks());
        }
        assert_eq!(eng.live_tasks(), 0, "drained run must free every task slot");
        assert!(peak > 0, "run should have had in-flight tasks");
        assert!(
            peak < eng.metrics.hp_generated as usize + eng.metrics.lp_generated as usize,
            "peak live tasks ({peak}) should stay below the whole run history"
        );
    }

    #[test]
    fn conveyor_ladder_trades_accuracy_for_completions() {
        use crate::workload::gen::variants::Ladder;
        // A frame period no full-model configuration can meet (the
        // four-core stage alone takes ~11.96 s padded): without a ladder
        // every stage-3 task is rejected outright; with the stage-3
        // family attached the schedulers step down and deliver degraded
        // inferences instead of nothing.
        let mut cfg = SystemConfig::default();
        cfg.seed = 33;
        cfg.frame_period_s = 12.0;
        let trace = Arc::new(Trace::generate(TraceSpec::Weighted(3), cfg.n_devices, 10, 33));
        let rungs = Ladder::stage3_family(&cfg).compile(&cfg);
        let run = |lp_ladder: Vec<VariantRung>| {
            let extras = RunExtras { lp_ladder, ..Default::default() };
            Engine::with_extras(
                cfg.clone(),
                Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps)),
                Arc::clone(&trace),
                "ladder",
                extras,
            )
            .run()
        };
        let plain = run(Vec::new());
        assert_eq!(plain.lp_completed_total(), 0, "12 s period fits no full-model config");
        assert_eq!(plain.accuracy_sum, 0.0);
        let laddered = run(rungs);
        let done = laddered.lp_completed_total();
        assert!(done > 0, "degradation should rescue stage-3 work");
        // Every completion ran a degraded rung, and the accounting
        // identities close.
        assert_eq!(laddered.rung_completions[0], 0);
        assert_eq!(laddered.degraded_completions, done);
        assert_eq!(laddered.rung_completions.iter().sum::<u64>(), done);
        assert!(laddered.degraded_placements >= laddered.degraded_completions);
        let mean = laddered.accuracy_per_deadline_met();
        assert!(
            (0.78 - 1e-9..=0.92 + 1e-9).contains(&mean),
            "mean delivered accuracy {mean} must sit within the degraded rungs"
        );
    }

    #[test]
    fn stage_plans_with_controller_off_decide_identically() {
        use crate::workload::gen::variants::Ladder;
        // Stage plans attached but the pressure controller off: boundary
        // events fire and advance `next_stage`, yet nothing is ever cut —
        // every placement, RNG draw, and delivered accuracy must match
        // the monolithic ladder run. Only queue-compaction cadence may
        // move (the extra boundary events shift the sweep heuristic), so
        // that gauge is masked before the full-struct comparison.
        let mut cfg = SystemConfig::default();
        cfg.seed = 33;
        cfg.frame_period_s = 12.0;
        let trace = Arc::new(Trace::generate(TraceSpec::Weighted(3), cfg.n_devices, 10, 33));
        let run = |staged: bool| {
            let ladder =
                if staged { Ladder::stage3_family_staged(&cfg) } else { Ladder::stage3_family(&cfg) };
            let mut extras = RunExtras { lp_ladder: ladder.compile(&cfg), ..Default::default() };
            if staged {
                extras.lp_stage_plans = ladder.compile_stage_plans();
            }
            Engine::with_extras(
                cfg.clone(),
                Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps)),
                Arc::clone(&trace),
                "anytime-off",
                extras,
            )
            .run()
        };
        let mut plain = run(false);
        let mut staged = run(true);
        assert_eq!(staged.truncated_completions, 0, "no controller, no cuts");
        assert_eq!(staged.pressure_events, 0);
        assert_eq!(staged.pressure_cuts, 0);
        assert_eq!(staged.stages_skipped, 0);
        plain.queue_compactions = 0;
        staged.queue_compactions = 0;
        assert_eq!(format!("{plain:?}"), format!("{staged:?}"));
    }

    #[test]
    fn pressure_escalation_truncates_and_conserves() {
        use crate::workload::gen::variants::Ladder;
        // Backlog threshold 1: every survey that finds live work
        // escalates, so every cuttable staged execution whose truncated
        // finish still meets its deadline gets cut at the next boundary.
        let mut cfg = SystemConfig::default();
        cfg.seed = 41;
        cfg.frame_period_s = 12.0;
        cfg.pressure_check_s = 0.5;
        cfg.pressure_backlog = 1;
        let trace = Arc::new(Trace::generate(TraceSpec::Weighted(3), cfg.n_devices, 12, 41));
        let ladder = Ladder::stage3_family_staged(&cfg);
        let extras = RunExtras {
            lp_ladder: ladder.compile(&cfg),
            lp_stage_plans: ladder.compile_stage_plans(),
            ..Default::default()
        };
        let m = Engine::with_extras(
            cfg.clone(),
            Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps)),
            trace,
            "anytime-on",
            extras,
        )
        .run();
        assert!(m.pressure_events > 0, "surveys must find live staged work");
        assert!(m.pressure_cuts > 0, "escalation must arm cuts");
        assert!(m.truncated_completions > 0, "armed cuts must land as truncated completions");
        assert!(
            m.truncated_completions <= m.pressure_cuts,
            "each truncated completion consumes one armed cut"
        );
        assert!(m.stages_skipped >= m.truncated_completions, "a cut skips at least one stage");
        // Truncated finishes still count as deadline-met completions and
        // still bank their rung, so both ledgers close.
        assert_eq!(m.rung_completions.iter().sum::<u64>(), m.lp_completed_total());
        assert!(m.accuracy_sum > 0.0);
        assert_lp_conserved(&m);
    }

    #[test]
    fn congestion_hurts_completion() {
        let mut cfg = SystemConfig::default();
        cfg.seed = 13;
        // One immutable trace allocation shared by both twin runs (the
        // old construction cloned it per engine).
        let trace = Arc::new(Trace::generate(TraceSpec::Weighted(4), cfg.n_devices, 20, 13));
        let quiet = Engine::new(
            cfg.clone(),
            Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps)),
            Arc::clone(&trace),
            "quiet",
        )
        .run();
        let mut cfg2 = cfg.clone();
        cfg2.duty_cycle = 0.75;
        let congested = Engine::new(
            cfg2.clone(),
            Box::new(RasScheduler::new(&cfg2, 0, cfg2.link_bps)),
            trace,
            "congested",
        )
        .run();
        assert!(
            congested.frames_completed <= quiet.frames_completed,
            "background traffic should not improve completion: quiet={} congested={}",
            quiet.frames_completed,
            congested.frames_completed
        );
    }

    /// LP conservation: every generated low-priority task ends exactly
    /// one way. The chaos campaign hard-asserts this on every run; the
    /// unit tests below check it on each robustness mechanism in
    /// isolation.
    fn assert_lp_conserved(m: &Metrics) {
        assert_eq!(
            m.lp_generated,
            m.lp_completed_total() + m.lp_violations + m.lp_lost,
            "{}: lp conservation (completed {} violated {} lost {})",
            m.label,
            m.lp_completed_total(),
            m.lp_violations,
            m.lp_lost
        );
    }

    #[test]
    fn zero_knob_robustness_stays_inert() {
        // All PR 8 knobs default off: no detector traffic, no retries,
        // no hedges, no partitions, no staleness — only the conservation
        // ledger (lp_lost) is allowed to move, and conservation closes.
        for ras in [true, false] {
            let m = run(ras, TraceSpec::Weighted(3), 15, 11);
            assert_eq!(m.retries, 0, "{}", m.label);
            assert_eq!(m.hedges_launched + m.hedges_won + m.hedges_wasted, 0, "{}", m.label);
            assert_eq!(m.devices_suspected + m.devices_cleared + m.false_suspicions, 0);
            assert_eq!(m.lat_detection.count, 0);
            assert_eq!(m.partitions_started + m.partitions_healed, 0);
            assert_eq!(m.partition_stalled_flows + m.partition_held_results, 0);
            assert_eq!(m.bw_stale_us, 0);
            assert_lp_conserved(&m);
        }
    }

    #[test]
    fn partition_stalls_work_then_heals_and_drains() {
        let mut cfg = SystemConfig::default();
        cfg.seed = 27;
        let trace = Trace::generate(TraceSpec::Weighted(4), cfg.n_devices, 20, 27);
        let extras = RunExtras {
            // Device 1 is unreachable-but-alive for ~130 s mid-run: its
            // flows stall (or its finished results are held) and resume
            // on heal — unlike a crash, nothing is force-lost.
            partitions: vec![(20_000_000, 1, false), (150_000_000, 1, true)],
            ..Default::default()
        };
        let sched: Box<dyn Scheduler> = Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps));
        let mut eng = Engine::with_extras(cfg.clone(), sched, trace, "partition", extras);
        while eng.step() {}
        eng.flush_partition_remnants();
        let m = eng.metrics;
        assert_eq!(m.partitions_started, 1);
        assert_eq!(m.partitions_healed, 1);
        assert!(
            m.partition_stalled_flows + m.partition_held_results > 0,
            "a 130 s partition under offload load must stall or hold something ({m:?})"
        );
        assert_lp_conserved(&m);
    }

    #[test]
    fn partition_without_heal_still_drains_the_slab() {
        let mut cfg = SystemConfig::default();
        cfg.seed = 29;
        let trace = Trace::generate(TraceSpec::Weighted(4), cfg.n_devices, 12, 29);
        let extras = RunExtras {
            partitions: vec![(20_000_000, 2, false)], // never heals
            ..Default::default()
        };
        let sched: Box<dyn Scheduler> = Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps));
        let mut eng = Engine::with_extras(cfg.clone(), sched, trace, "no-heal", extras);
        while eng.step() {}
        eng.flush_partition_remnants();
        assert_eq!(eng.live_tasks(), 0, "post-drain flush must reap partition remnants");
        assert_lp_conserved(&eng.metrics);
    }

    #[test]
    fn offload_timeout_retries_with_bounded_budget() {
        let mut cfg = SystemConfig::default();
        cfg.seed = 41;
        // A 1 ms timeout is shorter than any real transfer: every
        // offload times out, retries (with backoff), and finally drops —
        // the retry budget bounds the cycle and conservation closes.
        cfg.offload_timeout_s = 0.001;
        cfg.retry_limit = 2;
        let trace = Trace::generate(TraceSpec::Weighted(4), cfg.n_devices, 12, 41);
        let sched: Box<dyn Scheduler> = Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps));
        let mut eng = Engine::with_extras(cfg.clone(), sched, trace, "timeout", RunExtras::default());
        while eng.step() {}
        eng.flush_partition_remnants();
        let m = eng.metrics;
        assert!(m.retries > 0, "1 ms timeout under offload load must retry ({m:?})");
        assert!(m.retries <= m.offloaded_total * cfg.retry_limit as u64);
        assert_eq!(eng.tasks.len(), 0);
        assert_lp_conserved(&m);
    }

    #[test]
    fn hedging_settles_first_completion_wins() {
        let mut cfg = SystemConfig::default();
        cfg.seed = 43;
        // Hedge almost immediately: every offloaded placement races a
        // duplicate. Exactly one half of each pair may credit the ledger.
        cfg.hedge_timeout_s = 0.001;
        let trace = Trace::generate(TraceSpec::Weighted(3), cfg.n_devices, 15, 43);
        let sched: Box<dyn Scheduler> = Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps));
        let mut eng = Engine::with_extras(cfg.clone(), sched, trace, "hedge", RunExtras::default());
        while eng.step() {}
        eng.flush_partition_remnants();
        let m = eng.metrics;
        assert!(m.hedges_launched > 0, "hedge horizon of 1 ms must launch duplicates ({m:?})");
        assert!(m.hedges_won + m.hedges_wasted <= m.hedges_launched);
        assert_eq!(eng.tasks.len(), 0, "hedge pairs must fully settle");
        assert_lp_conserved(&m);
    }

    #[test]
    fn detector_suspects_a_crashed_device() {
        let mut cfg = SystemConfig::default();
        cfg.seed = 47;
        cfg.suspect_after = 1;
        cfg.confirm_after = 1;
        let trace = Trace::generate(TraceSpec::Weighted(2), cfg.n_devices, 20, 47);
        let extras = RunExtras {
            faults: vec![(40_000_000, 1, false)], // crash, never recovers
            ..Default::default()
        };
        let sched: Box<dyn Scheduler> = Box::new(RasScheduler::new(&cfg, 0, cfg.link_bps));
        let m = Engine::with_extras(cfg.clone(), sched, trace, "detector", extras).run();
        // Probe rounds every 30 s: the missed heartbeats push the crashed
        // device to Suspected, with a recorded detection lag; no probe
        // loss means no false positives.
        assert!(m.devices_suspected >= 1, "crashed device must be suspected ({m:?})");
        assert_eq!(m.false_suspicions, 0);
        assert!(m.lat_detection.count >= 1);
        assert_lp_conserved(&m);
    }

    #[test]
    fn wps_scheduling_latency_exceeds_ras() {
        let ras = run(true, TraceSpec::Weighted(4), 20, 9);
        let wps = run(false, TraceSpec::Weighted(4), 20, 9);
        assert!(
            wps.lat_lp_alloc.mean_ms() > ras.lat_lp_alloc.mean_ms(),
            "WPS LP alloc ({:.2} ms) should exceed RAS ({:.2} ms)",
            wps.lat_lp_alloc.mean_ms(),
            ras.lat_lp_alloc.mean_ms()
        );
    }
}
