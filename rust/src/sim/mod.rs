//! Simulation substrate: the testbed the paper ran on physical Raspberry
//! Pis, rebuilt as a deterministic discrete-event simulator (see DESIGN.md
//! §Substitutions).

pub mod engine;
pub mod events;
pub mod netsim;

pub use engine::{Engine, RunExtras};
pub use netsim::{LossyMedium, Medium};
