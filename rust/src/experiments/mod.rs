//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (Section VI). Each function returns the per-scenario
//! [`Metrics`] rows; `medge <figN>` prints them with the renderers in
//! [`crate::metrics::report`].

use crate::config::SystemConfig;
use crate::coordinator::scheduler::multi::MultiScheduler;
use crate::coordinator::scheduler::ras_sched::RasScheduler;
use crate::coordinator::scheduler::wps::WpsScheduler;
use crate::coordinator::scheduler::Scheduler;
use crate::metrics::Metrics;
use crate::sim::Engine;
use crate::workload::trace::{Trace, TraceSpec};

/// Which scheduler a scenario runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedKind {
    Wps,
    Ras,
    /// Future-work contextual multi-scheduler (ablation).
    Multi,
}

impl SchedKind {
    pub fn build(self, cfg: &SystemConfig) -> Box<dyn Scheduler> {
        match self {
            SchedKind::Wps => Box::new(WpsScheduler::new(cfg, 0, cfg.link_bps)),
            SchedKind::Ras => Box::new(RasScheduler::new(cfg, 0, cfg.link_bps)),
            SchedKind::Multi => Box::new(MultiScheduler::new(cfg, 0, cfg.link_bps, 8)),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SchedKind::Wps => "WPS",
            SchedKind::Ras => "RAS",
            SchedKind::Multi => "MULTI",
        }
    }
}

/// Run one scenario: `frames` trace frames of `spec` under `kind`.
pub fn run_scenario(cfg: &SystemConfig, kind: SchedKind, spec: TraceSpec, frames: usize, label: &str) -> Metrics {
    let trace = Trace::generate(spec, cfg.n_devices, frames, cfg.seed);
    let sched = kind.build(cfg);
    Engine::new(cfg.clone(), sched, trace, label).run()
}

/// Number of trace frames in a wall-clock experiment duration.
pub fn frames_for_minutes(cfg: &SystemConfig, minutes: f64) -> usize {
    ((minutes * 60.0) / cfg.frame_period_s).ceil() as usize
}

/// Fig. 4 + Fig. 5 — accuracy vs performance: WPS_N vs RAS_N over the
/// weighted 1..4 loads (the paper's main experiment; both figures come
/// from the same runs).
pub fn fig4_fig5(cfg: &SystemConfig, minutes: f64) -> Vec<Metrics> {
    let frames = frames_for_minutes(cfg, minutes);
    let mut out = Vec::new();
    for n in 1..=4u8 {
        for kind in [SchedKind::Wps, SchedKind::Ras] {
            let label = format!("{}_{}", kind.label(), n);
            out.push(run_scenario(cfg, kind, TraceSpec::Weighted(n), frames, &label));
        }
    }
    out
}

/// Fig. 6 + Fig. 7 — bandwidth interval rate: the RAS system on a 30-min
/// slice of the weighted-4 scenario, sweeping the probe interval over
/// {1.5, 5, 10, 20, 30} s.
pub fn fig6_fig7(cfg: &SystemConfig, minutes: f64) -> Vec<Metrics> {
    let frames = frames_for_minutes(cfg, minutes);
    [1.5f64, 5.0, 10.0, 20.0, 30.0]
        .iter()
        .map(|&interval| {
            let mut c = cfg.clone();
            c.bandwidth_interval_s = interval;
            let label = format!("BIT_{}", interval);
            run_scenario(&c, SchedKind::Ras, TraceSpec::Weighted(4), frames, &label)
        })
        .collect()
}

/// Fig. 8 + Table II — network traffic congestion: RAS on weighted-4 for
/// 30 min, background bursts at duty cycles {0, 25, 50, 75} % of the 30 s
/// bandwidth-update interval.
pub fn fig8_table2(cfg: &SystemConfig, minutes: f64) -> Vec<Metrics> {
    let frames = frames_for_minutes(cfg, minutes);
    [0.0f64, 0.25, 0.50, 0.75]
        .iter()
        .map(|&duty| {
            let mut c = cfg.clone();
            c.duty_cycle = duty;
            let label = format!("{}%", (duty * 100.0) as u32);
            run_scenario(&c, SchedKind::Ras, TraceSpec::Weighted(4), frames, &label)
        })
        .collect()
}

/// Ablation (future work, Section VII): the contextual multi-scheduler
/// against pure WPS and pure RAS across the weighted loads.
pub fn ablation_multi(cfg: &SystemConfig, minutes: f64) -> Vec<Metrics> {
    let frames = frames_for_minutes(cfg, minutes);
    let mut out = Vec::new();
    for n in 1..=4u8 {
        for kind in [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi] {
            let label = format!("{}_{}", kind.label(), n);
            out.push(run_scenario(cfg, kind, TraceSpec::Weighted(n), frames, &label));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> SystemConfig {
        SystemConfig { seed: 17, ..Default::default() }
    }

    #[test]
    fn frames_for_minutes_rounds_up() {
        let cfg = small_cfg();
        assert_eq!(frames_for_minutes(&cfg, 30.0), 96); // 1800 / 18.86 → 95.4
    }

    #[test]
    fn fig4_produces_eight_labelled_rows() {
        let runs = fig4_fig5(&small_cfg(), 3.0);
        assert_eq!(runs.len(), 8);
        assert_eq!(runs[0].label, "WPS_1");
        assert_eq!(runs[7].label, "RAS_4");
        for m in &runs {
            assert!(m.frames_total > 0);
        }
    }

    #[test]
    fn fig6_sweeps_five_intervals() {
        let runs = fig6_fig7(&small_cfg(), 2.0);
        assert_eq!(runs.len(), 5);
        // Higher probe frequency ⇒ at least as many bandwidth updates.
        assert!(runs[0].bandwidth_updates >= runs[4].bandwidth_updates);
    }

    #[test]
    fn fig8_sweeps_duty_cycles() {
        let runs = fig8_table2(&small_cfg(), 2.0);
        assert_eq!(runs.len(), 4);
        assert_eq!(runs[0].label, "0%");
        assert_eq!(runs[3].label, "75%");
    }
}
