//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (Section VI). Each function composes its scenarios with the
//! [`crate::scenario::ScenarioBuilder`] and fans them across worker
//! threads with [`crate::scenario::Sweep`]; `medge <figN>` prints the
//! returned [`Metrics`] rows with the renderers in
//! [`crate::metrics::report`]. Rows are returned in grid order and are
//! byte-identical to sequential execution (each engine run is
//! single-threaded and seed-deterministic).

pub mod hotpath;

use crate::config::SystemConfig;
use crate::energy::EnergyModel;
use crate::metrics::Metrics;
use crate::scenario::{Scenario, ScenarioBuilder, Sweep};
use crate::workload::gen::{ArrivalProcess, Catalog, GenSpec, Ladder, TaskClass, Workload};
use crate::workload::trace::TraceSpec;

pub use crate::scenario::SchedKind;

/// Run one scenario: `frames` trace frames of `spec` under `kind`.
pub fn run_scenario(cfg: &SystemConfig, kind: SchedKind, spec: TraceSpec, frames: usize, label: &str) -> Metrics {
    scenario(cfg, kind, spec, frames, label).run()
}

/// Build (without running) one labelled scenario on a shared base config.
pub fn scenario(cfg: &SystemConfig, kind: SchedKind, spec: TraceSpec, frames: usize, label: &str) -> Scenario {
    ScenarioBuilder::new()
        .config(cfg.clone())
        .scheduler(kind)
        .trace(spec)
        .frames(frames)
        .named(label)
        .build()
}

/// Number of trace frames in a wall-clock experiment duration.
pub fn frames_for_minutes(cfg: &SystemConfig, minutes: f64) -> usize {
    crate::scenario::frames_for_minutes(cfg, minutes)
}

/// The paper's main grid — `kinds` × weighted 1..4 — as a parallel sweep.
pub fn weighted_grid(cfg: &SystemConfig, kinds: &[SchedKind], minutes: f64) -> Sweep {
    let frames = frames_for_minutes(cfg, minutes);
    let mut sweep = Sweep::new();
    for n in 1..=4u8 {
        for &kind in kinds {
            let label = format!("{}_{}", kind.label(), n);
            sweep = sweep.add(scenario(cfg, kind, TraceSpec::Weighted(n), frames, &label));
        }
    }
    sweep
}

/// Fig. 4 + Fig. 5 — accuracy vs performance: WPS_N vs RAS_N over the
/// weighted 1..4 loads (the paper's main experiment; both figures come
/// from the same runs).
pub fn fig4_fig5(cfg: &SystemConfig, minutes: f64) -> Vec<Metrics> {
    weighted_grid(cfg, &[SchedKind::Wps, SchedKind::Ras], minutes).run()
}

/// Fig. 6 + Fig. 7 — bandwidth interval rate: the RAS system on a 30-min
/// slice of the weighted-4 scenario, sweeping the probe interval over
/// {1.5, 5, 10, 20, 30} s.
pub fn fig6_fig7(cfg: &SystemConfig, minutes: f64) -> Vec<Metrics> {
    let frames = frames_for_minutes(cfg, minutes);
    let mut sweep = Sweep::new();
    for &interval in &[1.5f64, 5.0, 10.0, 20.0, 30.0] {
        sweep = sweep.add(
            ScenarioBuilder::new()
                .config(cfg.clone())
                .scheduler(SchedKind::Ras)
                .trace(TraceSpec::Weighted(4))
                .frames(frames)
                .bandwidth_interval_s(interval)
                .named(format!("BIT_{}", interval))
                .build(),
        );
    }
    sweep.run()
}

/// Fig. 8 + Table II — network traffic congestion: RAS on weighted-4 for
/// 30 min, background bursts at duty cycles {0, 25, 50, 75} % of the 30 s
/// bandwidth-update interval.
pub fn fig8_table2(cfg: &SystemConfig, minutes: f64) -> Vec<Metrics> {
    let frames = frames_for_minutes(cfg, minutes);
    let mut sweep = Sweep::new();
    for &duty in &[0.0f64, 0.25, 0.50, 0.75] {
        sweep = sweep.add(
            ScenarioBuilder::new()
                .config(cfg.clone())
                .scheduler(SchedKind::Ras)
                .trace(TraceSpec::Weighted(4))
                .frames(frames)
                .duty_cycle(duty)
                .named(format!("{}%", (duty * 100.0) as u32))
                .build(),
        );
    }
    sweep.run()
}

/// Ablation (future work, Section VII): the contextual multi-scheduler
/// against pure WPS and pure RAS across the weighted loads.
pub fn ablation_multi(cfg: &SystemConfig, minutes: f64) -> Vec<Metrics> {
    weighted_grid(cfg, &[SchedKind::Wps, SchedKind::Ras, SchedKind::Multi], minutes).run()
}

/// The default open-loop processes `medge loadgen` sweeps: a steady
/// Poisson stream and a bursty MMPP whose ON-state rate is well past the
/// fleet's service capacity (the "high-volume workload" regime).
pub fn default_loadgen_processes() -> Vec<ArrivalProcess> {
    vec![
        ArrivalProcess::Poisson { rate_per_min: 6.0 },
        ArrivalProcess::Mmpp {
            on_rate_per_min: 24.0,
            off_rate_per_min: 1.0,
            mean_on_s: 45.0,
            mean_off_s: 90.0,
        },
    ]
}

/// Generative-workload grid: schedulers × arrival processes over the
/// heterogeneous edge-serving catalog, as a parallel sweep. `cap` is the
/// admission control bound (0 = open admission). Rows are labelled
/// `KIND_process` (`RAS_poisson6`, `WPS_mmpp24`, …).
pub fn loadgen_grid(
    cfg: &SystemConfig,
    kinds: &[SchedKind],
    procs: &[ArrivalProcess],
    minutes: f64,
    cap: usize,
) -> Sweep {
    let catalog = Catalog::edge_serving(cfg);
    let mut sweep = Sweep::new();
    for proc in procs {
        for &kind in kinds {
            sweep = sweep.add(
                ScenarioBuilder::new()
                    .config(cfg.clone())
                    .scheduler(kind)
                    .workload(Workload::Generative(GenSpec {
                        arrivals: proc.clone(),
                        catalog: catalog.clone(),
                        admission_cap: cap,
                    }))
                    .minutes(minutes)
                    .named(format!("{}_{}", kind.label(), proc.label()))
                    .build(),
            );
        }
    }
    sweep
}

/// The single-class catalog the accuracy frontier sweeps: the paper's
/// stage-3 DNN with the model family truncated to `depth` rungs
/// (depth 1 = the full model only, i.e. the no-degradation twin).
pub fn frontier_catalog(cfg: &SystemConfig, depth: usize) -> Catalog {
    let family = Ladder::stage3_family(cfg).truncated(depth);
    Catalog::new(vec![TaskClass::low("stage3", cfg.frame_period_s, 0.0, 1.0, 0.8)
        .batch(2)
        .ladder(family)])
}

/// MMPP burst arrivals whose ON-state rate is `on_rate_per_min` — the
/// deadline-pressure knob of the accuracy frontier.
pub fn frontier_arrivals(on_rate_per_min: f64) -> ArrivalProcess {
    ArrivalProcess::Mmpp {
        on_rate_per_min,
        off_rate_per_min: 1.0,
        mean_on_s: 45.0,
        mean_off_s: 45.0,
    }
}

/// The accuracy-frontier grid: offered load × ladder depth × scheduler
/// on the stage-3 class under bursty MMPP pressure. Each depth-1 row is
/// the no-degradation twin of its deeper siblings (same seed, same
/// arrival plan), so adjacent rows trace the deadline-met ↑ /
/// mean-accuracy ↓ frontier directly. Labels: `KIND_rRATEdDEPTH`.
pub fn accuracy_frontier(
    cfg: &SystemConfig,
    kinds: &[SchedKind],
    depths: &[usize],
    minutes: f64,
) -> Sweep {
    let rates = [12.0f64, 24.0];
    let mut sweep = Sweep::new();
    for &rate in &rates {
        for &depth in depths {
            for &kind in kinds {
                sweep = sweep.add(
                    ScenarioBuilder::new()
                        .config(cfg.clone())
                        .scheduler(kind)
                        .workload(Workload::generative(
                            frontier_arrivals(rate),
                            frontier_catalog(cfg, depth),
                        ))
                        .minutes(minutes)
                        .named(format!("{}_r{}d{}", kind.label(), rate as u32, depth))
                        .build(),
                );
            }
        }
    }
    sweep
}

// ---- anytime truncation grid (PR 10) ------------------------------------

/// Schedulers the anytime grid sweeps: every LP policy, including the
/// Fresa & Champati accuracy-maximizing greedy baseline.
pub const ANYTIME_KINDS: [SchedKind; 4] =
    [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi, SchedKind::Greedy];

/// Pressure-controller knobs the anytime grid's `_cut` twins run with:
/// survey every 0.5 s, escalate past an 8-task backlog.
pub const ANYTIME_CHECK_S: f64 = 0.5;
pub const ANYTIME_BACKLOG: u32 = 8;

/// The staged frontier catalog: the stage-3 class running the staged
/// model family ([`Ladder::stage3_family_staged`]), so every placement
/// carries per-rung anytime stage plans the pressure controller can cut.
pub fn anytime_catalog(cfg: &SystemConfig) -> Catalog {
    let family = Ladder::stage3_family_staged(cfg);
    Catalog::new(vec![TaskClass::low("stage3", cfg.frame_period_s, 0.0, 1.0, 0.8)
        .batch(2)
        .ladder(family)])
}

/// The anytime grid: offered load × truncation {full, cut} × scheduler
/// on the staged stage-3 class under bursty MMPP pressure. Twins share
/// seed and arrival plan — same workload, the only difference is the
/// pressure controller — so each `_cut` row reads directly against its
/// `_full` sibling: deadline-met should rise while accuracy goodput
/// holds (the anytime acceptance claim, property-locked in
/// `tests/anytime_props.rs`). Labels: `KIND_rRATE_full` / `KIND_rRATE_cut`.
pub fn anytime_grid(cfg: &SystemConfig, kinds: &[SchedKind], minutes: f64) -> Sweep {
    let rates = [12.0f64, 24.0];
    let mut sweep = Sweep::new();
    for &rate in &rates {
        for &kind in kinds {
            for cut in [false, true] {
                let mut b = ScenarioBuilder::new()
                    .config(cfg.clone())
                    .scheduler(kind)
                    .workload(Workload::generative(
                        frontier_arrivals(rate),
                        anytime_catalog(cfg),
                    ))
                    .minutes(minutes)
                    .named(format!(
                        "{}_r{}_{}",
                        kind.label(),
                        rate as u32,
                        if cut { "cut" } else { "full" }
                    ));
                if cut {
                    b = b.pressure(ANYTIME_CHECK_S, ANYTIME_BACKLOG);
                }
                sweep = sweep.add(b.build());
            }
        }
    }
    sweep
}

/// Parse a comma list of ladder depths for `medge accuracy` — strict:
/// a malformed or out-of-range entry is an error, never a panic or a
/// silent clamp.
pub fn parse_depths(s: &str) -> anyhow::Result<Vec<usize>> {
    let max = Ladder::stage3_family(&SystemConfig::default()).depth();
    let depths: Vec<usize> = s
        .split(',')
        .filter(|t| !t.is_empty())
        .map(|t| {
            let d: usize =
                t.parse().map_err(|_| anyhow::anyhow!("bad ladder depth: {t}"))?;
            anyhow::ensure!(
                (1..=max).contains(&d),
                "ladder depth out of range 1..={max}: {d}"
            );
            Ok(d)
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(!depths.is_empty(), "empty ladder-depth list");
    Ok(depths)
}

/// Fault-stress grid (beyond the paper): each scheduler on the weighted-4
/// load, clean vs faulted (5% packet loss, 25% probe loss, the last
/// device crashing at 30% and recovering at 55% of the run) — the
/// robustness counterpart of the fig. 4 comparison. Labels carry an `F`
/// suffix on the faulted twin, matching `medge sweep --faults`.
pub fn fault_stress(cfg: &SystemConfig, kinds: &[SchedKind], minutes: f64) -> Vec<Metrics> {
    let frames = frames_for_minutes(cfg, minutes);
    let total_s = minutes * 60.0;
    let crash_device = cfg.n_devices.saturating_sub(1);
    let mut sweep = Sweep::new();
    for &kind in kinds {
        let base = ScenarioBuilder::new()
            .config(cfg.clone())
            .scheduler(kind)
            .trace(TraceSpec::Weighted(4))
            .frames(frames);
        sweep = sweep.add(base.clone().named(format!("{}_4", kind.label())).build());
        sweep = sweep.add(
            base.named(format!("{}_4F", kind.label()))
                .loss_rate(0.05)
                .probe_loss(0.25)
                .crash_at(total_s * 0.30, crash_device)
                .recover_at(total_s * 0.55, crash_device)
                .build(),
        );
    }
    sweep.run()
}

// ---- chaos campaign (seeded fault sweeps with hard invariants) ----------

/// RNG domain tag for the chaos schedule sampler ("CHS") — its own
/// stream, so the sampled fault cocktail for seed `k` never shifts when
/// campaign parameters change.
const CHAOS_SEED_TAG: u64 = 0x43_4853;

/// Schedulers every chaos campaign sweeps.
pub const CHAOS_KINDS: [SchedKind; 3] = [SchedKind::Wps, SchedKind::Ras, SchedKind::Multi];

/// Default seeds per scheduler for `medge chaos` (`--quick` uses
/// [`CHAOS_QUICK_SEEDS`]).
pub const CHAOS_SEEDS: usize = 50;
pub const CHAOS_QUICK_SEEDS: usize = 10;

/// One randomized chaos cell: a seed-derived fault cocktail (packet and
/// probe loss, per-device crash or partition windows) with every
/// robustness knob on (detector, offload timeout + retry, hedging,
/// bandwidth staleness) plus a flight recorder, so a failing cell can
/// dump its full event timeline. Same `seed` ⇒ byte-identical schedule
/// and run (the recorder makes no RNG draws).
pub fn chaos_scenario(cfg: &SystemConfig, kind: SchedKind, seed: u64, minutes: f64) -> Scenario {
    let mut rng = crate::util::rng::Rng::seed_from_u64(seed ^ CHAOS_SEED_TAG);
    let total_s = minutes * 60.0;
    let mut cfg = cfg.clone();
    cfg.seed = seed;
    let mut b = ScenarioBuilder::new()
        .config(cfg.clone())
        .scheduler(kind)
        .trace(TraceSpec::Weighted(4))
        .frames(frames_for_minutes(&cfg, minutes))
        .named(format!("{}_chaos{}", kind.label(), seed))
        .record_trace(crate::obs::DEFAULT_CAPACITY)
        .loss_rate(rng.gen_f64() * 0.10)
        .probe_loss(rng.gen_f64() * 0.40)
        .detector(1 + rng.index(3) as u32, 1 + rng.index(2) as u32)
        .offload_timeout(0.2 + rng.gen_f64() * 0.8, 1 + rng.index(3) as u32)
        .hedge(0.2 + rng.gen_f64() * 0.8)
        .bw_stale_after(2 + rng.index(3) as u32);
    // At most one fault window per device — windows are disjoint per
    // device by construction, so the plan always validates. Device 0 is
    // spared: the coordinator's host must survive the campaign.
    for device in 1..cfg.n_devices {
        let start = total_s * (0.1 + rng.gen_f64() * 0.5);
        let len = total_s * (0.05 + rng.gen_f64() * 0.3);
        let end = (start + len).min(total_s * 0.95);
        match rng.index(4) {
            0 => b = b.crash_at(start, device).recover_at(end, device),
            1 => b = b.partition_at(start, device).heal_at(end, device),
            2 => b = b.crash_at(start, device), // never recovers
            _ => {}                             // spared this run
        }
    }
    b.build()
}

/// The conservation invariants every chaos cell must satisfy, however
/// hostile the sampled schedule: every generated task reaches exactly one
/// terminal counter (no leaks, no double credit), placements balance the
/// core mix, and hedge pairs credit at most one side.
pub fn chaos_invariants(m: &Metrics) -> anyhow::Result<()> {
    let ensure = |ok: bool, what: &str| {
        anyhow::ensure!(ok, "{}: chaos invariant violated: {what}\n{m:?}", m.label);
        Ok(())
    };
    ensure(
        m.hp_generated == m.hp_allocated_no_preempt + m.hp_allocated_with_preempt + m.hp_rejected,
        "hp offered == allocated + rejected",
    )?;
    ensure(
        m.lp_generated == m.lp_completed_total() + m.lp_violations + m.lp_lost,
        "lp offered == completed + violated + lost",
    )?;
    ensure(
        m.two_core_allocs + m.four_core_allocs + m.cloud_offloads
            == m.lp_allocated_initial + m.lp_realloc_success,
        "core mix == successful placements",
    )?;
    ensure(m.hedges_won + m.hedges_wasted <= m.hedges_launched, "hedge pairs settle once")?;
    ensure(m.devices_cleared <= m.devices_suspected, "clears need prior suspicions")?;
    ensure(m.offloaded_completed <= m.offloaded_total, "offload completions bounded")?;
    ensure(m.frames_completed <= m.frames_total, "frame completions bounded")?;
    Ok(())
}

/// Where a failing chaos cell dumps its flight recorder (Perfetto
/// trace-event JSON, loadable in `ui.perfetto.dev`; CI uploads it as an
/// artifact when the chaos-smoke job fails).
pub const CHAOS_DUMP_PATH: &str = "CHAOS_FLIGHT_RECORDER.json";

/// The chaos campaign: `seeds` randomized fault schedules per scheduler
/// in [`CHAOS_KINDS`], each drained to completion and hard-checked
/// against [`chaos_invariants`] plus an empty task slab (no leaked
/// work). Returns every row for reporting; the first violated invariant
/// aborts the campaign with a seed-labelled error, dumping the failing
/// cell's flight recorder to [`CHAOS_DUMP_PATH`] for triage.
pub fn chaos_campaign(cfg: &SystemConfig, seeds: usize, minutes: f64) -> anyhow::Result<Vec<Metrics>> {
    let mut rows = Vec::with_capacity(seeds * CHAOS_KINDS.len());
    for seed in 0..seeds as u64 {
        for kind in CHAOS_KINDS {
            let mut eng = chaos_scenario(cfg, kind, seed, minutes).engine();
            let m = eng.drain().clone();
            let leaked = eng.live_tasks();
            let verdict = if leaked != 0 {
                Err(anyhow::anyhow!(
                    "{}: chaos invariant violated: {leaked} tasks leaked in the slab after drain",
                    m.label
                ))
            } else {
                chaos_invariants(&m)
            };
            if let Err(e) = verdict {
                // Post-mortem: the cell's full event timeline, so triage
                // starts from the flight recorder instead of a replay.
                let note = match eng.trace_json() {
                    Some(json) => match std::fs::write(CHAOS_DUMP_PATH, json) {
                        Ok(()) => format!("flight recorder dumped to {CHAOS_DUMP_PATH}"),
                        Err(io) => format!("flight-recorder dump failed: {io}"),
                    },
                    None => "no flight recorder attached".to_string(),
                };
                return Err(e.context(note));
            }
            rows.push(m);
        }
    }
    Ok(rows)
}

// ---- energy & cloud-tier grids (beyond the paper) -----------------------

/// Default WAN for the cloud-tier grids: 20 Mb/s, 40 ms RTT — a cable
/// uplink an order of magnitude thinner than the 40 Mb/s LAN, so the
/// cloud is a spill valve, not a free lunch. A config that already
/// enables the cloud keeps its own numbers.
fn with_cloud(b: ScenarioBuilder, cfg: &SystemConfig) -> ScenarioBuilder {
    if cfg.cloud_wan_bps > 0.0 {
        b
    } else {
        b.cloud(20e6, 40.0)
    }
}

/// Battery-constrained fleet: `kinds` × the weighted-4 conveyor load with
/// every device on a `battery_j`-joule battery (Pi 2B power model) and
/// the cloud tier reachable. The comparison axis is
/// [`Metrics::deadline_met_per_kj`] — deadlines bought per kilojoule of
/// fleet energy — where the energy-aware scheduler must beat the
/// deadline-only ones. Labels: `KIND_bat<J>`.
pub fn energy_battery_grid(
    cfg: &SystemConfig,
    kinds: &[SchedKind],
    minutes: f64,
    battery_j: f64,
    model: &EnergyModel,
) -> Sweep {
    let frames = frames_for_minutes(cfg, minutes);
    let mut sweep = Sweep::new();
    for &kind in kinds {
        let b = ScenarioBuilder::new()
            .config(cfg.clone())
            .scheduler(kind)
            .trace(TraceSpec::Weighted(4))
            .frames(frames)
            .energy(model.clone())
            .battery_j(battery_j)
            .named(format!("{}_bat{}", kind.label(), battery_j as u64));
        sweep = sweep.add(with_cloud(b, cfg).build());
    }
    sweep
}

/// Cloud-burst-under-overload: `kinds` × {edge-only, +cloud} twins on an
/// MMPP arrival stream whose ON-state rate swamps the 4-device fleet.
/// Same seed and arrival plan per pair, so any deadline-met gap is the
/// cloud tier's doing — the acceptance claim is that the cloud twin wins
/// it on every scheduler. Labels: `KIND_edge` / `KIND_cloud`.
pub fn cloud_burst_grid(cfg: &SystemConfig, kinds: &[SchedKind], minutes: f64) -> Sweep {
    let burst = ArrivalProcess::Mmpp {
        on_rate_per_min: 36.0,
        off_rate_per_min: 1.0,
        mean_on_s: 60.0,
        mean_off_s: 60.0,
    };
    let catalog = Catalog::edge_serving(cfg);
    let mut sweep = Sweep::new();
    for &kind in kinds {
        for cloud in [false, true] {
            let mut b = ScenarioBuilder::new()
                .config(cfg.clone())
                .scheduler(kind)
                .workload(Workload::Generative(GenSpec {
                    arrivals: burst.clone(),
                    catalog: catalog.clone(),
                    admission_cap: 0,
                }))
                .minutes(minutes)
                .named(format!(
                    "{}_{}",
                    kind.label(),
                    if cloud { "cloud" } else { "edge" }
                ));
            if cloud {
                b = with_cloud(b, cfg);
            }
            sweep = sweep.add(b.build());
        }
    }
    sweep
}

/// Diurnal drain: a day-shaped run — quiet start, a congestion storm
/// through the middle third, quiet again — over a battery ladder
/// {mains, generous, tight} for each scheduler. The battery timelines
/// ([`Metrics::battery_final_j`]) and depletion counts trace how far
/// each budget carries the fleet through the storm. Labels:
/// `KIND_mains` / `KIND_bat<J>`.
pub fn diurnal_drain_grid(
    cfg: &SystemConfig,
    kinds: &[SchedKind],
    minutes: f64,
    batteries_j: &[f64],
    model: &EnergyModel,
) -> Sweep {
    let frames = frames_for_minutes(cfg, minutes);
    let total_s = minutes * 60.0;
    let mut sweep = Sweep::new();
    for &kind in kinds {
        for bat in std::iter::once(None).chain(batteries_j.iter().copied().map(Some)) {
            let label = match bat {
                None => format!("{}_mains", kind.label()),
                Some(j) => format!("{}_bat{}", kind.label(), j as u64),
            };
            let mut b = ScenarioBuilder::new()
                .config(cfg.clone())
                .scheduler(kind)
                .trace(TraceSpec::Weighted(4))
                .frames(frames)
                .energy(model.clone())
                .congestion_at(total_s / 3.0, 36e6, 0.75)
                .congestion_at(total_s * 2.0 / 3.0, 0.0, 0.0)
                .named(label);
            if let Some(j) = bat {
                b = b.battery_j(j);
            }
            sweep = sweep.add(with_cloud(b, cfg).build());
        }
    }
    sweep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> SystemConfig {
        SystemConfig { seed: 17, ..Default::default() }
    }

    #[test]
    fn frames_for_minutes_rounds_up() {
        let cfg = small_cfg();
        assert_eq!(frames_for_minutes(&cfg, 30.0), 96); // 1800 / 18.86 → 95.4
    }

    #[test]
    fn fig4_produces_eight_labelled_rows() {
        let runs = fig4_fig5(&small_cfg(), 3.0);
        assert_eq!(runs.len(), 8);
        assert_eq!(runs[0].label, "WPS_1");
        assert_eq!(runs[7].label, "RAS_4");
        for m in &runs {
            assert!(m.frames_total > 0);
        }
    }

    #[test]
    fn fig6_sweeps_five_intervals() {
        let runs = fig6_fig7(&small_cfg(), 2.0);
        assert_eq!(runs.len(), 5);
        // Higher probe frequency ⇒ at least as many bandwidth updates.
        assert!(runs[0].bandwidth_updates >= runs[4].bandwidth_updates);
    }

    #[test]
    fn fig8_sweeps_duty_cycles() {
        let runs = fig8_table2(&small_cfg(), 2.0);
        assert_eq!(runs.len(), 4);
        assert_eq!(runs[0].label, "0%");
        assert_eq!(runs[3].label, "75%");
    }

    #[test]
    fn fault_stress_pairs_clean_and_faulted_rows() {
        let runs = fault_stress(&small_cfg(), &[SchedKind::Ras], 3.0);
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].label, "RAS_4");
        assert_eq!(runs[1].label, "RAS_4F");
        // The clean row must be fault-free; the twin must inject.
        assert_eq!(runs[0].device_crashes, 0);
        assert_eq!(runs[0].retransmitted_mbits, 0.0);
        assert_eq!(runs[1].device_crashes, 1);
        assert!(runs[1].retransmitted_mbits > 0.0);
    }

    #[test]
    fn loadgen_grid_labels_and_offers_load() {
        let kinds = [SchedKind::Wps, SchedKind::Ras];
        let procs = default_loadgen_processes();
        let rows = loadgen_grid(&small_cfg(), &kinds, &procs, 4.0, 0).run();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].label, "WPS_poisson6");
        assert_eq!(rows[3].label, "RAS_mmpp24");
        for m in &rows {
            assert!(m.gen_arrivals > 0, "{}: no arrivals fired", m.label);
            assert!(m.offered_tasks > 0);
            assert_eq!(m.admission_dropped, 0, "{}: open admission must not drop", m.label);
        }
    }

    #[test]
    fn accuracy_frontier_labels_and_twins() {
        let cfg = small_cfg();
        let rows =
            accuracy_frontier(&cfg, &[SchedKind::Ras], &[1, 3], 4.0).run();
        // 2 rates × 2 depths × 1 scheduler.
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].label, "RAS_r12d1");
        assert_eq!(rows[3].label, "RAS_r24d3");
        for m in &rows {
            assert!(m.gen_arrivals > 0, "{}: plan fired no arrivals", m.label);
            assert_eq!(
                m.rung_completions.iter().sum::<u64>(),
                m.lp_deadline_met(),
                "{}: per-rung identity",
                m.label
            );
        }
        // Depth-1 twins never degrade.
        assert_eq!(rows[0].degraded_completions, 0);
        assert_eq!(rows[2].degraded_completions, 0);
    }

    #[test]
    fn anytime_grid_twins_share_load_and_cut_rows_truncate() {
        let rows = anytime_grid(&small_cfg(), &[SchedKind::Ras], 4.0).run();
        // 2 rates × {full, cut} × 1 scheduler.
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].label, "RAS_r12_full");
        assert_eq!(rows[1].label, "RAS_r12_cut");
        assert_eq!(rows[3].label, "RAS_r24_cut");
        for pair in rows.chunks(2) {
            let (full, cut) = (&pair[0], &pair[1]);
            assert_eq!(full.truncated_completions, 0, "{}: controller off", full.label);
            assert_eq!(full.pressure_events, 0);
            assert_eq!(
                full.offered_tasks, cut.offered_tasks,
                "twins must share the arrival plan"
            );
            for m in [full, cut] {
                assert_eq!(
                    m.lp_generated,
                    m.lp_completed_total() + m.lp_violations + m.lp_lost,
                    "{}: lp conservation",
                    m.label
                );
            }
        }
        // The overloaded cut twin actually truncates — the grid is not a
        // vacuous comparison of identical runs.
        assert!(
            rows[3].truncated_completions > 0,
            "r24 cut twin must truncate: {:?}",
            rows[3]
        );
    }

    #[test]
    fn parse_depths_is_strict() {
        assert_eq!(parse_depths("1,2,3").unwrap(), vec![1, 2, 3]);
        assert_eq!(parse_depths("2").unwrap(), vec![2]);
        assert!(parse_depths("0").is_err(), "below range");
        assert!(parse_depths("4").is_err(), "past the family depth");
        assert!(parse_depths("two").is_err(), "not a number");
        assert!(parse_depths("").is_err(), "empty list");
        assert!(parse_depths("1,-2").is_err(), "negative");
    }

    #[test]
    fn energy_battery_grid_drains_and_labels() {
        let rows = energy_battery_grid(
            &small_cfg(),
            &[SchedKind::Ras, SchedKind::Energy],
            3.0,
            200.0,
            &EnergyModel::pi2b(),
        )
        .run();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].label, "RAS_bat200");
        assert_eq!(rows[1].label, "ENERGY_bat200");
        for m in &rows {
            assert!(m.energy_total_j > 0.0, "{}: power model must integrate", m.label);
            assert_eq!(m.battery_final_j.len(), 4, "{}: per-device timeline", m.label);
            assert!(
                m.battery_depletions > 0,
                "{}: a 200 J budget cannot survive 3 minutes",
                m.label
            );
        }
    }

    #[test]
    fn cloud_burst_grid_pairs_edge_and_cloud_twins() {
        let rows = cloud_burst_grid(&small_cfg(), &[SchedKind::Ras], 3.0).run();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].label, "RAS_edge");
        assert_eq!(rows[1].label, "RAS_cloud");
        assert_eq!(rows[0].cloud_offloads, 0, "edge twin has no cloud tier");
        // Same seed ⇒ identical offered load; only placement differs.
        assert_eq!(rows[0].offered_tasks, rows[1].offered_tasks);
    }

    #[test]
    fn diurnal_drain_grid_spans_the_battery_ladder() {
        let rows = diurnal_drain_grid(
            &small_cfg(),
            &[SchedKind::Energy],
            3.0,
            &[400.0, 5000.0],
            &EnergyModel::pi2b(),
        )
        .run();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].label, "ENERGY_mains");
        assert_eq!(rows[1].label, "ENERGY_bat400");
        assert_eq!(rows[2].label, "ENERGY_bat5000");
        assert!(rows[0].battery_final_j.is_empty(), "mains row has no timeline");
        assert_eq!(rows[0].battery_depletions, 0);
        // The generous budget outlives (or at least matches) the tight one.
        assert!(rows[2].battery_depletions <= rows[1].battery_depletions);
    }

    #[test]
    fn chaos_scenario_is_seed_deterministic() {
        let cfg = small_cfg();
        let a = chaos_scenario(&cfg, SchedKind::Ras, 3, 2.0).run();
        let b = chaos_scenario(&cfg, SchedKind::Ras, 3, 2.0).run();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        // The schedule is sampled from the seed, not the scheduler, so
        // the same cocktail hits WPS and RAS alike (comparable rows).
        let w = chaos_scenario(&cfg, SchedKind::Wps, 3, 2.0).run();
        assert_eq!(a.device_crashes + a.partitions_started, w.device_crashes + w.partitions_started);
    }

    #[test]
    fn chaos_campaign_smoke_holds_invariants() {
        let rows = chaos_campaign(&small_cfg(), 3, 2.0).expect("chaos invariants must hold");
        assert_eq!(rows.len(), 9, "3 seeds x 3 schedulers");
        assert_eq!(rows[0].label, "WPS_chaos0");
        assert_eq!(rows[8].label, "MULTI_chaos2");
        // The cocktail actually bites somewhere in the campaign — a
        // vacuous pass (no faults sampled, detector never fired) would
        // make the invariant sweep meaningless.
        assert!(rows.iter().any(|m| m.device_crashes + m.partitions_started > 0));
        assert!(rows.iter().any(|m| m.devices_suspected > 0));
    }

    #[test]
    fn parallel_grid_equals_sequential_grid() {
        // The sweep fan-out must not change any row (engines are
        // independent and deterministic).
        let grid = weighted_grid(&small_cfg(), &[SchedKind::Wps, SchedKind::Ras], 2.0);
        let par = grid.run();
        let seq = grid.clone().threads(1).run();
        assert_eq!(par.len(), seq.len());
        for (p, q) in par.iter().zip(&seq) {
            assert_eq!(format!("{p:?}"), format!("{q:?}"));
        }
    }
}
