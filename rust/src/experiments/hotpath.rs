//! Hot-path benchmark suite: the measured counterpart of the slab /
//! incremental-medium rewrite, runnable as `medge bench [--quick]
//! [--json [PATH]]` or `cargo bench --bench hot_path`.
//!
//! Two kinds of rows feed the `BENCH_hotpath.json` trajectory:
//!
//! * **Head-to-head micro rows** — the optimised structure next to an
//!   in-binary replica of the structure it replaced (`*_baseline`
//!   rows: `HashMap` task lookup, the rescanning fluid medium). These
//!   keep the before/after comparison measurable from a single binary
//!   forever, not just across the PR that made the change.
//! * **Trajectory rows** — absolute numbers for the steady-state engine
//!   event rate, medium mutation churn, and the end-to-end sweep macro
//!   bench, tracked release over release by committing the JSON.
//!
//! The steady-state allocation gauge (`allocs/event`) is only emitted
//! when the calling binary installed
//! [`crate::util::bench::CountingAlloc`] as its global allocator and
//! passed a counter reader in [`SuiteOptions::alloc_count`].

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::scenario::{ScenarioBuilder, SchedKind, Sweep};
use crate::sim::netsim::Medium;
use crate::time::SimTime;
use crate::util::bench::{bench, BenchRow};
use crate::util::slab::Slab;
use crate::workload::trace::TraceSpec;

/// Suite knobs.
#[derive(Debug, Clone, Copy, Default)]
pub struct SuiteOptions {
    /// Short sampling targets + small scenario sizes (CI smoke job).
    pub quick: bool,
    /// Reader for the process-wide allocation counter, when the binary
    /// installed a counting global allocator.
    pub alloc_count: Option<fn() -> u64>,
}

/// The pre-rewrite fluid medium, reduced to the parts the comparison
/// needs: `HashMap` flow table, per-flow drain loop, full rescan in
/// `next_completion`. Semantics match the old `sim::netsim::Medium`.
struct RescanMedium {
    link_bps: f64,
    flows: HashMap<u64, f64>,
    last_update: SimTime,
}

impl RescanMedium {
    fn new(link_bps: f64) -> Self {
        Self { link_bps, flows: HashMap::new(), last_update: 0 }
    }

    fn per_flow_bps(&self) -> f64 {
        if self.flows.is_empty() {
            return self.link_bps;
        }
        self.link_bps / self.flows.len() as f64
    }

    fn drain_to(&mut self, now: SimTime) {
        if now == self.last_update || self.flows.is_empty() {
            self.last_update = now;
            return;
        }
        let dt_s = (now - self.last_update) as f64 / 1e6;
        let share = self.per_flow_bps();
        for r in self.flows.values_mut() {
            *r = (*r - share * dt_s).max(0.0);
        }
        self.last_update = now;
    }

    fn add_flow(&mut self, now: SimTime, id: u64, bytes: u64) {
        self.drain_to(now);
        self.flows.insert(id, bytes as f64 * 8.0);
    }

    fn remove_flow(&mut self, now: SimTime, id: u64) {
        self.drain_to(now);
        self.flows.remove(&id);
    }

    fn next_completion(&mut self, now: SimTime) -> Option<(SimTime, u64)> {
        self.drain_to(now);
        let share = self.per_flow_bps();
        let (id, rem) = self
            .flows
            .iter()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(a.0.cmp(b.0)))?;
        Some((now + (rem / share * 1e6).ceil() as u64, *id))
    }
}

fn sample(quick: bool) -> Duration {
    if quick {
        Duration::from_millis(40)
    } else {
        Duration::from_millis(250)
    }
}

/// Run every suite row, printing each as it completes.
pub fn run_suite(opts: &SuiteOptions) -> Vec<BenchRow> {
    let target = sample(opts.quick);
    let mut rows: Vec<BenchRow> = Vec::new();
    let mut push = |rows: &mut Vec<BenchRow>, r: &crate::util::bench::BenchResult| {
        rows.push(BenchRow::from(r));
    };

    println!("== hot_path micro: task lookup (N = 4096 live tasks) ==");
    const N: usize = 4096;
    {
        let mut map: HashMap<u64, u64> = HashMap::with_capacity(N);
        for id in 0..N as u64 {
            map.insert(id, id * 3);
        }
        let mut i = 0u64;
        let r = bench("task_lookup/hashmap_baseline", target, || {
            i = (i.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407)) % N as u64;
            map[&i]
        });
        push(&mut rows, &r);
    }
    {
        let mut slab: Slab<u64> = Slab::with_capacity(N);
        let handles: Vec<_> = (0..N as u64).map(|id| slab.insert(id * 3)).collect();
        let mut i = 0u64;
        let r = bench("task_lookup/slab", target, || {
            i = (i.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407)) % N as u64;
            *slab.get(handles[i as usize]).unwrap()
        });
        push(&mut rows, &r);
    }

    println!("\n== hot_path micro: medium next_completion (24 live flows) ==");
    // Identical op pattern on both media: advance time, predict, and
    // occasionally churn a flow — the engine's arm_medium cadence.
    {
        let mut m = RescanMedium::new(40e6);
        for id in 0..24u64 {
            m.add_flow(0, id, 150_000 + id * 10_000);
        }
        let mut t: SimTime = 0;
        let mut churn = 24u64;
        let r = bench("medium_next_completion/rescan_baseline", target, || {
            t += 100;
            if t % 5_000 == 0 {
                m.remove_flow(t, churn - 24);
                m.add_flow(t, churn, 150_000);
                churn += 1;
            }
            m.next_completion(t)
        });
        push(&mut rows, &r);
    }
    {
        let mut m = Medium::new(40e6, 0.0);
        for id in 0..24u64 {
            m.add_flow(0, id, 150_000 + id * 10_000);
        }
        let mut t: SimTime = 0;
        let mut churn = 24u64;
        let r = bench("medium_next_completion/incremental", target, || {
            t += 100;
            if t % 5_000 == 0 {
                m.remove_flow(t, churn - 24);
                m.add_flow(t, churn, 150_000);
                churn += 1;
            }
            m.next_completion(t)
        });
        push(&mut rows, &r);
    }

    println!("\n== hot_path macro: steady-state engine event rate ==");
    let frames = if opts.quick { 8 } else { 24 };
    // `ladder` attaches the three-rung stage-3 model family: the delta
    // between the laddered row and the baseline is the whole per-event
    // cost of the degradation machinery (ladder dispatch, rung
    // accounting, and any step-down retries the scheduler performs).
    let scenario = |kind: SchedKind, ladder: Option<crate::workload::gen::Ladder>| {
        let mut b = ScenarioBuilder::new()
            .scheduler(kind)
            .trace(TraceSpec::Weighted(3))
            .frames(frames)
            .seed(42);
        if let Some(l) = ladder {
            b = b.lp_ladder(l);
        }
        b.build()
    };
    let steady_row = |name: &str, s: crate::scenario::Scenario| {
        let mut eng = s.engine();
        let t0 = Instant::now();
        let mut events = 0u64;
        while eng.step() {
            events += 1;
        }
        let ns_per_event = t0.elapsed().as_nanos() as f64 / events.max(1) as f64;
        BenchRow {
            name: name.to_string(),
            unit: "ns/op".to_string(),
            iters: events,
            value: ns_per_event,
            mean_ns: ns_per_event,
            p95_ns: ns_per_event,
            throughput_per_s: 1e9 / ns_per_event.max(0.1),
        }
    };
    // Cloud-tier steady state rides the same conveyor load with the WAN
    // tier and the Pi 2B power model on: the delta against the plain row
    // is the whole per-event cost of the energy integrator plus the
    // cloud placement/upload machinery.
    let cloud_scenario = ScenarioBuilder::new()
        .scheduler(SchedKind::Energy)
        .trace(TraceSpec::Weighted(3))
        .frames(frames)
        .seed(42)
        .cloud(20e6, 40.0)
        .energy(crate::energy::EnergyModel::pi2b())
        .build();
    // Anytime steady state: the staged ladder plus the pressure
    // controller surveying at the grid cadence — the delta against the
    // laddered row is the whole per-event cost of the stage-boundary
    // chains and pressure surveys.
    let anytime_scenario = ScenarioBuilder::new()
        .scheduler(SchedKind::Ras)
        .trace(TraceSpec::Weighted(3))
        .frames(frames)
        .seed(42)
        .lp_ladder(crate::workload::gen::Ladder::stage3_family_staged(
            &crate::config::SystemConfig::default(),
        ))
        .pressure(
            crate::experiments::ANYTIME_CHECK_S,
            crate::experiments::ANYTIME_BACKLOG,
        )
        .build();
    for (name, s) in [
        ("engine_event/steady_state", scenario(SchedKind::Ras, None)),
        (
            "engine_event/steady_state_laddered",
            scenario(
                SchedKind::Ras,
                Some(crate::workload::gen::Ladder::stage3_family(
                    &crate::config::SystemConfig::default(),
                )),
            ),
        ),
        ("engine_event/steady_state_anytime", anytime_scenario),
        ("engine_event/steady_state_cloud", cloud_scenario),
    ] {
        let row = steady_row(name, s);
        println!("{}", row.report());
        rows.push(row);
    }

    // Steady-state allocation gauge: warm the run up, then count
    // allocations per event over the tail. The engine's own event
    // handling targets zero; residual allocations come from scheduler
    // decision vectors (outside this PR's scope) and amortised queue
    // growth.
    if let Some(counter) = opts.alloc_count {
        let mut eng = scenario(SchedKind::Ras, None).engine();
        let warmup = 500u64;
        let mut events = 0u64;
        let mut tail_events = 0u64;
        let mut snap = 0u64;
        while eng.step() {
            events += 1;
            if events == warmup {
                snap = counter();
            }
            if events > warmup {
                tail_events += 1;
            }
        }
        let allocs = if tail_events > 0 { counter().saturating_sub(snap) } else { 0 };
        let per_event = allocs as f64 / tail_events.max(1) as f64;
        let row =
            BenchRow::gauge("engine_event/steady_state_allocs", "allocs/event", tail_events, per_event);
        println!("{}", row.report());
        rows.push(row);
    }

    println!("\n== hot_path macro: per-phase timing split ==");
    {
        // ROADMAP item 5's instrumented profile: one steady-state run
        // with the timing knob on, split into the engine's four phases
        // (dispatch is inclusive of the nested scheduler share).
        // Wall-clock trajectory gauges, not a head-to-head — the same
        // scenario as `engine_event/steady_state`, so the phase rows sum
        // to roughly that row's ns/event.
        let s = ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(3))
            .frames(frames)
            .seed(42)
            .timing(true)
            .build();
        let mut eng = s.engine();
        let mut events = 0u64;
        while eng.step() {
            events += 1;
        }
        let m = eng.drain().clone();
        for (phase, ns) in [
            ("dispatch", m.phase_dispatch_ns),
            ("sched", m.phase_sched_ns),
            ("medium", m.phase_medium_ns),
            ("compact", m.phase_compact_ns),
        ] {
            let row = BenchRow::gauge(
                &format!("engine_phase/{phase}"),
                "ns/event",
                events,
                ns as f64 / events.max(1) as f64,
            );
            println!("{}", row.report());
            rows.push(row);
        }
    }

    println!("\n== hot_path macro: fleet scale ladder ==");
    {
        // The scale acceptance gate (ROADMAP item 1): ns/event may not
        // grow more than ~2× from 100 devices to 100k. Past 512 devices
        // the schedulers shard the fleet into ~√n-device cells, the
        // conveyor chains one TraceFrame event per cell, and the
        // calendar queue keeps pops O(log bucket) — so per-event cost
        // should stay near-flat in fleet size. Quick mode (the CI smoke
        // job) climbs 100 → 10k; the full suite reaches 100k.
        let ladder: &[usize] = if opts.quick { &[100, 10_000] } else { &[100, 10_000, 100_000] };
        let ladder_frames = if opts.quick { 2 } else { 4 };
        for &n in ladder {
            let s = ScenarioBuilder::new()
                .scheduler(SchedKind::Ras)
                .trace(TraceSpec::Weighted(2))
                .devices(n)
                .frames(ladder_frames)
                .seed(42)
                .build();
            let label = if n % 1_000 == 0 { format!("{}k", n / 1_000) } else { n.to_string() };
            let row = steady_row(&format!("engine_event/steady_state_{label}"), s);
            println!("{}", row.report());
            rows.push(row);
        }
    }

    println!("\n== hot_path macro: end-to-end sweep ==");
    {
        let sweep_frames = if opts.quick { 4 } else { 12 };
        let mut sweep = Sweep::new().threads(2);
        for kind in [SchedKind::Wps, SchedKind::Ras] {
            for load in [2u8, 3] {
                sweep = sweep.add(
                    ScenarioBuilder::new()
                        .scheduler(kind)
                        .trace(TraceSpec::Weighted(load))
                        .frames(sweep_frames)
                        .seed(7)
                        .build(),
                );
            }
        }
        let t0 = Instant::now();
        let out = sweep.run();
        let el = t0.elapsed();
        let ns = el.as_nanos() as f64;
        let row = BenchRow {
            name: "sweep_macro/end_to_end".to_string(),
            unit: "ns/op".to_string(),
            iters: out.len() as u64,
            value: ns / out.len().max(1) as f64,
            mean_ns: ns / out.len().max(1) as f64,
            p95_ns: ns / out.len().max(1) as f64,
            throughput_per_s: out.len() as f64 / el.as_secs_f64().max(1e-9),
        };
        println!("{}  ({} rows)", row.report(), out.len());
        rows.push(row);
    }

    rows
}
