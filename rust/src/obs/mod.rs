//! Observability: flight-recorder tracing, explainable placement
//! decisions, and a Perfetto-exportable run timeline.
//!
//! The engine can carry an optional [`FlightRecorder`] — a bounded
//! ring buffer of structured [`TraceEvent`]s covering the full task
//! lifecycle (arrival → admission → placement → transfer → exec →
//! complete/violation, plus preemption, re-offer, rung-walk, hedge and
//! retry), probe rounds, bandwidth-estimator updates, detector state
//! transitions, partition/heal windows, and battery/cloud transitions.
//! Schedulers additionally emit [`DecisionRecord`]s from inside their
//! `schedule_*` paths (per-candidate scores, rejection reasons, the
//! chosen rung) so the RAS/WPS disagreements the paper studies become
//! inspectable data instead of println archaeology.
//!
//! ## Determinism contract
//!
//! Every recorded field is **simulated** state: sim-time timestamps, a
//! run-local sequence counter, task/device ids, scores the scheduler
//! already computed. No wall clock, no RNG, no allocation-order
//! artifacts — so a recording is bit-identical across repeated runs and
//! across sweep thread counts, and the recorder itself draws nothing
//! from the engine's RNG streams. With recording disabled (the default)
//! the engine keeps `None` and every hook is a skipped `Option` check:
//! zero events, zero draws, byte-identical `json_rows` (locked by the
//! `zero_trace_knob` golden test).
//!
//! ## Export
//!
//! [`FlightRecorder::perfetto_json`] serialises the buffer to the
//! Chrome trace event format (the JSON Perfetto and `chrome://tracing`
//! load directly): one track per device plus a link track and a cloud
//! track, "X" complete spans for exec/transfer/upload/probe windows
//! reconstructed by pairing start/finish records, and "i" instant
//! events for violations, suspicions, and placement decisions. See
//! README §Observability for the cookbook.

use crate::coordinator::task::{DeviceId, TaskId};
use crate::time::SimTime;

/// Default ring capacity when a scenario enables recording without
/// choosing one: large enough to hold a full conveyor golden run, small
/// enough (~a few MB) to keep per-seed chaos recorders cheap.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Per-phase wall-clock accumulators for the engine's hot path, behind
/// an off-by-default knob ([`crate::sim::engine::RunExtras::timing`]).
/// Wall-clock values are **not** deterministic — they never feed the
/// simulation, never enter golden comparisons, and surface only through
/// the `phase_*_ns` gauge fields (zero whenever the knob is off).
/// `dispatch_ns` is inclusive of the nested scheduler time.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTimers {
    /// Total wall time inside `Engine::handle` (event dispatch).
    pub dispatch_ns: u64,
    /// Wall time inside placement `Scheduler::on_event` calls.
    pub sched_ns: u64,
    /// Wall time advancing the shared medium's fluid model.
    pub medium_ns: u64,
    /// Wall time in event-queue compaction sweeps.
    pub compact_ns: u64,
}

/// Which [`PhaseTimers`] accumulator a measured interval belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Dispatch,
    Sched,
    Medium,
    Compact,
}

impl PhaseTimers {
    /// Fold an elapsed interval into the chosen accumulator.
    pub fn add(&mut self, phase: Phase, ns: u64) {
        match phase {
            Phase::Dispatch => self.dispatch_ns += ns,
            Phase::Sched => self.sched_ns += ns,
            Phase::Medium => self.medium_ns += ns,
            Phase::Compact => self.compact_ns += ns,
        }
    }
}

/// Why a scheduler passed over (or refused) a candidate placement.
/// The taxonomy mirrors `Metrics::reject_reasons` but is per-decision
/// and per-candidate instead of run-aggregated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// No computation/communication window fits before the deadline.
    WindowInfeasible,
    /// The failure detector believes the device is down.
    Suspected,
    /// Battery-aware policy refused the device (depleted or reserved).
    Battery,
    /// The device's availability cell collapsed (sharded fleet) or it
    /// had no free cores at any acceptable configuration.
    CellCollapsed,
    /// The device is offline (crashed / left / partitioned).
    Offline,
}

impl RejectReason {
    /// Stable lowercase label used in exports.
    pub fn label(&self) -> &'static str {
        match self {
            RejectReason::WindowInfeasible => "window_infeasible",
            RejectReason::Suspected => "suspected",
            RejectReason::Battery => "battery",
            RejectReason::CellCollapsed => "cell_collapsed",
            RejectReason::Offline => "offline",
        }
    }
}

/// One candidate the scheduler considered for a placement.
#[derive(Debug, Clone, PartialEq)]
pub struct CandidateScore {
    pub device: DeviceId,
    /// Scheduler-specific figure of merit (RAS: window slack µs; WPS:
    /// completion-time score; ENERGY: estimated joules). Lower/higher
    /// semantics are per-scheduler; the record is evidence, not a rank.
    pub score: f64,
    /// `None` when the candidate was feasible (it may still lose the
    /// comparison); `Some(reason)` when it was ruled out.
    pub reject: Option<RejectReason>,
}

/// An explainable placement decision, emitted from inside a scheduler's
/// `schedule_*` path when the engine has explainability enabled.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionRecord {
    /// Which scheduler decided (`"RAS"`, `"WPS"`, `"MULTI"`, `"ENERGY"`).
    pub scheduler: &'static str,
    /// Representative task (first of the batch for LP requests).
    pub task: TaskId,
    /// Tasks covered by this decision (1 for HP, batch size for LP).
    pub batch: usize,
    pub high_priority: bool,
    /// Every candidate that was scored or ruled out, in consideration
    /// order.
    pub candidates: Vec<CandidateScore>,
    /// Winning `(device, cores)`; `None` when the request was rejected.
    pub chosen: Option<(DeviceId, u8)>,
    /// Degradation ladder rung the placement committed to (0 = full
    /// model), when a rung-walk was involved.
    pub rung: Option<usize>,
    /// The batch went to the cloud tier instead of an edge device.
    pub cloud: bool,
}

impl DecisionRecord {
    /// `"placed"` / `"cloud"` / `"rejected"` — the outcome label exports
    /// use.
    pub fn outcome(&self) -> &'static str {
        if self.cloud {
            "cloud"
        } else if self.chosen.is_some() {
            "placed"
        } else {
            "rejected"
        }
    }
}

/// Everything the flight recorder can witness. Fields carry simulated
/// state only (see the module docs' determinism contract).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A conveyor frame's requests entered the system.
    FrameArrive { index: usize },
    /// A generative-workload arrival fired.
    GenArrive { index: usize },
    /// Tasks dropped at admission (queue bound) or because every device
    /// was offline.
    AdmissionDrop { tasks: usize },
    /// High-priority placement succeeded.
    HpPlace { task: TaskId, device: DeviceId, cores: u8 },
    /// High-priority placement failed; the frame's deadline is lost.
    HpReject { task: TaskId },
    /// A low-priority task was preempted by an HP arrival.
    Preempt { task: TaskId, device: DeviceId },
    /// Low-priority placement succeeded (rung = committed ladder rung).
    LpPlace { task: TaskId, device: DeviceId, cores: u8, rung: usize },
    /// A low-priority batch was rejected outright.
    LpReject { tasks: usize },
    /// Crash-lost tasks re-entered scheduling.
    Reoffer { tasks: usize },
    /// An offloaded input transfer started on the shared link.
    TransferStart { task: TaskId, device: DeviceId },
    /// The transfer drained; compute starts next.
    TransferDone { task: TaskId },
    /// A cloud upload started on the WAN.
    CloudUploadStart { task: TaskId },
    /// The cloud upload drained.
    CloudUploadDone { task: TaskId },
    /// Compute began on a device.
    ExecStart { task: TaskId, device: DeviceId },
    /// A task finished (violated = past its deadline).
    Complete { task: TaskId, device: DeviceId, high_priority: bool, violated: bool },
    /// Deadline violation (also flagged on the matching `Complete`).
    Violation { task: TaskId },
    /// The recovery layer cancelled a timed-out offload and retried.
    Retry { task: TaskId, attempt: u32 },
    /// A hedged duplicate launched for a slow offload.
    HedgeLaunch { task: TaskId, device: DeviceId },
    /// A bandwidth probe round began against `device`.
    ProbeStart { device: DeviceId },
    /// The round ended with `survivors` of its pings delivered.
    ProbeEnd { device: DeviceId, survivors: u64 },
    /// The EWMA bandwidth estimate moved.
    BandwidthUpdate { est_bps: f64 },
    /// The estimate aged past the staleness horizon.
    BandwidthStale,
    /// The failure detector suspected (or confirmed) a device.
    DetectorSuspect { device: DeviceId, confirmed: bool },
    /// A heartbeat cleared a suspected device.
    DetectorClear { device: DeviceId },
    PartitionStart { device: DeviceId },
    PartitionHeal { device: DeviceId },
    DeviceJoin { device: DeviceId },
    DeviceLeave { device: DeviceId },
    DeviceCrash { device: DeviceId },
    DeviceRecover { device: DeviceId },
    BatteryDeplete { device: DeviceId },
    /// A running anytime execution crossed a stage boundary and kept
    /// going (stage = the 1-based stage that just completed).
    StageBoundary { task: TaskId, device: DeviceId, stage: u8 },
    /// The pressure controller's cut landed: the task completed at
    /// `stage` instead of its full depth.
    Truncate { task: TaskId, device: DeviceId, stage: u8 },
    /// An explainable scheduler decision (see [`DecisionRecord`]).
    Decision(DecisionRecord),
}

/// A timestamped, sequence-numbered trace record.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    pub at: SimTime,
    /// Run-local monotonic sequence (total events *seen*, including any
    /// that were later overwritten).
    pub seq: u64,
    pub event: TraceEvent,
}

/// Anything that can consume trace records. [`FlightRecorder`] is the
/// in-tree sink; the trait keeps the engine decoupled from the storage
/// policy so tests (and future streaming exporters) can substitute
/// their own.
pub trait TraceSink {
    fn record(&mut self, at: SimTime, event: TraceEvent);
}

/// Bounded ring-buffer trace sink: fixed capacity, overwrite-oldest.
/// The crash-dump shape — when a chaos invariant trips, the last
/// `capacity` events leading up to the failure are exactly what is
/// needed, and a runaway run cannot exhaust memory.
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    capacity: usize,
    /// Ring storage; `head` indexes the oldest record once full.
    buf: Vec<TraceRecord>,
    head: usize,
    seq: u64,
    overwritten: u64,
}

impl FlightRecorder {
    /// A recorder holding at most `capacity` records. Zero capacity is
    /// the explicit OFF value at the scenario layer and never reaches
    /// here; it is clamped to 1 for safety.
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self { capacity, buf: Vec::new(), head: 0, seq: 0, overwritten: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Records currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total events witnessed, including overwritten ones.
    pub fn total_seen(&self) -> u64 {
        self.seq
    }

    /// Events evicted by the ring bound.
    pub fn overwritten(&self) -> u64 {
        self.overwritten
    }

    /// Records in arrival order (oldest surviving first).
    pub fn records(&self) -> impl Iterator<Item = &TraceRecord> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }

    /// How many surviving records are scheduler [`DecisionRecord`]s.
    pub fn decisions(&self) -> usize {
        self.records().filter(|r| matches!(r.event, TraceEvent::Decision(_))).count()
    }

    /// Serialise to Chrome-trace/Perfetto JSON. `n_devices` sizes the
    /// track table: tid 0 is the controller, 1..=n the devices, n+1 the
    /// shared link, n+2 the cloud tier. Exec/transfer/upload/probe
    /// windows whose start *and* finish survived the ring become "X"
    /// complete spans; everything else (and unpaired starts) become "i"
    /// instants. Output is byte-stable for identical buffers.
    pub fn perfetto_json(&self, n_devices: usize) -> String {
        let ctrl = 0usize;
        let dev = |d: DeviceId| d + 1;
        let link = n_devices + 1;
        let cloud = n_devices + 2;
        let mut out = String::with_capacity(256 + self.buf.len() * 96);
        out.push_str("{\"displayTimeUnit\": \"ms\",\n\"traceEvents\": [\n");
        let mut first = true;
        let mut push = |out: &mut String, line: String| {
            if !first {
                out.push_str(",\n");
            }
            first = false;
            out.push_str(&line);
        };
        // Track naming metadata.
        push(&mut out, meta_event("process_name", 0, "medge sim"));
        push(&mut out, meta_thread(ctrl, "controller"));
        for d in 0..n_devices {
            push(&mut out, meta_thread(dev(d), &format!("device {d}")));
        }
        push(&mut out, meta_thread(link, "link"));
        push(&mut out, meta_thread(cloud, "cloud"));

        // Span pairing state: open windows keyed by task id. Linear
        // scans — open windows are bounded by in-flight work, and the
        // exporter is off the simulation path entirely.
        let mut exec_open: Vec<(TaskId, SimTime, DeviceId)> = Vec::new();
        let mut xfer_open: Vec<(TaskId, SimTime, DeviceId)> = Vec::new();
        let mut wan_open: Vec<(TaskId, SimTime)> = Vec::new();
        let mut probe_open: Vec<(DeviceId, SimTime)> = Vec::new();
        let take = |open: &mut Vec<(TaskId, SimTime, DeviceId)>, t: TaskId| {
            open.iter().position(|&(id, _, _)| id == t).map(|p| open.swap_remove(p))
        };

        for r in self.records() {
            let ts = r.at;
            match &r.event {
                TraceEvent::FrameArrive { index } => {
                    push(&mut out, instant(ts, ctrl, &format!("frame {index}"), ""));
                }
                TraceEvent::GenArrive { index } => {
                    push(&mut out, instant(ts, ctrl, &format!("arrival {index}"), ""));
                }
                TraceEvent::AdmissionDrop { tasks } => {
                    push(
                        &mut out,
                        instant(ts, ctrl, "admission_drop", &format!("\"tasks\": {tasks}")),
                    );
                }
                TraceEvent::HpPlace { task, device, cores } => {
                    push(
                        &mut out,
                        instant(
                            ts,
                            dev(*device),
                            &format!("hp_place #{task}"),
                            &format!("\"cores\": {cores}"),
                        ),
                    );
                }
                TraceEvent::HpReject { task } => {
                    push(&mut out, instant(ts, ctrl, &format!("hp_reject #{task}"), ""));
                }
                TraceEvent::Preempt { task, device } => {
                    push(&mut out, instant(ts, dev(*device), &format!("preempt #{task}"), ""));
                }
                TraceEvent::LpPlace { task, device, cores, rung } => {
                    push(
                        &mut out,
                        instant(
                            ts,
                            dev(*device),
                            &format!("lp_place #{task}"),
                            &format!("\"cores\": {cores}, \"rung\": {rung}"),
                        ),
                    );
                }
                TraceEvent::LpReject { tasks } => {
                    push(&mut out, instant(ts, ctrl, "lp_reject", &format!("\"tasks\": {tasks}")));
                }
                TraceEvent::Reoffer { tasks } => {
                    push(&mut out, instant(ts, ctrl, "reoffer", &format!("\"tasks\": {tasks}")));
                }
                TraceEvent::TransferStart { task, device } => {
                    xfer_open.push((*task, ts, *device));
                }
                TraceEvent::TransferDone { task } => match take(&mut xfer_open, *task) {
                    Some((_, t0, d)) => push(
                        &mut out,
                        span(t0, ts, link, &format!("xfer #{task}"), &format!("\"dest\": {d}")),
                    ),
                    None => push(&mut out, instant(ts, link, &format!("xfer_done #{task}"), "")),
                },
                TraceEvent::CloudUploadStart { task } => {
                    wan_open.push((*task, ts));
                }
                TraceEvent::CloudUploadDone { task } => {
                    match wan_open.iter().position(|&(id, _)| id == *task) {
                        Some(p) => {
                            let (_, t0) = wan_open.swap_remove(p);
                            push(&mut out, span(t0, ts, cloud, &format!("upload #{task}"), ""));
                        }
                        None => {
                            push(&mut out, instant(ts, cloud, &format!("upload_done #{task}"), ""))
                        }
                    }
                }
                TraceEvent::ExecStart { task, device } => {
                    exec_open.push((*task, ts, *device));
                }
                TraceEvent::Complete { task, device, high_priority, violated } => {
                    let args = format!(
                        "\"hp\": {high_priority}, \"violated\": {violated}"
                    );
                    match take(&mut exec_open, *task) {
                        Some((_, t0, d)) => {
                            push(&mut out, span(t0, ts, dev(d), &format!("exec #{task}"), &args))
                        }
                        None => push(
                            &mut out,
                            instant(ts, dev(*device), &format!("complete #{task}"), &args),
                        ),
                    }
                }
                TraceEvent::Violation { task } => {
                    push(&mut out, global_instant(ts, ctrl, &format!("violation #{task}")));
                }
                TraceEvent::Retry { task, attempt } => {
                    push(
                        &mut out,
                        instant(
                            ts,
                            ctrl,
                            &format!("retry #{task}"),
                            &format!("\"attempt\": {attempt}"),
                        ),
                    );
                }
                TraceEvent::HedgeLaunch { task, device } => {
                    push(&mut out, instant(ts, dev(*device), &format!("hedge #{task}"), ""));
                }
                TraceEvent::ProbeStart { device } => {
                    probe_open.push((*device, ts));
                }
                TraceEvent::ProbeEnd { device, survivors } => {
                    let args = format!("\"survivors\": {survivors}");
                    match probe_open.iter().position(|&(d, _)| d == *device) {
                        Some(p) => {
                            let (_, t0) = probe_open.swap_remove(p);
                            push(&mut out, span(t0, ts, link, "probe", &args));
                        }
                        None => push(&mut out, instant(ts, link, "probe_end", &args)),
                    }
                }
                TraceEvent::BandwidthUpdate { est_bps } => {
                    push(
                        &mut out,
                        instant(ts, ctrl, "bw_update", &format!("\"est_bps\": {}", num(*est_bps))),
                    );
                }
                TraceEvent::BandwidthStale => {
                    push(&mut out, instant(ts, ctrl, "bw_stale", ""));
                }
                TraceEvent::DetectorSuspect { device, confirmed } => {
                    push(
                        &mut out,
                        global_instant(
                            ts,
                            dev(*device),
                            if *confirmed { "confirm_down" } else { "suspect" },
                        ),
                    );
                }
                TraceEvent::DetectorClear { device } => {
                    push(&mut out, instant(ts, dev(*device), "suspicion_cleared", ""));
                }
                TraceEvent::PartitionStart { device } => {
                    push(&mut out, instant(ts, dev(*device), "partition", ""));
                }
                TraceEvent::PartitionHeal { device } => {
                    push(&mut out, instant(ts, dev(*device), "heal", ""));
                }
                TraceEvent::DeviceJoin { device } => {
                    push(&mut out, instant(ts, dev(*device), "join", ""));
                }
                TraceEvent::DeviceLeave { device } => {
                    push(&mut out, instant(ts, dev(*device), "leave", ""));
                }
                TraceEvent::DeviceCrash { device } => {
                    push(&mut out, global_instant(ts, dev(*device), "crash"));
                }
                TraceEvent::DeviceRecover { device } => {
                    push(&mut out, instant(ts, dev(*device), "recover", ""));
                }
                TraceEvent::BatteryDeplete { device } => {
                    push(&mut out, global_instant(ts, dev(*device), "battery_depleted"));
                }
                TraceEvent::StageBoundary { task, device, stage } => {
                    push(
                        &mut out,
                        instant(
                            ts,
                            dev(*device),
                            &format!("stage #{task}"),
                            &format!("\"stage\": {stage}"),
                        ),
                    );
                }
                TraceEvent::Truncate { task, device, stage } => {
                    push(
                        &mut out,
                        instant(
                            ts,
                            dev(*device),
                            &format!("truncate #{task}"),
                            &format!("\"stage\": {stage}"),
                        ),
                    );
                }
                TraceEvent::Decision(d) => {
                    push(&mut out, instant(ts, ctrl, &decision_name(d), &decision_args(d)));
                }
            }
        }
        // Unpaired starts: the finish never happened (abandoned work) or
        // was recorded only — render what we know as instants.
        for (task, t0, d) in exec_open {
            push(&mut out, instant(t0, dev(d), &format!("exec_start #{task}"), ""));
        }
        for (task, t0, _) in xfer_open {
            push(&mut out, instant(t0, link, &format!("xfer_start #{task}"), ""));
        }
        for (task, t0) in wan_open {
            push(&mut out, instant(t0, cloud, &format!("upload_start #{task}"), ""));
        }
        for (d, t0) in probe_open {
            push(&mut out, instant(t0, link, &format!("probe_start d{d}"), ""));
        }
        out.push_str("\n]\n}\n");
        out
    }
}

impl TraceSink for FlightRecorder {
    fn record(&mut self, at: SimTime, event: TraceEvent) {
        self.seq += 1;
        let rec = TraceRecord { at, seq: self.seq, event };
        if self.buf.len() < self.capacity {
            self.buf.push(rec);
        } else {
            self.buf[self.head] = rec;
            self.head = (self.head + 1) % self.capacity;
            self.overwritten += 1;
        }
    }
}

/// Shortest-round-trip float rendering, matching `report::json_f64`:
/// non-finite values become `null` so the output stays valid JSON.
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn meta_event(name: &str, pid: usize, value: &str) -> String {
    format!(
        "{{\"name\": \"{name}\", \"ph\": \"M\", \"pid\": {pid}, \"tid\": 0, \
         \"args\": {{\"name\": \"{}\"}}}}",
        json_escape(value)
    )
}

fn meta_thread(tid: usize, name: &str) -> String {
    format!(
        "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": 0, \"tid\": {tid}, \
         \"args\": {{\"name\": \"{}\"}}}}",
        json_escape(name)
    )
}

/// A thread-scoped instant event; `args` is a pre-rendered `"k": v`
/// list (may be empty).
fn instant(ts: SimTime, tid: usize, name: &str, args: &str) -> String {
    format!(
        "{{\"name\": \"{}\", \"ph\": \"i\", \"s\": \"t\", \"ts\": {ts}, \"pid\": 0, \
         \"tid\": {tid}, \"args\": {{{args}}}}}",
        json_escape(name)
    )
}

/// A globally-scoped instant (violations, crashes, suspicions): drawn
/// full-height in the Perfetto UI.
fn global_instant(ts: SimTime, tid: usize, name: &str) -> String {
    format!(
        "{{\"name\": \"{}\", \"ph\": \"i\", \"s\": \"g\", \"ts\": {ts}, \"pid\": 0, \
         \"tid\": {tid}, \"args\": {{}}}}",
        json_escape(name)
    )
}

/// An "X" complete span from `t0` to `t1`.
fn span(t0: SimTime, t1: SimTime, tid: usize, name: &str, args: &str) -> String {
    format!(
        "{{\"name\": \"{}\", \"ph\": \"X\", \"ts\": {t0}, \"dur\": {}, \"pid\": 0, \
         \"tid\": {tid}, \"args\": {{{args}}}}}",
        json_escape(name),
        t1.saturating_sub(t0)
    )
}

fn decision_name(d: &DecisionRecord) -> String {
    format!("decide[{}] #{} {}", d.scheduler, d.task, d.outcome())
}

fn decision_args(d: &DecisionRecord) -> String {
    let mut cands = String::from("[");
    for (i, c) in d.candidates.iter().enumerate() {
        if i > 0 {
            cands.push_str(", ");
        }
        cands.push_str(&format!(
            "{{\"device\": {}, \"score\": {}, \"reject\": {}}}",
            c.device,
            num(c.score),
            match c.reject {
                Some(r) => format!("\"{}\"", r.label()),
                None => "null".to_string(),
            }
        ));
    }
    cands.push(']');
    let chosen = match d.chosen {
        Some((dev, cores)) => format!("{{\"device\": {dev}, \"cores\": {cores}}}"),
        None => "null".to_string(),
    };
    let rung = match d.rung {
        Some(r) => r.to_string(),
        None => "null".to_string(),
    };
    format!(
        "\"scheduler\": \"{}\", \"batch\": {}, \"hp\": {}, \"outcome\": \"{}\", \
         \"chosen\": {chosen}, \"rung\": {rung}, \"cloud\": {}, \"candidates\": {cands}",
        d.scheduler,
        d.batch,
        d.high_priority,
        d.outcome(),
        d.cloud
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(cap: usize) -> FlightRecorder {
        FlightRecorder::new(cap)
    }

    #[test]
    fn ring_holds_then_overwrites_oldest() {
        let mut r = rec(3);
        for i in 0..3u64 {
            r.record(i * 10, TraceEvent::GenArrive { index: i as usize });
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.overwritten(), 0);
        let seqs: Vec<u64> = r.records().map(|x| x.seq).collect();
        assert_eq!(seqs, vec![1, 2, 3]);
        // The 4th event evicts the 1st; order stays oldest-first.
        r.record(30, TraceEvent::GenArrive { index: 3 });
        assert_eq!(r.len(), 3);
        assert_eq!(r.overwritten(), 1);
        assert_eq!(r.total_seen(), 4);
        let seqs: Vec<u64> = r.records().map(|x| x.seq).collect();
        assert_eq!(seqs, vec![2, 3, 4]);
        // Wrap fully around: only the newest 3 survive.
        for i in 4..10u64 {
            r.record(i * 10, TraceEvent::GenArrive { index: i as usize });
        }
        let seqs: Vec<u64> = r.records().map(|x| x.seq).collect();
        assert_eq!(seqs, vec![8, 9, 10]);
        assert_eq!(r.overwritten(), 7);
    }

    #[test]
    fn zero_capacity_is_clamped_not_panicking() {
        let mut r = rec(0);
        assert_eq!(r.capacity(), 1);
        r.record(0, TraceEvent::BandwidthStale);
        r.record(1, TraceEvent::BandwidthStale);
        assert_eq!(r.len(), 1);
        assert_eq!(r.total_seen(), 2);
    }

    #[test]
    fn decisions_are_counted() {
        let mut r = rec(8);
        r.record(0, TraceEvent::FrameArrive { index: 0 });
        r.record(
            1,
            TraceEvent::Decision(DecisionRecord {
                scheduler: "ras",
                task: 7,
                batch: 1,
                high_priority: true,
                candidates: vec![CandidateScore { device: 0, score: 1.5, reject: None }],
                chosen: Some((0, 4)),
                rung: None,
                cloud: false,
            }),
        );
        assert_eq!(r.decisions(), 1);
    }

    #[test]
    fn perfetto_pairs_spans_and_is_byte_stable() {
        let mut r = rec(64);
        r.record(0, TraceEvent::TransferStart { task: 1, device: 2 });
        r.record(500, TraceEvent::TransferDone { task: 1 });
        r.record(500, TraceEvent::ExecStart { task: 1, device: 2 });
        r.record(
            900,
            TraceEvent::Complete { task: 1, device: 2, high_priority: false, violated: false },
        );
        r.record(950, TraceEvent::Violation { task: 9 });
        r.record(955, TraceEvent::StageBoundary { task: 5, device: 1, stage: 2 });
        r.record(956, TraceEvent::Truncate { task: 5, device: 1, stage: 2 });
        // Unpaired start: must degrade to an instant, not invalid JSON.
        r.record(960, TraceEvent::ExecStart { task: 3, device: 0 });
        let a = r.perfetto_json(4);
        let b = r.perfetto_json(4);
        assert_eq!(a, b, "export must be byte-stable");
        assert!(a.contains("\"traceEvents\""));
        assert!(a.contains("\"ph\": \"X\""), "paired windows become complete spans");
        assert!(a.contains("\"name\": \"xfer #1\""));
        assert!(a.contains("\"dur\": 400"), "exec span duration from pairing");
        assert!(a.contains("violation #9"));
        assert!(a.contains("\"name\": \"stage #5\""));
        assert!(a.contains("\"name\": \"truncate #5\""));
        assert!(a.contains("exec_start #3"), "unpaired start survives as instant");
        // Track metadata for every device plus link + cloud.
        assert!(a.contains("\"name\": \"device 3\""));
        assert!(a.contains("\"name\": \"link\""));
        assert!(a.contains("\"name\": \"cloud\""));
        // Structural sanity: balanced braces/brackets.
        let balance = |open: char, close: char| {
            a.chars().filter(|&c| c == open).count() == a.chars().filter(|&c| c == close).count()
        };
        assert!(balance('{', '}'));
        assert!(balance('[', ']'));
    }

    #[test]
    fn decision_args_render_candidates_and_rejections() {
        let d = DecisionRecord {
            scheduler: "wps",
            task: 42,
            batch: 3,
            high_priority: false,
            candidates: vec![
                CandidateScore { device: 0, score: 0.25, reject: None },
                CandidateScore {
                    device: 1,
                    score: f64::INFINITY,
                    reject: Some(RejectReason::Suspected),
                },
            ],
            chosen: Some((0, 2)),
            rung: Some(1),
            cloud: false,
        };
        assert_eq!(d.outcome(), "placed");
        let args = decision_args(&d);
        assert!(args.contains("\"scheduler\": \"wps\""));
        assert!(args.contains("\"reject\": \"suspected\""));
        assert!(args.contains("\"score\": null"), "non-finite scores render as null");
        assert!(args.contains("\"rung\": 1"));
        let rejected = DecisionRecord { chosen: None, cloud: false, ..d.clone() };
        assert_eq!(rejected.outcome(), "rejected");
        let clouded = DecisionRecord { chosen: None, cloud: true, ..d };
        assert_eq!(clouded.outcome(), "cloud");
    }
}
