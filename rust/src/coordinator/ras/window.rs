//! Availability windows: half-open periods `[t1, t2)` during which a track
//! (a `min_cores`-wide slice of a device) is guaranteed free.
//!
//! Fig. 2 of the paper: allocating a slot inside a window *bisects* it into
//! up to two remainder windows (left / right), which are only kept if they
//! still satisfy the list's minimum-duration requirement — this is what
//! guarantees that any window found by a containment query can actually
//! host a task of that configuration.


use crate::time::{SimDuration, SimTime};

/// A guaranteed period of availability `[t1, t2)` on one track.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AvailWindow {
    pub t1: SimTime,
    pub t2: SimTime,
}

impl AvailWindow {
    pub fn new(t1: SimTime, t2: SimTime) -> Self {
        debug_assert!(t1 <= t2, "window must be ordered: [{t1}, {t2})");
        Self { t1, t2 }
    }

    #[inline]
    pub fn duration(&self) -> SimDuration {
        self.t2 - self.t1
    }

    /// Does this window fully contain `[s1, s2)`? (The containment query.)
    #[inline]
    pub fn contains(&self, s1: SimTime, s2: SimTime) -> bool {
        self.t1 <= s1 && s2 <= self.t2
    }

    /// Does this window overlap `[s1, s2)` at all?
    #[inline]
    pub fn overlaps(&self, s1: SimTime, s2: SimTime) -> bool {
        self.t1 < s2 && s1 < self.t2
    }

    /// Remove `[s1, s2)` from this window, producing the 0–2 remainder
    /// windows (left-hand side, right-hand side). Remainders shorter than
    /// `min_dur` are dropped — they could never host a task of this
    /// configuration, and keeping them would break the guarantee that any
    /// window in the list can accommodate a task.
    ///
    /// `[s1, s2)` need not be contained: it is clipped to the window first
    /// (needed by the cross-list write path, where the allocated slot was
    /// chosen on a *different* configuration's list).
    pub fn bisect(&self, s1: SimTime, s2: SimTime, min_dur: SimDuration) -> (Option<AvailWindow>, Option<AvailWindow>) {
        let s1 = s1.max(self.t1);
        let s2 = s2.min(self.t2);
        if s1 >= s2 {
            // No actual overlap: the window survives whole on one side.
            // Caller should have checked overlaps(); treat as "keep all".
            return (Some(*self), None);
        }
        let left = if s1 > self.t1 && s1 - self.t1 >= min_dur {
            Some(AvailWindow::new(self.t1, s1))
        } else {
            None
        };
        let right = if s2 < self.t2 && self.t2 - s2 >= min_dur {
            Some(AvailWindow::new(s2, self.t2))
        } else {
            None
        };
        (left, right)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn containment_and_overlap() {
        let w = AvailWindow::new(100, 200);
        assert!(w.contains(100, 200));
        assert!(w.contains(120, 180));
        assert!(!w.contains(99, 150));
        assert!(!w.contains(150, 201));
        assert!(w.overlaps(199, 300));
        assert!(!w.overlaps(200, 300)); // half-open
        assert!(!w.overlaps(0, 100));
    }

    #[test]
    fn bisect_middle_keeps_both_sides() {
        let w = AvailWindow::new(0, 100);
        let (l, r) = w.bisect(40, 60, 10);
        assert_eq!(l, Some(AvailWindow::new(0, 40)));
        assert_eq!(r, Some(AvailWindow::new(60, 100)));
    }

    #[test]
    fn bisect_drops_fragments_below_min_duration() {
        let w = AvailWindow::new(0, 100);
        let (l, r) = w.bisect(5, 95, 10);
        assert_eq!(l, None); // 5 < 10
        assert_eq!(r, None); // 5 < 10
    }

    #[test]
    fn bisect_aligned_edges_produce_no_fragments() {
        let w = AvailWindow::new(0, 100);
        let (l, r) = w.bisect(0, 50, 1);
        assert_eq!(l, None);
        assert_eq!(r, Some(AvailWindow::new(50, 100)));
        let (l, r) = w.bisect(50, 100, 1);
        assert_eq!(l, Some(AvailWindow::new(0, 50)));
        assert_eq!(r, None);
    }

    #[test]
    fn bisect_clips_uncontained_slot() {
        let w = AvailWindow::new(100, 200);
        // Slot starts before the window: only the right remainder exists.
        let (l, r) = w.bisect(50, 150, 10);
        assert_eq!(l, None);
        assert_eq!(r, Some(AvailWindow::new(150, 200)));
        // Slot entirely outside: window survives.
        let (l, r) = w.bisect(300, 400, 10);
        assert_eq!(l, Some(w));
        assert_eq!(r, None);
    }

    #[test]
    fn bisect_full_cover_removes_window() {
        let w = AvailWindow::new(100, 200);
        let (l, r) = w.bisect(100, 200, 1);
        assert_eq!(l, None);
        assert_eq!(r, None);
    }
}
