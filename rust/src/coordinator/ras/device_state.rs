//! Per-device availability state: one [`ResourceAvailabilityList`] per task
//! configuration, plus the cross-list write and the full reconstruction used
//! after preemption (Section IV-A1).
//!
//! The asymmetry the paper exploits: *queries* (scheduling, latency-critical)
//! touch one list and early-exit; *writes* (after allocation, off the
//! latency path) fan out across all lists; *preemption* (rare) pays for a
//! full rebuild from the device's active workload because reclaimed windows
//! cannot be re-inserted — a window only certifies the track's minimum
//! capacity, not total usage.


use super::list::{ResourceAvailabilityList, WindowRef};
use crate::config::SystemConfig;
use crate::coordinator::task::{Allocation, TaskConfig, ALL_CONFIGS};
use crate::time::SimTime;

/// Availability state for one device: `lists[config.index()]`.
#[derive(Debug, Clone)]
pub struct DeviceAvailability {
    pub lists: Vec<ResourceAvailabilityList>,
}

impl DeviceAvailability {
    /// Fully-available device from time `from`.
    pub fn new(cfg: &SystemConfig, from: SimTime) -> Self {
        let lists = ALL_CONFIGS
            .iter()
            .map(|&c| {
                let cores = c.cores(cfg);
                let tracks = (cfg.cores_per_device / cores).max(1) as usize;
                ResourceAvailabilityList::fully_available(cores, c.proc_time(cfg), tracks, from)
            })
            .collect();
        Self { lists }
    }

    pub fn list(&self, c: TaskConfig) -> &ResourceAvailabilityList {
        &self.lists[c.index()]
    }

    pub fn list_mut(&mut self, c: TaskConfig) -> &mut ResourceAvailabilityList {
        &mut self.lists[c.index()]
    }

    /// Containment query on the configuration's own list (the fast path).
    pub fn query(&self, c: TaskConfig, s1: SimTime, s2: SimTime) -> Option<WindowRef> {
        self.list(c).query_containment(s1, s2)
    }

    /// Earliest fit of `dur` within `[s1, deadline)` on the configuration's
    /// list.
    pub fn query_earliest_fit(
        &self,
        c: TaskConfig,
        s1: SimTime,
        deadline: SimTime,
        dur: u64,
    ) -> Option<(WindowRef, SimTime)> {
        self.list(c).query_earliest_fit(s1, deadline, dur)
    }

    /// Record an allocation of `cores` over `[s1, s2)` across *all* lists
    /// (the background write the paper performs after task allocation).
    pub fn write_all(&mut self, s1: SimTime, s2: SimTime, cores: u32) {
        for l in &mut self.lists {
            l.write(s1, s2, cores);
        }
    }

    /// Rebuild every list from the device's active workload — the paper's
    /// preemption path: fresh fully-available lists, then replay each
    /// remaining allocation as a write.
    pub fn reconstruct<'a>(
        &mut self,
        cfg: &SystemConfig,
        now: SimTime,
        workload: impl Iterator<Item = &'a Allocation>,
    ) {
        *self = DeviceAvailability::new(cfg, now);
        for a in workload {
            if a.end > now {
                self.write_all(a.start.max(now), a.end, a.cores);
            }
        }
    }

    /// Advance all lists to `now` (drop the past).
    pub fn advance(&mut self, now: SimTime) {
        for l in &mut self.lists {
            l.advance(now);
        }
    }

    pub fn check_invariants(&self) -> Result<(), String> {
        for l in &self.lists {
            l.check_invariants()?;
        }
        Ok(())
    }

    /// Diagnostics: total windows across lists.
    pub fn window_count(&self) -> usize {
        self.lists.iter().map(|l| l.window_count()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::TaskConfig::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn alloc(device: usize, config: TaskConfig, cores: u32, start: SimTime, end: SimTime) -> Allocation {
        Allocation {
            task: 0,
            frame: 0,
            device,
            config,
            cores,
            start,
            end,
            deadline: end,
            offloaded: false,
            comm: None,
        }
    }

    #[test]
    fn track_counts_follow_core_ratio() {
        let c = cfg();
        let d = DeviceAvailability::new(&c, 0);
        assert_eq!(d.list(HighPriority).track_count(), 1); // 4 cores / 4
        assert_eq!(d.list(LowTwoCore).track_count(), 2); // 4 / 2
        assert_eq!(d.list(LowFourCore).track_count(), 1); // 4 / 4
    }

    #[test]
    fn cross_list_write_is_visible_everywhere() {
        let c = cfg();
        let mut d = DeviceAvailability::new(&c, 0);
        let (s1, s2) = (1_000_000, 1_000_000 + c.lp2_proc());
        // Allocate a two-core task.
        d.write_all(s1, s2, 2);
        d.check_invariants().unwrap();
        // Four-core config sees the device as busy there (2 free < 4).
        assert!(d.query(LowFourCore, s1, s1 + c.lp4_proc()).is_none());
        // Two-core config still has its second track.
        assert!(d.query(LowTwoCore, s1, s2).is_some());
        // A second two-core task fills the device for four-core *and*
        // two-core configs.
        d.write_all(s1, s2, 2);
        assert!(d.query(LowTwoCore, s1, s2).is_none());
        // HP list (one 4-core track): any occupancy blocks it.
        assert!(d.query(HighPriority, s1, s1 + c.hp_proc()).is_none());
        d.check_invariants().unwrap();
    }

    #[test]
    fn two_two_core_tasks_fit_but_not_three() {
        // The paper: "our devices have four cores, they can process at most
        // two DNN tasks with a two-core allocation locally".
        let c = cfg();
        let mut d = DeviceAvailability::new(&c, 0);
        let (s1, s2) = (0, c.lp2_proc());
        for expected_some in [true, true, false] {
            let q = d.query(LowTwoCore, s1, s2);
            assert_eq!(q.is_some(), expected_some);
            if let Some(r) = q {
                d.list_mut(LowTwoCore).allocate_at(r, s1, s2);
                // Mirror to the other lists, as the scheduler's write does.
                d.list_mut(HighPriority).write(s1, s2, 2);
                d.list_mut(LowFourCore).write(s1, s2, 2);
            }
        }
        d.check_invariants().unwrap();
    }

    #[test]
    fn reconstruct_matches_incremental_writes() {
        let c = cfg();
        let mut incr = DeviceAvailability::new(&c, 0);
        let allocs = vec![
            alloc(0, LowTwoCore, 2, 1_000_000, 18_000_000),
            alloc(0, HighPriority, 1, 2_000_000, 2_980_000),
            alloc(0, LowFourCore, 4, 20_000_000, 32_000_000),
        ];
        for a in &allocs {
            incr.write_all(a.start, a.end, a.cores);
        }
        let mut rebuilt = DeviceAvailability::new(&c, 0);
        rebuilt.reconstruct(&c, 0, allocs.iter());
        // Same availability answers on a probe grid. (Window layouts can
        // differ in which track holds which hole; query answers must not.)
        for t in (0..40_000_000).step_by(500_000) {
            for &cf in &ALL_CONFIGS {
                let dur = cf.proc_time(&c);
                assert_eq!(
                    incr.query(cf, t, t + dur).is_some(),
                    rebuilt.query(cf, t, t + dur).is_some(),
                    "mismatch at t={t} config={cf:?}"
                );
            }
        }
    }

    #[test]
    fn reconstruct_skips_completed_tasks() {
        let c = cfg();
        let mut d = DeviceAvailability::new(&c, 0);
        let past = alloc(0, LowTwoCore, 2, 0, 1_000_000);
        let future = alloc(0, LowFourCore, 4, 5_000_000, 17_000_000);
        d.reconstruct(&c, 2_000_000, [past, future].iter());
        d.check_invariants().unwrap();
        // Past allocation ignored; future one blocks everything.
        assert!(d.query(LowFourCore, 5_000_000, 5_000_000 + c.lp4_proc()).is_none());
    }
}
