//! The resource availability abstraction model (the paper's Section IV-A1):
//! computational capacity as guaranteed periods of availability.

pub mod device_state;
pub mod list;
pub mod window;

pub use device_state::DeviceAvailability;
pub use list::{ResourceAvailabilityList, WindowRef};
pub use window::AvailWindow;
