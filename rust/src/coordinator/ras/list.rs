//! Resource availability lists (Section IV-A1).
//!
//! One list per (device, task configuration). A device with `n` cores and a
//! configuration needing `j` cores gets `n / j` *tracks*; each track is a
//! sorted vector of non-overlapping [`AvailWindow`]s. A capacity query is a
//! *containment* search with early exit — the headline latency win over the
//! overlapping-range scan of the WPS baseline — and every window in the list
//! is guaranteed to satisfy the list's minimum core count and minimum
//! duration, so the first hit can always host the task.


use super::window::AvailWindow;
use crate::time::{SimDuration, SimTime, INFINITY};

/// Availability list for one (device, configuration) pair.
#[derive(Debug, Clone)]
pub struct ResourceAvailabilityList {
    /// Minimum core capacity each track represents (j in the paper).
    pub min_cores: u32,
    /// Minimum duration a window must have to be kept (the configuration's
    /// processing time — anything shorter could never host a task).
    pub min_dur: SimDuration,
    /// `n / j` tracks of sorted, non-overlapping windows.
    pub tracks: Vec<Vec<AvailWindow>>,
}

/// Location of a window found by a containment query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowRef {
    pub track: usize,
    pub index: usize,
}

impl ResourceAvailabilityList {
    /// A fully-available list: every track is one window `[from, INFINITY)`.
    pub fn fully_available(min_cores: u32, min_dur: SimDuration, track_count: usize, from: SimTime) -> Self {
        Self {
            min_cores,
            min_dur,
            tracks: vec![vec![AvailWindow::new(from, INFINITY)]; track_count],
        }
    }

    pub fn track_count(&self) -> usize {
        self.tracks.len()
    }

    /// Total number of windows across tracks (diagnostics / benches).
    pub fn window_count(&self) -> usize {
        self.tracks.iter().map(Vec::len).sum()
    }

    /// Containment query: find the first window (lowest track, earliest
    /// window) that fully contains `[s1, s2)`. Early exit on the first hit.
    ///
    /// Within a track, windows are sorted and non-overlapping, so the only
    /// candidate is the last window starting at or before `s1` — found by
    /// binary search, O(log w) per track.
    pub fn query_containment(&self, s1: SimTime, s2: SimTime) -> Option<WindowRef> {
        for (ti, track) in self.tracks.iter().enumerate() {
            if let Some(wi) = Self::track_containing(track, s1, s2) {
                return Some(WindowRef { track: ti, index: wi });
            }
        }
        None
    }

    /// Multi-containment query (Section IV-B2): *every* window that fully
    /// contains `[s1, s2)` — at most one per track, since windows within a
    /// track are disjoint. Used by low-priority batch scheduling, which
    /// needs one window per task in the request.
    pub fn query_all_containing(&self, s1: SimTime, s2: SimTime) -> Vec<WindowRef> {
        let mut out = Vec::new();
        for (ti, track) in self.tracks.iter().enumerate() {
            if let Some(wi) = Self::track_containing(track, s1, s2) {
                out.push(WindowRef { track: ti, index: wi });
            }
        }
        out
    }

    /// Multi-fit query: for each track, the earliest window that can host
    /// a `dur`-long slot positioned inside the placement window
    /// `[s1, deadline)`. Because every window in the list is at least
    /// `min_dur` (= the configuration's processing time) long, the first
    /// window starting early enough is guaranteed to host the task — the
    /// same early-exit property as pure containment, but it also finds
    /// placements on tracks that free up part-way through the placement
    /// window (essential for reallocating preempted tasks).
    pub fn query_all_fits(&self, s1: SimTime, deadline: SimTime, dur: SimDuration) -> Vec<(WindowRef, SimTime)> {
        let mut out = Vec::new();
        for (ti, track) in self.tracks.iter().enumerate() {
            let lo = track.partition_point(|w| w.t2 <= s1);
            for (wi, w) in track.iter().enumerate().skip(lo) {
                let start = w.t1.max(s1);
                if start + dur <= w.t2 && start + dur <= deadline {
                    out.push((WindowRef { track: ti, index: wi }, start));
                    break; // earliest per track — early exit
                }
                if w.t1 + dur > deadline {
                    break;
                }
            }
        }
        out
    }

    /// Find the earliest slot of length `dur` that starts at or after `s1`
    /// and finishes by `deadline`. Returns `(WindowRef, start)`. Used when
    /// the desired placement window `[now, deadline)` is wider than the
    /// processing time: the paper queries containment of the *placement*
    /// window's start, then slides the task to the earliest fit.
    pub fn query_earliest_fit(
        &self,
        s1: SimTime,
        deadline: SimTime,
        dur: SimDuration,
    ) -> Option<(WindowRef, SimTime)> {
        let mut best: Option<(WindowRef, SimTime)> = None;
        for (ti, track) in self.tracks.iter().enumerate() {
            // First window that ends after s1 (earlier ones are irrelevant).
            let lo = track.partition_point(|w| w.t2 <= s1);
            for (wi, w) in track.iter().enumerate().skip(lo) {
                let start = w.t1.max(s1);
                if start + dur <= w.t2 && start + dur <= deadline {
                    match best {
                        Some((_, b)) if b <= start => {}
                        _ => best = Some((WindowRef { track: ti, index: wi }, start)),
                    }
                    break; // earliest in this track found; try other tracks
                }
                if w.t1 > deadline {
                    break;
                }
            }
        }
        best
    }

    fn track_containing(track: &[AvailWindow], s1: SimTime, s2: SimTime) -> Option<usize> {
        // Last window with t1 <= s1.
        let idx = track.partition_point(|w| w.t1 <= s1);
        if idx == 0 {
            return None;
        }
        let wi = idx - 1;
        if track[wi].contains(s1, s2) {
            Some(wi)
        } else {
            None
        }
    }

    /// Allocate `[s1, s2)` out of the window at `r`, bisecting it and
    /// keeping remainders that satisfy `min_dur`. Panics in debug if the
    /// window does not contain the slot (callers query first).
    pub fn allocate_at(&mut self, r: WindowRef, s1: SimTime, s2: SimTime) {
        let track = &mut self.tracks[r.track];
        debug_assert!(track[r.index].contains(s1, s2), "allocate_at: slot not contained");
        let (l, rw) = track[r.index].bisect(s1, s2, self.min_dur);
        // Replace in place, preserving sort order.
        track.remove(r.index);
        let mut at = r.index;
        if let Some(w) = l {
            track.insert(at, w);
            at += 1;
        }
        if let Some(w) = rw {
            track.insert(at, w);
        }
    }

    /// Cross-list write (Section IV-A1 trade-off): record that `cores`
    /// cores are occupied over `[s1, s2)`. On a list whose tracks are
    /// `min_cores` wide, that blocks `ceil(cores / min_cores)` tracks —
    /// deliberately conservative (this is the "accuracy" the abstraction
    /// gives up for speed).
    ///
    /// Tracks whose window fully contains the interval are preferred (they
    /// fragment least); otherwise any overlapping availability is clipped.
    pub fn write(&mut self, s1: SimTime, s2: SimTime, cores: u32) {
        if s1 >= s2 {
            return;
        }
        let mut need = cores.div_ceil(self.min_cores).min(self.tracks.len() as u32);
        if need == 0 {
            return;
        }
        // Pass 1: tracks with a window fully containing [s1, s2).
        for ti in 0..self.tracks.len() {
            if need == 0 {
                break;
            }
            if let Some(wi) = Self::track_containing(&self.tracks[ti], s1, s2) {
                self.allocate_at(WindowRef { track: ti, index: wi }, s1, s2);
                need -= 1;
            }
        }
        // Pass 2: clip any overlapping availability from remaining tracks.
        if need > 0 {
            for ti in 0..self.tracks.len() {
                if need == 0 {
                    break;
                }
                if self.clip_track(ti, s1, s2) {
                    need -= 1;
                }
            }
        }
        // If still short, the device is simply out of capacity here — the
        // remaining tracks had no availability in the interval anyway, so
        // the conservative guarantee still holds.
    }

    /// Remove any overlap with `[s1, s2)` from track `ti`. Returns whether
    /// anything was removed.
    fn clip_track(&mut self, ti: usize, s1: SimTime, s2: SimTime) -> bool {
        let min_dur = self.min_dur;
        let track = &mut self.tracks[ti];
        let mut touched = false;
        let mut out: Vec<AvailWindow> = Vec::with_capacity(track.len() + 1);
        for w in track.iter() {
            if w.overlaps(s1, s2) {
                touched = true;
                let (l, r) = w.bisect(s1, s2, min_dur);
                if let Some(lw) = l {
                    out.push(lw);
                }
                if let Some(rw) = r {
                    out.push(rw);
                }
            } else {
                out.push(*w);
            }
        }
        if touched {
            *track = out;
        }
        touched
    }

    /// Drop windows entirely in the past and clamp the current one to `now`
    /// (keeping clamped windows even if they fall under `min_dur` would be
    /// wrong — they are dropped like any other fragment).
    pub fn advance(&mut self, now: SimTime) {
        for track in &mut self.tracks {
            track.retain_mut(|w| {
                if w.t2 <= now {
                    return false;
                }
                if w.t1 < now {
                    w.t1 = now;
                }
                w.duration() >= self.min_dur
            });
        }
    }

    /// Invariant check used by tests and proptests: windows sorted,
    /// non-overlapping, all at least `min_dur` long.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (ti, track) in self.tracks.iter().enumerate() {
            for (i, w) in track.iter().enumerate() {
                if w.t1 >= w.t2 {
                    return Err(format!("track {ti} window {i} is empty/inverted: [{}, {})", w.t1, w.t2));
                }
                if w.duration() < self.min_dur {
                    return Err(format!(
                        "track {ti} window {i} shorter than min_dur: {} < {}",
                        w.duration(),
                        self.min_dur
                    ));
                }
                if i > 0 && track[i - 1].t2 > w.t1 {
                    return Err(format!("track {ti} windows {i}-1 and {i} overlap or are unsorted"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn list2() -> ResourceAvailabilityList {
        // Two tracks of 2 cores each (a 4-core device, two-core config),
        // min duration 100.
        ResourceAvailabilityList::fully_available(2, 100, 2, 0)
    }

    #[test]
    fn fresh_list_contains_everything() {
        let l = list2();
        let r = l.query_containment(0, 1_000_000).unwrap();
        assert_eq!(r, WindowRef { track: 0, index: 0 });
        l.check_invariants().unwrap();
    }

    #[test]
    fn allocate_bisects_and_query_skips_hole() {
        let mut l = list2();
        let r = l.query_containment(1000, 2000).unwrap();
        l.allocate_at(r, 1000, 2000);
        l.check_invariants().unwrap();
        // The hole on track 0 forces the query onto track 1.
        let r2 = l.query_containment(1000, 2000).unwrap();
        assert_eq!(r2.track, 1);
        // Either side of the hole still available on track 0.
        assert_eq!(l.query_containment(0, 1000).unwrap().track, 0);
        assert_eq!(l.query_containment(2000, 5000).unwrap().track, 0);
    }

    #[test]
    fn exhausting_all_tracks_returns_none() {
        let mut l = list2();
        for _ in 0..2 {
            let r = l.query_containment(1000, 2000).unwrap();
            l.allocate_at(r, 1000, 2000);
        }
        assert!(l.query_containment(1000, 2000).is_none());
        // But a slot elsewhere still works.
        assert!(l.query_containment(2000, 3000).is_some());
        l.check_invariants().unwrap();
    }

    #[test]
    fn write_blocks_ceil_cores_over_min() {
        // 4 one-core tracks (HP list): a 2-core task blocks 2 tracks.
        let mut l = ResourceAvailabilityList::fully_available(1, 10, 4, 0);
        l.write(100, 200, 2);
        let free: usize = l
            .tracks
            .iter()
            .filter(|t| ResourceAvailabilityList::track_containing(t, 100, 200).is_some())
            .count();
        assert_eq!(free, 2);
        l.check_invariants().unwrap();

        // On a 1-track 4-core list, a 2-core task still blocks the whole
        // track (conservative rounding — the paper's accuracy trade-off).
        let mut l4 = ResourceAvailabilityList::fully_available(4, 10, 1, 0);
        l4.write(100, 200, 2);
        assert!(l4.query_containment(100, 200).is_none());
        assert!(l4.query_containment(200, 300).is_some());
    }

    #[test]
    fn write_clips_partial_overlaps() {
        let mut l = ResourceAvailabilityList::fully_available(2, 100, 1, 0);
        // First occupy [1000, 2000) so the track has a hole.
        l.write(1000, 2000, 2);
        // Now write an interval straddling the hole's right edge; no window
        // fully contains it, so pass 2 must clip.
        l.write(1500, 2500, 2);
        l.check_invariants().unwrap();
        assert!(l.query_containment(2000, 2400).is_none());
        assert!(l.query_containment(2500, 3000).is_some());
    }

    #[test]
    fn min_duration_fragments_are_dropped() {
        let mut l = ResourceAvailabilityList::fully_available(2, 1000, 1, 0);
        // Leaves a 500-long left fragment, below min_dur 1000 — dropped.
        l.write(500, 5000, 2);
        assert!(l.query_containment(0, 400).is_none());
        l.check_invariants().unwrap();
    }

    #[test]
    fn advance_clamps_and_drops() {
        let mut l = ResourceAvailabilityList::fully_available(2, 100, 2, 0);
        l.write(0, 1000, 4); // both tracks blocked until 1000
        l.advance(500);
        l.check_invariants().unwrap();
        assert!(l.query_containment(500, 600).is_none());
        assert!(l.query_containment(1000, 2000).is_some());
        l.advance(1500);
        for track in &l.tracks {
            assert!(track.iter().all(|w| w.t1 >= 1500));
        }
    }

    #[test]
    fn earliest_fit_slides_past_busy_region() {
        let mut l = ResourceAvailabilityList::fully_available(2, 100, 1, 0);
        l.write(0, 1000, 4);
        let (r, start) = l.query_earliest_fit(0, 10_000, 500).unwrap();
        assert_eq!(start, 1000);
        assert_eq!(r.track, 0);
        // Deadline too tight: no fit.
        assert!(l.query_earliest_fit(0, 1400, 500).is_none());
    }
}
