//! Exponentially-weighted moving average, as used by the controller to
//! smooth bandwidth probe results (the paper uses α = 0.3).


/// EWMA accumulator: `value ← α · sample + (1 − α) · value`.
#[derive(Debug, Clone)]
pub struct Ewma {
    pub alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "alpha must be in [0, 1]");
        Self { alpha, value: None }
    }

    /// Seeded with an initial value (the paper's initial iperf3 baseline).
    pub fn with_initial(alpha: f64, initial: f64) -> Self {
        Self { alpha, value: Some(initial) }
    }

    /// Feed a sample; returns the updated average.
    pub fn update(&mut self, sample: f64) -> f64 {
        let v = match self.value {
            None => sample,
            Some(v) => self.alpha * sample + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_sample_seeds() {
        let mut e = Ewma::new(0.3);
        assert_eq!(e.value(), None);
        assert_eq!(e.update(10.0), 10.0);
    }

    #[test]
    fn converges_towards_constant_input() {
        let mut e = Ewma::with_initial(0.3, 0.0);
        let mut v = 0.0;
        for _ in 0..50 {
            v = e.update(100.0);
        }
        assert!((v - 100.0).abs() < 1e-3);
    }

    #[test]
    fn alpha_weights_new_sample() {
        let mut e = Ewma::with_initial(0.3, 100.0);
        // 0.3·0 + 0.7·100 = 70
        assert!((e.update(0.0) - 70.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_alpha() {
        let _ = Ewma::new(1.5);
    }
}
