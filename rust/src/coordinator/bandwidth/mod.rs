//! Dynamic bandwidth estimation: EWMA over periodic ping probes.

pub mod estimator;
pub mod ewma;

pub use estimator::{BandwidthEstimator, ProbeRound};
pub use ewma::Ewma;
