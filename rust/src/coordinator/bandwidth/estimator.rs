//! Dynamic bandwidth estimation (Section V).
//!
//! The controller starts from an iperf3-style baseline, then periodically
//! (every `bandwidth_interval`) has a randomly chosen edge device send 10
//! 1400-byte pings to each other device, converts per-ping round-trip time
//! to bits per second, and folds the mean into an EWMA (α = 0.3). Each
//! update triggers a rebuild of the discretised network link.


use super::ewma::Ewma;
use crate::config::SystemConfig;
use crate::time::{SimDuration, SimTime};

/// Result of one probe round: per-ping throughput samples in bits/second.
#[derive(Debug, Clone)]
pub struct ProbeRound {
    pub host: usize,
    pub samples_bps: Vec<f64>,
}

impl ProbeRound {
    pub fn mean_bps(&self) -> Option<f64> {
        if self.samples_bps.is_empty() {
            return None;
        }
        Some(self.samples_bps.iter().sum::<f64>() / self.samples_bps.len() as f64)
    }
}

/// The controller's bandwidth estimator.
#[derive(Debug, Clone)]
pub struct BandwidthEstimator {
    ewma: Ewma,
    /// Probe interval (µs).
    pub interval: SimDuration,
    /// Time of the last completed update.
    pub last_update: SimTime,
    /// Time of the last *attempted* round, successful or not. A failed
    /// round (no samples — e.g. every ping lost) must still consume its
    /// slot: scheduling the next round off `last_update` alone would
    /// leave `next_due` in the past after a failure, and any driver that
    /// polls `next_due` would re-probe in a hot loop until a round
    /// finally succeeded.
    pub last_attempt: SimTime,
    /// Number of updates applied (diagnostics; Fig. 6/7 sweeps this rate).
    pub updates: u64,
    /// Rounds that carried no samples (probe failure; no update applied).
    pub failures: u64,
    /// Length of the current run of failed rounds (reset by any success).
    /// The EWMA keeps reporting the last estimate with full confidence
    /// through an arbitrarily long probe outage — this counter is what
    /// lets callers notice the estimate has gone stale.
    pub consecutive_failures: u64,
    /// Consecutive failures after which the estimate counts as stale
    /// (`0` = never; mirrors `SystemConfig::bw_stale_after`).
    pub stale_after: u32,
    /// When the estimate crossed the staleness threshold, if it is
    /// currently stale.
    stale_since: Option<SimTime>,
    /// Accumulated stale time from *completed* stale episodes (µs); the
    /// open episode, if any, is added by [`Self::stale_us`].
    stale_us_accum: u64,
}

impl BandwidthEstimator {
    /// Seed from the initial baseline test (the paper's startup iperf3).
    pub fn new(cfg: &SystemConfig, baseline_bps: f64) -> Self {
        Self {
            ewma: Ewma::with_initial(cfg.ewma_alpha, baseline_bps),
            interval: cfg.bandwidth_interval(),
            last_update: 0,
            last_attempt: 0,
            updates: 0,
            failures: 0,
            consecutive_failures: 0,
            stale_after: cfg.bw_stale_after,
            stale_since: None,
            stale_us_accum: 0,
        }
    }

    /// Current estimate in bits per second.
    pub fn estimate_bps(&self) -> f64 {
        self.ewma.value().expect("estimator is always seeded")
    }

    /// Fold a probe round into the estimate. Returns the new estimate, or
    /// `None` if the round carried no samples (probe failure — estimate
    /// unchanged, no link rebuild needed, but the attempt still counts
    /// towards the probe cadence).
    pub fn apply(&mut self, now: SimTime, round: &ProbeRound) -> Option<f64> {
        self.last_attempt = now;
        let Some(mean) = round.mean_bps() else {
            self.failures += 1;
            self.consecutive_failures += 1;
            if self.stale_after > 0
                && self.consecutive_failures >= u64::from(self.stale_after)
                && self.stale_since.is_none()
            {
                self.stale_since = Some(now);
            }
            return None;
        };
        self.last_update = now;
        self.updates += 1;
        self.consecutive_failures = 0;
        if let Some(since) = self.stale_since.take() {
            self.stale_us_accum += now.saturating_sub(since);
        }
        Some(self.ewma.update(mean))
    }

    /// Whether the estimate is stale at `now`: the staleness knob is on
    /// and at least `stale_after` consecutive probe rounds have failed
    /// since the last successful update.
    pub fn is_stale(&self, now: SimTime) -> bool {
        self.stale_since.is_some_and(|since| now >= since)
    }

    /// Total time the estimate has spent stale up to `now` (µs) — closed
    /// episodes plus the currently-open one, for `bw_stale_us`.
    pub fn stale_us(&self, now: SimTime) -> u64 {
        self.stale_us_accum
            + self.stale_since.map_or(0, |since| now.saturating_sub(since))
    }

    /// When the next probe is due: one interval after the last *attempt*
    /// (the discrete-event engine schedules probes on its own fixed
    /// clock, so it never hot-loops — but external drivers poll this, and
    /// before the `last_attempt` fix a failed round left it in the past).
    pub fn next_due(&self) -> SimTime {
        self.last_attempt + self.interval
    }

    /// Convert ping RTT (µs) for `bytes` payload into a bits/s sample, the
    /// way the paper's edge devices do.
    pub fn rtt_to_bps(bytes: u64, rtt_us: SimDuration) -> f64 {
        if rtt_us == 0 {
            return f64::INFINITY;
        }
        // Payload travels out and back: 2·bytes over the RTT.
        (2.0 * bytes as f64 * 8.0) / (rtt_us as f64 / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn seeded_estimate() {
        let e = BandwidthEstimator::new(&cfg(), 40e6);
        assert_eq!(e.estimate_bps(), 40e6);
        assert_eq!(e.next_due(), 30_000_000);
    }

    #[test]
    fn apply_moves_estimate_towards_samples() {
        let mut e = BandwidthEstimator::new(&cfg(), 40e6);
        let round = ProbeRound { host: 0, samples_bps: vec![20e6; 30] };
        let v = e.apply(1_000_000, &round).unwrap();
        // 0.3·20M + 0.7·40M = 34M
        assert!((v - 34e6).abs() < 1.0);
        assert_eq!(e.updates, 1);
        assert_eq!(e.next_due(), 31_000_000);
    }

    #[test]
    fn empty_round_is_ignored() {
        let mut e = BandwidthEstimator::new(&cfg(), 40e6);
        assert!(e.apply(5, &ProbeRound { host: 1, samples_bps: vec![] }).is_none());
        assert_eq!(e.estimate_bps(), 40e6);
        assert_eq!(e.updates, 0);
        assert_eq!(e.failures, 1);
    }

    #[test]
    fn failed_round_still_advances_next_due() {
        // Regression: `apply` returning `None` used to leave `last_update`
        // (and therefore `next_due`) untouched, so after a failed round
        // `next_due` sat in the past forever and a next_due-driven probe
        // loop would re-probe immediately in a hot loop.
        let mut e = BandwidthEstimator::new(&cfg(), 40e6);
        assert!(e.apply(30_000_000, &ProbeRound { host: 0, samples_bps: vec![] }).is_none());
        assert_eq!(e.next_due(), 60_000_000, "failed round must consume its slot");
        // A later successful round keeps the cadence from its own time.
        let round = ProbeRound { host: 0, samples_bps: vec![20e6] };
        assert!(e.apply(60_000_000, &round).is_some());
        assert_eq!(e.next_due(), 90_000_000);
        assert_eq!(e.failures, 1);
        assert_eq!(e.updates, 1);
    }

    #[test]
    fn staleness_disabled_by_default() {
        let mut e = BandwidthEstimator::new(&cfg(), 40e6);
        for i in 0..10u64 {
            assert!(e.apply(i * 30_000_000, &ProbeRound { host: 0, samples_bps: vec![] }).is_none());
        }
        assert_eq!(e.consecutive_failures, 10);
        assert!(!e.is_stale(300_000_000), "stale_after 0 must never go stale");
        assert_eq!(e.stale_us(300_000_000), 0);
    }

    #[test]
    fn staleness_crosses_threshold_and_recovers() {
        let c = SystemConfig { bw_stale_after: 2, ..Default::default() };
        let mut e = BandwidthEstimator::new(&c, 40e6);
        let empty = ProbeRound { host: 0, samples_bps: vec![] };
        assert!(e.apply(30_000_000, &empty).is_none());
        assert!(!e.is_stale(30_000_000), "one failure is below the threshold");
        assert!(e.apply(60_000_000, &empty).is_none());
        assert!(e.is_stale(60_000_000), "second consecutive failure crosses");
        assert_eq!(e.stale_us(90_000_000), 30_000_000);
        // A successful round clears staleness and banks the episode.
        let ok = ProbeRound { host: 0, samples_bps: vec![20e6] };
        assert!(e.apply(90_000_000, &ok).is_some());
        assert_eq!(e.consecutive_failures, 0);
        assert!(!e.is_stale(90_000_000));
        assert_eq!(e.stale_us(120_000_000), 30_000_000, "episode banked, clock stopped");
        // The run length restarts from zero after recovery.
        assert!(e.apply(120_000_000, &empty).is_none());
        assert!(!e.is_stale(120_000_000));
    }

    #[test]
    fn rtt_conversion() {
        // 1400 B out + back in 1 ms → 22.4 Mb/s.
        let bps = BandwidthEstimator::rtt_to_bps(1400, 1000);
        assert!((bps - 22.4e6).abs() < 1.0);
    }
}
