//! WPS — the "Weighted Pre-emption Scheduler" baseline (the authors' prior
//! work [16], which the paper compares against in Figs. 4 and 5).
//!
//! WPS keeps the *exact* network state: per-device lists of allocated
//! tasks and a list of reserved communication windows on the link. Every
//! query answers by **overlapping range search**: to test whether a task
//! fits on a device over a candidate window, it sweeps all allocations of
//! that device to compute exact peak core usage; to place a transfer it
//! scans all reserved communication windows for a gap. Candidate start
//! times are enumerated from the ends of existing allocations (plus the
//! request time), so the search is exhaustive within the deadline.
//!
//! That exactness is the "accuracy" in the paper's title: WPS packs
//! devices tighter (no conservative track rounding, no minimum-duration
//! fragment loss, per-core granularity) and therefore allocates more tasks
//! overall. The price is query cost that grows with the live workload —
//! the "performance" the abstraction model trades it against.
//!
//! WPS predates the dynamic bandwidth mechanism: it plans transfers with
//! the static baseline estimate and ignores probe updates, which is
//! exactly what the paper's congestion experiments punish.

use super::{
    place_degrading_tiered, select_victim, CloudPlan, Decision, ExplainLog, HpOutcome, LpOutcome,
    Ops, Outcome, SchedEvent, Scheduler, WorkloadState, EXPLAIN_CANDIDATE_CAP,
};
use crate::config::SystemConfig;
use crate::obs::{CandidateScore, DecisionRecord, RejectReason};
use crate::coordinator::cost::ENERGY_SCORE_OPS;
use crate::coordinator::fleet::FleetCells;
use crate::coordinator::task::{Allocation, DeviceId, Task, TaskConfig, TaskId};
use crate::energy::EnergyModel;
use crate::time::{SimDuration, SimTime};

/// Placement scoring policy. Deadline feasibility is identical in both
/// modes — the mode only decides which *feasible* placement wins, so the
/// energy variant never trades a deadline for joules.
#[derive(Debug, Clone, Default)]
pub enum ScoreMode {
    /// The published WPS weighting: completion time dominates.
    #[default]
    Latency,
    /// Joules dominate: the cheapest feasible placement wins, with a
    /// scarcity multiplier that steers work away from low-battery
    /// devices. Completion time survives only as a tie-break.
    Energy { model: EnergyModel },
}

/// A reserved transfer window on the link (exact representation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct CommWindow {
    task: TaskId,
    t1: SimTime,
    t2: SimTime,
}

/// The exhaustive baseline scheduler.
pub struct WpsScheduler {
    cfg: SystemConfig,
    state: WorkloadState,
    /// Fleet membership (scenario churn): inactive devices are skipped by
    /// the exhaustive search.
    active: Vec<bool>,
    /// Detector belief (PR 8): devices the failure detector suspects are
    /// down. Suspected devices leave the candidate pool like crashed ones,
    /// but their exact state stays — the belief may be wrong, and in-flight
    /// allocations can still complete.
    suspected: Vec<bool>,
    /// Sharded fleet hierarchy. For WPS "idle" means *zero live
    /// allocations*: every idle remote device produces the same candidate
    /// start, the same operation count, and (under the latency score) the
    /// same score, so a whole idle cell collapses to one representative
    /// evaluation — with the flat scan's full cost still charged.
    cells: FleetCells,
    /// Reserved communication windows, kept sorted by start.
    comms: Vec<CommWindow>,
    /// Static bandwidth estimate (bits/s) fixed at startup.
    bps: f64,
    /// Cloud tier (None when `cloud_wan_bps` is 0 — the default), holding
    /// its own passively-updated WAN estimate.
    cloud: Option<CloudPlan>,
    /// Which feasible placement wins ([`ScoreMode::Latency`] by default —
    /// byte-identical to the pre-energy scheduler).
    mode: ScoreMode,
    /// Battery fractions by device (empty until the engine reports them;
    /// missing entries read as 1.0 = mains-powered).
    levels: Vec<f64>,
    /// Explainability buffer ([`Scheduler::set_explain`]): off by
    /// default, so the exhaustive search never constructs a record. The
    /// energy variant shares this buffer (its LP path bypasses
    /// [`Scheduler::on_event`] and records via
    /// [`WpsScheduler::explain_lp_decision`]).
    explain: ExplainLog,
}

impl WpsScheduler {
    pub fn new(cfg: &SystemConfig, _now: SimTime, baseline_bps: f64) -> Self {
        Self {
            cfg: cfg.clone(),
            state: WorkloadState::new(cfg.n_devices),
            active: vec![true; cfg.n_devices],
            suspected: vec![false; cfg.n_devices],
            cells: FleetCells::new(cfg.cell_size, cfg.n_devices),
            comms: Vec::new(),
            bps: baseline_bps,
            cloud: CloudPlan::from_config(cfg),
            mode: ScoreMode::Latency,
            levels: Vec::new(),
            explain: ExplainLog::default(),
        }
    }

    /// Same exact-state machinery, different placement score (used by the
    /// energy-aware scheduler variant).
    pub fn with_score_mode(
        cfg: &SystemConfig,
        now: SimTime,
        baseline_bps: f64,
        mode: ScoreMode,
    ) -> Self {
        Self { mode, ..Self::new(cfg, now, baseline_bps) }
    }

    fn device_active(&self, d: DeviceId) -> bool {
        d < self.active.len() && self.active[d]
    }

    fn device_suspected(&self, d: DeviceId) -> bool {
        d < self.suspected.len() && self.suspected[d]
    }

    /// Transfer duration for `task`'s actual input at the static
    /// estimate. The exact baseline is exact about sizes too: a
    /// half-size class reserves half the window, a double-size class
    /// double — conveyor tasks carry exactly `image_bytes`, reproducing
    /// the old fixed unit bit for bit.
    fn transfer_time_for(&self, task: &Task) -> SimDuration {
        let s = (task.input_bytes as f64 * 8.0) / self.bps.max(1.0);
        crate::time::secs(s).max(1)
    }

    /// Exact feasibility: does `cores` fit on `device` over `[t1, t2)`?
    fn fits(&self, device: DeviceId, t1: SimTime, t2: SimTime, cores: u32, ops: &mut Ops) -> bool {
        let (peak, o) = self.state.peak_usage(device, t1, t2);
        *ops += o;
        peak + cores <= self.cfg.cores_per_device
    }

    /// Earliest start in `[from, deadline - dur]` at which `cores` fit on
    /// `device` for `dur`. Candidate starts are `from` and the end of every
    /// allocation on the device (classic exhaustive event-point search).
    fn earliest_start(
        &self,
        device: DeviceId,
        from: SimTime,
        deadline: SimTime,
        dur: SimDuration,
        cores: u32,
        ops: &mut Ops,
    ) -> Option<SimTime> {
        if from + dur > deadline {
            return None;
        }
        // Candidate starts: the request time, the end of every allocation
        // on the device, and a scan of the feasible window at unit-transfer
        // granularity. The grid scan is what makes the baseline "more
        // exhaustive": the prior-work scheduler evaluates placements at
        // communication-slot resolution rather than only at event points,
        // which is where its published latency overheads (140–205 ms per
        // low-priority allocation on an M1) come from.
        let mut candidates: Vec<SimTime> = vec![from];
        for a in self.state.device_allocs(device) {
            *ops += 1;
            if a.end > from && a.end + dur <= deadline {
                candidates.push(a.end);
            }
        }
        // Fixed-resolution sweep of the feasible start window.
        let span = deadline.saturating_sub(from).saturating_sub(dur);
        let step = (span / Self::GRID_CANDIDATES as u64).max(1);
        let mut t = from;
        for _ in 0..Self::GRID_CANDIDATES {
            t += step;
            if t + dur > deadline {
                break;
            }
            candidates.push(t);
        }
        candidates.sort_unstable();
        candidates.dedup();
        for s in candidates {
            if self.fits(device, s, s + dur, cores, ops) {
                return Some(s);
            }
        }
        None
    }

    /// Grid-scan resolution bound per (device, config) search.
    const GRID_CANDIDATES: usize = 64;

    /// Earliest gap on the link of length `dur` starting at or after
    /// `from`, finishing by `deadline`. Scans all reserved windows
    /// (overlapping range search on the exact link state).
    fn earliest_comm(&self, from: SimTime, deadline: SimTime, dur: SimDuration, ops: &mut Ops) -> Option<(SimTime, SimTime)> {
        let mut t = from;
        // comms sorted by t1; walk forward through reservations.
        for w in &self.comms {
            *ops += 1;
            if w.t2 <= t {
                continue;
            }
            if t + dur <= w.t1 {
                break; // gap before this reservation
            }
            t = w.t2;
        }
        if t + dur <= deadline {
            Some((t, t + dur))
        } else {
            None
        }
    }

    fn reserve_comm(&mut self, task: TaskId, t1: SimTime, t2: SimTime) {
        let pos = self.comms.partition_point(|w| w.t1 < t1);
        self.comms.insert(pos, CommWindow { task, t1, t2 });
    }

    fn release_comm(&mut self, task: TaskId) {
        self.comms.retain(|w| w.task != task);
    }

    /// Weighted placement score (lower = better): completion time dominates,
    /// with a bonus for local placement (no transfer risk, sized by the
    /// transfer this task would otherwise pay) and a penalty per core
    /// used (keep capacity free) — the "weighted" in WPS.
    fn score(&self, end: SimTime, local: bool, cores: u32, transfer: SimDuration) -> f64 {
        let mut s = end as f64;
        if local {
            s -= transfer as f64;
        }
        s += cores as f64 * 50_000.0;
        s
    }

    /// Dispatch on [`ScoreMode`]. Latency mode charges nothing extra and
    /// reproduces [`Self::score`] exactly; energy mode charges
    /// [`ENERGY_SCORE_OPS`] per candidate for the joules estimate and the
    /// battery lookup.
    fn score_placement(&self, task: &Task, a: &Allocation, local: bool, ops: &mut Ops) -> f64 {
        let transfer = self.transfer_time_for(task);
        match &self.mode {
            ScoreMode::Latency => self.score(a.end, local, a.cores, transfer),
            ScoreMode::Energy { model } => {
                *ops += ENERGY_SCORE_OPS;
                let bytes = if local { 0 } else { task.input_bytes };
                let joules =
                    model.placement_joules(a.config.index(), a.end - a.start, bytes, self.bps);
                // Scarcity: the same joules cost more on a device that is
                // running out of them. A full (or mains) device multiplies
                // by 1; an empty one by 11.
                let level = self.levels.get(a.device).copied().unwrap_or(1.0);
                let scarcity = 1.0 + 10.0 * (1.0 - level.clamp(0.0, 1.0));
                joules * scarcity * 1e9 + a.end as f64
            }
        }
    }

    /// Cell bookkeeping after an allocation lands on `device`: the device
    /// leaves the idle (uniform-answer) pool and its earliest-finish index
    /// key grows to cover the new allocation.
    fn note_insert(&mut self, a: &Allocation) {
        if a.device < self.active.len() && self.cells.device_active(a.device) {
            self.cells.note_busy(a.device);
            let key = self.cells.avail_key(a.device).map_or(a.end, |k| k.max(a.end));
            self.cells.set_avail_key(a.device, key);
        }
    }

    /// Cell bookkeeping after an allocation left `device`: back to the
    /// idle pool when nothing remains, re-keyed otherwise.
    fn note_removed(&mut self, device: DeviceId) {
        // Suspended (believed-down) devices are out of the cell index;
        // their keys rebuild wholesale when the suspicion clears.
        if device >= self.active.len() || !self.cells.device_active(device) {
            return;
        }
        match self.state.device_allocs(device).map(|a| a.end).max() {
            Some(end) => self.cells.set_avail_key(device, end),
            None => self.cells.note_idle(device),
        }
    }

    /// Record an allocation decided by another scheduler (used by the
    /// contextual multi-scheduler ablation).
    pub fn mirror_external(&mut self, a: &Allocation) {
        if let Some((c1, c2)) = a.comm {
            self.reserve_comm(a.task, c1, c2);
        }
        self.state.insert(*a);
        self.note_insert(a);
    }

    /// Expose comm reservations for white-box tests.
    #[cfg(test)]
    fn comm_count(&self) -> usize {
        self.comms.len()
    }
}

impl WpsScheduler {
    /// Schedule a high-priority task (always local to its source device).
    /// Legacy-shaped entry point; [`Scheduler::on_event`] dispatches here.
    pub fn schedule_high(&mut self, now: SimTime, task: &Task) -> HpOutcome {
        let mut ops: Ops = 0;
        if !self.device_active(task.source) {
            // The source device left the fleet: nowhere to run HP work.
            return HpOutcome::Rejected { victims: vec![], ops: 1 };
        }
        let dur = task.proc_for(TaskConfig::HighPriority);
        let cores = TaskConfig::HighPriority.cores(&self.cfg);
        let dev = task.source;
        // Exhaustive: earliest exact start within the deadline.
        if let Some(s) = self.earliest_start(dev, now, task.deadline, dur, cores, &mut ops) {
            let alloc = Allocation {
                task: task.id,
                frame: task.frame,
                device: dev,
                config: TaskConfig::HighPriority,
                cores,
                start: s,
                end: s + dur,
                deadline: task.deadline,
                offloaded: false,
                comm: None,
            };
            self.state.insert(alloc);
            self.note_insert(&alloc);
            return HpOutcome::Allocated { alloc, ops };
        }
        // Preemption at the desired window [now, now + dur): evict the
        // farthest-deadline overlapping low-priority task, re-validate the
        // whole device schedule (WPS keeps exact state consistent after
        // eviction), and re-run the exhaustive search; repeat while the
        // window stays busy.
        let mut victims: Vec<Allocation> = Vec::new();
        for _ in 0..self.cfg.cores_per_device {
            let (victim, v_ops) = select_victim(&self.state, dev, now, now + dur);
            ops += v_ops;
            let Some(victim) = victim else { break };
            let victim_alloc = self.state.remove(victim).expect("victim tracked");
            self.release_comm(victim);
            self.note_removed(dev);
            victims.push(victim_alloc);
            // Preemption-aware consistency pass (the prior-work system's
            // defining feature): after an eviction, re-validate that every
            // remaining allocation on the device still has a feasible
            // placement — a full exhaustive re-search per allocation. This
            // is the dominant cost of WPS preemption (the paper measures
            // it at ≥250 ms) and the source of the reallocation knock-on:
            // the victim's reallocation can only begin once it completes.
            let remaining: Vec<(SimTime, SimDuration, u32)> = self
                .state
                .device_allocs(dev)
                .map(|a| (a.deadline, a.end - a.start, a.cores))
                .collect();
            for (dl, d, c) in remaining {
                let _ = self.earliest_start(dev, now, dl.max(now + d), d, c, &mut ops);
            }
            // Preemption-aware relocation check: before the eviction is
            // final, exhaustively search the whole network for a feasible
            // new placement for the victim (both configurations, every
            // device, grid resolution). The result informs the controller
            // (the victim re-enters low-priority scheduling either way),
            // but the search cost is intrinsic to the operation — this is
            // the bulk of the ≥250 ms preemption latency the paper
            // measures for WPS, and the reason victim reallocation starts
            // so close to the deadline.
            let (v_deadline, v_dur, v_cores) = (
                victims.last().unwrap().deadline,
                victims.last().unwrap().end - victims.last().unwrap().start,
                victims.last().unwrap().cores,
            );
            // The relocation search's *result* is discarded — only its
            // exact cost is charged — so idle cells collapse to one
            // representative probe whose cost every member repeats.
            for c in 0..self.cells.n_cells() {
                let members = self.cells.cell_active(c);
                if members == 0 {
                    continue;
                }
                if self.cells.all_idle(c) {
                    let rep = self.cells.first_member(c).expect("active cell");
                    let mut rep_ops: Ops = 0;
                    let _ = self.earliest_start(rep, now, v_deadline.max(now + v_dur), v_dur, v_cores, &mut rep_ops);
                    rep_ops += self.comms.len() as Ops; // transfer-slot rescan per device
                    ops += rep_ops * members as Ops;
                    continue;
                }
                for device in self.cells.members(c).collect::<Vec<_>>() {
                    let _ = self.earliest_start(device, now, v_deadline.max(now + v_dur), v_dur, v_cores, &mut ops);
                    ops += self.comms.len() as Ops; // transfer-slot rescan per device
                }
            }
            if let Some(s) = self.earliest_start(dev, now, task.deadline, dur, cores, &mut ops) {
                let alloc = Allocation {
                    task: task.id,
                    frame: task.frame,
                    device: dev,
                    config: TaskConfig::HighPriority,
                    cores,
                    start: s,
                    end: s + dur,
                    deadline: task.deadline,
                    offloaded: false,
                    comm: None,
                };
                self.state.insert(alloc);
                self.note_insert(&alloc);
                return HpOutcome::Preempted { alloc, victims, ops };
            }
        }
        HpOutcome::Rejected { victims, ops }
    }

    /// Schedule a batch of low-priority tasks (one shared class per request),
    /// borrowed in place from the caller's storage (no clones).
    /// Legacy-shaped entry point; [`Scheduler::on_event`] dispatches here.
    pub fn schedule_low(&mut self, now: SimTime, tasks: &[&Task], _realloc: bool) -> LpOutcome {
        let mut ops: Ops = 0;
        if tasks.is_empty() {
            return LpOutcome::Rejected { ops: 1 };
        }
        if !self.device_active(tasks[0].source) {
            // The source device (which holds the input images) is gone.
            return LpOutcome::Rejected { ops: 1 };
        }
        let mut committed: Vec<Allocation> = Vec::with_capacity(tasks.len());
        for &task in tasks {
            // Exhaustive search: every device × event-point starts; keep
            // the best-scoring placement. Configurations are tried in the
            // system's conservative order (Section IV-B2): two cores
            // first, four only if no two-core placement meets the
            // deadline anywhere. The scan descends the cell hierarchy:
            // under the latency score, every idle remote device produces
            // the same candidate start, cost, and score, and the `<=`
            // tie-break keeps the first — so an all-idle remote cell
            // collapses to one representative evaluation, with every
            // member's flat-scan cost still charged.
            let mut best: Option<(Allocation, f64)> = None;
            for config in [TaskConfig::LowTwoCore, TaskConfig::LowFourCore] {
                if best.is_some() {
                    break; // two-core placement found: stay conservative
                }
                // Class-aware stage cost: the task carries its own
                // per-configuration duration (conveyor tasks carry the
                // paper's benchmark times — identical arithmetic).
                let dur = task.proc_for(config);
                let cores = config.cores(&self.cfg);
                for c in 0..self.cells.n_cells() {
                    let members = self.cells.cell_active(c);
                    if members == 0 {
                        continue;
                    }
                    let uniform = matches!(self.mode, ScoreMode::Latency)
                        && self.cells.all_idle(c)
                        && self.cells.map().cell_of(task.source) != c;
                    if uniform {
                        let rep = self.cells.first_member(c).expect("active cell");
                        let mut rep_ops: Ops = 0;
                        let cand = self.try_place(task, rep, config, dur, cores, now, &mut rep_ops);
                        ops += rep_ops * members as Ops;
                        if let Some((alloc, sc)) = cand {
                            match &best {
                                Some((_, b)) if *b <= sc => {}
                                _ => best = Some((alloc, sc)),
                            }
                        }
                        continue;
                    }
                    for device in self.cells.members(c).collect::<Vec<_>>() {
                        if let Some((alloc, sc)) =
                            self.try_place(task, device, config, dur, cores, now, &mut ops)
                        {
                            match &best {
                                Some((_, b)) if *b <= sc => {}
                                _ => best = Some((alloc, sc)),
                            }
                        }
                    }
                }
            }
            match best {
                Some((alloc, _)) => {
                    if let Some((c1, c2)) = alloc.comm {
                        self.reserve_comm(alloc.task, c1, c2);
                    }
                    self.state.insert(alloc);
                    self.note_insert(&alloc);
                    committed.push(alloc);
                }
                None => {
                    // Atomic request: roll back anything already placed.
                    for a in &committed {
                        self.state.remove(a.task);
                        self.release_comm(a.task);
                        ops += 1;
                    }
                    let devices: Vec<DeviceId> = committed.iter().map(|a| a.device).collect();
                    for d in devices {
                        self.note_removed(d);
                    }
                    return LpOutcome::Rejected { ops };
                }
            }
        }
        LpOutcome::Allocated { allocs: committed, ops }
    }

    /// One (task, device, configuration) placement attempt: the exact
    /// transfer-gap search, the exhaustive start search, and the score —
    /// charging exactly what the flat scan charges per device. `None`
    /// when no feasible start (or transfer slot) exists in the deadline.
    #[allow(clippy::too_many_arguments)]
    fn try_place(
        &self,
        task: &Task,
        device: DeviceId,
        config: TaskConfig,
        dur: SimDuration,
        cores: u32,
        now: SimTime,
        ops: &mut Ops,
    ) -> Option<(Allocation, f64)> {
        let local = device == task.source;
        let (from, comm) = if local {
            (now, None)
        } else {
            // Transfer must complete before processing starts.
            let t = self.transfer_time_for(task);
            match self.earliest_comm(now, task.deadline.saturating_sub(dur), t, ops) {
                Some((c1, c2)) => (c2, Some((c1, c2))),
                None => return None,
            }
        };
        let s = self.earliest_start(device, from, task.deadline, dur, cores, ops)?;
        let alloc = Allocation {
            task: task.id,
            frame: task.frame,
            device,
            config,
            cores,
            start: s,
            end: s + dur,
            deadline: task.deadline,
            offloaded: !local,
            comm,
        };
        let sc = self.score_placement(task, &alloc, local, ops);
        Some((alloc, sc))
    }

    /// Record label: the exact-state machinery serves both the published
    /// baseline and the energy variant — the score mode is the identity.
    fn explain_label(&self) -> &'static str {
        match self.mode {
            ScoreMode::Latency => "WPS",
            ScoreMode::Energy { .. } => "ENERGY",
        }
    }

    /// Excluded-candidate tail shared by the HP and LP records: suspected
    /// and departed devices, bounded by [`EXPLAIN_CANDIDATE_CAP`] (lowest
    /// ids first — deterministic). A departed device whose battery read
    /// empty is attributed to the battery, not generic churn.
    fn explain_excluded(&self, candidates: &mut Vec<CandidateScore>) {
        for dev in 0..self.active.len().min(EXPLAIN_CANDIDATE_CAP) {
            let reject = if self.device_suspected(dev) {
                Some(RejectReason::Suspected)
            } else if !self.active[dev] {
                if self.levels.get(dev).copied().unwrap_or(1.0) <= 0.0 {
                    Some(RejectReason::Battery)
                } else {
                    Some(RejectReason::Offline)
                }
            } else {
                None
            };
            if let Some(reject) = reject {
                candidates.push(CandidateScore {
                    device: dev,
                    score: f64::INFINITY,
                    reject: Some(reject),
                });
            }
        }
    }

    /// Explainability record for a high-priority decision (source-pinned:
    /// the candidate set is the single source device).
    fn explain_hp(&mut self, task: &Task, d: &Decision) {
        let (chosen, reject, score) = match &d.outcome {
            Outcome::HpAllocated { alloc, .. } => {
                (Some((alloc.device, alloc.cores as u8)), None, alloc.end as f64)
            }
            _ if !self.device_active(task.source) => {
                (None, Some(RejectReason::Offline), f64::INFINITY)
            }
            _ => (None, Some(RejectReason::WindowInfeasible), f64::INFINITY),
        };
        self.explain.push(DecisionRecord {
            scheduler: self.explain_label(),
            task: task.id,
            batch: 1,
            high_priority: true,
            candidates: vec![CandidateScore { device: task.source, score, reject }],
            chosen,
            rung: None,
            cloud: false,
        });
    }

    /// Explainability record for one low-priority decision. Placed
    /// batches carry the *actual placement score* per winning device
    /// (recomputed from the committed allocation — latency or joules,
    /// whichever mode is live); rejections pin the source with a
    /// window-infeasibility. Called from [`Scheduler::on_event`] and from
    /// the energy variant's tier-inverted LP path, which bypasses it.
    pub(crate) fn explain_lp_decision(&mut self, tasks: &[&Task], d: &Decision) {
        if !self.explain.on() {
            return;
        }
        let cloud_dev = self.cloud.as_ref().map(|c| c.device);
        let mut candidates: Vec<CandidateScore> = Vec::new();
        let mut chosen = None;
        let mut cloud = false;
        match &d.outcome {
            Outcome::LpAllocated { allocs } => {
                for a in allocs {
                    if Some(a.device) == cloud_dev {
                        cloud = true;
                    }
                    let score = match tasks.iter().find(|t| t.id == a.task) {
                        Some(t) => {
                            let mut o: Ops = 0;
                            self.score_placement(t, a, !a.offloaded, &mut o)
                        }
                        None => a.end as f64,
                    };
                    candidates.push(CandidateScore { device: a.device, score, reject: None });
                }
                chosen = allocs.first().map(|a| (a.device, a.cores as u8));
            }
            _ => {
                candidates.push(CandidateScore {
                    device: tasks.first().map(|t| t.source).unwrap_or(0),
                    score: f64::INFINITY,
                    reject: Some(RejectReason::WindowInfeasible),
                });
            }
        }
        self.explain_excluded(&mut candidates);
        self.explain.push(DecisionRecord {
            scheduler: self.explain_label(),
            task: tasks.first().map(|t| t.id).unwrap_or(0),
            batch: tasks.len(),
            high_priority: false,
            candidates,
            chosen,
            rung: d.variant.map(|v| v as usize),
            cloud,
        });
    }

    /// Explain-gate passthrough for the energy wrapper.
    pub(crate) fn explain_set(&mut self, on: bool) {
        self.explain.set(on);
    }

    /// Drain passthrough for the energy wrapper.
    pub(crate) fn explain_drain(&mut self) -> Vec<DecisionRecord> {
        self.explain.drain()
    }

    /// Task finished (free its resources from the scheduler's state).
    pub fn on_complete(&mut self, _now: SimTime, task: TaskId) {
        // Exact state: removal is cheap and fully reclaims capacity —
        // the accuracy advantage of the baseline representation.
        let removed = self.state.remove(task);
        self.release_comm(task);
        if let Some(a) = removed {
            self.note_removed(a.device);
        }
    }

    /// Task missed its deadline and was abandoned.
    pub fn on_violation(&mut self, _now: SimTime, task: TaskId) {
        let removed = self.state.remove(task);
        self.release_comm(task);
        if let Some(a) = removed {
            self.note_removed(a.device);
        }
    }

    /// WPS predates the dynamic mechanism: static estimate, no rebuild.
    pub fn on_bandwidth_update(&mut self, _now: SimTime, _bps: f64) -> Ops {
        0
    }

    /// A device joined the fleet (exact state just grows a slot).
    pub fn on_device_joined(&mut self, _now: SimTime, device: DeviceId) -> Ops {
        while self.active.len() <= device {
            self.active.push(false);
            self.suspected.push(false);
        }
        self.state.ensure_device(device);
        self.suspected[device] = false;
        self.active[device] = true;
        self.cells.set_active(device, true);
        1
    }

    /// A device left the fleet: evict its live allocations (returned so
    /// the controller can reschedule them) and release their link slots.
    pub fn on_device_left(&mut self, _now: SimTime, device: DeviceId) -> (Vec<Allocation>, Ops) {
        if !self.device_active(device) && !self.device_suspected(device) {
            return (Vec::new(), 1);
        }
        if self.device_suspected(device) {
            // The suspicion was right (or churn beat the heartbeat): the
            // device already left the candidate pool — only the eviction
            // of its still-tracked allocations remains.
            self.suspected[device] = false;
        } else {
            self.active[device] = false;
            self.cells.set_active(device, false);
        }
        let evicted = self.state.evict_device(device);
        let mut ops: Ops = 1;
        for a in &evicted {
            self.release_comm(a.task);
            ops += 2;
        }
        (evicted, ops)
    }

    /// The failure detector suspects `device` is down. Belief, not truth:
    /// the device leaves the candidate pool (no new placements) but its
    /// exact state — allocations and comm windows — stands, because the
    /// work may well still complete.
    pub fn on_device_suspected(&mut self, device: DeviceId) -> Ops {
        if !self.device_active(device) || self.device_suspected(device) {
            return 1;
        }
        self.suspected[device] = true;
        self.active[device] = false;
        self.cells.set_active(device, false);
        1
    }

    /// A heartbeat cleared the suspicion: restore the device to the
    /// candidate pool and rebuild its cell key from the exact state (it
    /// may have finished — or accumulated — work while believed down).
    pub fn on_device_cleared(&mut self, device: DeviceId) -> Ops {
        if !self.device_suspected(device) {
            return 1;
        }
        self.suspected[device] = false;
        self.active[device] = true;
        self.cells.set_active(device, true);
        if let Some(end) = self.state.device_allocs(device).map(|a| a.end).max() {
            self.cells.note_busy(device);
            self.cells.set_avail_key(device, end);
        }
        1
    }
}

impl Scheduler for WpsScheduler {
    fn name(&self) -> &'static str {
        "WPS"
    }

    fn on_event(&mut self, now: SimTime, ev: SchedEvent<'_>) -> Decision {
        match ev {
            SchedEvent::HighPriority { task } => {
                let d: Decision = self.schedule_high(now, task).into();
                if self.explain.on() {
                    self.explain_hp(task, &d);
                }
                d
            }
            SchedEvent::LowPriorityBatch { tasks, realloc, ladder } => {
                // Shared degradation policy over the *exact* state: WPS
                // only steps down when no placement truly exists, so it
                // degrades strictly less often than RAS's conservative
                // windows require — the two abstractions disagree about
                // when degradation is necessary. With a cloud tier
                // configured, each rung falls through to a WAN
                // feasibility check before the ladder steps down.
                let cloud = self.cloud;
                let d =
                    place_degrading_tiered(now, tasks, ladder, realloc, cloud.as_ref(), |n, ts, r| {
                        self.schedule_low(n, ts, r)
                    });
                self.explain_lp_decision(tasks, &d);
                d
            }
            SchedEvent::Complete { task } => {
                self.on_complete(now, task);
                Decision::ack(1)
            }
            SchedEvent::Violation { task } => {
                self.on_violation(now, task);
                Decision::ack(1)
            }
            SchedEvent::BandwidthUpdate { bps } => Decision::ack(self.on_bandwidth_update(now, bps)),
            SchedEvent::DeviceJoined { device } => Decision::ack(self.on_device_joined(now, device)),
            SchedEvent::DeviceLeft { device } | SchedEvent::DeviceCrashed { device } => {
                // Exact state makes no distinction between a drained and
                // a crashed device: evict and surface the allocations.
                let (evicted, ops) = self.on_device_left(now, device);
                Decision { outcome: Outcome::Ack { evicted }, ops, variant: None }
            }
            SchedEvent::DeviceRecovered { device } => {
                Decision::ack(self.on_device_joined(now, device))
            }
            SchedEvent::Reoffer { tasks, ladder } => {
                // Re-place on the remaining deadline budget; the
                // exhaustive search rejects (drop-by-deadline) when no
                // start fits before the original deadline — after the
                // remaining ladder tail (and the cloud tier, if any) has
                // been exhausted.
                let cloud = self.cloud;
                let d = place_degrading_tiered(now, tasks, ladder, true, cloud.as_ref(), |n, ts, r| {
                    self.schedule_low(n, ts, r)
                });
                self.explain_lp_decision(tasks, &d);
                d
            }
            SchedEvent::CloudBandwidthUpdate { bps } => {
                // Passive WAN estimate refresh from the engine — free: no
                // link-state rebuild, just a stored scalar.
                if let Some(c) = &mut self.cloud {
                    c.update(bps);
                }
                Decision::ack(0)
            }
            SchedEvent::BatteryLevels { levels } => {
                // Stored for the energy score; the latency score ignores
                // them. Only dispatched when a battery is configured.
                self.levels.clear();
                self.levels.extend_from_slice(levels);
                Decision::ack(0)
            }
            SchedEvent::DeviceSuspected { device } => {
                Decision::ack(self.on_device_suspected(device))
            }
            SchedEvent::DeviceCleared { device } => {
                Decision::ack(self.on_device_cleared(device))
            }
            // WPS predates the dynamic bandwidth mechanism: a stale
            // estimator changes nothing for a scheduler that never
            // believed the estimator in the first place.
            SchedEvent::BandwidthStale => Decision::ack(0),
            SchedEvent::Pressure { candidates, escalate } => {
                super::decide_pressure(candidates, escalate)
            }
        }
    }

    fn bandwidth_estimate(&self) -> f64 {
        self.bps
    }

    fn state(&self) -> &WorkloadState {
        &self.state
    }

    fn set_explain(&mut self, on: bool) {
        self.explain.set(on);
    }

    fn drain_decisions(&mut self) -> Vec<DecisionRecord> {
        self.explain.drain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::task_refs;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn hp(id: TaskId, source: DeviceId, now: SimTime, c: &SystemConfig) -> Task {
        Task::high(id, id, source, now, c)
    }

    fn lp_batch(base: TaskId, n: usize, source: DeviceId, now: SimTime, c: &SystemConfig) -> Vec<Task> {
        let deadline = now + c.frame_period();
        (0..n as u64)
            .map(|i| Task::low(base + i, base, source, now, deadline, c))
            .collect()
    }

    #[test]
    fn hp_allocates_exact_start() {
        let c = cfg();
        let mut s = WpsScheduler::new(&c, 0, c.link_bps);
        match s.schedule_high(0, &hp(1, 0, 0, &c)) {
            HpOutcome::Allocated { alloc, .. } => {
                assert_eq!(alloc.start, 0);
                assert_eq!(alloc.end, c.hp_proc());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hp_queues_behind_existing_instead_of_preempting() {
        // WPS's exact search can slide the HP task to the end of an
        // existing allocation if it still meets the deadline — better
        // placement accuracy than RAS's fixed-window preemption. Give the
        // deadline enough room for one queued processing slot.
        let c = SystemConfig { hp_deadline_s: 2.0, ..cfg() };
        let mut s = WpsScheduler::new(&c, 0, c.link_bps);
        // One HP task holds the whole device until hp_proc.
        assert!(matches!(s.schedule_high(0, &hp(1, 0, 0, &c)), HpOutcome::Allocated { .. }));
        // Deadline budget (2.0 s) leaves room to queue after 0.98 s.
        match s.schedule_high(0, &hp(9, 0, 0, &c)) {
            HpOutcome::Allocated { alloc, .. } => assert_eq!(alloc.start, c.hp_proc()),
            other => panic!("expected queued allocation, got {other:?}"),
        }
    }

    #[test]
    fn lp_placement_is_exact_three_two_core_tasks_fit_nowhere_locally() {
        // A 4-core device holds exactly two 2-core tasks concurrently;
        // the third must offload — and with exact accounting WPS knows it.
        let c = cfg();
        let mut s = WpsScheduler::new(&c, 0, c.link_bps);
        let tasks = lp_batch(1, 3, 2, 0, &c);
        match s.schedule_low(0, &task_refs(&tasks), false) {
            LpOutcome::Allocated { allocs, .. } => {
                let local = allocs.iter().filter(|a| a.device == 2).count();
                assert_eq!(local, 2);
                let offloaded: Vec<_> = allocs.iter().filter(|a| a.offloaded).collect();
                assert_eq!(offloaded.len(), 1);
                assert!(offloaded[0].comm.is_some());
            }
            LpOutcome::Rejected { .. } => panic!("should fit"),
        }
        assert_eq!(s.comm_count(), 1);
    }

    #[test]
    fn degradation_only_fires_when_the_exact_state_is_full() {
        use crate::coordinator::scheduler::{task_refs, Outcome, SchedEvent};
        use crate::coordinator::task::VariantRung;
        let c = cfg();
        let mut s = WpsScheduler::new(&c, 0, c.link_bps);
        let deadline = c.frame_period();
        let ladder = [
            VariantRung { accuracy: 0.97, input_bytes: c.image_bytes, proc_us: [c.lp2_proc(), c.lp4_proc()] },
            VariantRung { accuracy: 0.80, input_bytes: c.image_bytes / 4, proc_us: [2_000_000, 1_500_000] },
        ];
        // An idle fleet: the full-accuracy rung fits, so the ladder must
        // NOT degrade (exact state says rung 0 is feasible).
        let t1 = Task::low(1, 1, 0, 0, deadline, &c);
        let refs = task_refs(std::slice::from_ref(&t1));
        let d = s.on_event(0, SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &ladder });
        assert_eq!(d.variant, Some(0), "idle fleet: full accuracy must win");
        assert!(matches!(d.outcome, Outcome::LpAllocated { .. }));
        // A deadline no full-model configuration can meet anywhere: the
        // exhaustive search fails rung 0 and the ladder steps down.
        let t2 = Task::low(2, 2, 1, 0, c.lp4_proc() - 1, &c);
        let refs = task_refs(std::slice::from_ref(&t2));
        let d = s.on_event(0, SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &ladder });
        assert_eq!(d.variant, Some(1));
        let Outcome::LpAllocated { allocs } = d.outcome else { panic!("{:?}", d.outcome) };
        assert_eq!(allocs[0].end - allocs[0].start, 2_000_000);
    }

    #[test]
    fn cloud_tier_catches_rejections_before_degradation() {
        use crate::coordinator::scheduler::{task_refs, Outcome, SchedEvent};
        use crate::coordinator::task::VariantRung;
        let c = SystemConfig { cloud_wan_bps: 20e6, cloud_rtt_ms: 40.0, ..cfg() };
        let mut s = WpsScheduler::new(&c, 0, c.link_bps);
        let ladder = [
            VariantRung { accuracy: 0.97, input_bytes: c.image_bytes, proc_us: [c.lp2_proc(), c.lp4_proc()] },
            VariantRung { accuracy: 0.80, input_bytes: c.image_bytes / 4, proc_us: [2_000_000, 1_500_000] },
        ];
        // A deadline no edge configuration can meet (tighter than the
        // four-core stage), but with ~12 s of slack the cloud absorbs it
        // at full accuracy: the rung must NOT step down.
        let t = Task::low(1, 1, 0, 0, c.lp4_proc() - 1, &c);
        let refs = task_refs(std::slice::from_ref(&t));
        let d = s.on_event(0, SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &ladder });
        assert_eq!(d.variant, Some(0), "cloud tier should hold the rung");
        let Outcome::LpAllocated { allocs } = d.outcome else { panic!("{:?}", d.outcome) };
        assert_eq!(allocs[0].device, c.n_devices, "placed on the cloud pseudo-device");
        assert_eq!(allocs[0].cores, 0);
        // Cloud allocations never enter the edge workload state.
        let (peak, _) = s.state().peak_usage(0, 0, 30_000_000);
        assert_eq!(peak, 0);
    }

    #[test]
    fn energy_score_steers_work_off_low_battery_devices() {
        let c = cfg();
        let mut lat = WpsScheduler::new(&c, 0, c.link_bps);
        let mut en = WpsScheduler::with_score_mode(
            &c,
            0,
            c.link_bps,
            ScoreMode::Energy { model: EnergyModel::pi2b() },
        );
        let batch = lp_batch(1, 1, 2, 0, &c);
        // Full batteries: the frugal choice is local two-core (no radio
        // joules) — same placement the latency score picks on an idle
        // fleet, but the energy score pays extra ops for knowing it.
        let lo = match lat.schedule_low(0, &task_refs(&batch), false) {
            LpOutcome::Allocated { allocs, ops } => (allocs[0].device, ops),
            other => panic!("{other:?}"),
        };
        let eo = match en.schedule_low(0, &task_refs(&batch), false) {
            LpOutcome::Allocated { allocs, ops } => (allocs[0].device, ops),
            other => panic!("{other:?}"),
        };
        assert_eq!(lo.0, 2);
        assert_eq!(eo.0, 2);
        assert!(eo.1 > lo.1, "energy scoring must charge extra ops: {} vs {}", eo.1, lo.1);
        // Nearly-drained source: the scarcity multiplier makes the local
        // placement dearer than paying the transfer to a full device.
        let mut en2 = WpsScheduler::with_score_mode(
            &c,
            0,
            c.link_bps,
            ScoreMode::Energy { model: EnergyModel::pi2b() },
        );
        let levels = [1.0, 1.0, 0.02, 1.0];
        let d = en2.on_event(0, SchedEvent::BatteryLevels { levels: &levels });
        assert_eq!(d.ops, 0);
        match en2.schedule_low(0, &task_refs(&batch), false) {
            LpOutcome::Allocated { allocs, .. } => {
                assert_ne!(allocs[0].device, 2, "drained device must lose the placement");
                assert!(allocs[0].offloaded);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn explain_mode_records_per_candidate_scores() {
        use crate::coordinator::task::VariantRung;
        let c = cfg();
        let mut s = WpsScheduler::new(&c, 0, c.link_bps);
        s.set_explain(true);
        let ladder = [VariantRung {
            accuracy: 0.97,
            input_bytes: c.image_bytes,
            proc_us: [c.lp2_proc(), c.lp4_proc()],
        }];
        let tasks = lp_batch(1, 3, 2, 0, &c);
        let refs = task_refs(&tasks);
        let d = s.on_event(
            0,
            SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &ladder },
        );
        let Outcome::LpAllocated { allocs } = &d.outcome else { panic!("{:?}", d.outcome) };
        let recs = s.drain_decisions();
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        assert_eq!(r.scheduler, "WPS");
        assert_eq!(r.batch, 3);
        assert_eq!(r.outcome(), "placed");
        // Every winning device carries a finite placement score.
        let placed: Vec<_> = r.candidates.iter().filter(|x| x.reject.is_none()).collect();
        assert_eq!(placed.len(), allocs.len());
        assert!(placed.iter().all(|x| x.score.is_finite()));
        // The local placements beat the offload on the weighted score.
        let local_max = placed
            .iter()
            .filter(|x| x.device == 2)
            .map(|x| x.score)
            .fold(f64::MIN, f64::max);
        let off = placed.iter().find(|x| x.device != 2).expect("one offload");
        assert!(local_max < off.score, "{local_max} vs {}", off.score);
    }

    #[test]
    fn comm_windows_never_overlap() {
        let c = cfg();
        let mut s = WpsScheduler::new(&c, 0, c.link_bps);
        // Force many offloads: source device 0 saturated with 4+ tasks.
        let t1 = lp_batch(1, 4, 0, 0, &c);
        assert!(matches!(s.schedule_low(0, &task_refs(&t1), false), LpOutcome::Allocated { .. }));
        let t2 = lp_batch(11, 4, 0, 0, &c);
        let _ = s.schedule_low(0, &task_refs(&t2), false);
        for w in s.comms.windows(2) {
            assert!(w[0].t2 <= w[1].t1, "comm windows overlap: {w:?}");
        }
    }

    #[test]
    fn violation_and_completion_reclaim_capacity() {
        let c = cfg();
        let mut s = WpsScheduler::new(&c, 0, c.link_bps);
        let tasks = lp_batch(1, 2, 0, 0, &c);
        assert!(matches!(s.schedule_low(0, &task_refs(&tasks), false), LpOutcome::Allocated { .. }));
        let (peak, _) = s.state().peak_usage(0, 0, 1_000_000);
        assert_eq!(peak, 4);
        s.on_complete(100, 1);
        s.on_violation(100, 2);
        let (peak, _) = s.state().peak_usage(0, 0, 1_000_000);
        assert_eq!(peak, 0);
    }

    #[test]
    fn bandwidth_updates_are_ignored() {
        let c = cfg();
        let mut s = WpsScheduler::new(&c, 0, c.link_bps);
        assert_eq!(s.on_bandwidth_update(0, 1.0), 0);
        assert_eq!(s.bandwidth_estimate(), c.link_bps);
    }

    #[test]
    fn suspicion_excludes_candidate_but_keeps_allocations() {
        let c = cfg();
        let mut s = WpsScheduler::new(&c, 0, c.link_bps);
        let tasks = lp_batch(1, 3, 2, 0, &c);
        let allocs = match s.schedule_low(0, &task_refs(&tasks), false) {
            LpOutcome::Allocated { allocs, .. } => allocs,
            other => panic!("{other:?}"),
        };
        let off = *allocs.iter().find(|a| a.offloaded).expect("one offload");
        let dev = off.device;
        assert_eq!(s.on_device_suspected(dev), 1);
        assert_eq!(s.on_device_suspected(dev), 1, "idempotent");
        // Exact state untouched: the in-flight allocation still holds cores.
        let (peak, _) = s.state().peak_usage(dev, off.start, off.end);
        assert!(peak > 0, "suspicion must not evict");
        // New work routes around the believed-down device.
        let more = lp_batch(11, 3, 2, 0, &c);
        if let LpOutcome::Allocated { allocs, .. } = s.schedule_low(0, &task_refs(&more), false) {
            assert!(allocs.iter().all(|a| a.device != dev), "suspected device got work");
        }
        // Clearing restores the device; completion then reclaims normally.
        assert_eq!(s.on_device_cleared(dev), 1);
        s.on_complete(off.end, off.task);
        let (peak, _) = s.state().peak_usage(dev, off.start, off.end);
        assert_eq!(peak, 0);
    }

    #[test]
    fn crash_on_suspected_device_still_evicts() {
        let c = cfg();
        let mut s = WpsScheduler::new(&c, 0, c.link_bps);
        let tasks = lp_batch(1, 3, 2, 0, &c);
        let allocs = match s.schedule_low(0, &task_refs(&tasks), false) {
            LpOutcome::Allocated { allocs, .. } => allocs,
            other => panic!("{other:?}"),
        };
        let off = *allocs.iter().find(|a| a.offloaded).expect("one offload");
        s.on_device_suspected(off.device);
        // The suspicion was right: the crash notice must still evict even
        // though the candidate-pool flags already show the device as gone.
        let (evicted, _) = s.on_device_left(0, off.device);
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].task, off.task);
        let (peak, _) = s.state().peak_usage(off.device, off.start, off.end);
        assert_eq!(peak, 0);
        // Already handled: a second notice is a cheap no-op.
        let (again, _) = s.on_device_left(0, off.device);
        assert!(again.is_empty());
    }

    #[test]
    fn never_oversubscribes_device_cores() {
        let c = cfg();
        let mut s = WpsScheduler::new(&c, 0, c.link_bps);
        let mut id = 0u64;
        for round in 0..6u64 {
            let now = round * 2_000_000;
            for d in 0..c.n_devices {
                let _ = s.schedule_high(now, &hp(id, d, now, &c));
                id += 1;
            }
            let batch = lp_batch(id, (round as usize % 4) + 1, (round as usize) % 4, now, &c);
            id += batch.len() as u64;
            let _ = s.schedule_low(now, &task_refs(&batch), false);
        }
        for d in 0..c.n_devices {
            for t in (0..40_000_000u64).step_by(250_000) {
                let (peak, _) = s.state().peak_usage(d, t, t + 250_000);
                assert!(peak <= c.cores_per_device, "device {d} oversubscribed at {t}: {peak}");
            }
        }
    }
}
