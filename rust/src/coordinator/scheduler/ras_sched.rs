//! The paper's scheduler (RAS): containment queries over resource
//! availability lists, a discretised network link, and dynamic bandwidth
//! estimation (Sections IV-A and IV-B).

use super::{
    place_degrading_tiered, select_victim, CloudPlan, Decision, ExplainLog, HpOutcome, LpOutcome,
    Ops, Outcome, SchedEvent, Scheduler, WorkloadState, EXPLAIN_CANDIDATE_CAP,
};
use crate::config::SystemConfig;
use crate::obs::{CandidateScore, DecisionRecord, RejectReason};
use crate::coordinator::fleet::{FleetCells, LazyShuffle};
use crate::coordinator::netlink::{CommTask, DiscretisedLink};
use crate::coordinator::ras::{DeviceAvailability, WindowRef};
use crate::coordinator::task::{Allocation, DeviceId, Task, TaskConfig, TaskId};
use crate::time::{SimDuration, SimTime};
use crate::util::Rng;

/// Fraction of the last estimate RAS plans with while the estimate is
/// stale ([`SchedEvent::BandwidthStale`]): the transfer unit grows by
/// 1/0.7 ≈ 1.43×, widening every conservative communication window until
/// a fresh probe round lands. Deliberately milder than a congestion
/// measurement — staleness is *uncertainty*, not evidence of collapse.
const STALE_BW_DISCOUNT: f64 = 0.7;

/// The resource-availability abstraction scheduler.
pub struct RasScheduler {
    cfg: SystemConfig,
    devices: Vec<DeviceAvailability>,
    /// Fleet membership (scenario churn): inactive devices are skipped by
    /// every placement loop and hold no availability.
    active: Vec<bool>,
    /// Believed-down devices (failure detector, [`SchedEvent::DeviceSuspected`]):
    /// removed from the candidate pool like inactive devices, but their
    /// allocations and availability lists stay — a false suspicion must
    /// not lose work, and a cleared device resumes with its state intact.
    suspected: Vec<bool>,
    /// Whether the device was on the closed-form never-written path when
    /// it was suspended, so clearing restores the cell bookkeeping
    /// exactly instead of mistaking a written device for a fresh one.
    suspected_idle: Vec<bool>,
    /// The bandwidth estimate went stale ([`SchedEvent::BandwidthStale`])
    /// and has not been refreshed: plan transfers at
    /// [`STALE_BW_DISCOUNT`] of the last estimate.
    stale_widen: bool,
    /// Sharded fleet hierarchy: per-cell active/quiescent counts and the
    /// earliest-finish candidate index. Placement descends cell → device
    /// through this instead of scanning every slot; devices whose lists
    /// were never written are answered closed-form without being touched.
    cells: FleetCells,
    /// Reference time of the most recent placement scan. The flat scan
    /// advances *every* active device to the scan time; the sharded scan
    /// leaves never-written devices untouched, so any later write to one
    /// of them first catches it up to this point (a no-op for devices
    /// the scan did visit) — reproducing the flat state exactly.
    last_scan: SimTime,
    link: DiscretisedLink,
    state: WorkloadState,
    /// Current bandwidth estimate (bits/s) — updated by probe rounds.
    bps: f64,
    /// Guest-scatter stream base (derived from the config seed).
    scatter_seed: u64,
    /// Placement decisions that drew a scatter permutation so far. Each
    /// decision derives a fresh stream from `(scatter_seed, counter)`:
    /// the eager regime consumes the whole permutation's draws while
    /// the lazy regime stops at the candidates it actually used, and a
    /// per-decision stream keeps that draw-count difference from ever
    /// leaking into the next decision's permutation — the two regimes
    /// stay decision-identical across a whole run.
    scatter_decisions: u64,
    /// Cumulative link rebuilds (Fig. 6/7 diagnostics).
    pub link_rebuilds: u64,
    /// Items dropped during cascades.
    pub cascade_dropped: u64,
    /// Placement-attempt failure diagnostics: [no viable config, link
    /// capacity, insufficient windows, commit-time failure]. Counted per
    /// failed *attempt* (a config fallback or a ladder-rung probe that
    /// later succeeds still leaves its mark), not per rejected batch —
    /// see [`Scheduler::reject_diag`].
    pub reject_reasons: [u64; 4],
    /// Cloud tier (None when `cloud_wan_bps` is 0 — the default): an
    /// extra placement target checked after the availability lists and
    /// the discretised link reject a rung.
    cloud: Option<CloudPlan>,
    /// Explainability buffer ([`Scheduler::set_explain`]): off by
    /// default, so the hot path never constructs a record.
    explain: ExplainLog,
}

impl RasScheduler {
    pub fn new(cfg: &SystemConfig, now: SimTime, baseline_bps: f64) -> Self {
        let unit = cfg.transfer_unit(baseline_bps);
        Self {
            devices: (0..cfg.n_devices).map(|_| DeviceAvailability::new(cfg, now)).collect(),
            active: vec![true; cfg.n_devices],
            suspected: vec![false; cfg.n_devices],
            suspected_idle: vec![false; cfg.n_devices],
            stale_widen: false,
            cells: FleetCells::new(cfg.cell_size, cfg.n_devices),
            last_scan: now,
            link: DiscretisedLink::build(now, unit, cfg.base_buckets, cfg.exp_buckets),
            state: WorkloadState::new(cfg.n_devices),
            bps: baseline_bps,
            scatter_seed: cfg.seed ^ 0x5241_53, // "RAS"
            scatter_decisions: 0,
            link_rebuilds: 0,
            cascade_dropped: 0,
            reject_reasons: [0; 4],
            cloud: CloudPlan::from_config(cfg),
            explain: ExplainLog::default(),
            cfg: cfg.clone(),
        }
    }

    fn device_active(&self, d: DeviceId) -> bool {
        d < self.devices.len() && self.active[d]
    }

    fn device_suspected(&self, d: DeviceId) -> bool {
        d < self.suspected.len() && self.suspected[d]
    }

    /// Estimate the placement math plans with: discounted while stale.
    fn planning_bps(&self) -> f64 {
        if self.stale_widen {
            self.bps * STALE_BW_DISCOUNT
        } else {
            self.bps
        }
    }

    /// Fresh scatter stream for one placement decision. Seeded from the
    /// scheduler seed and a decision counter (golden-ratio mixed), so
    /// the stream depends only on *which* decision this is — never on
    /// how many draws earlier decisions consumed.
    fn scatter_rng(&mut self) -> Rng {
        self.scatter_decisions += 1;
        Rng::seed_from_u64(
            self.scatter_seed ^ self.scatter_decisions.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        )
    }

    /// Viable low-priority configurations in preference order
    /// (Section IV-B2): two cores first (conservative), four cores when
    /// two would violate the deadline — and, per the compensation the
    /// congestion experiments observe (Table II), also as a *fallback*
    /// when the two-core attempt finds no placement: a shorter processing
    /// time widens the allocation window that long transfers eat into.
    /// Durations are class-aware: the task says what each configuration
    /// costs (the conveyor classes carry the paper's benchmark times).
    fn viable_configs(&self, now: SimTime, task: &Task, deadline: SimTime) -> Vec<TaskConfig> {
        let mut out = Vec::with_capacity(2);
        if now + task.proc_for(TaskConfig::LowTwoCore) <= deadline {
            out.push(TaskConfig::LowTwoCore);
        }
        if now + task.proc_for(TaskConfig::LowFourCore) <= deadline {
            out.push(TaskConfig::LowFourCore);
        }
        out
    }

    fn commit(
        &mut self,
        device: DeviceId,
        config: TaskConfig,
        r: WindowRef,
        task: &Task,
        start: SimTime,
        end: SimTime,
        comm: Option<(SimTime, SimTime)>,
    ) -> (Allocation, Ops) {
        let cores = config.cores(&self.cfg);
        let dev = &mut self.devices[device];
        dev.list_mut(config).allocate_at(r, start, end);
        // Background cross-list write (the paper's post-allocation write).
        let mut ops: Ops = 2;
        for c in crate::coordinator::task::ALL_CONFIGS {
            if c != config {
                dev.list_mut(c).write(start, end, cores);
                ops += dev.list(c).track_count() as Ops;
            }
        }
        let alloc = Allocation {
            task: task.id,
            frame: task.frame,
            device,
            config,
            cores,
            start,
            end,
            deadline: task.deadline,
            offloaded: device != task.source,
            comm,
        };
        self.state.insert(alloc);
        self.cells.note_busy(device);
        let key = self.cells.avail_key(device).map_or(end, |k| k.max(end));
        self.cells.set_avail_key(device, key);
        (alloc, ops)
    }

    /// Re-derive a device's earliest-finish index key from its live
    /// allocations (after a completion, violation, or eviction). A
    /// suspended device is not in the cell index (its key was cleared
    /// with its membership); its key is rebuilt when it is cleared.
    fn refresh_avail_key(&mut self, device: DeviceId) {
        if !self.cells.device_active(device) {
            return;
        }
        match self.state.device_allocs(device).map(|a| a.end).max() {
            Some(end) => self.cells.set_avail_key(device, end),
            None => self.cells.clear_avail_key(device),
        }
    }

    /// Roll a failed batch back: drop the already-committed allocations and
    /// reconstruct the touched devices (windows cannot be re-inserted).
    fn rollback(&mut self, committed: &[Allocation], now: SimTime) -> Ops {
        let mut ops: Ops = 0;
        let mut touched: Vec<DeviceId> = Vec::new();
        for a in committed {
            self.state.remove(a.task);
            self.link.remove_task(a.task);
            if !touched.contains(&a.device) {
                touched.push(a.device);
            }
            ops += 2;
        }
        for d in touched {
            ops += self.reconstruct_device(d, now);
        }
        ops
    }

    fn reconstruct_device(&mut self, device: DeviceId, now: SimTime) -> Ops {
        let allocs: Vec<Allocation> = self.state.device_allocs(device).copied().collect();
        let n = allocs.len() as Ops;
        self.devices[device].reconstruct(&self.cfg, now, allocs.iter());
        if allocs.is_empty() {
            // Rebuilt with no residents: indistinguishable from a fresh
            // construct, so the closed-form placement path applies again.
            self.cells.note_idle(device);
        } else if self.cells.device_active(device) {
            self.cells.note_busy(device);
            let end = allocs.iter().map(|a| a.end).max().unwrap();
            self.cells.set_avail_key(device, end);
        }
        // Cost: one fresh list set + one cross-list write per live task.
        n * 7 + 7
    }

    /// Record an allocation decided by another scheduler (used by the
    /// contextual multi-scheduler ablation): occupancy is written across
    /// the device's availability lists and the exact state, without going
    /// through this scheduler's own placement logic.
    pub fn mirror_external(&mut self, a: &Allocation) {
        // Catch a scan-skipped (never-written) device up to the flat
        // scan's reference time before the first write lands on it.
        self.devices[a.device].advance(self.last_scan);
        self.devices[a.device].write_all(a.start, a.end, a.cores);
        self.state.insert(*a);
        self.cells.note_busy(a.device);
        let key = self.cells.avail_key(a.device).map_or(a.end, |k| k.max(a.end));
        self.cells.set_avail_key(a.device, key);
    }

    /// Expose internals for white-box tests/benches.
    pub fn device_availability(&self, d: DeviceId) -> &DeviceAvailability {
        &self.devices[d]
    }

    pub fn link(&self) -> &DiscretisedLink {
        &self.link
    }

    pub fn check_invariants(&self) -> Result<(), String> {
        for d in &self.devices {
            d.check_invariants()?;
        }
        self.link.check_invariants()
    }

    /// One attempt of the low-priority batch algorithm with a fixed core
    /// configuration. Returns the committed allocations, or `None` after
    /// rolling back (the caller may retry with the four-core config).
    fn try_config(
        &mut self,
        now: SimTime,
        tasks: &[&Task],
        deadline: SimTime,
        config: TaskConfig,
        ops: &mut Ops,
    ) -> Option<Vec<Allocation>> {
        // Class-aware stage cost: batch members share one class by
        // construction (one arrival = one class), so the head task's
        // duration is the batch's.
        let proc = tasks[0].proc_for(config);
        let source = tasks[0].source;

        // Step 2: check communication viability — a potential slot per task
        // (not all will be used; local placements skip the link).
        let comm_deadline = deadline.saturating_sub(proc);
        *ops += 2;
        if !self.link.can_place(now, comm_deadline, tasks.len() as u32) {
            // Not enough link capacity even if everything offloads — but if
            // the source device alone can host the batch the request can
            // still succeed, so only reject when it cannot.
            let local = self.devices[source]
                .list(config)
                .query_all_fits(now, deadline, proc)
                .len();
            *ops += self.devices[source].list(config).track_count() as Ops;
            if local < tasks.len() {
                self.reject_reasons[1] = self.reject_reasons[1].saturating_add(1);
                return None;
            }
        }

        // NOTE on class sizes: the discretised link plans in whole units
        // of D, which the paper sizes from the *maximum* model input
        // (`cfg.image_bytes`). Classes whose input exceeds that image
        // overrun their reserved slot on the real medium — placement
        // error that is inherent to the abstraction (the accuracy the
        // model trades for performance), not corrected here; the exact
        // WPS baseline sizes its windows per task.
        // Steps 3 and 4 come in two regimes that make identical
        // decisions. Small remote pools take the historical shape (full
        // scan, eager shuffle) over a per-decision scatter stream. Past
        // the cutover, placement descends the cell hierarchy instead:
        // closed-form window counts for never-written cells, per-device
        // queries only for devices that are actually inspected, and a
        // lazily-materialized shuffle — the same permutation, with RNG
        // cost proportional to candidates consumed. The regime is
        // chosen by *remote candidate count alone* — never by cell layout
        // — so decisions are independent of `cell_size` at every scale,
        // and the per-decision stream keeps the regimes' different draw
        // counts from ever diverging their later permutations.
        let unit = self.cfg.transfer_unit(self.planning_bps());
        self.last_scan = now;
        let picks = if self.cells.active_total().saturating_sub(1) <= self.cfg.lazy_shuffle_cutover
        {
            self.pick_windows_eager(now, tasks.len(), deadline, config, proc, source, unit, ops)
        } else {
            self.pick_windows_lazy(now, tasks.len(), deadline, config, proc, source, unit, ops)
        };
        let Some(picks) = picks else {
            self.reject_reasons[2] = self.reject_reasons[2].saturating_add(1);
            return None;
        };

        // Step 5: commit task-by-task; offloads reserve a link slot that
        // must complete before the processing slot opens.
        let mut committed: Vec<Allocation> = Vec::with_capacity(tasks.len());
        for (&task, (device, r, fit_start)) in tasks.iter().zip(picks) {
            // Bring the device's lists to the scan's reference time before
            // touching them — a no-op for every device the scan visited,
            // and the flat-equivalent catch-up for devices the closed-form
            // fresh path answered without touching.
            self.devices[device].advance(now);
            let (start, comm) = if device == task.source {
                (fit_start, None)
            } else {
                let placed = self.link.place(
                    now,
                    comm_deadline,
                    CommTask { task: task.id, from: task.source, to: device, planned_start: now },
                );
                *ops += 3;
                match placed {
                    Some((_idx, c1, c2)) => (fit_start.max(c2), Some((c1, c2))),
                    None => {
                        self.reject_reasons[3] = self.reject_reasons[3].saturating_add(1);
                        *ops += self.rollback(&committed, now);
                        return None;
                    }
                }
            };
            let end = start + proc;
            // A late communication slot can push the start past the fitted
            // window's end; re-verify containment before committing.
            let window_ok = {
                let list = self.devices[device].list(config);
                list.tracks[r.track]
                    .get(r.index)
                    .map(|w| w.contains(start, end))
                    .unwrap_or(false)
            };
            if end > task.deadline || !window_ok {
                self.reject_reasons[3] = self.reject_reasons[3].saturating_add(1);
                *ops += self.rollback(&committed, now);
                return None;
            }
            let (alloc, c_ops) = self.commit(device, config, r, task, start, end, comm);
            *ops += c_ops;
            committed.push(alloc);
        }
        Some(committed)
    }

    /// Steps 3–4, historical form: multi-fit query of the placement window
    /// [now, deadline) across every device — the earliest slot per track
    /// that can host the configuration's processing time (every window in
    /// a list is at least that long by construction, so the first window
    /// starting early enough is guaranteed to fit — same early-exit speed
    /// as pure containment, but tracks that free up part-way through the
    /// placement window are still usable, which reallocation of preempted
    /// tasks depends on). Remote candidates must leave room for one unit
    /// transfer before processing starts. Then prioritise source-device
    /// windows, shuffle the remote devices eagerly, and round-robin one
    /// window at a time (load balancing). `None` = not enough windows.
    #[allow(clippy::too_many_arguments)]
    fn pick_windows_eager(
        &mut self,
        now: SimTime,
        need: usize,
        deadline: SimTime,
        config: TaskConfig,
        proc: SimDuration,
        source: DeviceId,
        unit: SimDuration,
        ops: &mut Ops,
    ) -> Option<Vec<(DeviceId, WindowRef, SimTime)>> {
        let mut windows: Vec<(DeviceId, WindowRef, SimTime)> = Vec::new();
        for d in 0..self.devices.len() {
            if !self.active[d] {
                continue;
            }
            self.devices[d].advance(now);
            let earliest = if d == source { now } else { now + unit };
            let list = self.devices[d].list(config);
            *ops += list.track_count() as Ops;
            for (r, start) in list.query_all_fits(earliest, deadline, proc) {
                windows.push((d, r, start));
            }
        }
        if windows.len() < need {
            return None;
        }
        let mut source_windows: Vec<(DeviceId, WindowRef, SimTime)> =
            windows.iter().copied().filter(|(d, ..)| *d == source).collect();
        let mut remote_devices: Vec<DeviceId> =
            (0..self.devices.len()).filter(|&d| d != source && self.active[d]).collect();
        // Forward Fisher–Yates over the decision's scatter stream: the
        // fully-consumed form of the lazy regime's [`LazyShuffle`], so
        // both regimes enumerate remote candidates in the same order.
        let mut rng = self.scatter_rng();
        for i in 0..remote_devices.len() {
            let j = i + rng.index(remote_devices.len() - i);
            remote_devices.swap(i, j);
        }
        let mut remote_per_dev: Vec<Vec<(DeviceId, WindowRef, SimTime)>> = remote_devices
            .iter()
            .map(|&d| windows.iter().copied().filter(|(w, ..)| *w == d).collect())
            .collect();
        let mut picks: Vec<(DeviceId, WindowRef, SimTime)> = Vec::with_capacity(need);
        while picks.len() < need {
            if let Some(w) = source_windows.pop() {
                picks.push(w);
                continue;
            }
            let mut advanced = false;
            for dev_windows in remote_per_dev.iter_mut() {
                if picks.len() == need {
                    break;
                }
                if let Some(w) = dev_windows.pop() {
                    picks.push(w);
                    advanced = true;
                }
            }
            if picks.len() < need && !advanced {
                return None;
            }
        }
        Some(picks)
    }

    /// Steps 3–4, sharded form (remote pools past the shuffle cutover):
    /// the window census descends cells — never-written cells contribute
    /// `active × tracks` windows in O(1) via the closed-form fresh-list
    /// answer, written members get the exact advance + multi-fit query —
    /// and early-exits at the batch size (the census only feeds the
    /// enough-windows verdict). Candidate devices then materialize out of
    /// a lazy Fisher–Yates permutation one draw per device consumed,
    /// querying windows on demand. The virtual cost charged is identical
    /// to the flat scan's (one multi-fit query per active device): the
    /// hierarchy prunes real work, not modelled work.
    #[allow(clippy::too_many_arguments)]
    fn pick_windows_lazy(
        &mut self,
        now: SimTime,
        need: usize,
        deadline: SimTime,
        config: TaskConfig,
        proc: SimDuration,
        source: DeviceId,
        unit: SimDuration,
        ops: &mut Ops,
    ) -> Option<Vec<(DeviceId, WindowRef, SimTime)>> {
        let tracks = self.devices[source].list(config).track_count();
        *ops += self.cells.active_total() as Ops * tracks as Ops;

        // Source first: exact, and advanced on every scan — the next
        // decision's link-pressure fallback query reads this state.
        self.devices[source].advance(now);
        let mut source_windows: Vec<(DeviceId, WindowRef, SimTime)> = self.devices[source]
            .list(config)
            .query_all_fits(now, deadline, proc)
            .into_iter()
            .map(|(r, s)| (source, r, s))
            .collect();

        let fresh_fits = if now + unit + proc <= deadline { tracks } else { 0 };
        let mut count = source_windows.len();
        'census: for c in 0..self.cells.n_cells() {
            if count >= need {
                break;
            }
            if self.cells.cell_active(c) == 0 {
                continue;
            }
            if self.cells.all_idle(c) && self.cells.map().cell_of(source) != c {
                count += self.cells.cell_active(c) as usize * fresh_fits;
                continue;
            }
            for d in self.cells.members(c).collect::<Vec<_>>() {
                if d == source {
                    continue;
                }
                count += self.count_fits(d, now, unit, deadline, proc, config, fresh_fits);
                if count >= need {
                    break 'census;
                }
            }
        }
        if count < need {
            return None;
        }

        let mut picks: Vec<(DeviceId, WindowRef, SimTime)> = Vec::with_capacity(need);
        while picks.len() < need {
            let Some(w) = source_windows.pop() else { break };
            picks.push(w);
        }
        // First round: draw remote devices out of the lazy permutation
        // until the batch is placed (or every remote has been seen once).
        // The stream is the same one the eager regime's forward shuffle
        // consumes, so the consumed prefix — and therefore every pick —
        // is identical in both regimes.
        let mut shuffle = LazyShuffle::new(self.cells.active_total() - 1);
        let mut rng = self.scatter_rng();
        let mut alive: Vec<Vec<(DeviceId, WindowRef, SimTime)>> = Vec::new();
        while picks.len() < need {
            let Some(rank) = shuffle.next(&mut rng) else { break };
            let d = self.cells.nth_active_excluding(rank, source).expect("rank < remote count");
            let mut ws = self.windows_for(d, now, unit, deadline, proc, config);
            if let Some(w) = ws.pop() {
                picks.push(w);
            }
            if !ws.is_empty() {
                alive.push(ws);
            }
        }
        // Later rounds: only devices with windows left can contribute.
        while picks.len() < need {
            let mut advanced = false;
            alive.retain_mut(|ws| {
                if picks.len() < need {
                    if let Some(w) = ws.pop() {
                        picks.push(w);
                        advanced = true;
                    }
                }
                !ws.is_empty()
            });
            if picks.len() < need && !advanced {
                return None;
            }
        }
        Some(picks)
    }

    /// Multi-fit windows for one remote device: closed-form for a
    /// never-written device (each track is a single `[construction, ∞)`
    /// window, so every track fits at the earliest remote start — without
    /// touching the device), exact advance + query otherwise.
    fn windows_for(
        &mut self,
        d: DeviceId,
        now: SimTime,
        unit: SimDuration,
        deadline: SimTime,
        proc: SimDuration,
        config: TaskConfig,
    ) -> Vec<(DeviceId, WindowRef, SimTime)> {
        let earliest = now + unit;
        if self.cells.device_idle(d) {
            let k = self.devices[d].list(config).track_count();
            if earliest + proc <= deadline {
                (0..k).map(|t| (d, WindowRef { track: t, index: 0 }, earliest)).collect()
            } else {
                Vec::new()
            }
        } else {
            self.devices[d].advance(now);
            self.devices[d]
                .list(config)
                .query_all_fits(earliest, deadline, proc)
                .into_iter()
                .map(|(r, s)| (d, r, s))
                .collect()
        }
    }

    /// Window count for one remote device (census only — refs discarded).
    #[allow(clippy::too_many_arguments)]
    fn count_fits(
        &mut self,
        d: DeviceId,
        now: SimTime,
        unit: SimDuration,
        deadline: SimTime,
        proc: SimDuration,
        config: TaskConfig,
        fresh_fits: usize,
    ) -> usize {
        if self.cells.device_idle(d) {
            fresh_fits
        } else {
            self.devices[d].advance(now);
            self.devices[d].list(config).query_all_fits(now + unit, deadline, proc).len()
        }
    }
}

impl RasScheduler {
    /// Schedule a high-priority task (always local to its source device).
    /// Legacy-shaped entry point; [`Scheduler::on_event`] dispatches here.
    pub fn schedule_high(&mut self, now: SimTime, task: &Task) -> HpOutcome {
        let mut ops: Ops = 0;
        let (t1, t2) = (now, now + task.proc_for(TaskConfig::HighPriority));
        if t2 > task.deadline {
            return HpOutcome::Rejected { victims: vec![], ops: 1 };
        }
        if !self.device_active(task.source) {
            // The source device left the fleet: nowhere to run HP work.
            return HpOutcome::Rejected { victims: vec![], ops: 1 };
        }
        let dev = task.source;
        self.devices[dev].advance(now);
        // Containment query on the device's high-priority list.
        let q = self.devices[dev].query(TaskConfig::HighPriority, t1, t2);
        ops += self.devices[dev].list(TaskConfig::HighPriority).track_count() as Ops;
        if let Some(r) = q {
            let (alloc, c_ops) = self.commit(dev, TaskConfig::HighPriority, r, task, t1, t2, None);
            return HpOutcome::Allocated { alloc, ops: ops + c_ops };
        }
        // Preemption request for the source device at the same window
        // (Section IV-B3): evict the overlapping low-priority task with
        // the farthest deadline, rebuild the availability lists from the
        // remaining workload, then allocate. If the window is still busy
        // (another low-priority task overlaps), the controller regenerates
        // the preemption request — bounded by the device's possible
        // co-resident tasks.
        let mut victims: Vec<Allocation> = Vec::new();
        for _ in 0..self.cfg.cores_per_device {
            let (victim, v_ops) = select_victim(&self.state, dev, t1, t2);
            ops += v_ops;
            let Some(victim) = victim else { break };
            let victim_alloc = self.state.remove(victim).expect("victim tracked");
            self.link.remove_task(victim);
            victims.push(victim_alloc);
            ops += self.reconstruct_device(dev, now);
            let q = self.devices[dev].query(TaskConfig::HighPriority, t1, t2);
            ops += self.devices[dev].list(TaskConfig::HighPriority).track_count() as Ops;
            if let Some(r) = q {
                let (alloc, c_ops) = self.commit(dev, TaskConfig::HighPriority, r, task, t1, t2, None);
                return HpOutcome::Preempted { alloc, victims, ops: ops + c_ops };
            }
        }
        // The window never freed (nothing preemptable overlapped, or only
        // non-preemptable high-priority work remains). Evicted tasks still
        // re-enter low-priority scheduling, matching the paper's
        // "preempted task will have a chance to receive reallocation".
        HpOutcome::Rejected { victims, ops }
    }

    /// Schedule a batch of low-priority tasks (one shared class per request),
    /// borrowed in place from the caller's storage (no clones).
    /// Legacy-shaped entry point; [`Scheduler::on_event`] dispatches here.
    pub fn schedule_low(&mut self, now: SimTime, tasks: &[&Task], _realloc: bool) -> LpOutcome {
        let mut ops: Ops = 0;
        if tasks.is_empty() {
            return LpOutcome::Rejected { ops: 1 };
        }
        if !self.device_active(tasks[0].source) {
            // The source device (which holds the input images) is gone.
            return LpOutcome::Rejected { ops: 1 };
        }
        let deadline = tasks.iter().map(|t| t.deadline).min().unwrap();
        // Step 1: enumerate viable core configurations (or exit early).
        let configs = self.viable_configs(now, tasks[0], deadline);
        if configs.is_empty() {
            self.reject_reasons[0] = self.reject_reasons[0].saturating_add(1);
            return LpOutcome::Rejected { ops: 1 };
        }
        for config in configs {
            match self.try_config(now, tasks, deadline, config, &mut ops) {
                Some(allocs) => return LpOutcome::Allocated { allocs, ops },
                None => continue, // fall back to the faster configuration
            }
        }
        LpOutcome::Rejected { ops }
    }


    /// Explainability record for a high-priority decision. HP work is
    /// pinned to its source device, so the candidate set is that single
    /// device; the score is the planned finish time (lower = earlier).
    fn explain_hp(&mut self, task: &Task, d: &Decision) {
        let (chosen, reject, score) = match &d.outcome {
            Outcome::HpAllocated { alloc, .. } => {
                (Some((alloc.device, alloc.cores as u8)), None, alloc.end as f64)
            }
            _ if !self.device_active(task.source) => {
                (None, Some(RejectReason::Offline), f64::INFINITY)
            }
            _ => (None, Some(RejectReason::WindowInfeasible), f64::INFINITY),
        };
        self.explain.push(DecisionRecord {
            scheduler: "RAS",
            task: task.id,
            batch: 1,
            high_priority: true,
            candidates: vec![CandidateScore { device: task.source, score, reject }],
            chosen,
            rung: None,
            cloud: false,
        });
    }

    /// Explainability record for one low-priority decision (shared by
    /// `LowPriorityBatch` and `Reoffer`). Placed batches list every
    /// device that took work (score = planned finish time); rejections
    /// attribute the failure from the [`Self::reject_reasons`] delta —
    /// "insufficient windows" means the availability census collapsed
    /// ([`RejectReason::CellCollapsed`]), anything else is a window /
    /// link / commit infeasibility at this deadline. Suspected and
    /// departed devices are appended as rejected candidates (bounded by
    /// [`EXPLAIN_CANDIDATE_CAP`], lowest ids first, deterministic).
    fn explain_lp(&mut self, tasks: &[&Task], d: &Decision, rr_before: [u64; 4]) {
        let cloud_dev = self.cloud.as_ref().map(|c| c.device);
        let mut candidates: Vec<CandidateScore> = Vec::new();
        let mut chosen = None;
        let mut cloud = false;
        match &d.outcome {
            Outcome::LpAllocated { allocs } => {
                for a in allocs {
                    if Some(a.device) == cloud_dev {
                        cloud = true;
                    }
                    candidates.push(CandidateScore {
                        device: a.device,
                        score: a.end as f64,
                        reject: None,
                    });
                }
                chosen = allocs.first().map(|a| (a.device, a.cores as u8));
            }
            _ => {
                let reason = if self.reject_reasons[2] > rr_before[2] {
                    RejectReason::CellCollapsed
                } else {
                    RejectReason::WindowInfeasible
                };
                candidates.push(CandidateScore {
                    device: tasks.first().map(|t| t.source).unwrap_or(0),
                    score: f64::INFINITY,
                    reject: Some(reason),
                });
            }
        }
        for dev in 0..self.devices.len().min(EXPLAIN_CANDIDATE_CAP) {
            if self.device_suspected(dev) {
                candidates.push(CandidateScore {
                    device: dev,
                    score: f64::INFINITY,
                    reject: Some(RejectReason::Suspected),
                });
            } else if !self.active[dev] {
                candidates.push(CandidateScore {
                    device: dev,
                    score: f64::INFINITY,
                    reject: Some(RejectReason::Offline),
                });
            }
        }
        self.explain.push(DecisionRecord {
            scheduler: "RAS",
            task: tasks.first().map(|t| t.id).unwrap_or(0),
            batch: tasks.len(),
            high_priority: false,
            candidates,
            chosen,
            rung: d.variant.map(|v| v as usize),
            cloud,
        });
    }

    /// Task finished (free its resources from the scheduler's state).
    pub fn on_complete(&mut self, _now: SimTime, task: TaskId) {
        // Windows are not re-inserted (their true capacity is unknown) —
        // completion only clears the exact-state bookkeeping. The device
        // stays off the closed-form path (its lists were written), but
        // its earliest-finish index key shrinks with the departing task.
        let removed = self.state.remove(task);
        self.link.remove_task(task);
        if let Some(a) = removed {
            self.refresh_avail_key(a.device);
        }
    }

    /// Task missed its deadline and was abandoned.
    pub fn on_violation(&mut self, now: SimTime, task: TaskId) {
        if let Some(a) = self.state.remove(task) {
            self.link.remove_task(task);
            // Reclaim the abandoned reservation if a meaningful tail
            // remains: same reconstruction path as preemption.
            if a.end > now + self.cfg.hp_proc() {
                self.reconstruct_device(a.device, now);
            } else {
                self.refresh_avail_key(a.device);
            }
        }
    }

    /// A probe round produced a new estimate: rebuild the discretised link
    /// at the new transfer unit. Returns the (non-trivial) rebuild ops.
    /// A fresh estimate also ends any stale-widening episode.
    pub fn on_bandwidth_update(&mut self, now: SimTime, bps: f64) -> Ops {
        self.bps = bps;
        self.stale_widen = false;
        let unit = self.cfg.transfer_unit(bps);
        let (fresh, dropped) = self.link.rebuild(now, unit);
        let ops = (self.link.pending() + self.link.buckets.len()) as Ops + fresh.buckets.len() as Ops;
        self.link = fresh;
        self.link_rebuilds += 1;
        self.cascade_dropped += dropped as u64;
        ops
    }

    /// A device joined the fleet: give it fresh, fully-available lists.
    /// Rejoining a departed slot reactivates it; an index past the current
    /// fleet grows it (intermediate slots stay inactive). A join (or a
    /// crash recovery) supersedes any standing suspicion of the slot.
    pub fn on_device_joined(&mut self, now: SimTime, device: DeviceId) -> Ops {
        while self.devices.len() <= device {
            self.devices.push(DeviceAvailability::new(&self.cfg, now));
            self.active.push(false);
            self.suspected.push(false);
            self.suspected_idle.push(false);
        }
        self.state.ensure_device(device);
        self.suspected[device] = false;
        self.suspected_idle[device] = false;
        if !self.active[device] {
            self.active[device] = true;
            self.devices[device] = DeviceAvailability::new(&self.cfg, now);
            self.cells.set_active(device, true);
        }
        // One fresh list per configuration.
        self.devices[device].lists.len() as Ops
    }

    /// A device left the fleet: evict its live allocations (returned so the
    /// controller can reschedule them) and drop its availability. A
    /// *suspected* device is already out of the candidate pool but still
    /// holds its allocations — a real departure/crash on top of the
    /// suspicion must still evict them, so suspicion does not short the
    /// early return.
    pub fn on_device_left(&mut self, now: SimTime, device: DeviceId) -> (Vec<Allocation>, Ops) {
        if !self.device_active(device) && !self.device_suspected(device) {
            return (Vec::new(), 1);
        }
        if device < self.suspected.len() {
            self.suspected[device] = false;
            self.suspected_idle[device] = false;
        }
        self.active[device] = false;
        self.cells.set_active(device, false);
        let evicted = self.state.evict_device(device);
        let mut ops: Ops = 1;
        for a in &evicted {
            self.link.remove_task(a.task);
            ops += 2;
        }
        self.devices[device] = DeviceAvailability::new(&self.cfg, now);
        (evicted, ops)
    }

    /// The failure detector suspects `device`: pull it from the candidate
    /// pool (like a departure) but keep its allocations and availability
    /// lists (unlike one) — if the suspicion is false, nothing was lost.
    /// Suspicion of an already-departed slot is a no-op: the oracle-level
    /// eviction already ran.
    pub fn on_device_suspected(&mut self, device: DeviceId) -> Ops {
        if !self.device_active(device) || self.device_suspected(device) {
            return 1;
        }
        self.suspected[device] = true;
        self.suspected_idle[device] = self.cells.device_idle(device);
        self.active[device] = false;
        self.cells.set_active(device, false);
        1
    }

    /// A heartbeat reached a suspected device: restore it to the
    /// candidate pool with its availability intact — cell idle/busy and
    /// earliest-finish bookkeeping are rebuilt from the live state, not
    /// reset like a join.
    pub fn on_device_cleared(&mut self, device: DeviceId) -> Ops {
        if !self.device_suspected(device) {
            return 1;
        }
        self.suspected[device] = false;
        self.active[device] = true;
        self.cells.set_active(device, true);
        if !self.suspected_idle[device] {
            self.cells.note_busy(device);
        }
        self.suspected_idle[device] = false;
        self.refresh_avail_key(device);
        1
    }

    /// The bandwidth estimate went stale: switch to the discounted
    /// planning estimate and rebuild the link at the wider unit, so both
    /// processing-window math and communication reservations turn
    /// conservative until a fresh probe round lands.
    pub fn on_bandwidth_stale(&mut self, now: SimTime) -> Ops {
        if self.stale_widen {
            return 1;
        }
        self.stale_widen = true;
        let unit = self.cfg.transfer_unit(self.planning_bps());
        let (fresh, dropped) = self.link.rebuild(now, unit);
        let ops =
            (self.link.pending() + self.link.buckets.len()) as Ops + fresh.buckets.len() as Ops;
        self.link = fresh;
        self.link_rebuilds += 1;
        self.cascade_dropped += dropped as u64;
        ops
    }
}

impl Scheduler for RasScheduler {
    fn name(&self) -> &'static str {
        "RAS"
    }

    fn on_event(&mut self, now: SimTime, ev: SchedEvent<'_>) -> Decision {
        match ev {
            SchedEvent::HighPriority { task } => {
                let d: Decision = self.schedule_high(now, task).into();
                if self.explain.on() {
                    self.explain_hp(task, &d);
                }
                d
            }
            SchedEvent::LowPriorityBatch { tasks, realloc, ladder } => {
                // Shared degradation policy over this scheduler's own
                // feasibility verdict: RAS steps down when its
                // *conservative windows* and discretised link say the
                // rung cannot be placed — which can be earlier than the
                // exact state would require (abstraction inaccuracy). The
                // cloud tier backstops each rung before the step-down, so
                // RAS's conservatism shows up as cloud traffic, not as
                // extra degradation.
                let cloud = self.cloud;
                let rr_before = self.reject_reasons;
                let d =
                    place_degrading_tiered(now, tasks, ladder, realloc, cloud.as_ref(), |n, ts, r| {
                        self.schedule_low(n, ts, r)
                    });
                if self.explain.on() {
                    self.explain_lp(tasks, &d, rr_before);
                }
                d
            }
            SchedEvent::Complete { task } => {
                self.on_complete(now, task);
                Decision::ack(1)
            }
            SchedEvent::Violation { task } => {
                self.on_violation(now, task);
                Decision::ack(1)
            }
            SchedEvent::BandwidthUpdate { bps } => Decision::ack(self.on_bandwidth_update(now, bps)),
            SchedEvent::DeviceJoined { device } => Decision::ack(self.on_device_joined(now, device)),
            SchedEvent::DeviceLeft { device } | SchedEvent::DeviceCrashed { device } => {
                // Crash or graceful leave: either way the device's
                // placements are invalid and must be surfaced; what
                // becomes of the work is the engine's call.
                let (evicted, ops) = self.on_device_left(now, device);
                Decision { outcome: Outcome::Ack { evicted }, ops, variant: None }
            }
            SchedEvent::DeviceRecovered { device } => {
                Decision::ack(self.on_device_joined(now, device))
            }
            SchedEvent::Reoffer { tasks, ladder } => {
                // Crash-lost work re-enters placement on its remaining
                // deadline budget; `viable_configs` drops tasks whose
                // budget no longer fits any configuration. The remaining
                // ladder tail still applies — a re-offer may degrade
                // further (or spill to the cloud) before dropping.
                let cloud = self.cloud;
                let rr_before = self.reject_reasons;
                let d = place_degrading_tiered(now, tasks, ladder, true, cloud.as_ref(), |n, ts, r| {
                    self.schedule_low(n, ts, r)
                });
                if self.explain.on() {
                    self.explain_lp(tasks, &d, rr_before);
                }
                d
            }
            SchedEvent::CloudBandwidthUpdate { bps } => {
                // Passive WAN estimate refresh — no discretised-link
                // rebuild (the WAN is not the probed LAN medium).
                if let Some(c) = &mut self.cloud {
                    c.update(bps);
                }
                Decision::ack(0)
            }
            SchedEvent::BatteryLevels { .. } => {
                // The paper's scheduler is energy-oblivious: levels are
                // acknowledged and ignored.
                Decision::ack(0)
            }
            SchedEvent::DeviceSuspected { device } => {
                Decision::ack(self.on_device_suspected(device))
            }
            SchedEvent::DeviceCleared { device } => Decision::ack(self.on_device_cleared(device)),
            SchedEvent::BandwidthStale => Decision::ack(self.on_bandwidth_stale(now)),
            SchedEvent::Pressure { candidates, escalate } => {
                // The engine surveys against committed placements (its
                // ground truth); RAS applies the shared rescue policy.
                super::decide_pressure(candidates, escalate)
            }
        }
    }

    fn bandwidth_estimate(&self) -> f64 {
        self.bps
    }

    fn state(&self) -> &WorkloadState {
        &self.state
    }

    fn reject_diag(&self) -> [u64; 4] {
        self.reject_reasons
    }

    fn set_explain(&mut self, on: bool) {
        self.explain.set(on);
    }

    fn drain_decisions(&mut self) -> Vec<DecisionRecord> {
        self.explain.drain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::task_refs;
    use crate::coordinator::task::Priority;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn hp(id: TaskId, source: DeviceId, now: SimTime, c: &SystemConfig) -> Task {
        Task::high(id, id, source, now, c)
    }

    fn lp_batch(base: TaskId, n: usize, source: DeviceId, now: SimTime, c: &SystemConfig) -> Vec<Task> {
        let deadline = now + c.frame_period();
        (0..n as u64)
            .map(|i| Task::low(base + i, base, source, now, deadline, c))
            .collect()
    }

    #[test]
    fn hp_allocates_locally() {
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        match s.schedule_high(0, &hp(1, 0, 0, &c)) {
            HpOutcome::Allocated { alloc, .. } => {
                assert_eq!(alloc.device, 0);
                assert_eq!(alloc.start, 0);
                assert_eq!(alloc.end, c.hp_proc());
                assert!(!alloc.offloaded);
            }
            other => panic!("expected Allocated, got {other:?}"),
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn lp_batch_prefers_source_then_balances() {
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        let tasks = lp_batch(10, 4, 1, 0, &c);
        match s.schedule_low(0, &task_refs(&tasks), false) {
            LpOutcome::Allocated { allocs, .. } => {
                assert_eq!(allocs.len(), 4);
                // Source device hosts its two-core capacity (2 tracks).
                let local = allocs.iter().filter(|a| a.device == 1).count();
                assert_eq!(local, 2);
                // Offloaded tasks carry comm windows; locals don't.
                for a in &allocs {
                    assert_eq!(a.offloaded, a.device != 1);
                    assert_eq!(a.comm.is_some(), a.offloaded);
                    assert_eq!(a.config, TaskConfig::LowTwoCore);
                    assert!(a.end <= a.deadline);
                }
            }
            LpOutcome::Rejected { .. } => panic!("batch should fit an idle network"),
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn lp_uses_four_cores_when_two_would_violate() {
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        let now = 0;
        // Deadline leaves room for the 4-core config only.
        let deadline = now + c.lp4_proc() + 100_000;
        let tasks = vec![Task::low(1, 1, 0, now, deadline, &c)];
        match s.schedule_low(now, &task_refs(&tasks), false) {
            LpOutcome::Allocated { allocs, .. } => {
                assert_eq!(allocs[0].config, TaskConfig::LowFourCore);
            }
            LpOutcome::Rejected { .. } => panic!("4-core config should fit"),
        }
    }

    #[test]
    fn lp_rejects_when_no_config_fits() {
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        let tasks = vec![Task::low(1, 1, 0, 0, c.lp4_proc() - 1, &c)];
        assert!(matches!(s.schedule_low(0, &task_refs(&tasks), false), LpOutcome::Rejected { .. }));
    }

    #[test]
    fn explain_mode_records_placement_decisions() {
        use crate::coordinator::task::VariantRung;
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        let ladder = [VariantRung {
            accuracy: 0.97,
            input_bytes: c.image_bytes,
            proc_us: [c.lp2_proc(), c.lp4_proc()],
        }];
        // Off by default: decisions leave no records behind.
        let tasks = lp_batch(10, 2, 0, 0, &c);
        let refs = task_refs(&tasks);
        let d = s.on_event(
            0,
            SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &ladder },
        );
        assert!(matches!(d.outcome, Outcome::LpAllocated { .. }));
        assert!(s.drain_decisions().is_empty(), "explain off must record nothing");

        s.set_explain(true);
        let tasks = lp_batch(20, 2, 0, 1_000, &c);
        let refs = task_refs(&tasks);
        let d = s.on_event(
            1_000,
            SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &ladder },
        );
        assert!(matches!(d.outcome, Outcome::LpAllocated { .. }));
        let _ = s.on_event(1_000, SchedEvent::HighPriority { task: &hp(30, 1, 1_000, &c) });
        let recs = s.drain_decisions();
        assert_eq!(recs.len(), 2, "one record per placement decision");
        let lp = &recs[0];
        assert_eq!(lp.scheduler, "RAS");
        assert_eq!(lp.batch, 2);
        assert!(!lp.high_priority);
        assert!(lp.chosen.is_some());
        assert_eq!(lp.rung, None, "single-rung ladder places untouched");
        assert!(!lp.cloud);
        assert_eq!(lp.candidates.iter().filter(|x| x.reject.is_none()).count(), 2);
        let hp_rec = &recs[1];
        assert!(hp_rec.high_priority);
        assert_eq!(hp_rec.outcome(), "placed");
        assert!(s.drain_decisions().is_empty(), "drain takes everything");

        // A suspected device surfaces as a rejected candidate.
        s.on_device_suspected(2);
        let tasks = lp_batch(40, 1, 0, 2_000, &c);
        let refs = task_refs(&tasks);
        let _ = s.on_event(
            2_000,
            SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &ladder },
        );
        let recs = s.drain_decisions();
        assert!(recs[0].candidates.iter().any(|x| {
            x.device == 2 && x.reject == Some(crate::obs::RejectReason::Suspected)
        }));
    }

    #[test]
    fn infeasible_rung_degrades_through_the_ladder() {
        use crate::coordinator::scheduler::Outcome;
        use crate::coordinator::task::VariantRung;
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        // Deadline too tight for either paper configuration: rung 0 has
        // no viable config, but a tiny variant fits comfortably.
        let deadline = c.lp4_proc() - 1;
        let task = Task::low(1, 1, 0, 0, deadline, &c);
        let ladder = [
            VariantRung { accuracy: 0.97, input_bytes: c.image_bytes, proc_us: [c.lp2_proc(), c.lp4_proc()] },
            VariantRung { accuracy: 0.80, input_bytes: c.image_bytes / 4, proc_us: [2_000_000, 1_500_000] },
        ];
        let refs = crate::coordinator::scheduler::task_refs(std::slice::from_ref(&task));
        let d = s.on_event(
            0,
            crate::coordinator::scheduler::SchedEvent::LowPriorityBatch {
                tasks: &refs,
                realloc: false,
                ladder: &ladder,
            },
        );
        assert_eq!(d.variant, Some(1), "rung 0 is infeasible, rung 1 must place");
        let Outcome::LpAllocated { allocs } = d.outcome else {
            panic!("degraded rung should have been placed: {:?}", d.outcome)
        };
        assert_eq!(allocs.len(), 1);
        // The allocation was planned with the degraded rung's duration.
        assert_eq!(allocs[0].end - allocs[0].start, 2_000_000);
        assert!(allocs[0].end <= deadline);
        s.check_invariants().unwrap();
    }

    #[test]
    fn cloud_tier_backstops_an_infeasible_rung() {
        use crate::coordinator::scheduler::Outcome;
        use crate::coordinator::task::VariantRung;
        let c = SystemConfig { cloud_wan_bps: 20e6, cloud_rtt_ms: 40.0, ..cfg() };
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        // No viable edge config for this deadline, but the cloud absorbs
        // the full-accuracy rung: RAS reports no degradation.
        let deadline = c.lp4_proc() - 1;
        let task = Task::low(1, 1, 0, 0, deadline, &c);
        let ladder = [
            VariantRung { accuracy: 0.97, input_bytes: c.image_bytes, proc_us: [c.lp2_proc(), c.lp4_proc()] },
            VariantRung { accuracy: 0.80, input_bytes: c.image_bytes / 4, proc_us: [2_000_000, 1_500_000] },
        ];
        let refs = task_refs(std::slice::from_ref(&task));
        let d = s.on_event(
            0,
            SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &ladder },
        );
        assert_eq!(d.variant, Some(0), "cloud tier must hold the rung");
        let Outcome::LpAllocated { allocs } = d.outcome else { panic!("{:?}", d.outcome) };
        assert_eq!(allocs[0].device, c.n_devices);
        assert_eq!(s.state().len(), 0, "cloud placements stay out of edge state");
        s.check_invariants().unwrap();
    }

    #[test]
    fn hp_preempts_farthest_deadline_lp() {
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        // The HP stage needs the whole device: a resident 2-core LP task
        // forces a preemption request.
        let tasks = lp_batch(10, 1, 0, 0, &c);
        assert!(matches!(s.schedule_low(0, &task_refs(&tasks), false), LpOutcome::Allocated { .. }));
        match s.schedule_high(0, &hp(30, 0, 0, &c)) {
            HpOutcome::Preempted { alloc, victims, .. } => {
                assert_eq!(victims.len(), 1);
                assert_eq!(victims[0].task, 10);
                assert_eq!(alloc.task, 30);
                assert_eq!(victims[0].config.priority(), Priority::Low);
            }
            other => panic!("expected Preempted, got {other:?}"),
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn hp_evicts_multiple_victims_when_needed() {
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        // Two co-resident 2-core LP tasks: freeing the whole device takes
        // two preemption rounds.
        let tasks = lp_batch(10, 2, 0, 0, &c);
        assert!(matches!(s.schedule_low(0, &task_refs(&tasks), false), LpOutcome::Allocated { .. }));
        match s.schedule_high(0, &hp(30, 0, 0, &c)) {
            HpOutcome::Preempted { victims, .. } => {
                assert_eq!(victims.len(), 2);
            }
            other => panic!("expected Preempted, got {other:?}"),
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn hp_rejected_when_nothing_to_preempt() {
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        // An HP task holds the device; HP work is not preemptable.
        assert!(matches!(s.schedule_high(0, &hp(1, 0, 0, &c)), HpOutcome::Allocated { .. }));
        match s.schedule_high(0, &hp(9, 0, 0, &c)) {
            HpOutcome::Rejected { victims, .. } => assert!(victims.is_empty()),
            other => panic!("expected Rejected, got {other:?}"),
        }
    }

    #[test]
    fn bandwidth_update_rebuilds_link_and_cascades() {
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        let tasks = lp_batch(1, 4, 0, 0, &c);
        assert!(matches!(s.schedule_low(0, &task_refs(&tasks), false), LpOutcome::Allocated { .. }));
        let pending_before = s.link().pending();
        assert!(pending_before > 0, "offloads should reserve link slots");
        let ops = s.on_bandwidth_update(1_000, c.link_bps / 2.0);
        assert!(ops > 0);
        assert_eq!(s.link_rebuilds, 1);
        // Unit doubled after halving bandwidth.
        assert_eq!(s.link().unit, c.transfer_unit(c.link_bps / 2.0));
        s.check_invariants().unwrap();
    }

    #[test]
    fn completion_clears_state() {
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        let t = hp(1, 0, 0, &c);
        let HpOutcome::Allocated { alloc, .. } = s.schedule_high(0, &t) else {
            panic!()
        };
        assert_eq!(s.state().len(), 1);
        s.on_complete(alloc.end, 1);
        assert_eq!(s.state().len(), 0);
    }

    #[test]
    fn suspicion_removes_candidate_but_keeps_allocations() {
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        // Place a batch that lands work on device 2 (remote from source 0).
        let tasks = lp_batch(10, 4, 0, 0, &c);
        let LpOutcome::Allocated { allocs, .. } = s.schedule_low(0, &task_refs(&tasks), false)
        else {
            panic!("idle fleet must place")
        };
        // Suspect a remote device that actually holds work.
        let dev = allocs.iter().map(|a| a.device).find(|&d| d != 0).expect("remote placement");
        let mine: Vec<TaskId> =
            allocs.iter().filter(|a| a.device == dev).map(|a| a.task).collect();
        let before = s.state().len();
        s.on_device_suspected(dev);
        // Allocations survive the suspicion...
        assert_eq!(s.state().len(), before, "suspicion must not evict work");
        // ...but the device takes no new placements.
        let more = lp_batch(50, 4, 0, 1_000, &c);
        if let LpOutcome::Allocated { allocs, .. } =
            s.schedule_low(1_000, &task_refs(&more), false)
        {
            assert!(allocs.iter().all(|a| a.device != dev), "suspected device placed: {allocs:?}");
        }
        // Clearing restores it without resetting availability: completing
        // a pre-suspicion task still resolves against the same state.
        s.on_device_cleared(dev);
        for t in mine {
            s.on_complete(20_000_000, t);
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn crash_on_suspected_device_still_evicts() {
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        let tasks = lp_batch(10, 4, 0, 0, &c);
        let LpOutcome::Allocated { allocs, .. } = s.schedule_low(0, &task_refs(&tasks), false)
        else {
            panic!("idle fleet must place")
        };
        let dev = allocs.iter().map(|a| a.device).find(|&d| d != 0).expect("remote placement");
        let held = s.state().device_allocs(dev).count();
        assert!(held > 0);
        s.on_device_suspected(dev);
        // The real crash lands after the suspicion: the eviction must not
        // be shorted by the device already being out of the pool.
        let (evicted, _) = s.on_device_left(1_000, dev);
        assert_eq!(evicted.len(), held, "suspected-then-crashed must still evict");
        assert_eq!(s.state().device_allocs(dev).count(), 0);
        s.check_invariants().unwrap();
    }

    #[test]
    fn stale_estimate_widens_planning_and_recovers() {
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        let unit_fresh = s.link().unit;
        let ops = s.on_bandwidth_stale(1_000);
        assert!(ops > 0);
        assert!(s.link().unit > unit_fresh, "stale widening must grow the transfer unit");
        assert_eq!(s.link_rebuilds, 1);
        assert_eq!(s.on_bandwidth_stale(2_000), 1, "already stale: no second rebuild");
        // A fresh estimate at the original bandwidth restores the unit.
        s.on_bandwidth_update(3_000, c.link_bps);
        assert_eq!(s.link().unit, unit_fresh);
        assert_eq!(s.bandwidth_estimate(), c.link_bps);
        s.check_invariants().unwrap();
    }

    #[test]
    fn never_oversubscribes_device_cores() {
        // Property-style check at unit level: after a storm of requests,
        // exact peak usage per device never exceeds its cores.
        let c = cfg();
        let mut s = RasScheduler::new(&c, 0, c.link_bps);
        let mut id = 0u64;
        for round in 0..6u64 {
            let now = round * 2_000_000;
            for d in 0..c.n_devices {
                let _ = s.schedule_high(now, &hp(id, d, now, &c));
                id += 1;
            }
            let batch = lp_batch(id, (round as usize % 4) + 1, (round as usize) % 4, now, &c);
            id += batch.len() as u64;
            let _ = s.schedule_low(now, &task_refs(&batch), false);
        }
        for d in 0..c.n_devices {
            for t in (0..40_000_000u64).step_by(250_000) {
                let (peak, _) = s.state().peak_usage(d, t, t + 250_000);
                assert!(peak <= c.cores_per_device, "device {d} oversubscribed at {t}: {peak}");
            }
        }
        s.check_invariants().unwrap();
    }
}
