//! The accuracy-maximizing greedy baseline (Fresa & Champati).
//!
//! "Offloading Algorithms for Maximizing Inference Accuracy" frames the
//! model-selection problem as packing accuracy into a hard time budget:
//! under load, the model with the best *accuracy per unit of compute
//! time* delivers the most total accuracy per deadline window, even when
//! it is not the most accurate model that would fit. This scheduler
//! ports that greedy onto the exact-state (WPS) machinery as a fourth
//! low-priority policy:
//!
//! * The ladder-descent schedulers (RAS/WPS/MULTI/ENERGY via
//!   [`super::place_degrading`]) try rung 0 first and step down only on
//!   their own infeasibility verdict — per-task accuracy is primary.
//! * GREEDY ranks the batch's rungs by **accuracy density**
//!   (`accuracy / four-core time`, the steepest accuracy-per-unit-time)
//!   and attempts placement in that order — fleet accuracy *goodput* is
//!   primary. On the stage-3 family the density order is exactly
//!   inverted (tiny > distilled > full), so GREEDY and the ladder
//!   policies genuinely disagree whenever the fleet has slack, which is
//!   the comparison the `medge anytime` grid measures.
//!
//! The deadline budget itself is enforced by the exact-state attempt
//! (WPS never places past a deadline), so every greedy pick is feasible
//! by construction. Empty and one-rung ladders skip the ranking and
//! decide bit-identically to WPS — the zero-knob contract all scenario
//! subsystems share. The cloud tier, when enabled, is consulted in the
//! same density order only after every edge attempt rejects.

use super::wps::WpsScheduler;
use super::{
    task_refs, CloudPlan, Decision, LpOutcome, Ops, Outcome, SchedEvent, Scheduler, WorkloadState,
};
use crate::config::SystemConfig;
use crate::coordinator::task::{Task, VariantRung};
use crate::time::SimTime;

/// Rung indices ordered by descending accuracy density, ties broken by
/// the shallower rung (deterministic; at most [`crate::coordinator::task::MAX_RUNGS`]
/// entries so the sort is trivial).
fn density_order(ladder: &[VariantRung]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..ladder.len()).collect();
    let density = |k: usize| {
        let r = &ladder[k];
        r.accuracy / (r.proc_us[1].max(1) as f64)
    };
    order.sort_by(|&a, &b| {
        density(b).partial_cmp(&density(a)).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    order
}

/// The Fresa & Champati greedy baseline (see module docs).
pub struct GreedyScheduler {
    inner: WpsScheduler,
    /// Cloud tier (None when `cloud_wan_bps` is 0) — consulted in
    /// density order only after the edge rejects every rung.
    cloud: Option<CloudPlan>,
}

impl GreedyScheduler {
    pub fn new(cfg: &SystemConfig, now: SimTime, baseline_bps: f64) -> Self {
        Self { inner: WpsScheduler::new(cfg, now, baseline_bps), cloud: CloudPlan::from_config(cfg) }
    }

    fn place_low(
        &mut self,
        now: SimTime,
        tasks: &[&Task],
        ladder: &[VariantRung],
        realloc: bool,
    ) -> Decision {
        let d = self.place_low_inner(now, tasks, ladder, realloc);
        self.inner.explain_lp_decision(tasks, &d);
        d
    }

    fn place_low_inner(
        &mut self,
        now: SimTime,
        tasks: &[&Task],
        ladder: &[VariantRung],
        realloc: bool,
    ) -> Decision {
        if ladder.len() <= 1 {
            // Nothing to rank: identical to the plain exact-state attempt
            // (with the cloud as WPS's own tiered path would use it).
            let d: Decision = self.inner.schedule_low(now, tasks, realloc).into();
            if !matches!(d.outcome, Outcome::LpRejected) {
                return d;
            }
            let Some(cloud) = self.cloud else { return d };
            let mut cd: Decision = cloud.attempt(now, tasks).into();
            cd.ops += d.ops;
            return cd;
        }
        let order = density_order(ladder);
        let mut spent: Ops = 0;
        // Edge first, densest rung first: the greedy packs the most
        // accuracy-per-unit-time the exact state can hold.
        for &k in &order {
            let degraded: Vec<Task>;
            let refs: Vec<&Task>;
            let batch: &[&Task] = if k == 0 {
                tasks
            } else {
                degraded = tasks.iter().map(|t| t.at_rung(&ladder[k])).collect();
                refs = task_refs(&degraded);
                &refs
            };
            match self.inner.schedule_low(now, batch, realloc) {
                LpOutcome::Allocated { allocs, ops } => {
                    return Decision {
                        outcome: Outcome::LpAllocated { allocs },
                        ops: spent + ops,
                        variant: Some(k as u8),
                    };
                }
                LpOutcome::Rejected { ops } => spent += ops,
            }
        }
        if let Some(cloud) = self.cloud {
            for &k in &order {
                let degraded: Vec<Task>;
                let refs: Vec<&Task>;
                let batch: &[&Task] = if k == 0 {
                    tasks
                } else {
                    degraded = tasks.iter().map(|t| t.at_rung(&ladder[k])).collect();
                    refs = task_refs(&degraded);
                    &refs
                };
                match cloud.attempt(now, batch) {
                    LpOutcome::Allocated { allocs, ops } => {
                        return Decision {
                            outcome: Outcome::LpAllocated { allocs },
                            ops: spent + ops,
                            variant: Some(k as u8),
                        };
                    }
                    LpOutcome::Rejected { ops } => spent += ops,
                }
            }
        }
        Decision { outcome: Outcome::LpRejected, ops: spent, variant: None }
    }
}

impl Scheduler for GreedyScheduler {
    fn name(&self) -> &'static str {
        "GREEDY"
    }

    fn on_event(&mut self, now: SimTime, ev: SchedEvent<'_>) -> Decision {
        match ev {
            SchedEvent::LowPriorityBatch { tasks, realloc, ladder } => {
                self.place_low(now, tasks, ladder, realloc)
            }
            SchedEvent::Reoffer { tasks, ladder } => self.place_low(now, tasks, ladder, true),
            SchedEvent::CloudBandwidthUpdate { bps } => {
                if let Some(c) = &mut self.cloud {
                    c.update(bps);
                }
                Decision::ack(0)
            }
            // Pressure, HP placement, completions, churn — the inner
            // exact-state scheduler's business (Pressure routes to the
            // shared rescue policy there).
            other => self.inner.on_event(now, other),
        }
    }

    fn bandwidth_estimate(&self) -> f64 {
        self.inner.bandwidth_estimate()
    }

    fn state(&self) -> &WorkloadState {
        self.inner.state()
    }

    fn reject_diag(&self) -> [u64; 4] {
        self.inner.reject_diag()
    }

    fn set_explain(&mut self, on: bool) {
        self.inner.explain_set(on);
    }

    fn drain_decisions(&mut self) -> Vec<crate::obs::DecisionRecord> {
        self.inner.explain_drain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::Ladder;

    fn sched(c: &SystemConfig) -> GreedyScheduler {
        GreedyScheduler::new(c, 0, c.link_bps)
    }

    #[test]
    fn density_order_inverts_the_stage3_family() {
        let cfg = SystemConfig::default();
        let compiled = Ladder::stage3_family(&cfg).compile(&cfg);
        // tiny (0.78 / ~3.25 s) > distilled (0.92 / ~6.7 s) > full
        // (0.97 / ~12 s): the greedy tries the cheap rungs first.
        assert_eq!(density_order(&compiled), vec![2, 1, 0]);
        // One-rung ladders rank trivially.
        assert_eq!(density_order(&compiled[..1]), vec![0]);
    }

    #[test]
    fn idle_fleet_places_the_densest_rung_not_the_most_accurate() {
        let cfg = SystemConfig::default();
        let mut s = sched(&cfg);
        let fam = Ladder::stage3_family(&cfg).compile(&cfg);
        let t = Task::low(1, 1, 0, 0, cfg.frame_period(), &cfg);
        let refs = task_refs(std::slice::from_ref(&t));
        let d = s.on_event(
            0,
            SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &fam },
        );
        // An idle fleet could host rung 0; the greedy still picks the
        // densest rung — the policy difference the anytime grid measures.
        assert_eq!(d.variant, Some(2));
        let Outcome::LpAllocated { allocs } = d.outcome else { panic!("{:?}", d.outcome) };
        assert_eq!(allocs[0].end - allocs[0].start, fam[2].proc_us[0]);
    }

    #[test]
    fn short_ladders_decide_exactly_like_wps() {
        let cfg = SystemConfig::default();
        let mut greedy = sched(&cfg);
        let mut wps = WpsScheduler::new(&cfg, 0, cfg.link_bps);
        for id in 1..=6u64 {
            let t = Task::low(id, id, (id as usize - 1) % cfg.n_devices, 0, cfg.frame_period(), &cfg);
            let refs = task_refs(std::slice::from_ref(&t));
            let ev = SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &[] };
            let a = greedy.on_event(0, ev);
            let b = wps.on_event(0, ev);
            assert_eq!(a, b, "task {id}: empty-ladder decisions must match WPS exactly");
        }
    }

    #[test]
    fn exhausted_fleet_rejects_after_the_whole_density_order() {
        let cfg = SystemConfig::default();
        let mut s = sched(&cfg);
        let fam = Ladder::stage3_family(&cfg).compile(&cfg);
        // A deadline too tight for any rung anywhere.
        let t = Task::low(1, 1, 0, 0, 1_000, &cfg);
        let refs = task_refs(std::slice::from_ref(&t));
        let d = s.on_event(
            0,
            SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &fam },
        );
        assert_eq!(d.outcome, Outcome::LpRejected);
        assert_eq!(d.variant, None);
    }
}
