//! The energy-aware scheduler variant: deadline feasibility first,
//! joules second.
//!
//! Structurally this is WPS's exact-state machinery with the placement
//! score swapped ([`ScoreMode::Energy`]): among *feasible* placements the
//! cheapest-joules candidate wins, with a scarcity multiplier steering
//! work away from low-battery devices (reported by the engine via
//! [`SchedEvent::BatteryLevels`]). What changes beyond the score is the
//! tier order. The tiered wrapper used by WPS/RAS/MULTI spends the cloud
//! *before* degrading (full accuracy on the cloud beats a degraded edge
//! placement); this scheduler inverts that: it walks the whole
//! model-variant ladder on the edge first — so the rung degrades
//! precisely when only the cloud (or a device the scarcity penalty is
//! protecting) could hold full accuracy — and touches the cloud only as
//! the last resort, at the deepest rung, where the upload (and therefore
//! the device's radio energy) is smallest. Edge compute dwarfs radio
//! transmit in the power model, so once the ladder is exhausted the
//! cheapest joules for the fleet is to ship the smallest variant out.

use super::wps::{ScoreMode, WpsScheduler};
use super::{
    place_degrading, task_refs, CloudPlan, Decision, LpOutcome, Outcome, SchedEvent, Scheduler,
    WorkloadState,
};
use crate::config::SystemConfig;
use crate::coordinator::task::{Task, VariantRung};
use crate::energy::EnergyModel;
use crate::time::SimTime;

/// Battery-aware three-tier scheduler (see module docs).
pub struct EnergyScheduler {
    inner: WpsScheduler,
    /// Cloud tier (None when `cloud_wan_bps` is 0) — consulted only
    /// after the full edge ladder rejects.
    cloud: Option<CloudPlan>,
}

impl EnergyScheduler {
    /// `model` should be the run's own power model so the score ranks
    /// placements by the joules the engine will actually integrate.
    pub fn new(cfg: &SystemConfig, now: SimTime, baseline_bps: f64, model: EnergyModel) -> Self {
        Self {
            inner: WpsScheduler::with_score_mode(
                cfg,
                now,
                baseline_bps,
                ScoreMode::Energy { model },
            ),
            cloud: CloudPlan::from_config(cfg),
        }
    }

    /// Edge ladder first (shared [`place_degrading`] policy over the
    /// energy-scored exact search), cloud last, at the deepest rung.
    /// Explainability records route through the inner exact-state
    /// scheduler's buffer (labelled "ENERGY" by its score mode), because
    /// this path bypasses the inner [`Scheduler::on_event`] hooks.
    fn place_low(
        &mut self,
        now: SimTime,
        tasks: &[&Task],
        ladder: &[VariantRung],
        realloc: bool,
    ) -> Decision {
        let d = self.place_low_inner(now, tasks, ladder, realloc);
        self.inner.explain_lp_decision(tasks, &d);
        d
    }

    fn place_low_inner(
        &mut self,
        now: SimTime,
        tasks: &[&Task],
        ladder: &[VariantRung],
        realloc: bool,
    ) -> Decision {
        let inner = &mut self.inner;
        let d = place_degrading(now, tasks, ladder, realloc, |n, ts, r| {
            inner.schedule_low(n, ts, r)
        });
        if !matches!(d.outcome, Outcome::LpRejected) {
            return d;
        }
        let Some(cloud) = self.cloud else { return d };
        let spent = d.ops;
        if ladder.len() > 1 {
            // Deepest rung: smallest upload, fewest radio joules. The
            // class's cloud service time is rung-invariant (the cloud
            // runs the full model), so depth only buys transfer slack.
            let k = ladder.len() - 1;
            let degraded: Vec<Task> =
                tasks.iter().map(|t| t.at_rung(&ladder[k])).collect();
            let refs = task_refs(&degraded);
            match cloud.attempt(now, &refs) {
                LpOutcome::Allocated { allocs, ops } => Decision {
                    outcome: Outcome::LpAllocated { allocs },
                    ops: spent + ops,
                    variant: Some(k as u8),
                },
                LpOutcome::Rejected { ops } => {
                    Decision { outcome: Outcome::LpRejected, ops: spent + ops, variant: None }
                }
            }
        } else {
            let mut cd: Decision = cloud.attempt(now, tasks).into();
            cd.ops += spent;
            cd
        }
    }
}

impl Scheduler for EnergyScheduler {
    fn name(&self) -> &'static str {
        "ENERGY"
    }

    fn on_event(&mut self, now: SimTime, ev: SchedEvent<'_>) -> Decision {
        match ev {
            SchedEvent::LowPriorityBatch { tasks, realloc, ladder } => {
                self.place_low(now, tasks, ladder, realloc)
            }
            SchedEvent::Reoffer { tasks, ladder } => self.place_low(now, tasks, ladder, true),
            SchedEvent::CloudBandwidthUpdate { bps } => {
                if let Some(c) = &mut self.cloud {
                    c.update(bps);
                }
                Decision::ack(0)
            }
            // Everything else — HP placement, completions, churn, battery
            // levels — is the inner exact-state scheduler's business.
            other => self.inner.on_event(now, other),
        }
    }

    fn bandwidth_estimate(&self) -> f64 {
        self.inner.bandwidth_estimate()
    }

    fn state(&self) -> &WorkloadState {
        self.inner.state()
    }

    fn set_explain(&mut self, on: bool) {
        self.inner.explain_set(on);
    }

    fn drain_decisions(&mut self) -> Vec<crate::obs::DecisionRecord> {
        self.inner.explain_drain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::TaskConfig;

    fn cloud_cfg() -> SystemConfig {
        SystemConfig { cloud_wan_bps: 20e6, cloud_rtt_ms: 40.0, ..SystemConfig::default() }
    }

    fn sched(c: &SystemConfig) -> EnergyScheduler {
        EnergyScheduler::new(c, 0, c.link_bps, EnergyModel::pi2b())
    }

    fn ladder(c: &SystemConfig) -> [VariantRung; 2] {
        [
            VariantRung {
                accuracy: 0.97,
                input_bytes: c.image_bytes,
                proc_us: [c.lp2_proc(), c.lp4_proc()],
            },
            VariantRung {
                accuracy: 0.80,
                input_bytes: c.image_bytes / 4,
                proc_us: [2_000_000, 1_500_000],
            },
        ]
    }

    #[test]
    fn idle_fleet_keeps_work_on_the_edge_at_full_accuracy() {
        let c = cloud_cfg();
        let mut s = sched(&c);
        let t = Task::low(1, 1, 0, 0, c.frame_period(), &c);
        let refs = task_refs(std::slice::from_ref(&t));
        let lad = ladder(&c);
        let d = s.on_event(
            0,
            SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &lad },
        );
        assert_eq!(d.variant, Some(0));
        let Outcome::LpAllocated { allocs } = d.outcome else { panic!("{:?}", d.outcome) };
        assert!(allocs[0].device < c.n_devices, "idle edge must host the work");
    }

    #[test]
    fn degrades_on_the_edge_before_touching_the_cloud() {
        // Same setup where the WPS-tiered policy goes to the cloud at
        // full accuracy: no edge config meets the deadline at rung 0, but
        // rung 1 fits locally. The energy policy prefers the degraded
        // edge placement (compute joules on a full battery beat shipping
        // the input over the WAN only in accuracy terms — this scheduler
        // spends accuracy to keep latitude, per its tier order).
        let c = cloud_cfg();
        let mut s = sched(&c);
        let t = Task::low(1, 1, 0, 0, c.lp4_proc() - 1, &c);
        let refs = task_refs(std::slice::from_ref(&t));
        let lad = ladder(&c);
        let d = s.on_event(
            0,
            SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &lad },
        );
        assert_eq!(d.variant, Some(1), "ladder must be exhausted before the cloud");
        let Outcome::LpAllocated { allocs } = d.outcome else { panic!("{:?}", d.outcome) };
        assert!(allocs[0].device < c.n_devices);
        assert_eq!(allocs[0].end - allocs[0].start, 2_000_000);
    }

    #[test]
    fn explain_records_carry_the_energy_label_and_cloud_flag() {
        let c = cloud_cfg();
        let mut s = sched(&c);
        s.set_explain(true);
        let deadline = c.frame_period();
        let mut last = None;
        for id in 1..=9u64 {
            let t = Task::low(id, id, (id as usize - 1) % c.n_devices, 0, deadline, &c);
            let refs = task_refs(std::slice::from_ref(&t));
            last = Some(s.on_event(
                0,
                SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &[] },
            ));
        }
        let Outcome::LpAllocated { allocs } = last.unwrap().outcome else { panic!() };
        assert_eq!(allocs[0].device, c.n_devices);
        let recs = s.drain_decisions();
        assert_eq!(recs.len(), 9, "one record per placement decision");
        assert!(recs.iter().all(|r| r.scheduler == "ENERGY"));
        assert!(!recs[0].cloud, "first task lands on the idle edge");
        assert!(recs[8].cloud, "overflow work is attributed to the cloud");
        assert_eq!(recs[8].outcome(), "cloud");
    }

    #[test]
    fn saturated_fleet_spills_to_the_cloud_last() {
        let c = cloud_cfg();
        let mut s = sched(&c);
        // 4 devices × two concurrent 2-core stages = 8 edge slots within
        // one frame period; the 9th task finds no edge placement in any
        // configuration and must land on the cloud.
        let deadline = c.frame_period();
        let mut last = None;
        for id in 1..=9u64 {
            let t = Task::low(id, id, (id as usize - 1) % c.n_devices, 0, deadline, &c);
            let refs = task_refs(std::slice::from_ref(&t));
            last = Some(s.on_event(
                0,
                SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &[] },
            ));
        }
        let d = last.unwrap();
        let Outcome::LpAllocated { allocs } = d.outcome else { panic!("{:?}", d.outcome) };
        assert_eq!(allocs[0].device, c.n_devices, "overflow work belongs to the cloud");
        assert_eq!(allocs[0].cores, 0);
        assert_eq!(allocs[0].config, TaskConfig::LowFourCore);
        assert_eq!(d.variant, None, "empty ladder places without a rung");
    }
}
