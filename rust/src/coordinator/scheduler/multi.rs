//! Contextual multi-scheduler — the paper's *future work* (Section VII):
//! "utilising a more accurate approach under lightly loaded conditions and
//! switching to light-weight scheduling abstraction models in times of
//! higher network load."
//!
//! Implemented here as an ablation: requests are routed to an inner WPS
//! while the live workload is below `switch_threshold` allocations, and to
//! an inner RAS above it. Both inner schedulers see *every* state-changing
//! event (completions, violations, bandwidth updates) so whichever is
//! active always works from a current view; allocations are tracked by the
//! scheduler that made them, and the merged exact state is exposed for the
//! engine.

use super::ras_sched::RasScheduler;
use super::wps::WpsScheduler;
use super::{
    place_degrading_tiered, CloudPlan, Decision, ExplainLog, HpOutcome, LpOutcome, Ops, Outcome,
    SchedEvent, Scheduler, WorkloadState,
};
use crate::config::SystemConfig;
use crate::obs::{CandidateScore, DecisionRecord, RejectReason};
use crate::coordinator::task::{Allocation, DeviceId, Task, TaskId};
use crate::time::SimTime;

/// Which inner scheduler handled a task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Owner {
    Wps,
    Ras,
}

/// Load-adaptive composite scheduler.
pub struct MultiScheduler {
    wps: WpsScheduler,
    ras: RasScheduler,
    owners: std::collections::HashMap<TaskId, Owner>,
    merged: WorkloadState,
    /// Switch to RAS when live allocations reach this count.
    pub switch_threshold: usize,
    /// Diagnostics: requests served by each inner scheduler.
    pub wps_requests: u64,
    pub ras_requests: u64,
    /// Cloud tier (None when `cloud_wan_bps` is 0): owned here so the
    /// fallback applies regardless of which inner scheduler is active.
    cloud: Option<CloudPlan>,
    /// Explainability buffer ([`Scheduler::set_explain`]). Records are
    /// built here, not in the inners (routing bypasses their
    /// `on_event` hooks), labelled by the inner that served the request.
    explain: ExplainLog,
    /// Inner scheduler the most recent placement request routed to —
    /// the contextual switch is exactly what the records must expose.
    last_owner: Owner,
}

impl MultiScheduler {
    pub fn new(cfg: &SystemConfig, now: SimTime, baseline_bps: f64, switch_threshold: usize) -> Self {
        Self {
            wps: WpsScheduler::new(cfg, now, baseline_bps),
            ras: RasScheduler::new(cfg, now, baseline_bps),
            owners: std::collections::HashMap::new(),
            merged: WorkloadState::new(cfg.n_devices),
            switch_threshold,
            wps_requests: 0,
            ras_requests: 0,
            cloud: CloudPlan::from_config(cfg),
            explain: ExplainLog::default(),
            last_owner: Owner::Wps,
        }
    }

    fn use_ras(&self) -> bool {
        self.merged.len() >= self.switch_threshold
    }

    /// Mirror an allocation made by `owner` into the other scheduler's
    /// state so both views stay consistent.
    fn record(&mut self, owner: Owner, allocs: &[crate::coordinator::task::Allocation]) {
        for a in allocs {
            self.owners.insert(a.task, owner);
            self.merged.insert(*a);
            // The passive scheduler must also see the occupancy, or its
            // next activation would double-book the device.
            match owner {
                Owner::Wps => self.ras.mirror_external(a),
                Owner::Ras => self.wps.mirror_external(a),
            }
        }
    }

    fn drop_task(&mut self, now: SimTime, task: TaskId) {
        self.owners.remove(&task);
        self.merged.remove(task);
        self.wps.on_complete(now, task);
        self.ras.on_complete(now, task);
    }

    /// Schedule a high-priority task through the load-selected inner
    /// scheduler. Legacy-shaped entry point; [`Scheduler::on_event`]
    /// dispatches here.
    pub fn schedule_high(&mut self, now: SimTime, task: &Task) -> HpOutcome {
        let (owner, out) = if self.use_ras() {
            self.ras_requests += 1;
            (Owner::Ras, self.ras.schedule_high(now, task))
        } else {
            self.wps_requests += 1;
            (Owner::Wps, self.wps.schedule_high(now, task))
        };
        self.last_owner = owner;
        match &out {
            HpOutcome::Allocated { alloc, .. } => self.record(owner, std::slice::from_ref(alloc)),
            HpOutcome::Preempted { alloc, victims, .. } => {
                for v in victims {
                    self.drop_task(now, v.task);
                }
                self.record(owner, std::slice::from_ref(alloc));
            }
            HpOutcome::Rejected { victims, .. } => {
                for v in victims {
                    self.drop_task(now, v.task);
                }
            }
        }
        out
    }

    /// Schedule a low-priority batch through the load-selected inner
    /// scheduler. Legacy-shaped entry point; [`Scheduler::on_event`]
    /// dispatches here.
    pub fn schedule_low(&mut self, now: SimTime, tasks: &[&Task], realloc: bool) -> LpOutcome {
        let (owner, out) = if self.use_ras() {
            self.ras_requests += 1;
            (Owner::Ras, self.ras.schedule_low(now, tasks, realloc))
        } else {
            self.wps_requests += 1;
            (Owner::Wps, self.wps.schedule_low(now, tasks, realloc))
        };
        self.last_owner = owner;
        if let LpOutcome::Allocated { allocs, .. } = &out {
            let allocs = allocs.clone();
            self.record(owner, &allocs);
        }
        out
    }

    /// Record label: which inner served the final routed attempt — the
    /// contextual switch made visible per decision.
    fn explain_label(&self) -> &'static str {
        match self.last_owner {
            Owner::Wps => "MULTI/WPS",
            Owner::Ras => "MULTI/RAS",
        }
    }

    /// Explainability record for a high-priority decision.
    fn explain_hp(&mut self, task: &Task, d: &Decision) {
        let (chosen, reject, score) = match &d.outcome {
            Outcome::HpAllocated { alloc, .. } => {
                (Some((alloc.device, alloc.cores as u8)), None, alloc.end as f64)
            }
            _ => (None, Some(RejectReason::WindowInfeasible), f64::INFINITY),
        };
        self.explain.push(DecisionRecord {
            scheduler: self.explain_label(),
            task: task.id,
            batch: 1,
            high_priority: true,
            candidates: vec![CandidateScore { device: task.source, score, reject }],
            chosen,
            rung: None,
            cloud: false,
        });
    }

    /// Explainability record for one low-priority decision (shared by
    /// `LowPriorityBatch` and `Reoffer`). The score is the planned finish
    /// time — the one quantity both inner abstractions agree on.
    fn explain_lp(&mut self, tasks: &[&Task], d: &Decision) {
        let cloud_dev = self.cloud.as_ref().map(|c| c.device);
        let mut candidates: Vec<CandidateScore> = Vec::new();
        let mut chosen = None;
        let mut cloud = false;
        match &d.outcome {
            Outcome::LpAllocated { allocs } => {
                for a in allocs {
                    if Some(a.device) == cloud_dev {
                        cloud = true;
                    }
                    candidates.push(CandidateScore {
                        device: a.device,
                        score: a.end as f64,
                        reject: None,
                    });
                }
                chosen = allocs.first().map(|a| (a.device, a.cores as u8));
            }
            _ => {
                candidates.push(CandidateScore {
                    device: tasks.first().map(|t| t.source).unwrap_or(0),
                    score: f64::INFINITY,
                    reject: Some(RejectReason::WindowInfeasible),
                });
            }
        }
        self.explain.push(DecisionRecord {
            scheduler: self.explain_label(),
            task: tasks.first().map(|t| t.id).unwrap_or(0),
            batch: tasks.len(),
            high_priority: false,
            candidates,
            chosen,
            rung: d.variant.map(|v| v as usize),
            cloud,
        });
    }

    /// Task finished: both inner schedulers must see the state change.
    pub fn on_complete(&mut self, now: SimTime, task: TaskId) {
        self.drop_task(now, task);
    }

    /// Task missed its deadline: both inner schedulers must see it.
    pub fn on_violation(&mut self, now: SimTime, task: TaskId) {
        self.owners.remove(&task);
        self.merged.remove(task);
        self.wps.on_violation(now, task);
        self.ras.on_violation(now, task);
    }

    /// Bandwidth estimate update, fanned to both inner schedulers.
    pub fn on_bandwidth_update(&mut self, now: SimTime, bps: f64) -> Ops {
        self.wps.on_bandwidth_update(now, bps) + self.ras.on_bandwidth_update(now, bps)
    }

    /// Fleet join, fanned to both inner schedulers.
    pub fn on_device_joined(&mut self, now: SimTime, device: DeviceId) -> Ops {
        self.merged.ensure_device(device);
        self.wps.on_device_joined(now, device) + self.ras.on_device_joined(now, device)
    }

    /// Fleet leave: evictions come from the merged (authoritative) state;
    /// both inner schedulers drop their own view of the departed device.
    pub fn on_device_left(&mut self, now: SimTime, device: DeviceId) -> (Vec<Allocation>, Ops) {
        let evicted: Vec<Allocation> = self.merged.device_allocs(device).copied().collect();
        let (_, wps_ops) = self.wps.on_device_left(now, device);
        let (_, ras_ops) = self.ras.on_device_left(now, device);
        for a in &evicted {
            self.owners.remove(&a.task);
            self.merged.remove(a.task);
        }
        (evicted, wps_ops + ras_ops)
    }
}

impl Scheduler for MultiScheduler {
    fn name(&self) -> &'static str {
        "MULTI"
    }

    fn on_event(&mut self, now: SimTime, ev: SchedEvent<'_>) -> Decision {
        match ev {
            SchedEvent::HighPriority { task } => {
                let d: Decision = self.schedule_high(now, task).into();
                if self.explain.on() {
                    self.explain_hp(task, &d);
                }
                d
            }
            SchedEvent::LowPriorityBatch { tasks, realloc, ladder } => {
                // The shared policy wraps the load-routed placement:
                // every rung is routed afresh, so a batch whose rung 0
                // failed under RAS can still land its degraded rung
                // under RAS (or WPS, if completions dropped the load
                // below the switch threshold mid-ladder). `record` keeps
                // both inner views consistent with whichever rung stuck;
                // cloud placements bypass `record` entirely (they hold no
                // edge resources).
                let cloud = self.cloud;
                let d =
                    place_degrading_tiered(now, tasks, ladder, realloc, cloud.as_ref(), |n, ts, r| {
                        self.schedule_low(n, ts, r)
                    });
                if self.explain.on() {
                    self.explain_lp(tasks, &d);
                }
                d
            }
            SchedEvent::Complete { task } => {
                self.on_complete(now, task);
                Decision::ack(1)
            }
            SchedEvent::Violation { task } => {
                self.on_violation(now, task);
                Decision::ack(1)
            }
            SchedEvent::BandwidthUpdate { bps } => Decision::ack(self.on_bandwidth_update(now, bps)),
            SchedEvent::DeviceJoined { device } => Decision::ack(self.on_device_joined(now, device)),
            SchedEvent::DeviceLeft { device } | SchedEvent::DeviceCrashed { device } => {
                // Both inner schedulers drop the device either way; the
                // engine decides whether the work drains or is lost.
                let (evicted, ops) = self.on_device_left(now, device);
                Decision { outcome: Outcome::Ack { evicted }, ops, variant: None }
            }
            SchedEvent::DeviceRecovered { device } => {
                Decision::ack(self.on_device_joined(now, device))
            }
            SchedEvent::Reoffer { tasks, ladder } => {
                // Load-routed like any placement request; `record` keeps
                // both inner views consistent with the re-placement, and
                // the remaining ladder tail may degrade it further.
                let cloud = self.cloud;
                let d = place_degrading_tiered(now, tasks, ladder, true, cloud.as_ref(), |n, ts, r| {
                    self.schedule_low(n, ts, r)
                });
                if self.explain.on() {
                    self.explain_lp(tasks, &d);
                }
                d
            }
            SchedEvent::CloudBandwidthUpdate { bps } => {
                if let Some(c) = &mut self.cloud {
                    c.update(bps);
                }
                // Fan to both inner schedulers so their (dormant) plans
                // stay current if the routing policy ever consults them.
                let a = self.wps.on_event(now, SchedEvent::CloudBandwidthUpdate { bps });
                let b = self.ras.on_event(now, SchedEvent::CloudBandwidthUpdate { bps });
                Decision::ack(a.ops + b.ops)
            }
            SchedEvent::BatteryLevels { levels } => {
                let a = self.wps.on_event(now, SchedEvent::BatteryLevels { levels });
                let b = self.ras.on_event(now, SchedEvent::BatteryLevels { levels });
                Decision::ack(a.ops + b.ops)
            }
            SchedEvent::DeviceSuspected { device } => {
                // Belief, not truth: both inner candidate pools shrink, but
                // the merged state keeps the device's allocations — the
                // detector may be wrong and the work may still complete.
                let a = self.wps.on_device_suspected(device);
                let b = self.ras.on_device_suspected(device);
                Decision::ack(a + b)
            }
            SchedEvent::DeviceCleared { device } => {
                let a = self.wps.on_device_cleared(device);
                let b = self.ras.on_device_cleared(device);
                Decision::ack(a + b)
            }
            SchedEvent::BandwidthStale => {
                // Only RAS plans with the dynamic estimate; WPS acks free.
                let a = self.wps.on_event(now, SchedEvent::BandwidthStale);
                let b = self.ras.on_event(now, SchedEvent::BandwidthStale);
                Decision::ack(a.ops + b.ops)
            }
            SchedEvent::Pressure { candidates, escalate } => {
                // Truncation is a policy over the engine's survey, not
                // over either inner's state: answer once, shared policy.
                super::decide_pressure(candidates, escalate)
            }
        }
    }

    fn bandwidth_estimate(&self) -> f64 {
        if self.use_ras() {
            self.ras.bandwidth_estimate()
        } else {
            self.wps.bandwidth_estimate()
        }
    }

    fn state(&self) -> &WorkloadState {
        &self.merged
    }

    fn set_explain(&mut self, on: bool) {
        self.explain.set(on);
    }

    fn drain_decisions(&mut self) -> Vec<DecisionRecord> {
        self.explain.drain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::task_refs;
    use crate::coordinator::task::DeviceId;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn lp_batch(base: TaskId, n: usize, source: DeviceId, now: SimTime, c: &SystemConfig) -> Vec<Task> {
        let deadline = now + c.frame_period();
        (0..n as u64)
            .map(|i| Task::low(base + i, base, source, now, deadline, c))
            .collect()
    }

    #[test]
    fn light_load_routes_to_wps_heavy_to_ras() {
        let c = cfg();
        let mut s = MultiScheduler::new(&c, 0, c.link_bps, 3);
        // First batch (empty state) goes to WPS.
        let b1 = lp_batch(1, 3, 0, 0, &c);
        assert!(matches!(s.schedule_low(0, &task_refs(&b1), false), LpOutcome::Allocated { .. }));
        assert_eq!(s.wps_requests, 1);
        assert_eq!(s.ras_requests, 0);
        // State now ≥ threshold: next request goes to RAS.
        let b2 = lp_batch(11, 2, 1, 0, &c);
        let _ = s.schedule_low(0, &task_refs(&b2), false);
        assert_eq!(s.ras_requests, 1);
    }

    #[test]
    fn completion_returns_control_to_wps() {
        let c = cfg();
        let mut s = MultiScheduler::new(&c, 0, c.link_bps, 2);
        let b = lp_batch(1, 2, 0, 0, &c);
        assert!(matches!(s.schedule_low(0, &task_refs(&b), false), LpOutcome::Allocated { .. }));
        assert!(s.use_ras());
        s.on_complete(1_000, 1);
        s.on_complete(1_000, 2);
        assert!(!s.use_ras());
    }

    #[test]
    fn suspicion_fans_to_both_inners_and_crash_still_evicts() {
        let c = cfg();
        let mut s = MultiScheduler::new(&c, 0, c.link_bps, 3);
        let b1 = lp_batch(1, 3, 0, 0, &c);
        let LpOutcome::Allocated { allocs, .. } = s.schedule_low(0, &task_refs(&b1), false)
        else {
            panic!("batch should fit")
        };
        let dev = allocs.iter().find(|a| a.offloaded).expect("one offload").device;
        let d = s.on_event(0, SchedEvent::DeviceSuspected { device: dev });
        assert!(matches!(d.outcome, Outcome::Ack { .. }));
        // Belief, not truth: the merged state keeps the allocation.
        assert!(s.state().device_allocs(dev).next().is_some());
        // New placements route around the suspected device in both inners.
        let b2 = lp_batch(11, 3, 0, 0, &c);
        if let LpOutcome::Allocated { allocs, .. } = s.schedule_low(0, &task_refs(&b2), false) {
            assert!(allocs.iter().all(|a| a.device != dev));
        }
        // A real crash of the suspected device still evicts from merged.
        let d = s.on_event(0, SchedEvent::DeviceCrashed { device: dev });
        let Outcome::Ack { evicted } = d.outcome else { panic!("ack expected") };
        assert_eq!(evicted.len(), 1);
        assert!(s.state().device_allocs(dev).next().is_none());
    }

    #[test]
    fn explain_records_expose_the_contextual_switch() {
        use crate::coordinator::task::VariantRung;
        let c = cfg();
        let mut s = MultiScheduler::new(&c, 0, c.link_bps, 3);
        s.set_explain(true);
        let ladder = [VariantRung {
            accuracy: 0.97,
            input_bytes: c.image_bytes,
            proc_us: [c.lp2_proc(), c.lp4_proc()],
        }];
        // Light load routes to WPS, then the live state crosses the
        // threshold and the next batch routes to RAS — the records must
        // show exactly that switch.
        let b1 = lp_batch(1, 3, 0, 0, &c);
        let refs = task_refs(&b1);
        let _ = s.on_event(
            0,
            SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &ladder },
        );
        let b2 = lp_batch(11, 2, 1, 0, &c);
        let refs = task_refs(&b2);
        let _ = s.on_event(
            0,
            SchedEvent::LowPriorityBatch { tasks: &refs, realloc: false, ladder: &ladder },
        );
        let recs = s.drain_decisions();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].scheduler, "MULTI/WPS");
        assert_eq!(recs[1].scheduler, "MULTI/RAS");
        assert!(recs.iter().all(|r| r.chosen.is_some()));
    }

    #[test]
    fn no_double_booking_across_inner_schedulers() {
        let c = cfg();
        let mut s = MultiScheduler::new(&c, 0, c.link_bps, 3);
        let mut id = 1u64;
        for round in 0..5u64 {
            let now = round * 3_000_000;
            let batch = lp_batch(id, 3, (round % 4) as usize, now, &c);
            id += 3;
            let _ = s.schedule_low(now, &task_refs(&batch), false);
        }
        for d in 0..c.n_devices {
            for t in (0..40_000_000u64).step_by(500_000) {
                let (peak, _) = s.state().peak_usage(d, t, t + 500_000);
                assert!(peak <= c.cores_per_device, "device {d} oversubscribed at {t}");
            }
        }
    }
}
