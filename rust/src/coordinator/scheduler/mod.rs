//! Scheduling algorithms (Section IV-B) behind a typed event/decision API.
//!
//! Two schedulers implement the common [`Scheduler`] trait:
//!
//! * [`ras_sched::RasScheduler`] — the paper's contribution: containment
//!   queries on resource-availability lists + the discretised network link
//!   + dynamic bandwidth estimation.
//! * [`wps::WpsScheduler`] — the authors' prior "weighted pre-emption
//!   scheduler" baseline: exact per-device task lists searched with
//!   overlapping-range scans. More accurate placement, more work per
//!   decision.
//!
//! ## The event/decision contract
//!
//! The discrete-event engine no longer calls a bag of per-occurrence
//! callbacks; every scheduler-visible occurrence is a [`SchedEvent`]
//! dispatched through a single entry point:
//!
//! ```text
//! fn on_event(&mut self, now: SimTime, ev: SchedEvent<'_>) -> Decision
//! ```
//!
//! A [`Decision`] carries the allocation [`Outcome`] *and* the operation
//! count ([`Ops`]) uniformly: the number of elementary data-structure
//! steps the dispatch performed (windows visited, overlap checks,
//! write/bisect operations). The engine converts ops to virtual
//! scheduling latency through the configured cost model, so the
//! accuracy-vs-performance feedback loop the paper studies — slow
//! scheduling delays task starts and burns deadline slack — is driven by
//! the real algorithmic costs of the two implementations. Criterion-style
//! benches additionally measure raw wall-clock for the §Perf pass.
//!
//! [`SchedEvent::DeviceJoined`] / [`SchedEvent::DeviceLeft`] extend the
//! paper's fixed four-Pi testbed to churning fleets (scenario API): a
//! departing device's live allocations come back in
//! [`Outcome::Ack`]`::evicted` so the engine can cancel and reschedule
//! them. The fault-injection layer adds [`SchedEvent::DeviceCrashed`] /
//! [`SchedEvent::DeviceRecovered`] (crash-invalidated placements: the
//! evicted work is lost, not drained) and [`SchedEvent::Reoffer`]
//! (crash-lost tasks re-entering placement on their remaining deadline
//! budget).
//!
//! [`SchedEvent::LowPriorityBatch`] / [`SchedEvent::Reoffer`] carry the
//! batch's model-variant ladder; all three schedulers route placement
//! through the shared [`place_degrading`] policy, stepping down to a
//! cheaper DNN variant when their own state deems the current rung
//! infeasible. [`Decision::variant`] reports the rung the batch was
//! placed at, and the engine accounts the delivered accuracy.
//!
//! The legacy callback shapes ([`HpOutcome`], [`LpOutcome`], and the
//! [`SchedulerCompat`] extension trait) remain as a thin compatibility
//! layer over `on_event`; `rust/tests/sched_event_equivalence.rs` holds a
//! golden-seed proof that both surfaces decide identically.

pub mod energy_sched;
pub mod greedy;
pub mod multi;
pub mod ras_sched;
pub mod wps;

use std::collections::HashMap;

use crate::coordinator::task::{Allocation, DeviceId, Task, TaskConfig, TaskId, VariantRung};
use crate::time::SimTime;

/// Operation count for one scheduling call.
pub type Ops = u64;

/// A typed occurrence dispatched to the scheduler by the engine.
///
/// Batch events carry `&[&Task]` rather than `&[Task]`: the engine's
/// tasks live in a generational slab, and the dispatch borrows them in
/// place (a stack array of references, no clones, no allocation). Owned
/// task buffers — tests, examples, the compat shim — adapt through
/// [`task_refs`].
#[derive(Debug, Clone, Copy)]
pub enum SchedEvent<'a> {
    /// A high-priority task requests placement (always local to source).
    HighPriority { task: &'a Task },
    /// A batch of low-priority tasks requests placement atomically (the
    /// conveyor emits 1–4 per frame; generative workloads emit arbitrary
    /// class-defined batch sizes). Batch members share one task class —
    /// same deadline, same per-configuration durations. `realloc` marks
    /// re-entry of preempted tasks (tracked separately in Fig. 4/5).
    ///
    /// `ladder` is the batch's remaining model-variant ladder (rung 0 =
    /// the tasks' current spec). Empty or one-rung ladders never degrade
    /// and decide bit-identically to the pre-ladder API; deeper ladders
    /// let the scheduler step down to a cheaper variant instead of
    /// rejecting ([`place_degrading`]).
    LowPriorityBatch { tasks: &'a [&'a Task], realloc: bool, ladder: &'a [VariantRung] },
    /// A task finished on its device (free its resources).
    Complete { task: TaskId },
    /// A task missed its deadline and was abandoned.
    Violation { task: TaskId },
    /// A bandwidth probe round produced a new estimate (bits/s). The RAS
    /// link rebuild is *not* free — Fig. 6/7 hinge on the returned ops.
    BandwidthUpdate { bps: f64 },
    /// A device joined the fleet (scenario churn / fleet growth).
    DeviceJoined { device: DeviceId },
    /// A device left the fleet; its live allocations must be evicted and
    /// surfaced in the decision so the engine can reschedule them.
    DeviceLeft { device: DeviceId },
    /// A device crashed (fault injection). Mechanically like
    /// [`SchedEvent::DeviceLeft`] — evict and surface its allocations —
    /// but the engine treats the evicted work as *lost*, not drained:
    /// flows are aborted and survivors come back as
    /// [`SchedEvent::Reoffer`], never as completions.
    DeviceCrashed { device: DeviceId },
    /// A crashed device came back with fresh, empty availability.
    DeviceRecovered { device: DeviceId },
    /// Crash-lost low-priority tasks re-offered for placement with
    /// whatever deadline budget remains (the crash already burned part of
    /// it). LP-shaped outcome: re-place, or reject to drop-by-deadline.
    /// `ladder` as on [`SchedEvent::LowPriorityBatch`]: a re-offer may
    /// degrade further down the tasks' remaining rungs before dropping.
    Reoffer { tasks: &'a [&'a Task], ladder: &'a [VariantRung] },
    /// The cloud tier's WAN bandwidth estimator produced a new estimate
    /// (bits/s) — fed passively from completed uploads, not probe
    /// rounds. Only dispatched when the cloud tier is enabled;
    /// schedulers fold it into their [`CloudPlan`] and acknowledge.
    CloudBandwidthUpdate { bps: f64 },
    /// Fresh per-device battery levels as a fraction of capacity
    /// (1.0 = full or mains powered), indexed by device id. Only
    /// dispatched when a battery is configured, immediately before
    /// low-priority placement dispatches — the energy-aware scheduler
    /// penalises low-battery candidates; others acknowledge for free.
    BatteryLevels { levels: &'a [f64] },
    /// The failure detector suspects `device` is down (missed-heartbeat
    /// threshold crossed — see [`crate::fault::detector`]). This is
    /// *belief*, not truth: the device may be alive (false positive
    /// under probe loss) or may have been dead for a while (detection
    /// lag). Schedulers stop placing on it until cleared; existing
    /// allocations stay (a false suspicion must not lose work — only a
    /// real `DeviceCrashed`/`DeviceLeft` evicts). Only dispatched when
    /// the detector is enabled (`suspect_after > 0`).
    DeviceSuspected { device: DeviceId },
    /// A heartbeat reached a suspected device: the suspicion was wrong
    /// (or the device healed). Resume placing on it with its existing
    /// availability intact — unlike [`SchedEvent::DeviceJoined`],
    /// nothing is reset.
    DeviceCleared { device: DeviceId },
    /// The bandwidth estimate went stale (`bw_stale_after` consecutive
    /// failed probe rounds): the EWMA still reports its last value with
    /// full confidence, but it is old. RAS widens its conservative
    /// windows while stale (cleared by the next successful
    /// [`SchedEvent::BandwidthUpdate`]); WPS ignores it — its estimate
    /// was static anyway. Only dispatched when `bw_stale_after > 0`.
    BandwidthStale,
    /// The deadline-pressure controller's periodic survey of running
    /// *staged* low-priority executions (anytime/imprecise computation):
    /// each candidate is a live task whose next optional-stage boundary
    /// is still ahead, with the engine's predicted finish times at the
    /// cut and at full depth. The scheduler answers with
    /// [`Outcome::Truncate`] naming which candidates to cut short at
    /// their next boundary; the engine commits the cuts and the tasks
    /// complete early with partial accuracy. `escalate` is set when the
    /// queued low-priority backlog crossed `pressure_backlog` — backlog
    /// pressure justifies cutting tasks that would have met their
    /// deadlines anyway, to free capacity sooner. Only dispatched when
    /// `pressure_check_s > 0` and at least one candidate exists.
    Pressure { candidates: &'a [PressureCandidate], escalate: bool },
}

/// One running staged execution the deadline-pressure controller may cut
/// short, as the engine surveys it for [`SchedEvent::Pressure`]. All
/// predictions are engine ground truth (the engine knows the actual
/// execution duration it committed to): the scheduler chooses *policy*,
/// the engine supplies *state*.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PressureCandidate {
    pub task: TaskId,
    /// Device the execution runs on (edge only — cloud executions are
    /// monolithic and never surveyed).
    pub device: DeviceId,
    /// The next uncommitted stage boundary (1-based): the earliest stage
    /// the task could still be cut after. Always `>=` the plan's
    /// mandatory prefix — the engine never offers a cut below it.
    pub cut_stage: u8,
    /// Total stages in the task's plan.
    pub n_stages: u8,
    /// Predicted completion time if cut at `cut_stage`.
    pub cut_finish: SimTime,
    /// Predicted completion time at full depth.
    pub full_finish: SimTime,
    pub deadline: SimTime,
    /// Accuracy forfeited by cutting at `cut_stage` instead of running
    /// to full depth.
    pub accuracy_loss: f64,
    /// The device runs on a battery predicted to deplete before
    /// `full_finish`: running to full depth likely loses the task (and
    /// the device) entirely, so a cut that beats the depletion is a
    /// rescue even when the deadline itself is safe.
    pub battery_doomed: bool,
}

/// One committed truncation in an [`Outcome::Truncate`] decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TruncateCut {
    /// Index into the [`SchedEvent::Pressure`] `candidates` slice.
    pub index: u16,
    /// Cut after this stage (1-based; normally the candidate's
    /// `cut_stage` — a later boundary is legal, an earlier one is not).
    pub at_stage: u8,
}

/// The shared deadline-pressure truncation policy (the anytime
/// counterpart of [`place_degrading`]): cut a candidate at its next
/// boundary when the cut still meets the deadline **and** either the
/// full-depth run would not (salvage partial credit instead of a
/// violation), the device's battery dies before full depth (PR 6's
/// energy-aware follow-up), or backlog pressure escalated the survey
/// (free capacity sooner at a known accuracy cost). Every candidate
/// evaluation is charged [`crate::coordinator::cost::PRESSURE_EVAL_OPS`].
///
/// All four schedulers route [`SchedEvent::Pressure`] through this
/// policy; what differs between them is *which executions exist at all*
/// (their placement decisions), not how rescue cuts are judged.
pub fn decide_pressure(candidates: &[PressureCandidate], escalate: bool) -> Decision {
    let mut ops: Ops = 0;
    let mut cuts = Vec::new();
    for (i, c) in candidates.iter().enumerate() {
        ops += crate::coordinator::cost::PRESSURE_EVAL_OPS;
        let rescue = c.full_finish > c.deadline || c.battery_doomed;
        if c.cut_finish <= c.deadline && (rescue || escalate) {
            cuts.push(TruncateCut { index: i as u16, at_stage: c.cut_stage });
        }
    }
    Decision { outcome: Outcome::Truncate { cuts }, ops, variant: None }
}

/// Adapt an owned/contiguous task buffer to the reference-slice shape
/// [`SchedEvent`] batch events carry. The engine never needs this (it
/// borrows straight out of its slab); tests, examples, and the
/// [`SchedulerCompat`] shim do.
pub fn task_refs(tasks: &[Task]) -> Vec<&Task> {
    tasks.iter().collect()
}

/// The allocation outcome of one dispatched event.
#[derive(Debug, Clone, PartialEq)]
pub enum Outcome {
    /// High-priority task placed. `victims` are the low-priority tasks
    /// preempted on the way (empty ⇔ no preemption, Section IV-B3); they
    /// should re-enter low-priority scheduling once preemption completes.
    HpAllocated { alloc: Allocation, victims: Vec<Allocation> },
    /// High-priority task unplaceable. Tasks evicted by a preemption
    /// attempt that ultimately gave up still surface as `victims` and get
    /// their reallocation chance.
    HpRejected { victims: Vec<Allocation> },
    /// Low-priority batch placed atomically.
    LpAllocated { allocs: Vec<Allocation> },
    /// Low-priority batch rejected atomically (the paper: if fewer windows
    /// are found than tasks, the whole request fails).
    LpRejected,
    /// State change absorbed. Topology changes report the allocations they
    /// evicted (non-empty only for [`SchedEvent::DeviceLeft`]).
    Ack { evicted: Vec<Allocation> },
    /// Answer to [`SchedEvent::Pressure`]: cut these running staged
    /// executions short at their next stage boundary (empty = no cuts
    /// this round). The engine arms each cut; the task completes at the
    /// boundary with the cumulative accuracy banked there.
    Truncate { cuts: Vec<TruncateCut> },
}

/// What one [`Scheduler::on_event`] dispatch decided, with uniform ops
/// accounting (subsumes the legacy [`HpOutcome`] / [`LpOutcome`] pair).
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    pub outcome: Outcome,
    pub ops: Ops,
    /// Model-variant selection for ladder-aware low-priority placements:
    /// the rung index (into the event's `ladder` slice) the allocations
    /// were made at — `Some(0)` = placed at full accuracy, `Some(k > 0)`
    /// = explicitly degraded k rungs. `None` everywhere a ladder was not
    /// consulted (non-LP outcomes, empty/one-rung ladders, rejections),
    /// which keeps ladder-free decisions identical to the pre-ladder API.
    pub variant: Option<u8>,
}

impl Decision {
    /// Plain acknowledgement with no evictions.
    pub fn ack(ops: Ops) -> Self {
        Decision { outcome: Outcome::Ack { evicted: Vec::new() }, ops, variant: None }
    }

    /// Unwrap a high-priority decision into the legacy outcome shape.
    /// Panics on non-HP outcomes (contract violation).
    pub fn into_hp(self) -> HpOutcome {
        let ops = self.ops;
        match self.outcome {
            Outcome::HpAllocated { alloc, victims } if victims.is_empty() => {
                HpOutcome::Allocated { alloc, ops }
            }
            Outcome::HpAllocated { alloc, victims } => HpOutcome::Preempted { alloc, victims, ops },
            Outcome::HpRejected { victims } => HpOutcome::Rejected { victims, ops },
            other => panic!("decision is not a high-priority outcome: {other:?}"),
        }
    }

    /// Unwrap a low-priority decision into the legacy outcome shape.
    /// Panics on non-LP outcomes (contract violation).
    pub fn into_lp(self) -> LpOutcome {
        let ops = self.ops;
        match self.outcome {
            Outcome::LpAllocated { allocs } => LpOutcome::Allocated { allocs, ops },
            Outcome::LpRejected => LpOutcome::Rejected { ops },
            other => panic!("decision is not a low-priority outcome: {other:?}"),
        }
    }
}

/// Outcome of a high-priority scheduling request (legacy shape, kept for
/// the compatibility layer and the schedulers' internal logic).
#[derive(Debug, Clone, PartialEq)]
pub enum HpOutcome {
    /// Task fits locally without disturbing anyone.
    Allocated { alloc: Allocation, ops: Ops },
    /// No window on the source device: the scheduler performed preemption
    /// (Section IV-B3). `victims` were evicted and should re-enter
    /// low-priority scheduling once the preemption completes. Never
    /// constructed with empty `victims` (that is `Allocated`), which keeps
    /// the [`Decision`] round-trip exact.
    Preempted {
        alloc: Allocation,
        victims: Vec<Allocation>,
        ops: Ops,
    },
    /// Preemption could not free the window either (no overlapping
    /// low-priority task to evict, or only non-preemptable high-priority
    /// work overlaps). Any low-priority tasks that *were* evicted before
    /// the attempt gave up still re-enter low-priority scheduling.
    Rejected { victims: Vec<Allocation>, ops: Ops },
}

/// Outcome of a low-priority batch scheduling request (legacy shape). The
/// paper treats the request atomically: if fewer windows are found than
/// tasks, the whole request fails.
#[derive(Debug, Clone, PartialEq)]
pub enum LpOutcome {
    Allocated { allocs: Vec<Allocation>, ops: Ops },
    Rejected { ops: Ops },
}

impl From<HpOutcome> for Decision {
    fn from(o: HpOutcome) -> Self {
        match o {
            HpOutcome::Allocated { alloc, ops } => Decision {
                outcome: Outcome::HpAllocated { alloc, victims: Vec::new() },
                ops,
                variant: None,
            },
            HpOutcome::Preempted { alloc, victims, ops } => {
                Decision { outcome: Outcome::HpAllocated { alloc, victims }, ops, variant: None }
            }
            HpOutcome::Rejected { victims, ops } => {
                Decision { outcome: Outcome::HpRejected { victims }, ops, variant: None }
            }
        }
    }
}

impl From<LpOutcome> for Decision {
    fn from(o: LpOutcome) -> Self {
        match o {
            LpOutcome::Allocated { allocs, ops } => {
                Decision { outcome: Outcome::LpAllocated { allocs }, ops, variant: None }
            }
            LpOutcome::Rejected { ops } => {
                Decision { outcome: Outcome::LpRejected, ops, variant: None }
            }
        }
    }
}

/// The shared degradation policy all three schedulers route low-priority
/// placement through (Fresa & Champati's model-selection idea mounted on
/// the paper's schedulers): try the full-accuracy rung; only when the
/// scheduler's own state deems it infeasible, step down the ladder to a
/// cheaper variant before rejecting. The *policy* is shared, but the
/// infeasibility verdict is each scheduler's own — RAS decides against
/// its conservative availability windows and discretised link, WPS
/// against exact state — so the two abstractions disagree about when
/// degradation is necessary, which is the accuracy-vs-performance
/// trade-off of the paper's title made literal.
///
/// Ops from failed rungs accumulate into the final decision: degradation
/// is not free, and the controller's virtual latency model charges every
/// attempted rung. With an empty or one-rung `ladder` the single attempt
/// is returned unchanged (`variant: None`) — bit-identical decisions,
/// ops, and internal RNG evolution vs the pre-ladder API.
///
/// Rung 0 is always attempted with the tasks exactly as given (their
/// current spec *is* rung 0 by construction); deeper rungs re-spec the
/// batch through [`Task::at_rung`].
pub fn place_degrading(
    now: SimTime,
    tasks: &[&Task],
    ladder: &[VariantRung],
    realloc: bool,
    mut attempt: impl FnMut(SimTime, &[&Task], bool) -> LpOutcome,
) -> Decision {
    if ladder.len() <= 1 {
        return attempt(now, tasks, realloc).into();
    }
    let mut spent: Ops = 0;
    for (k, rung) in ladder.iter().enumerate() {
        let out = if k == 0 {
            attempt(now, tasks, realloc)
        } else {
            let degraded: Vec<Task> = tasks.iter().map(|t| t.at_rung(rung)).collect();
            let refs = task_refs(&degraded);
            attempt(now, &refs, realloc)
        };
        match out {
            LpOutcome::Allocated { allocs, ops } => {
                return Decision {
                    outcome: Outcome::LpAllocated { allocs },
                    ops: spent + ops,
                    variant: Some(k as u8),
                };
            }
            LpOutcome::Rejected { ops } => spent += ops,
        }
    }
    Decision { outcome: Outcome::LpRejected, ops: spent, variant: None }
}

/// The cloud tier as the schedulers plan over it: the pseudo device id,
/// the current WAN bandwidth estimate, and the fixed propagation delay.
/// `None` while the tier is disabled — every cloud code path below is
/// then never taken, keeping edge-only decisions bit-identical to the
/// pre-cloud API.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CloudPlan {
    /// Pseudo device id ([`crate::coordinator::task::cloud_device`]).
    pub device: DeviceId,
    /// Current WAN bandwidth estimate, bits/s (engine-fed EWMA).
    pub est_bps: f64,
    /// Fixed round-trip propagation delay, µs.
    pub rtt_us: SimTime,
}

impl CloudPlan {
    pub fn from_config(cfg: &crate::config::SystemConfig) -> Option<Self> {
        if cfg.cloud_wan_bps <= 0.0 {
            return None;
        }
        Some(Self {
            device: crate::coordinator::task::cloud_device(cfg),
            est_bps: cfg.cloud_wan_bps,
            rtt_us: crate::time::millis(cfg.cloud_rtt_ms.max(0.0)),
        })
    }

    /// Fold in a fresh WAN estimate ([`SchedEvent::CloudBandwidthUpdate`]).
    pub fn update(&mut self, bps: f64) {
        if bps > 0.0 {
            self.est_bps = bps;
        }
    }

    /// Try to place `tasks` on the cloud tier: every batch member must
    /// make its deadline through upload + propagation + its
    /// deterministic `cloud_us` service time, with the planned upload
    /// share splitting the WAN estimate across the batch (concurrent
    /// uploads contend — planning with the full link would be the kind
    /// of optimism the paper's abstractions are measured against). The
    /// executor itself is high-capacity: no windows, no victim search —
    /// which is why the attempt is so much cheaper (in ops) than an edge
    /// placement.
    ///
    /// Cloud allocations carry `cores: 0` and are **not** entered into
    /// [`WorkloadState`]: they occupy no edge resources, and the engine
    /// tracks their lifecycle against the WAN medium instead.
    pub fn attempt(&self, now: SimTime, tasks: &[&Task]) -> LpOutcome {
        let mut ops: Ops = 0;
        let mut allocs = Vec::with_capacity(tasks.len());
        let share = self.est_bps / tasks.len().max(1) as f64;
        for t in tasks {
            ops += crate::coordinator::cost::CLOUD_CHECK_OPS;
            if t.cloud_us == 0 {
                return LpOutcome::Rejected { ops }; // class never runs there
            }
            let transfer_us = if t.input_bytes > 0 && share > 0.0 {
                (t.input_bytes as f64 * 8.0 / share * 1e6).ceil() as SimTime
            } else {
                0
            };
            let upload_end = now + transfer_us;
            let end = upload_end + self.rtt_us + t.cloud_us;
            if end > t.deadline {
                return LpOutcome::Rejected { ops }; // batch is atomic
            }
            allocs.push(Allocation {
                task: t.id,
                frame: t.frame,
                device: self.device,
                config: TaskConfig::LowFourCore,
                cores: 0,
                start: upload_end + self.rtt_us / 2,
                end,
                deadline: t.deadline,
                offloaded: true,
                comm: Some((now, upload_end)),
            });
        }
        LpOutcome::Allocated { allocs, ops }
    }
}

/// [`place_degrading`] with the cloud tier interleaved: at every rung,
/// the edge attempt runs first (the scheduler's own verdict, exactly as
/// in `place_degrading`), and only when the edge rejects is the cloud
/// tried *at the same rung* — full accuracy on the cloud beats a
/// degraded edge placement, so the ladder steps down only when neither
/// tier can hold the current rung. With `cloud: None` this is
/// bit-identical to [`place_degrading`] (same attempts, same ops, same
/// variant), which is what keeps edge-only runs on the golden rows.
pub fn place_degrading_tiered(
    now: SimTime,
    tasks: &[&Task],
    ladder: &[VariantRung],
    realloc: bool,
    cloud: Option<&CloudPlan>,
    mut attempt: impl FnMut(SimTime, &[&Task], bool) -> LpOutcome,
) -> Decision {
    let Some(cloud) = cloud else {
        return place_degrading(now, tasks, ladder, realloc, attempt);
    };
    if ladder.len() <= 1 {
        // Short-ladder fast path mirrors `place_degrading`: one untouched
        // edge attempt (variant stays None), cloud as the fallback.
        return match attempt(now, tasks, realloc) {
            LpOutcome::Rejected { ops } => {
                let mut d: Decision = cloud.attempt(now, tasks).into();
                d.ops += ops;
                d
            }
            placed => placed.into(),
        };
    }
    let mut spent: Ops = 0;
    for (k, rung) in ladder.iter().enumerate() {
        let degraded: Vec<Task>;
        let refs: Vec<&Task>;
        let batch: &[&Task] = if k == 0 {
            tasks
        } else {
            degraded = tasks.iter().map(|t| t.at_rung(rung)).collect();
            refs = task_refs(&degraded);
            &refs
        };
        match attempt(now, batch, realloc) {
            LpOutcome::Allocated { allocs, ops } => {
                return Decision {
                    outcome: Outcome::LpAllocated { allocs },
                    ops: spent + ops,
                    variant: Some(k as u8),
                };
            }
            LpOutcome::Rejected { ops } => spent += ops,
        }
        match cloud.attempt(now, batch) {
            LpOutcome::Allocated { allocs, ops } => {
                return Decision {
                    outcome: Outcome::LpAllocated { allocs },
                    ops: spent + ops,
                    variant: Some(k as u8),
                };
            }
            LpOutcome::Rejected { ops } => spent += ops,
        }
    }
    Decision { outcome: Outcome::LpRejected, ops: spent, variant: None }
}

/// The scheduling interface the discrete-event engine drives.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Single typed entry point: every scheduler-visible occurrence flows
    /// through here. See the module docs for the event/decision contract.
    fn on_event(&mut self, now: SimTime, ev: SchedEvent<'_>) -> Decision;

    /// Current bandwidth estimate used for transfer planning (bits/s).
    fn bandwidth_estimate(&self) -> f64;

    /// Access the committed allocation table (engine reads placements).
    fn state(&self) -> &WorkloadState;

    /// Diagnostic counters: low-priority placement-attempt failure
    /// reasons `[no viable config, link capacity, insufficient windows,
    /// commit]`. These count failed *attempts*, not rejected batches: a
    /// two-core failure that falls back to four cores successfully still
    /// counts, and on a multi-rung ladder every failed rung probe counts
    /// — so deeper ladders legitimately record more failures even as
    /// batch rejections fall.
    fn reject_diag(&self) -> [u64; 4] {
        [0; 4]
    }

    /// Enable (or disable) decision explainability: while on, placement
    /// dispatches record [`crate::obs::DecisionRecord`]s (per-candidate
    /// scores, rejection reasons, the chosen rung) for the engine's
    /// flight recorder to drain. Off by default and a no-op for
    /// schedulers that don't explain themselves — the zero-cost-when-off
    /// contract is theirs to keep (no allocation, no extra work while
    /// disabled).
    fn set_explain(&mut self, _on: bool) {}

    /// Drain the decision records accumulated since the last call, in
    /// decision order. Returns an empty vec (no allocation) while
    /// explainability is off.
    fn drain_decisions(&mut self) -> Vec<crate::obs::DecisionRecord> {
        Vec::new()
    }
}

/// Most excluded-device candidates a single [`crate::obs::DecisionRecord`]
/// enumerates: explainability is a debug surface and must stay cheap and
/// bounded on 100k-device fleets — the cap is deterministic (always the
/// lowest device ids), so recordings remain bit-identical across runs.
pub const EXPLAIN_CANDIDATE_CAP: usize = 64;

/// Shared explainability buffer the schedulers embed: a gate plus a
/// record list. All pushes route through [`ExplainLog::push`], which is
/// a single branch while disabled — the schedulers only *construct* a
/// record (candidate vectors and all) after checking [`ExplainLog::on`],
/// so the off path allocates nothing.
#[derive(Debug, Clone, Default)]
pub struct ExplainLog {
    on: bool,
    records: Vec<crate::obs::DecisionRecord>,
}

impl ExplainLog {
    pub fn set(&mut self, on: bool) {
        self.on = on;
        if !on {
            self.records = Vec::new();
        }
    }

    /// Whether records should be constructed at all.
    pub fn on(&self) -> bool {
        self.on
    }

    pub fn push(&mut self, rec: crate::obs::DecisionRecord) {
        if self.on {
            self.records.push(rec);
        }
    }

    /// Take everything recorded so far (empty + allocation-free when off
    /// or drained).
    pub fn drain(&mut self) -> Vec<crate::obs::DecisionRecord> {
        std::mem::take(&mut self.records)
    }
}

/// Callback-style compatibility shim over the typed event API: the
/// pre-redesign `Scheduler` surface, implemented for every
/// [`Scheduler`] (including trait objects) by routing through
/// [`Scheduler::on_event`]. Existing drivers and tests keep working; new
/// code should dispatch events directly.
pub trait SchedulerCompat {
    fn schedule_high(&mut self, now: SimTime, task: &Task) -> HpOutcome;
    fn schedule_low(&mut self, now: SimTime, tasks: &[Task], realloc: bool) -> LpOutcome;
    fn on_complete(&mut self, now: SimTime, task: TaskId);
    fn on_violation(&mut self, now: SimTime, task: TaskId);
    fn on_bandwidth_update(&mut self, now: SimTime, bps: f64) -> Ops;
}

impl<S: Scheduler + ?Sized> SchedulerCompat for S {
    fn schedule_high(&mut self, now: SimTime, task: &Task) -> HpOutcome {
        self.on_event(now, SchedEvent::HighPriority { task }).into_hp()
    }

    fn schedule_low(&mut self, now: SimTime, tasks: &[Task], realloc: bool) -> LpOutcome {
        let refs = task_refs(tasks);
        self.on_event(now, SchedEvent::LowPriorityBatch { tasks: &refs, realloc, ladder: &[] })
            .into_lp()
    }

    fn on_complete(&mut self, now: SimTime, task: TaskId) {
        let _ = self.on_event(now, SchedEvent::Complete { task });
    }

    fn on_violation(&mut self, now: SimTime, task: TaskId) {
        let _ = self.on_event(now, SchedEvent::Violation { task });
    }

    fn on_bandwidth_update(&mut self, now: SimTime, bps: f64) -> Ops {
        self.on_event(now, SchedEvent::BandwidthUpdate { bps }).ops
    }
}

/// Exact allocation bookkeeping shared by both schedulers: WPS searches
/// this directly; RAS keeps it for preemption victim selection and
/// availability-list reconstruction.
///
/// Removal is O(1): `slot` tracks each task's position in its device's
/// `by_device` entry and is maintained across `swap_remove`. The previous
/// layout paid an O(n) position scan per removal, which the preemption /
/// violation / churn paths hit once per live task (see
/// `rust/benches/micro_structures.rs` for the measured difference).
#[derive(Debug, Clone, Default)]
pub struct WorkloadState {
    pub allocations: HashMap<TaskId, Allocation>,
    /// Task ids allocated to each device.
    pub by_device: Vec<Vec<TaskId>>,
    /// task → index into `by_device[device]` (position-indexed removal).
    slot: HashMap<TaskId, usize>,
}

impl WorkloadState {
    pub fn new(n_devices: usize) -> Self {
        Self {
            allocations: HashMap::new(),
            by_device: vec![Vec::new(); n_devices],
            slot: HashMap::new(),
        }
    }

    /// Grow the per-device index to cover `device` (fleet churn).
    pub fn ensure_device(&mut self, device: DeviceId) {
        if self.by_device.len() <= device {
            self.by_device.resize_with(device + 1, Vec::new);
        }
    }

    /// Number of device slots tracked (left devices keep their slot).
    pub fn device_count(&self) -> usize {
        self.by_device.len()
    }

    pub fn insert(&mut self, a: Allocation) {
        self.ensure_device(a.device);
        debug_assert!(!self.allocations.contains_key(&a.task), "duplicate insert");
        self.slot.insert(a.task, self.by_device[a.device].len());
        self.by_device[a.device].push(a.task);
        self.allocations.insert(a.task, a);
    }

    pub fn remove(&mut self, task: TaskId) -> Option<Allocation> {
        let a = self.allocations.remove(&task)?;
        let pos = self.slot.remove(&task).expect("slot tracked for live task");
        let dev = &mut self.by_device[a.device];
        dev.swap_remove(pos);
        if let Some(&moved) = dev.get(pos) {
            self.slot.insert(moved, pos);
        }
        Some(a)
    }

    /// Remove and return every allocation on `device`, in the same order
    /// [`WorkloadState::device_allocs`] would have yielded them (the
    /// eviction paths depend on that order for determinism). Moves the
    /// allocations out instead of cloning them first.
    pub fn evict_device(&mut self, device: DeviceId) -> Vec<Allocation> {
        let ids: Vec<TaskId> = match self.by_device.get(device) {
            Some(v) if !v.is_empty() => v.clone(),
            _ => return Vec::new(),
        };
        ids.into_iter().filter_map(|t| self.remove(t)).collect()
    }

    pub fn get(&self, task: TaskId) -> Option<&Allocation> {
        self.allocations.get(&task)
    }

    /// Allocations on `device`, in arbitrary order.
    pub fn device_allocs(&self, device: DeviceId) -> impl Iterator<Item = &Allocation> {
        self.by_device
            .get(device)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
            .iter()
            .filter_map(|t| self.allocations.get(t))
    }

    /// Exact peak core usage on `device` over `[t1, t2)` — the ground
    /// truth both schedulers must respect. Used by tests to verify no
    /// scheduler ever over-subscribes a device, and by WPS as its search
    /// primitive. Returns (peak_cores, overlap_checks_performed).
    pub fn peak_usage(&self, device: DeviceId, t1: SimTime, t2: SimTime) -> (u32, Ops) {
        // Hot path for the WPS baseline (called per candidate-start per
        // device per request): keep the event list on the stack for the
        // common case (≤16 overlapping allocations) and fall back to the
        // heap only beyond that. See EXPERIMENTS.md §Perf.
        const INLINE: usize = 32;
        let mut inline: [(SimTime, i64); INLINE] = [(0, 0); INLINE];
        let mut n = 0usize;
        let mut spill: Vec<(SimTime, i64)> = Vec::new();
        let mut ops: Ops = 0;
        let push = |ev: (SimTime, i64), n: &mut usize, spill: &mut Vec<(SimTime, i64)>, inline: &mut [(SimTime, i64); INLINE]| {
            if *n < INLINE {
                inline[*n] = ev;
                *n += 1;
            } else {
                spill.push(ev);
            }
        };
        for a in self.device_allocs(device) {
            ops += 1;
            if a.overlaps(t1, t2) {
                push((a.start.max(t1), a.cores as i64), &mut n, &mut spill, &mut inline);
                push((a.end.min(t2), -(a.cores as i64)), &mut n, &mut spill, &mut inline);
            }
        }
        let events: &mut [(SimTime, i64)] = if spill.is_empty() {
            &mut inline[..n]
        } else {
            spill.extend_from_slice(&inline[..n]);
            &mut spill[..]
        };
        events.sort_unstable();
        let mut cur = 0i64;
        let mut peak = 0i64;
        for &(_, d) in events.iter() {
            cur += d;
            peak = peak.max(cur);
        }
        (peak as u32, ops + 1)
    }

    pub fn len(&self) -> usize {
        self.allocations.len()
    }

    pub fn is_empty(&self) -> bool {
        self.allocations.is_empty()
    }
}

/// Selects the preemption victim per the paper: among low-priority
/// allocations on `device` overlapping `[t1, t2)`, the one with the
/// *farthest* deadline. Returns (victim_task, ops).
pub fn select_victim(state: &WorkloadState, device: DeviceId, t1: SimTime, t2: SimTime) -> (Option<TaskId>, Ops) {
    let mut ops = 0;
    let mut best: Option<(TaskId, SimTime)> = None;
    for a in state.device_allocs(device) {
        ops += 1;
        if a.config.priority() == crate::coordinator::task::Priority::Low && a.overlaps(t1, t2) {
            match best {
                Some((_, d)) if d >= a.deadline => {}
                _ => best = Some((a.task, a.deadline)),
            }
        }
    }
    (best.map(|(t, _)| t), ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::TaskConfig;

    fn alloc(task: TaskId, device: DeviceId, cores: u32, start: SimTime, end: SimTime, deadline: SimTime, config: TaskConfig) -> Allocation {
        Allocation {
            task,
            frame: 0,
            device,
            config,
            cores,
            start,
            end,
            deadline,
            offloaded: false,
            comm: None,
        }
    }

    #[test]
    fn workload_insert_remove() {
        let mut w = WorkloadState::new(2);
        w.insert(alloc(1, 0, 2, 0, 100, 100, TaskConfig::LowTwoCore));
        w.insert(alloc(2, 1, 4, 0, 100, 100, TaskConfig::LowFourCore));
        assert_eq!(w.len(), 2);
        assert_eq!(w.device_allocs(0).count(), 1);
        let a = w.remove(1).unwrap();
        assert_eq!(a.task, 1);
        assert!(w.remove(1).is_none());
        assert_eq!(w.device_allocs(0).count(), 0);
    }

    #[test]
    fn slot_index_survives_swap_remove_churn() {
        // Removal in arbitrary order must keep positions consistent: the
        // swap_remove moves the last task into the removed slot, and the
        // index must follow it.
        let mut w = WorkloadState::new(1);
        for t in 0..20u64 {
            w.insert(alloc(t, 0, 2, t * 10, t * 10 + 100, 1000, TaskConfig::LowTwoCore));
        }
        // Remove from the middle, the front, and the back, interleaved.
        for &t in &[7u64, 0, 19, 3, 18, 11] {
            assert_eq!(w.remove(t).unwrap().task, t);
        }
        let mut left: Vec<TaskId> = w.device_allocs(0).map(|a| a.task).collect();
        left.sort_unstable();
        let mut expect: Vec<TaskId> = (0..20).filter(|t| ![7, 0, 19, 3, 18, 11].contains(t)).collect();
        expect.sort_unstable();
        assert_eq!(left, expect);
        // Remove everything that remains, in insertion order.
        for t in expect {
            assert_eq!(w.remove(t).unwrap().task, t);
        }
        assert!(w.is_empty());
        assert!(w.by_device[0].is_empty());
    }

    #[test]
    fn evict_device_moves_allocations_out_in_index_order() {
        let mut w = WorkloadState::new(2);
        for t in 0..6u64 {
            w.insert(alloc(t, (t % 2) as usize, 2, 0, 100, 100, TaskConfig::LowTwoCore));
        }
        let order_before: Vec<TaskId> = w.device_allocs(0).map(|a| a.task).collect();
        let evicted = w.evict_device(0);
        assert_eq!(evicted.iter().map(|a| a.task).collect::<Vec<_>>(), order_before);
        assert_eq!(w.device_allocs(0).count(), 0);
        assert_eq!(w.device_allocs(1).count(), 3, "other devices untouched");
        assert!(w.evict_device(0).is_empty());
        assert!(w.evict_device(7).is_empty(), "unknown device is a no-op");
    }

    #[test]
    fn ensure_device_grows_fleet() {
        let mut w = WorkloadState::new(2);
        w.insert(alloc(1, 5, 2, 0, 100, 100, TaskConfig::LowTwoCore));
        assert_eq!(w.device_count(), 6);
        assert_eq!(w.device_allocs(5).count(), 1);
        assert_eq!(w.device_allocs(9).count(), 0); // out of range: empty
    }

    #[test]
    fn peak_usage_stacks_concurrent_tasks() {
        let mut w = WorkloadState::new(1);
        w.insert(alloc(1, 0, 2, 0, 100, 100, TaskConfig::LowTwoCore));
        w.insert(alloc(2, 0, 2, 50, 150, 150, TaskConfig::LowTwoCore));
        let (peak, _) = w.peak_usage(0, 0, 200);
        assert_eq!(peak, 4);
        let (peak, _) = w.peak_usage(0, 0, 50);
        assert_eq!(peak, 2);
        let (peak, _) = w.peak_usage(0, 100, 150);
        assert_eq!(peak, 2);
        let (peak, _) = w.peak_usage(0, 150, 300);
        assert_eq!(peak, 0);
    }

    #[test]
    fn victim_is_farthest_deadline_low_priority_overlap() {
        let mut w = WorkloadState::new(1);
        w.insert(alloc(1, 0, 2, 0, 100, 500, TaskConfig::LowTwoCore));
        w.insert(alloc(2, 0, 2, 0, 100, 900, TaskConfig::LowTwoCore));
        w.insert(alloc(3, 0, 1, 0, 100, 2000, TaskConfig::HighPriority)); // HP: never a victim
        w.insert(alloc(4, 0, 2, 200, 300, 9999, TaskConfig::LowTwoCore)); // no overlap
        let (v, _) = select_victim(&w, 0, 0, 100);
        assert_eq!(v, Some(2));
        let (v, _) = select_victim(&w, 0, 150, 180);
        assert_eq!(v, None);
    }

    #[test]
    fn decision_roundtrips_legacy_outcomes() {
        let a = alloc(1, 0, 4, 0, 100, 200, TaskConfig::HighPriority);
        let v = alloc(2, 0, 2, 0, 100, 900, TaskConfig::LowTwoCore);

        let hp = HpOutcome::Allocated { alloc: a, ops: 7 };
        assert_eq!(Decision::from(hp.clone()).into_hp(), hp);

        let hp = HpOutcome::Preempted { alloc: a, victims: vec![v], ops: 9 };
        assert_eq!(Decision::from(hp.clone()).into_hp(), hp);

        let hp = HpOutcome::Rejected { victims: vec![v], ops: 3 };
        assert_eq!(Decision::from(hp.clone()).into_hp(), hp);

        let lp = LpOutcome::Allocated { allocs: vec![v], ops: 11 };
        assert_eq!(Decision::from(lp.clone()).into_lp(), lp);

        let lp = LpOutcome::Rejected { ops: 2 };
        assert_eq!(Decision::from(lp.clone()).into_lp(), lp);
    }

    #[test]
    #[should_panic(expected = "not a high-priority outcome")]
    fn hp_unwrap_rejects_lp_decision() {
        let _ = Decision::from(LpOutcome::Rejected { ops: 1 }).into_hp();
    }

    fn rung(acc: f64, bytes: u64, p2: crate::time::SimDuration) -> crate::coordinator::task::VariantRung {
        crate::coordinator::task::VariantRung { accuracy: acc, input_bytes: bytes, proc_us: [p2, p2 / 2] }
    }

    fn lp_task(id: TaskId) -> Task {
        let cfg = crate::config::SystemConfig::default();
        Task::low(id, 1, 0, 0, 10_000_000, &cfg)
    }

    #[test]
    fn degrading_with_short_ladder_is_a_single_untouched_attempt() {
        // Empty and one-rung ladders must not even inspect the rung: one
        // attempt, tasks passed through as-is, variant None.
        for ladder in [vec![], vec![rung(0.5, 1, 1)]] {
            let t = lp_task(1);
            let mut calls = 0;
            let d = place_degrading(0, &[&t], &ladder, false, |_, ts, _| {
                calls += 1;
                assert_eq!(ts[0].input_bytes, t.input_bytes, "tasks must pass through untouched");
                LpOutcome::Rejected { ops: 7 }
            });
            assert_eq!(calls, 1);
            assert_eq!(d, Decision { outcome: Outcome::LpRejected, ops: 7, variant: None });
        }
    }

    #[test]
    fn degrading_steps_down_and_accumulates_ops() {
        let t = lp_task(1);
        let ladder = [
            rung(0.95, t.input_bytes, t.proc_us[0]),
            rung(0.85, 400_000, 8_000_000),
            rung(0.70, 100_000, 2_000_000),
        ];
        let mut seen: Vec<(u64, crate::time::SimDuration)> = Vec::new();
        let d = place_degrading(0, &[&t], &ladder, false, |_, ts, _| {
            seen.push((ts[0].input_bytes, ts[0].proc_us[0]));
            if seen.len() < 3 {
                LpOutcome::Rejected { ops: 10 }
            } else {
                let a = alloc(1, 0, 2, 0, 100, 100, TaskConfig::LowTwoCore);
                LpOutcome::Allocated { allocs: vec![a], ops: 5 }
            }
        });
        // Rung 0 saw the task as-is; deeper rungs saw the degraded spec.
        assert_eq!(seen, vec![
            (t.input_bytes, t.proc_us[0]),
            (400_000, 8_000_000),
            (100_000, 2_000_000),
        ]);
        assert_eq!(d.variant, Some(2));
        assert_eq!(d.ops, 25, "failed rungs' ops must be charged");
        assert!(matches!(d.outcome, Outcome::LpAllocated { .. }));
    }

    #[test]
    fn degrading_rejects_only_after_the_whole_ladder() {
        let t = lp_task(1);
        let ladder = [rung(0.9, 1_000, 1_000), rung(0.8, 500, 500)];
        let mut calls = 0;
        let d = place_degrading(0, &[&t], &ladder, true, |_, _, realloc| {
            calls += 1;
            assert!(realloc, "realloc flag must pass through every attempt");
            LpOutcome::Rejected { ops: 3 }
        });
        assert_eq!(calls, 2);
        assert_eq!(d, Decision { outcome: Outcome::LpRejected, ops: 6, variant: None });
    }

    fn cloud_plan() -> CloudPlan {
        let cfg = crate::config::SystemConfig {
            cloud_wan_bps: 20e6,
            cloud_rtt_ms: 40.0,
            ..Default::default()
        };
        CloudPlan::from_config(&cfg).unwrap()
    }

    #[test]
    fn cloud_plan_gates_on_config_and_checks_deadlines() {
        assert!(CloudPlan::from_config(&crate::config::SystemConfig::default()).is_none());
        let plan = cloud_plan();
        // The conveyor LP task has ~18.8 s of slack: upload (~440 ms at
        // 20 Mb/s) + 40 ms RTT + ~1.45 s cloud service fits easily.
        let t = lp_task(1);
        match plan.attempt(0, &[&t]) {
            LpOutcome::Allocated { allocs, ops } => {
                assert_eq!(allocs.len(), 1);
                let a = &allocs[0];
                assert_eq!(a.device, plan.device);
                assert_eq!(a.cores, 0, "cloud placements hold no edge cores");
                assert!(a.offloaded);
                let (c0, c1) = a.comm.unwrap();
                assert_eq!(c0, 0);
                assert_eq!(a.end, c1 + plan.rtt_us + t.cloud_us);
                assert!(a.end <= t.deadline);
                assert_eq!(ops, crate::coordinator::cost::CLOUD_CHECK_OPS);
            }
            other => panic!("expected cloud allocation, got {other:?}"),
        }
        // No slack left → atomic rejection; cloud-less classes reject too.
        let mut tight = t;
        tight.deadline = 100_000;
        assert!(matches!(plan.attempt(0, &[&tight]), LpOutcome::Rejected { .. }));
        let mut never = t;
        never.cloud_us = 0;
        assert!(matches!(plan.attempt(0, &[&never]), LpOutcome::Rejected { .. }));
        // Batch uploads split the WAN share: a batch that fits solo can
        // miss together (atomic batch semantics).
        let slack = t.cloud_us + plan.rtt_us + 500_000; // solo upload ≈ 440 ms
        let mut batch_task = t;
        batch_task.deadline = slack;
        assert!(matches!(plan.attempt(0, &[&batch_task]), LpOutcome::Allocated { .. }));
        let twin = Task { id: 2, ..batch_task };
        assert!(matches!(
            plan.attempt(0, &[&batch_task, &twin]),
            LpOutcome::Rejected { .. },
        ));
    }

    #[test]
    fn tiered_without_cloud_is_plain_place_degrading() {
        let t = lp_task(1);
        let ladder = [rung(0.9, 1_000, 1_000), rung(0.8, 500, 500)];
        let tiered = place_degrading_tiered(0, &[&t], &ladder, false, None, |_, _, _| {
            LpOutcome::Rejected { ops: 3 }
        });
        let plain =
            place_degrading(0, &[&t], &ladder, false, |_, _, _| LpOutcome::Rejected { ops: 3 });
        assert_eq!(tiered, plain);
    }

    #[test]
    fn tiered_prefers_cloud_over_degradation() {
        // Edge always rejects; the cloud is feasible: the batch must land
        // on the cloud at rung 0 — NOT degrade first.
        let t = lp_task(1);
        let ladder = [
            rung(1.0, t.input_bytes, t.proc_us[0]),
            rung(0.8, 500, 500_000),
        ];
        let plan = cloud_plan();
        let d = place_degrading_tiered(0, &[&t], &ladder, false, Some(&plan), |_, _, _| {
            LpOutcome::Rejected { ops: 5 }
        });
        assert_eq!(d.variant, Some(0), "cloud holds the rung: no degradation");
        match &d.outcome {
            Outcome::LpAllocated { allocs } => assert_eq!(allocs[0].device, plan.device),
            other => panic!("expected cloud allocation, got {other:?}"),
        }
        assert_eq!(d.ops, 5 + crate::coordinator::cost::CLOUD_CHECK_OPS);
    }

    #[test]
    fn tiered_degrades_when_neither_tier_holds_the_rung() {
        // Edge always rejects; the cloud can only make the deadline once
        // the rung shrinks the upload: degradation fires, then cloud.
        let plan = cloud_plan();
        let mut t = lp_task(1);
        // Deadline leaves room for a 100 kB upload but not the 1.1 MB one.
        t.deadline = t.cloud_us + plan.rtt_us + 120_000;
        let ladder = [
            rung(1.0, t.input_bytes, t.proc_us[0]),
            rung(0.8, 100_000, 500_000),
        ];
        let d = place_degrading_tiered(0, &[&t], &ladder, false, Some(&plan), |_, _, _| {
            LpOutcome::Rejected { ops: 5 }
        });
        assert_eq!(d.variant, Some(1), "rung 1 lands on the cloud");
        match &d.outcome {
            Outcome::LpAllocated { allocs } => {
                assert_eq!(allocs[0].device, plan.device);
            }
            other => panic!("expected cloud allocation, got {other:?}"),
        }
        // Fully infeasible: rejected after edge+cloud at every rung.
        let mut hopeless = t;
        hopeless.deadline = 1_000;
        let d = place_degrading_tiered(0, &[&hopeless], &ladder, false, Some(&plan), |_, _, _| {
            LpOutcome::Rejected { ops: 5 }
        });
        assert_eq!(d.outcome, Outcome::LpRejected);
        assert_eq!(d.ops, 2 * 5 + 2 * crate::coordinator::cost::CLOUD_CHECK_OPS);
    }

    fn pressure_candidate(
        task: TaskId,
        cut_finish: SimTime,
        full_finish: SimTime,
        deadline: SimTime,
        battery_doomed: bool,
    ) -> PressureCandidate {
        PressureCandidate {
            task,
            device: 0,
            cut_stage: 1,
            n_stages: 3,
            cut_finish,
            full_finish,
            deadline,
            accuracy_loss: 0.27,
            battery_doomed,
        }
    }

    #[test]
    fn pressure_policy_cuts_only_rescuable_deadline_misses() {
        let cands = [
            // Full depth misses the deadline, the cut saves it: rescue.
            pressure_candidate(1, 900, 1_500, 1_000, false),
            // Full depth meets the deadline: left alone without escalation.
            pressure_candidate(2, 600, 900, 1_000, false),
            // Even the cut misses: no point truncating (take the credit).
            pressure_candidate(3, 1_100, 1_500, 1_000, false),
            // Deadline safe but the battery dies mid-run: energy rescue.
            pressure_candidate(4, 700, 950, 1_000, true),
        ];
        let d = decide_pressure(&cands, false);
        let Outcome::Truncate { cuts } = &d.outcome else {
            panic!("pressure must answer with Truncate, got {:?}", d.outcome)
        };
        assert_eq!(
            cuts.as_slice(),
            &[TruncateCut { index: 0, at_stage: 1 }, TruncateCut { index: 3, at_stage: 1 }]
        );
        assert_eq!(d.ops, 4 * crate::coordinator::cost::PRESSURE_EVAL_OPS);
        assert_eq!(d.variant, None);
    }

    #[test]
    fn pressure_escalation_also_cuts_safe_tasks() {
        let cands = [
            pressure_candidate(1, 600, 900, 1_000, false), // safe either way
            pressure_candidate(2, 1_100, 1_500, 1_000, false), // unsalvageable
        ];
        // Backlog escalation frees capacity: the safe task is cut too,
        // but a cut that cannot meet the deadline is still pointless.
        let d = decide_pressure(&cands, true);
        let Outcome::Truncate { cuts } = &d.outcome else { panic!() };
        assert_eq!(cuts.as_slice(), &[TruncateCut { index: 0, at_stage: 1 }]);
        // Without escalation the same survey cuts nothing.
        let d = decide_pressure(&cands, false);
        assert_eq!(d.outcome, Outcome::Truncate { cuts: Vec::new() });
    }

    #[test]
    fn explain_log_gates_and_drains() {
        let rec = || crate::obs::DecisionRecord {
            scheduler: "test",
            task: 1,
            batch: 1,
            high_priority: true,
            candidates: Vec::new(),
            chosen: None,
            rung: None,
            cloud: false,
        };
        let mut log = ExplainLog::default();
        assert!(!log.on(), "explainability must default OFF");
        log.push(rec());
        assert!(log.drain().is_empty(), "pushes while off are dropped");
        log.set(true);
        log.push(rec());
        log.push(rec());
        assert_eq!(log.drain().len(), 2);
        assert!(log.drain().is_empty(), "drain takes everything");
        log.push(rec());
        log.set(false);
        assert!(log.drain().is_empty(), "disabling clears pending records");
    }
}
