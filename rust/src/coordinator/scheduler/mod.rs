//! Scheduling algorithms (Section IV-B).
//!
//! Two schedulers implement the common [`Scheduler`] trait:
//!
//! * [`ras_sched::RasScheduler`] — the paper's contribution: containment
//!   queries on resource-availability lists + the discretised network link
//!   + dynamic bandwidth estimation.
//! * [`wps::WpsScheduler`] — the authors' prior "weighted pre-emption
//!   scheduler" baseline: exact per-device task lists searched with
//!   overlapping-range scans. More accurate placement, more work per
//!   decision.
//!
//! Every scheduling entry point returns the decision *and* an operation
//! count (`ops`): the number of elementary data-structure steps the call
//! performed (windows visited, overlap checks, write/bisect operations).
//! The DES engine converts ops to virtual scheduling latency through the
//! configured cost model, so the accuracy-vs-performance feedback loop the
//! paper studies — slow scheduling delays task starts and burns deadline
//! slack — is driven by the real algorithmic costs of the two
//! implementations. Criterion benches additionally measure raw wall-clock
//! for the §Perf pass.

pub mod multi;
pub mod ras_sched;
pub mod wps;

use std::collections::HashMap;


use crate::coordinator::task::{Allocation, DeviceId, Task, TaskId};
use crate::time::SimTime;

/// Operation count for one scheduling call.
pub type Ops = u64;

/// Outcome of a high-priority scheduling request.
#[derive(Debug, Clone)]
pub enum HpOutcome {
    /// Task fits locally without disturbing anyone.
    Allocated { alloc: Allocation, ops: Ops },
    /// No window on the source device: the scheduler performed preemption
    /// (Section IV-B3). `victims` were evicted and should re-enter
    /// low-priority scheduling once the preemption completes.
    Preempted {
        alloc: Allocation,
        victims: Vec<Allocation>,
        ops: Ops,
    },
    /// Preemption could not free the window either (no overlapping
    /// low-priority task to evict, or only non-preemptable high-priority
    /// work overlaps). Any low-priority tasks that *were* evicted before
    /// the attempt gave up still re-enter low-priority scheduling.
    Rejected { victims: Vec<Allocation>, ops: Ops },
}

/// Outcome of a low-priority batch scheduling request. The paper treats
/// the request atomically: if fewer windows are found than tasks, the
/// whole request fails.
#[derive(Debug, Clone)]
pub enum LpOutcome {
    Allocated { allocs: Vec<Allocation>, ops: Ops },
    Rejected { ops: Ops },
}

/// The scheduling interface the discrete-event engine drives.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Schedule a high-priority task (always local to its source device).
    fn schedule_high(&mut self, now: SimTime, task: &Task) -> HpOutcome;

    /// Schedule a batch of low-priority DNN tasks (1–4 per request).
    /// `realloc` marks re-entry of preempted tasks (tracked separately in
    /// the paper's Fig. 4/5).
    fn schedule_low(&mut self, now: SimTime, tasks: &[Task], realloc: bool) -> LpOutcome;

    /// Task finished (free its resources from the scheduler's state).
    fn on_complete(&mut self, now: SimTime, task: TaskId);

    /// Task missed its deadline and was abandoned.
    fn on_violation(&mut self, now: SimTime, task: TaskId);

    /// A bandwidth probe round produced a new estimate (bits/s). Returns
    /// the ops spent updating internal structures (the RAS link rebuild is
    /// *not* free — Fig. 6/7 hinge on this).
    fn on_bandwidth_update(&mut self, now: SimTime, bps: f64) -> Ops;

    /// Current bandwidth estimate used for transfer planning (bits/s).
    fn bandwidth_estimate(&self) -> f64;

    /// Access the committed allocation table (engine reads placements).
    fn state(&self) -> &WorkloadState;

    /// Diagnostic counters: low-priority rejection reasons
    /// `[no viable config, link capacity, insufficient windows, commit]`.
    fn reject_diag(&self) -> [u64; 4] {
        [0; 4]
    }
}

/// Exact allocation bookkeeping shared by both schedulers: WPS searches
/// this directly; RAS keeps it for preemption victim selection and
/// availability-list reconstruction.
#[derive(Debug, Clone, Default)]
pub struct WorkloadState {
    pub allocations: HashMap<TaskId, Allocation>,
    /// Task ids allocated to each device.
    pub by_device: Vec<Vec<TaskId>>,
}

impl WorkloadState {
    pub fn new(n_devices: usize) -> Self {
        Self {
            allocations: HashMap::new(),
            by_device: vec![Vec::new(); n_devices],
        }
    }

    pub fn insert(&mut self, a: Allocation) {
        self.by_device[a.device].push(a.task);
        self.allocations.insert(a.task, a);
    }

    pub fn remove(&mut self, task: TaskId) -> Option<Allocation> {
        let a = self.allocations.remove(&task)?;
        if let Some(pos) = self.by_device[a.device].iter().position(|&t| t == task) {
            self.by_device[a.device].swap_remove(pos);
        }
        Some(a)
    }

    pub fn get(&self, task: TaskId) -> Option<&Allocation> {
        self.allocations.get(&task)
    }

    /// Allocations on `device`, in arbitrary order.
    pub fn device_allocs(&self, device: DeviceId) -> impl Iterator<Item = &Allocation> {
        self.by_device[device].iter().filter_map(|t| self.allocations.get(t))
    }

    /// Exact peak core usage on `device` over `[t1, t2)` — the ground
    /// truth both schedulers must respect. Used by tests to verify no
    /// scheduler ever over-subscribes a device, and by WPS as its search
    /// primitive. Returns (peak_cores, overlap_checks_performed).
    pub fn peak_usage(&self, device: DeviceId, t1: SimTime, t2: SimTime) -> (u32, Ops) {
        // Hot path for the WPS baseline (called per candidate-start per
        // device per request): keep the event list on the stack for the
        // common case (≤16 overlapping allocations) and fall back to the
        // heap only beyond that. See EXPERIMENTS.md §Perf.
        const INLINE: usize = 32;
        let mut inline: [(SimTime, i64); INLINE] = [(0, 0); INLINE];
        let mut n = 0usize;
        let mut spill: Vec<(SimTime, i64)> = Vec::new();
        let mut ops: Ops = 0;
        let push = |ev: (SimTime, i64), n: &mut usize, spill: &mut Vec<(SimTime, i64)>, inline: &mut [(SimTime, i64); INLINE]| {
            if *n < INLINE {
                inline[*n] = ev;
                *n += 1;
            } else {
                spill.push(ev);
            }
        };
        for a in self.device_allocs(device) {
            ops += 1;
            if a.overlaps(t1, t2) {
                push((a.start.max(t1), a.cores as i64), &mut n, &mut spill, &mut inline);
                push((a.end.min(t2), -(a.cores as i64)), &mut n, &mut spill, &mut inline);
            }
        }
        let events: &mut [(SimTime, i64)] = if spill.is_empty() {
            &mut inline[..n]
        } else {
            spill.extend_from_slice(&inline[..n]);
            &mut spill[..]
        };
        events.sort_unstable();
        let mut cur = 0i64;
        let mut peak = 0i64;
        for &(_, d) in events.iter() {
            cur += d;
            peak = peak.max(cur);
        }
        (peak as u32, ops + 1)
    }

    pub fn len(&self) -> usize {
        self.allocations.len()
    }

    pub fn is_empty(&self) -> bool {
        self.allocations.is_empty()
    }
}

/// Selects the preemption victim per the paper: among low-priority
/// allocations on `device` overlapping `[t1, t2)`, the one with the
/// *farthest* deadline. Returns (victim_task, ops).
pub fn select_victim(state: &WorkloadState, device: DeviceId, t1: SimTime, t2: SimTime) -> (Option<TaskId>, Ops) {
    let mut ops = 0;
    let mut best: Option<(TaskId, SimTime)> = None;
    for a in state.device_allocs(device) {
        ops += 1;
        if a.config.priority() == crate::coordinator::task::Priority::Low && a.overlaps(t1, t2) {
            match best {
                Some((_, d)) if d >= a.deadline => {}
                _ => best = Some((a.task, a.deadline)),
            }
        }
    }
    (best.map(|(t, _)| t), ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::TaskConfig;

    fn alloc(task: TaskId, device: DeviceId, cores: u32, start: SimTime, end: SimTime, deadline: SimTime, config: TaskConfig) -> Allocation {
        Allocation {
            task,
            frame: 0,
            device,
            config,
            cores,
            start,
            end,
            deadline,
            offloaded: false,
            comm: None,
        }
    }

    #[test]
    fn workload_insert_remove() {
        let mut w = WorkloadState::new(2);
        w.insert(alloc(1, 0, 2, 0, 100, 100, TaskConfig::LowTwoCore));
        w.insert(alloc(2, 1, 4, 0, 100, 100, TaskConfig::LowFourCore));
        assert_eq!(w.len(), 2);
        assert_eq!(w.device_allocs(0).count(), 1);
        let a = w.remove(1).unwrap();
        assert_eq!(a.task, 1);
        assert!(w.remove(1).is_none());
        assert_eq!(w.device_allocs(0).count(), 0);
    }

    #[test]
    fn peak_usage_stacks_concurrent_tasks() {
        let mut w = WorkloadState::new(1);
        w.insert(alloc(1, 0, 2, 0, 100, 100, TaskConfig::LowTwoCore));
        w.insert(alloc(2, 0, 2, 50, 150, 150, TaskConfig::LowTwoCore));
        let (peak, _) = w.peak_usage(0, 0, 200);
        assert_eq!(peak, 4);
        let (peak, _) = w.peak_usage(0, 0, 50);
        assert_eq!(peak, 2);
        let (peak, _) = w.peak_usage(0, 100, 150);
        assert_eq!(peak, 2);
        let (peak, _) = w.peak_usage(0, 150, 300);
        assert_eq!(peak, 0);
    }

    #[test]
    fn victim_is_farthest_deadline_low_priority_overlap() {
        let mut w = WorkloadState::new(1);
        w.insert(alloc(1, 0, 2, 0, 100, 500, TaskConfig::LowTwoCore));
        w.insert(alloc(2, 0, 2, 0, 100, 900, TaskConfig::LowTwoCore));
        w.insert(alloc(3, 0, 1, 0, 100, 2000, TaskConfig::HighPriority)); // HP: never a victim
        w.insert(alloc(4, 0, 2, 200, 300, 9999, TaskConfig::LowTwoCore)); // no overlap
        let (v, _) = select_victim(&w, 0, 0, 100);
        assert_eq!(v, Some(2));
        let (v, _) = select_victim(&w, 0, 150, 180);
        assert_eq!(v, None);
    }
}
