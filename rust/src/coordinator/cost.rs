//! Scheduler latency accounting.
//!
//! The paper's central claim is that scheduler *processing time adds to
//! task latency*, and that the cheap abstraction wins under load because of
//! it. To keep that feedback loop honest in simulation, the DES engine
//! measures the real wall-clock time of every scheduling call on this host,
//! scales it through [`CostModel`], and charges it to virtual time before
//! the decision takes effect — so the exhaustive WPS search really does
//! delay task starts relative to the RAS containment query.

use std::time::Instant;


use crate::time::SimDuration;

/// Elementary operations charged per task for a cloud-tier feasibility
/// check ([`crate::coordinator::scheduler::CloudPlan::attempt`]): one
/// transfer-time computation against the WAN estimate, one deadline
/// comparison, and the allocation write — far cheaper than an edge
/// placement's window search, which is the point: the cloud tier adds
/// capacity without adding controller latency.
pub const CLOUD_CHECK_OPS: crate::coordinator::scheduler::Ops = 4;

/// Elementary operations charged per candidate for the energy-aware
/// score term (`EnergyModel::placement_joules` + the battery lookup) on
/// top of the WPS base score.
pub const ENERGY_SCORE_OPS: crate::coordinator::scheduler::Ops = 2;

/// Elementary operations charged per running-task candidate evaluated by
/// a deadline-pressure truncation decision
/// ([`crate::coordinator::scheduler::decide_pressure`]): two predicted-
/// finish comparisons against the deadline — far cheaper than any
/// placement search, which is what makes the anytime controller viable
/// at a short check interval.
pub const PRESSURE_EVAL_OPS: crate::coordinator::scheduler::Ops = 2;

/// Converts measured wall-clock scheduler time into virtual latency.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Multiplier on measured nanoseconds (1.0 = charge raw measurement).
    /// The paper's controller is C++ on an M1; a scale > 1 can emulate a
    /// slower controller without changing relative algorithm costs.
    pub scale: f64,
    /// Floor charged per scheduling call (µs) — models fixed dispatch
    /// overhead (syscall, queueing) that a wall-clock microbenchmark on a
    /// fast host under-reports.
    pub floor_us: SimDuration,
}

impl Default for CostModel {
    fn default() -> Self {
        Self { scale: 1.0, floor_us: 50 }
    }
}

impl CostModel {
    pub fn new(scale: f64) -> Self {
        Self { scale, ..Default::default() }
    }

    /// Convert a measured wall-clock duration to charged virtual µs.
    pub fn charge(&self, wall: std::time::Duration) -> SimDuration {
        let us = (wall.as_nanos() as f64 * self.scale / 1000.0).round() as SimDuration;
        us.max(self.floor_us)
    }

    /// Run `f`, measure it, and return `(result, charged_virtual_us)`.
    pub fn timed<T>(&self, f: impl FnOnce() -> T) -> (T, SimDuration) {
        let t0 = Instant::now();
        let out = f();
        (out, self.charge(t0.elapsed()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floor_applies() {
        let c = CostModel::default();
        assert_eq!(c.charge(std::time::Duration::from_nanos(10)), 50);
    }

    #[test]
    fn scale_multiplies() {
        let c = CostModel { scale: 10.0, floor_us: 0 };
        assert_eq!(c.charge(std::time::Duration::from_micros(100)), 1000);
    }

    #[test]
    fn timed_returns_value_and_charge() {
        let c = CostModel::default();
        let (v, charged) = c.timed(|| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            42
        });
        assert_eq!(v, 42);
        assert!(charged >= 1000, "charged {charged} < 1ms");
    }
}
