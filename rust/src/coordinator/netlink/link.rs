//! The discretised network link (Section IV-A2).
//!
//! Construction: round the current time up to the next multiple of the unit
//! transfer time `D` — that alignment point is the *current time of
//! reasoning* `t_r`. The first `n` buckets have capacity 1·D ("higher
//! accuracy for potential windows in the near future"); the following `j`
//! buckets have exponentially increasing capacity `2, 4, 8, …` (and
//! correspondingly longer windows), bounding the structure's memory while
//! still covering a long horizon.
//!
//! Querying converts a timestamp to a bucket index in O(1):
//! `base_index = ((t_p − t_r) + (D − ((t_p − t_r) mod D))) / D` — i.e. the
//! number of D-units, rounded up. If that lands in the base region the
//! index is used directly; otherwise the exponential region is indexed by
//! `log2` of the distance past the base region.
//!
//! *Faithfulness note*: the paper prints the exponential-region formula as
//! `floor(log2(base_index) + 2)`, which is not monotone with the bucket
//! layout it describes (it maps base_index = n back below n for n > 4). We
//! implement the evident intent — an O(1) log2 lookup of the exponential
//! bucket whose span contains the timestamp: bucket `n + k` covers
//! base-units `[n + 2^{k+1} − 2, n + 2^{k+2} − 2)`, so
//! `k = floor(log2((base_index − n)/2 + 1))`. DESIGN.md records the
//! deviation.


use super::bucket::{Bucket, CommTask};
use crate::time::{round_up, SimDuration, SimTime};

/// The controller's model of the shared wireless link.
#[derive(Debug, Clone)]
pub struct DiscretisedLink {
    /// Unit transfer time D (µs): one maximum-size image at the estimated
    /// bandwidth.
    pub unit: SimDuration,
    /// Current time of reasoning t_r (start of bucket 0).
    pub t_r: SimTime,
    /// Number of capacity-1 base buckets (n).
    pub base_count: usize,
    /// Number of exponential buckets (j).
    pub exp_count: usize,
    pub buckets: Vec<Bucket>,
}

impl DiscretisedLink {
    /// Build an empty discretisation starting at the first multiple of
    /// `unit` at or after `now`.
    pub fn build(now: SimTime, unit: SimDuration, base_count: usize, exp_count: usize) -> Self {
        let unit = unit.max(1);
        let t_r = round_up(now, unit);
        let mut buckets = Vec::with_capacity(base_count + exp_count);
        let mut t = t_r;
        for _ in 0..base_count {
            buckets.push(Bucket::new(t, t + unit, 1));
            t += unit;
        }
        let mut cap: u32 = 2;
        for _ in 0..exp_count {
            let span = unit * cap as SimDuration;
            buckets.push(Bucket::new(t, t + span, cap));
            t += span;
            cap = cap.saturating_mul(2);
        }
        Self { unit, t_r, base_count, exp_count, buckets }
    }

    /// End of the link's covered horizon.
    pub fn horizon(&self) -> SimTime {
        self.buckets.last().map(|b| b.t2).unwrap_or(self.t_r)
    }

    /// O(1) timestamp → bucket index (the paper's query formula, with the
    /// exponential-region correction documented above). Returns `None` for
    /// timestamps before `t_r` that round to the past ("negative index":
    /// the communication has already happened) and for timestamps beyond
    /// the horizon.
    pub fn index(&self, t_p: SimTime) -> Option<usize> {
        if t_p + self.unit <= self.t_r {
            return None; // entirely in the past of the discretisation
        }
        let t_p = t_p.max(self.t_r);
        let off = t_p - self.t_r;
        // Number of whole D-units, rounding any partial unit up — matches
        // ((t_p - t_r) + (D - ((t_p - t_r) % D))) / D from the paper for
        // non-aligned t_p, and keeps aligned timestamps in their own slot.
        let base_index = (off / self.unit) as usize;
        if base_index < self.base_count {
            return Some(base_index);
        }
        // Exponential region: bucket n+k spans base-units
        // [n + 2^{k+1} - 2, n + 2^{k+2} - 2).
        let past = (base_index - self.base_count) as u64;
        let k = (past / 2 + 1).ilog2() as usize;
        let idx = self.base_count + k;
        if idx < self.buckets.len() && self.buckets[idx].t1 <= t_p && t_p < self.buckets[idx].t2 {
            Some(idx)
        } else if idx < self.buckets.len() {
            // Guard against rounding at region edges: linear fix-up by at
            // most one bucket.
            self.buckets
                .iter()
                .position(|b| b.t1 <= t_p && t_p < b.t2)
        } else {
            None
        }
    }

    /// Find the first bucket at or after `t_p` with spare capacity, insert
    /// the communication task, and return `(bucket_index, comm_window)`.
    /// The transfer starts at the later of the bucket's opening and `t_p`
    /// and takes one unit `D` (the bucket's capacity says how many unit
    /// transfers it can host; a wide exponential bucket hosts many, each
    /// still `D` long). Iterates forward from the O(1) index as the paper
    /// describes. `deadline` bounds when the transfer must complete.
    pub fn place(&mut self, t_p: SimTime, deadline: SimTime, mut comm: CommTask) -> Option<(usize, SimTime, SimTime)> {
        let start = self.index(t_p).unwrap_or(0);
        for i in start..self.buckets.len() {
            let b = &self.buckets[i];
            if b.t1 + self.unit > deadline {
                return None;
            }
            if !b.is_full() && b.t2 > t_p {
                let c1 = b.t1.max(t_p);
                let c2 = c1 + self.unit;
                if c2 > deadline {
                    return None;
                }
                comm.planned_start = c1;
                self.buckets[i].push(comm);
                return Some((i, c1, c2));
            }
        }
        None
    }

    /// Capacity-probe version of [`place`]: would `count` transfers fit
    /// starting from `t_p` before `deadline`? Does not mutate.
    pub fn can_place(&self, t_p: SimTime, deadline: SimTime, count: u32) -> bool {
        let start = match self.index(t_p) {
            Some(i) => i,
            None => 0,
        };
        let mut need = count;
        for b in &self.buckets[start..] {
            if b.t1 >= deadline {
                break;
            }
            if b.t2 <= t_p {
                continue;
            }
            need = need.saturating_sub(b.spare());
            if need == 0 {
                return true;
            }
        }
        false
    }

    /// Remove a pending communication task (e.g. its DNN task was
    /// preempted or violated its deadline before transfer).
    pub fn remove_task(&mut self, task: crate::coordinator::task::TaskId) -> Option<CommTask> {
        for b in &mut self.buckets {
            if let Some(c) = b.remove_task(task) {
                return Some(c);
            }
        }
        None
    }

    /// Rebuild the discretisation for a new unit transfer time (after a
    /// bandwidth estimate update) and *cascade* the pending items of `self`
    /// into the new structure (Section IV-A2): each item is re-indexed by
    /// its planned start; items whose index is negative (already in the
    /// past / completed) are excluded.
    pub fn rebuild(&self, now: SimTime, new_unit: SimDuration) -> (DiscretisedLink, usize) {
        let mut fresh = DiscretisedLink::build(now, new_unit, self.base_count, self.exp_count);
        let mut dropped = 0usize;
        for b in &self.buckets {
            for item in &b.items {
                // Items already started (or in the past) are excluded.
                if item.planned_start < fresh.t_r {
                    dropped += 1;
                    continue;
                }
                match fresh.index(item.planned_start) {
                    Some(idx) => {
                        // Insert at the indexed bucket or the next with
                        // room (same forward scan as placement).
                        let mut placed = false;
                        for i in idx..fresh.buckets.len() {
                            if !fresh.buckets[i].is_full() {
                                fresh.buckets[i].push(*item);
                                placed = true;
                                break;
                            }
                        }
                        if !placed {
                            dropped += 1;
                        }
                    }
                    None => dropped += 1,
                }
            }
        }
        (fresh, dropped)
    }

    /// Total pending communication tasks.
    pub fn pending(&self) -> usize {
        self.buckets.iter().map(|b| b.items.len()).sum()
    }

    /// Invariants: contiguous windows, capacities respected, exponential
    /// growth pattern.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut prev_t2 = self.t_r;
        for (i, b) in self.buckets.iter().enumerate() {
            if b.t1 != prev_t2 {
                return Err(format!("bucket {i} not contiguous: t1={} prev_t2={prev_t2}", b.t1));
            }
            if b.t2 - b.t1 != self.unit * b.capacity as u64 {
                return Err(format!("bucket {i} span != capacity·D"));
            }
            if b.items.len() as u32 > b.capacity {
                return Err(format!("bucket {i} over capacity"));
            }
            let expected_cap = if i < self.base_count {
                1
            } else {
                2u32 << (i - self.base_count)
            };
            if b.capacity != expected_cap {
                return Err(format!("bucket {i} capacity {} != expected {expected_cap}", b.capacity));
            }
            prev_t2 = b.t2;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn comm(task: u64) -> CommTask {
        CommTask { task, from: 0, to: 1, planned_start: 0 }
    }

    #[test]
    fn build_layout_matches_paper() {
        // D=100, 4 base buckets of capacity 1, then 2,4,8.
        let l = DiscretisedLink::build(50, 100, 4, 3);
        assert_eq!(l.t_r, 100); // rounded up to multiple of D
        l.check_invariants().unwrap();
        assert_eq!(l.buckets.len(), 7);
        assert_eq!(l.buckets[0].t1, 100);
        assert_eq!(l.buckets[3].t2, 500);
        assert_eq!(l.buckets[4].capacity, 2);
        assert_eq!(l.buckets[4].t2 - l.buckets[4].t1, 200);
        assert_eq!(l.buckets[6].capacity, 8);
        assert_eq!(l.horizon(), 500 + 200 + 400 + 800);
    }

    #[test]
    fn index_is_o1_and_monotone() {
        let l = DiscretisedLink::build(0, 100, 4, 3);
        // Base region.
        assert_eq!(l.index(0), Some(0));
        assert_eq!(l.index(99), Some(0));
        assert_eq!(l.index(100), Some(1));
        assert_eq!(l.index(399), Some(3));
        // Exponential region.
        assert_eq!(l.index(400), Some(4));
        assert_eq!(l.index(599), Some(4));
        assert_eq!(l.index(600), Some(5));
        assert_eq!(l.index(999), Some(5));
        assert_eq!(l.index(1000), Some(6));
        assert_eq!(l.index(1799), Some(6));
        // Past the horizon.
        assert_eq!(l.index(1800), None);
        // Every timestamp maps to the bucket that contains it.
        for t in 0..1800 {
            let i = l.index(t).unwrap();
            assert!(l.buckets[i].t1 <= t && t < l.buckets[i].t2, "t={t} i={i}");
        }
    }

    #[test]
    fn index_in_past_is_none() {
        let l = DiscretisedLink::build(1000, 100, 4, 3);
        assert_eq!(l.t_r, 1000);
        assert_eq!(l.index(0), None);
        assert_eq!(l.index(899), None);
        // Within one unit below t_r rounds up into bucket 0.
        assert_eq!(l.index(950), Some(0));
    }

    #[test]
    fn place_iterates_past_full_buckets() {
        let mut l = DiscretisedLink::build(0, 100, 2, 2);
        let (i0, t1, t2) = l.place(0, 10_000, comm(1)).unwrap();
        assert_eq!((i0, t1, t2), (0, 0, 100)); // one unit transfer from t=0
        // Bucket 0 now full (capacity 1) — next placement goes to bucket 1.
        let (i1, ..) = l.place(0, 10_000, comm(2)).unwrap();
        assert_eq!(i1, 1);
        // Fill bucket 1 too; next goes to the exponential bucket (cap 2).
        let (i2, ..) = l.place(0, 10_000, comm(3)).unwrap();
        assert_eq!(i2, 2);
        let (i3, ..) = l.place(0, 10_000, comm(4)).unwrap();
        assert_eq!(i3, 2);
        l.check_invariants().unwrap();
    }

    #[test]
    fn place_respects_deadline() {
        let mut l = DiscretisedLink::build(0, 100, 1, 1);
        assert!(l.place(0, 100, comm(1)).is_some()); // transfer [0, 100)
        // Bucket 0 full; bucket 1's transfer would finish at 200 > 100.
        assert!(l.place(0, 100, comm(2)).is_none());
        assert_eq!(l.pending(), 1);
        // A later deadline lets it start in bucket 1.
        let (_, c1, c2) = l.place(0, 250, comm(3)).unwrap();
        assert_eq!((c1, c2), (100, 200));
    }

    #[test]
    fn can_place_counts_spare_capacity() {
        let l = DiscretisedLink::build(0, 100, 2, 1);
        assert!(l.can_place(0, 200, 2)); // two base buckets
        assert!(!l.can_place(0, 200, 3)); // third would start at 200
        assert!(l.can_place(0, 400, 4)); // +2 in the exponential bucket
    }

    #[test]
    fn rebuild_cascades_pending_items() {
        let mut l = DiscretisedLink::build(0, 100, 4, 3);
        l.place(150, 10_000, comm(1)).unwrap();
        l.place(450, 10_000, comm(2)).unwrap();
        l.place(50, 10_000, comm(3)).unwrap(); // planned_start 50 < new t_r
        assert_eq!(l.pending(), 3);
        // Bandwidth halved → unit doubles; rebuild from t=200. The new
        // t_r is 200: items whose planned start precedes it (task 3 at 50
        // and task 1 at 150 — both already underway) are excluded.
        let (fresh, dropped) = l.rebuild(200, 200);
        fresh.check_invariants().unwrap();
        assert_eq!(dropped, 2);
        assert_eq!(fresh.pending(), 1);
        // Every survivor sits in the bucket containing (or following) its
        // planned start.
        for b in &fresh.buckets {
            for it in &b.items {
                assert!(b.t2 > it.planned_start, "task {} landed before its start", it.task);
            }
        }
    }

    #[test]
    fn remove_task_frees_capacity() {
        let mut l = DiscretisedLink::build(0, 100, 1, 0);
        l.place(0, 1000, comm(7)).unwrap();
        assert!(l.place(0, 100, comm(8)).is_none());
        assert!(l.remove_task(7).is_some());
        assert!(l.place(0, 100, comm(8)).is_some());
        assert!(l.remove_task(99).is_none());
    }
}
