//! Buckets of the discretised network link (Fig. 3).
//!
//! Each bucket `b_i` covers the time window `[t1_i, t2_i)` with
//! `t1_i == t2_{i-1}` and `t2_i == t1_i + c_i · D`, where `D` is the unit
//! transfer time (one maximum-size image at the estimated bandwidth) and
//! `c_i` the bucket's capacity in communication tasks.


use crate::coordinator::task::{DeviceId, TaskId};
use crate::time::SimTime;

/// A communication task occupying link capacity: the input-image transfer
/// of an offloaded DNN task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommTask {
    pub task: TaskId,
    pub from: DeviceId,
    pub to: DeviceId,
    /// The time the transfer was planned to start (used to re-index the
    /// task when the link is rebuilt and items cascade).
    pub planned_start: SimTime,
}

/// One slot of the discretised link.
#[derive(Debug, Clone)]
pub struct Bucket {
    pub t1: SimTime,
    pub t2: SimTime,
    /// Capacity in unit transfers (c_i).
    pub capacity: u32,
    pub items: Vec<CommTask>,
}

impl Bucket {
    pub fn new(t1: SimTime, t2: SimTime, capacity: u32) -> Self {
        debug_assert!(t1 < t2);
        Self { t1, t2, capacity, items: Vec::new() }
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.items.len() as u32 >= self.capacity
    }

    #[inline]
    pub fn spare(&self) -> u32 {
        self.capacity - self.items.len() as u32
    }

    pub fn push(&mut self, c: CommTask) {
        debug_assert!(!self.is_full());
        self.items.push(c);
    }

    pub fn remove_task(&mut self, task: TaskId) -> Option<CommTask> {
        let i = self.items.iter().position(|c| c.task == task)?;
        Some(self.items.remove(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_accounting() {
        let mut b = Bucket::new(0, 100, 2);
        assert_eq!(b.spare(), 2);
        b.push(CommTask { task: 1, from: 0, to: 1, planned_start: 0 });
        assert!(!b.is_full());
        b.push(CommTask { task: 2, from: 1, to: 2, planned_start: 10 });
        assert!(b.is_full());
        assert_eq!(b.spare(), 0);
        assert!(b.remove_task(1).is_some());
        assert!(b.remove_task(1).is_none());
        assert_eq!(b.spare(), 1);
    }
}
