//! Network link discretisation (the paper's Section IV-A2).

pub mod bucket;
pub mod link;

pub use bucket::{Bucket, CommTask};
pub use link::DiscretisedLink;
