//! Sharded fleet hierarchy: cells, per-cell availability aggregates,
//! and the sparse lazy shuffle — the scale-out layer that lets
//! placement descend cell → device instead of scanning the fleet.
//!
//! ## Cells
//!
//! Devices are grouped into contiguous *cells* of [`CellMap::span`]
//! slots (configured by `cell_size`, auto-sized to ~√n at scale). Each
//! cell maintains, incrementally on every scheduler state transition:
//!
//! * its **active** member count (fleet membership),
//! * its **idle** member count — members in the scheduler's quiescent
//!   state (RAS: availability lists never written since construction;
//!   WPS: zero live allocations), whose placement answer is *uniform*
//!   and can be computed once per cell instead of once per device,
//! * an ordered **active-member set**, so mixed cells iterate their
//!   real members in device order instead of probing every slot,
//! * an **availability index** over busy members keyed by their
//!   earliest-finish time, so top-k feasible candidates come out in
//!   `O(log span)` per pull ([`FleetCells::top_k`]) and the cell's
//!   earliest-finish aggregate is an `O(1)` peek
//!   ([`FleetCells::earliest_end`]).
//!
//! The hierarchy **prunes work, never changes answers**: schedulers use
//! the counters to pick between a per-cell uniform fast path and the
//! exact per-device path, both of which produce identical decisions,
//! operation counts, and RNG draws (proven by the sharded-vs-flat
//! equivalence suite in `rust/tests/fleet_scale.rs`).
//!
//! ## Lazy shuffle
//!
//! RAS scatters guest tasks over a uniformly shuffled candidate list.
//! Eagerly shuffling 100k candidates costs 100k RNG draws per decision;
//! [`LazyShuffle`] materializes the *prefix* of a forward Fisher–Yates
//! permutation on demand — one draw per element actually consumed — via
//! a sparse swap map. Consuming the whole permutation reproduces the
//! eager forward Fisher–Yates shuffle exactly (same draws, same order).

use std::collections::{BTreeSet, HashMap};

use crate::time::SimTime;
use crate::util::Rng;

/// Sentinel for "no availability-index entry".
const NO_KEY: SimTime = SimTime::MAX;

/// Static device → cell geometry. Cells are contiguous, `span` wide;
/// the last cell may be partial.
#[derive(Debug, Clone)]
pub struct CellMap {
    n: usize,
    span: usize,
}

impl CellMap {
    /// Fleets at or below this size get a single cell under auto
    /// sizing: descent overhead only pays for itself at scale.
    pub const AUTO_SINGLE_CELL_MAX: usize = 512;

    /// Resolve the configured `cell_size` (0 = auto) against the fleet
    /// size: auto gives one cell for small fleets and ~√n-device cells
    /// at scale, so cell count and cell span grow together.
    pub fn resolve_span(cell_size: usize, n: usize) -> usize {
        if cell_size > 0 {
            return cell_size;
        }
        if n <= Self::AUTO_SINGLE_CELL_MAX {
            n.max(1)
        } else {
            (n as f64).sqrt().ceil() as usize
        }
    }

    pub fn new(cell_size: usize, n: usize) -> Self {
        Self { n, span: Self::resolve_span(cell_size, n) }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn span(&self) -> usize {
        self.span
    }

    pub fn n_cells(&self) -> usize {
        self.n.div_ceil(self.span).max(1)
    }

    pub fn cell_of(&self, device: usize) -> usize {
        device / self.span
    }

    /// Device range of cell `c`, clipped to the fleet.
    pub fn range(&self, c: usize) -> std::ops::Range<usize> {
        let lo = c * self.span;
        lo.min(self.n)..((c + 1) * self.span).min(self.n)
    }

    /// Extend coverage to include `device` (mid-run joins past the
    /// initial fleet size).
    pub fn ensure(&mut self, device: usize) {
        if device >= self.n {
            self.n = device + 1;
        }
    }
}

/// Per-cell aggregate state over one scheduler's fleet view. The owner
/// reports membership (`set_active`), quiescence (`note_busy` /
/// `note_idle`), and earliest-finish keys (`set_avail_key` /
/// `clear_avail_key`); the aggregates answer cell-level questions in
/// `O(1)` and candidate pulls in `O(log span)`.
#[derive(Debug, Clone)]
pub struct FleetCells {
    map: CellMap,
    /// Per cell: active member count.
    active: Vec<u32>,
    /// Per cell: active members currently idle (quiescent).
    idle: Vec<u32>,
    /// Per cell: active members, in device order.
    members: Vec<BTreeSet<u32>>,
    /// Per cell: busy members keyed by earliest-finish time.
    avail: Vec<BTreeSet<(SimTime, u32)>>,
    /// Per device: current availability key (NO_KEY = none).
    key: Vec<SimTime>,
    is_active: Vec<bool>,
    is_idle: Vec<bool>,
    total_active: usize,
}

impl FleetCells {
    /// A fleet of `n` devices, all active and idle (the schedulers'
    /// construction state).
    pub fn new(cell_size: usize, n: usize) -> Self {
        let map = CellMap::new(cell_size, n);
        let cells = map.n_cells();
        let mut members: Vec<BTreeSet<u32>> = vec![BTreeSet::new(); cells];
        let mut active = vec![0u32; cells];
        let mut idle = vec![0u32; cells];
        for d in 0..n {
            let c = map.cell_of(d);
            members[c].insert(d as u32);
            active[c] += 1;
            idle[c] += 1;
        }
        Self {
            map,
            active,
            idle,
            members,
            avail: vec![BTreeSet::new(); cells],
            key: vec![NO_KEY; n],
            is_active: vec![true; n],
            is_idle: vec![true; n],
            total_active: n,
        }
    }

    pub fn map(&self) -> &CellMap {
        &self.map
    }

    pub fn n_cells(&self) -> usize {
        self.active.len()
    }

    fn grow_to(&mut self, device: usize) {
        self.map.ensure(device);
        let cells = self.map.n_cells();
        self.active.resize(cells, 0);
        self.idle.resize(cells, 0);
        self.members.resize_with(cells, BTreeSet::new);
        self.avail.resize_with(cells, BTreeSet::new);
        self.key.resize(device + 1, NO_KEY);
        self.is_active.resize(device + 1, false);
        self.is_idle.resize(device + 1, false);
    }

    /// Report fleet membership. Joining resets the member to idle with
    /// no availability key (schedulers rebuild state fresh on churn);
    /// leaving removes it from every aggregate.
    pub fn set_active(&mut self, device: usize, on: bool) {
        if device >= self.is_active.len() {
            self.grow_to(device);
        }
        if self.is_active[device] == on {
            return;
        }
        let c = self.map.cell_of(device);
        self.is_active[device] = on;
        if on {
            self.total_active += 1;
            self.active[c] += 1;
            self.members[c].insert(device as u32);
            self.is_idle[device] = true;
            self.idle[c] += 1;
            debug_assert_eq!(self.key[device], NO_KEY);
        } else {
            self.total_active -= 1;
            self.active[c] -= 1;
            self.members[c].remove(&(device as u32));
            if self.is_idle[device] {
                self.idle[c] -= 1;
            }
            self.is_idle[device] = false;
            self.clear_avail_key(device);
        }
    }

    /// The member left its quiescent state (first write / first live
    /// allocation). Idempotent.
    pub fn note_busy(&mut self, device: usize) {
        if device < self.is_idle.len() && self.is_idle[device] {
            self.is_idle[device] = false;
            if self.is_active[device] {
                self.idle[self.map.cell_of(device)] -= 1;
            }
        }
    }

    /// The member returned to its quiescent state (reconstructed fresh /
    /// last allocation gone). Idempotent; clears its availability key.
    pub fn note_idle(&mut self, device: usize) {
        if device < self.is_idle.len() && !self.is_idle[device] {
            self.is_idle[device] = true;
            if self.is_active[device] {
                self.idle[self.map.cell_of(device)] += 1;
            }
        }
        self.clear_avail_key(device);
    }

    /// (Re-)key `device` in its cell's availability index by its
    /// earliest-finish time.
    pub fn set_avail_key(&mut self, device: usize, end: SimTime) {
        if device >= self.key.len() {
            self.grow_to(device);
        }
        let c = self.map.cell_of(device);
        let old = self.key[device];
        if old == end {
            return;
        }
        if old != NO_KEY {
            self.avail[c].remove(&(old, device as u32));
        }
        // NO_KEY doubles as the sentinel: an explicit MAX key is
        // indistinguishable from "none", which is fine — it could never
        // win a top-k pull anyway.
        if end != NO_KEY {
            self.avail[c].insert((end, device as u32));
        }
        self.key[device] = end;
    }

    pub fn clear_avail_key(&mut self, device: usize) {
        if device < self.key.len() && self.key[device] != NO_KEY {
            let c = self.map.cell_of(device);
            self.avail[c].remove(&(self.key[device], device as u32));
            self.key[device] = NO_KEY;
        }
    }

    pub fn cell_active(&self, c: usize) -> u32 {
        self.active[c]
    }

    /// Active members across the whole fleet.
    pub fn active_total(&self) -> usize {
        self.total_active
    }

    /// Is this device an active fleet member?
    pub fn device_active(&self, device: usize) -> bool {
        device < self.is_active.len() && self.is_active[device]
    }

    /// Is this active member in its quiescent state? (Inactive devices
    /// report `false`.)
    pub fn device_idle(&self, device: usize) -> bool {
        device < self.is_idle.len() && self.is_active[device] && self.is_idle[device]
    }

    /// Current availability key of `device`, if any.
    pub fn avail_key(&self, device: usize) -> Option<SimTime> {
        self.key.get(device).copied().filter(|&k| k != NO_KEY)
    }

    /// The `rank`-th active device (ascending id) excluding `skip`:
    /// cell-prefix descent plus an in-cell walk, `O(cells + span)`
    /// instead of an `O(n)` materialized remote list.
    pub fn nth_active_excluding(&self, rank: usize, skip: usize) -> Option<usize> {
        let mut rest = rank;
        for c in 0..self.n_cells() {
            let mut here = self.active[c] as usize;
            let skip_here = self.device_active(skip) && self.map.cell_of(skip) == c;
            if skip_here {
                here -= 1;
            }
            if rest >= here {
                rest -= here;
                continue;
            }
            for d in self.members(c) {
                if d == skip {
                    continue;
                }
                if rest == 0 {
                    return Some(d);
                }
                rest -= 1;
            }
        }
        None
    }

    pub fn cell_idle(&self, c: usize) -> u32 {
        self.idle[c]
    }

    /// Every active member of `c` is quiescent (and there is at least
    /// one): the whole cell shares a single uniform placement answer.
    pub fn all_idle(&self, c: usize) -> bool {
        self.active[c] > 0 && self.idle[c] == self.active[c]
    }

    /// Active members of `c`, ascending by device id.
    pub fn members(&self, c: usize) -> impl Iterator<Item = usize> + '_ {
        self.members[c].iter().map(|&d| d as usize)
    }

    /// Lowest-id active member of `c` (the uniform fast path's winner
    /// under first-wins tie-breaking).
    pub fn first_member(&self, c: usize) -> Option<usize> {
        self.members[c].first().map(|&d| d as usize)
    }

    /// Cell-level earliest-finish aggregate: the smallest availability
    /// key among busy members (`None` when nothing is keyed).
    pub fn earliest_end(&self, c: usize) -> Option<SimTime> {
        self.avail[c].first().map(|&(t, _)| t)
    }

    /// Up to `k` busy members of `c` in earliest-finish order.
    pub fn top_k(&self, c: usize, k: usize) -> impl Iterator<Item = (SimTime, usize)> + '_ {
        self.avail[c].iter().take(k).map(|&(t, d)| (t, d as usize))
    }

    /// Fleet-wide top-k by earliest finish: a k-way merge over the
    /// per-cell indexes that touches `O(k + cells)` entries, never the
    /// whole fleet.
    pub fn top_k_fleet(&self, k: usize) -> Vec<(SimTime, usize)> {
        let mut heads: std::collections::BinaryHeap<std::cmp::Reverse<(SimTime, u32, usize)>> =
            self.avail
                .iter()
                .enumerate()
                .filter_map(|(c, set)| set.first().map(|&(t, d)| std::cmp::Reverse((t, d, c))))
                .collect();
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let Some(std::cmp::Reverse((t, d, c))) = heads.pop() else { break };
            out.push((t, d as usize));
            if let Some(&(nt, nd)) = self.avail[c].range((t, d + 1)..).next() {
                heads.push(std::cmp::Reverse((nt, nd, c)));
            }
        }
        out
    }
}

/// Sparse forward Fisher–Yates: the permutation prefix materializes on
/// demand, one RNG draw per element consumed. Consuming all `m`
/// elements reproduces the eager forward Fisher–Yates shuffle of
/// `0..m` exactly — same draws, same order — so switching between the
/// eager and lazy forms at a fixed cutover never changes decisions,
/// only how much of the permutation gets paid for.
#[derive(Debug)]
pub struct LazyShuffle {
    m: usize,
    next: usize,
    /// Sparse displaced-element map: position → value (identity where
    /// absent). Only positions touched by a swap are stored.
    swaps: HashMap<usize, usize>,
}

impl LazyShuffle {
    pub fn new(m: usize) -> Self {
        Self { m, next: 0, swaps: HashMap::new() }
    }

    fn slot(&self, k: usize) -> usize {
        self.swaps.get(&k).copied().unwrap_or(k)
    }

    /// Elements already drawn.
    pub fn drawn(&self) -> usize {
        self.next
    }

    /// Draw the next element of the permutation (`None` once all `m`
    /// are out). Exactly one `rng` draw per call.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self, rng: &mut Rng) -> Option<usize> {
        if self.next >= self.m {
            return None;
        }
        let i = self.next;
        let j = i + rng.index(self.m - i);
        let vi = self.slot(i);
        let vj = self.slot(j);
        self.swaps.insert(j, vi);
        self.swaps.remove(&i);
        self.next = i + 1;
        Some(vj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_ranges_partition_the_fleet() {
        for (cell_size, n) in [(0, 4), (0, 513), (0, 100_000), (3, 10), (7, 7), (16, 100)] {
            let map = CellMap::new(cell_size, n);
            let mut covered = 0usize;
            for c in 0..map.n_cells() {
                let r = map.range(c);
                assert_eq!(r.start, covered, "ranges must be contiguous");
                for d in r.clone() {
                    assert_eq!(map.cell_of(d), c);
                }
                covered = r.end;
            }
            assert_eq!(covered, n, "ranges must cover the fleet exactly");
        }
    }

    #[test]
    fn auto_sizing_is_single_cell_small_and_sqrt_at_scale() {
        assert_eq!(CellMap::new(0, 4).n_cells(), 1);
        assert_eq!(CellMap::new(0, 512).n_cells(), 1);
        let big = CellMap::new(0, 100_000);
        assert!(big.span() >= 300 && big.span() <= 340, "span {}", big.span());
        assert!(big.n_cells() >= 290 && big.n_cells() <= 340, "cells {}", big.n_cells());
    }

    #[test]
    fn aggregates_track_membership_and_quiescence() {
        let mut f = FleetCells::new(4, 10);
        assert_eq!(f.n_cells(), 3);
        assert!(f.all_idle(0) && f.all_idle(1) && f.all_idle(2));
        assert_eq!(f.first_member(1), Some(4));
        f.note_busy(5);
        assert!(!f.all_idle(1));
        assert_eq!((f.cell_active(1), f.cell_idle(1)), (4, 3));
        f.note_busy(5); // idempotent
        assert_eq!(f.cell_idle(1), 3);
        f.note_idle(5);
        assert!(f.all_idle(1));
        // Leaving shrinks; a cell of leavers goes quiet entirely.
        f.set_active(8, false);
        f.set_active(9, false);
        assert_eq!(f.cell_active(2), 0);
        assert!(!f.all_idle(2), "an empty cell is not 'all idle'");
        assert_eq!(f.members(2).count(), 0);
        // Rejoin resets to idle.
        f.note_busy(8); // no-op while inactive
        f.set_active(8, true);
        assert!(f.all_idle(2));
        assert_eq!(f.first_member(2), Some(8));
    }

    #[test]
    fn rank_select_matches_a_materialized_remote_list() {
        let mut f = FleetCells::new(3, 11);
        for d in [2usize, 5, 6, 10] {
            f.set_active(d, false);
        }
        assert_eq!(f.active_total(), 7);
        for skip in 0..11usize {
            let remotes: Vec<usize> =
                (0..11).filter(|&d| d != skip && f.device_active(d)).collect();
            for (r, &want) in remotes.iter().enumerate() {
                assert_eq!(f.nth_active_excluding(r, skip), Some(want), "rank {r} skip {skip}");
            }
            assert_eq!(f.nth_active_excluding(remotes.len(), skip), None);
        }
    }

    #[test]
    fn availability_index_orders_and_aggregates() {
        let mut f = FleetCells::new(4, 12);
        for (d, end) in [(0usize, 500u64), (1, 300), (2, 300), (5, 100), (9, 900)] {
            f.note_busy(d);
            f.set_avail_key(d, end);
        }
        assert_eq!(f.earliest_end(0), Some(300));
        assert_eq!(f.earliest_end(1), Some(100));
        assert_eq!(f.earliest_end(2), Some(900));
        // Ties break by device id; pulls come out sorted.
        let cell0: Vec<_> = f.top_k(0, 10).collect();
        assert_eq!(cell0, vec![(300, 1), (300, 2), (500, 0)]);
        let fleet = f.top_k_fleet(4);
        assert_eq!(fleet, vec![(100, 5), (300, 1), (300, 2), (500, 0)]);
        // Re-keying moves, clearing removes, leaving clears.
        f.set_avail_key(1, 50);
        assert_eq!(f.earliest_end(0), Some(50));
        f.clear_avail_key(1);
        assert_eq!(f.earliest_end(0), Some(300));
        f.set_active(5, false);
        assert_eq!(f.earliest_end(1), None);
        assert_eq!(f.top_k_fleet(10).len(), 3);
    }

    /// The lazy shuffle must reproduce the eager forward Fisher–Yates
    /// permutation *exactly* — same RNG draws, same order — when fully
    /// consumed, for many sizes and seeds. This is what lets the RAS
    /// candidate scatter switch between eager and lazy at a count
    /// cutover without changing a single decision.
    #[test]
    fn lazy_shuffle_equals_eager_forward_fisher_yates() {
        for m in [1usize, 2, 3, 7, 64, 257] {
            for seed in 0..5u64 {
                let mut r1 = Rng::seed_from_u64(0xF1_5e ^ seed);
                let mut r2 = Rng::seed_from_u64(0xF1_5e ^ seed);
                let mut eager: Vec<usize> = (0..m).collect();
                for i in 0..m {
                    let j = i + r1.index(m - i);
                    eager.swap(i, j);
                }
                let mut lazy = LazyShuffle::new(m);
                let got: Vec<usize> = (0..m).map(|_| lazy.next(&mut r2).unwrap()).collect();
                assert_eq!(got, eager, "m={m} seed={seed}");
                assert!(lazy.next(&mut r2).is_none());
                // Both consumed the same number of draws: the streams
                // agree on the next value.
                assert_eq!(r1.next_u64(), r2.next_u64());
            }
        }
    }

    #[test]
    fn lazy_shuffle_prefix_is_a_valid_partial_permutation() {
        let mut rng = Rng::seed_from_u64(77);
        let m = 10_000;
        let mut s = LazyShuffle::new(m);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            let v = s.next(&mut rng).unwrap();
            assert!(v < m);
            assert!(seen.insert(v), "duplicate {v} in permutation prefix");
        }
        assert_eq!(s.drawn(), 100);
        // The sparse map holds at most one entry per consumed element.
        assert!(s.swaps.len() <= 100);
    }
}
