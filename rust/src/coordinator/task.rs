//! Task model: the unit of work the controller schedules.
//!
//! The paper's pipeline (Fig. 1) produces two kinds of tasks per frame:
//! a *high-priority* task (stage 1 object detector + stage 2 binary
//! classifier, processed locally under a tight deadline) and 0–4
//! *low-priority* DNN tasks (stage 3 high-complexity classifier) that may
//! be offloaded. Low-priority tasks run in a two-core (slow) or four-core
//! (fast) configuration; the scheduler prefers two cores and only widens
//! to four when two cores would violate the deadline.


use crate::config::SystemConfig;
use crate::time::{SimDuration, SimTime};

/// Globally unique task identifier.
pub type TaskId = u64;
/// Index of an edge device (0-based).
pub type DeviceId = usize;
/// Identifier of a conveyor frame (one pipeline instance).
pub type FrameId = u64;

/// The pseudo device id of the cloud tier: one past the edge fleet.
/// Allocations carrying this id run on [`crate::sim::netsim::CloudTier`]
/// (WAN transfer + fixed propagation + the task's `cloud_us` service
/// time) instead of an edge device; the engine branches on
/// `device >= cfg.n_devices` before touching any per-device state.
pub fn cloud_device(cfg: &SystemConfig) -> DeviceId {
    cfg.n_devices
}

/// Task priority class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Priority {
    High,
    Low,
}

/// Maximum model-variant ladder depth the system tracks (per-rung
/// completion counters in [`crate::metrics::Metrics`] are sized by it;
/// ladder validation enforces it).
pub const MAX_RUNGS: usize = 8;

/// One rung of a compiled model-variant ladder, as the schedulers and
/// the engine consume it: the delivered inference accuracy of running
/// this variant, the input it ships on offload, and its planned
/// per-configuration stage durations (low-priority padding already
/// applied, like [`Task::proc_us`]). Rung 0 is the full-accuracy model —
/// by construction it equals the task's own compiled spec — and lower
/// rungs are cheaper on every axis (validated at the spec level, see
/// [`crate::workload::gen::variants::Ladder`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VariantRung {
    /// Delivered inference accuracy in (0, 1].
    pub accuracy: f64,
    /// Input transferred on offload, bytes.
    pub input_bytes: u64,
    /// `[two-core, four-core]` planned stage durations, µs.
    pub proc_us: [SimDuration; 2],
}

/// Maximum number of anytime stages a rung's stage plan may carry
/// (compiled plans are fixed-size `Copy` arrays so the engine's slab and
/// the scheduler API never allocate per task).
pub const MAX_STAGES: usize = 6;

/// Compiled anytime stage plan for one ladder rung: the imprecise-
/// computation split of the rung's execution into a mandatory prefix
/// plus optional refinement stages ("Scheduling Real-time Deep Learning
/// Services as Imprecise Computations"). A running low-priority task may
/// be cut short at the boundary after any stage `>= mandatory`,
/// delivering the cumulative accuracy earned so far instead of the full
/// rung accuracy. `n_stages == 0` means the rung is monolithic — the
/// engine schedules no boundary events and behaviour is byte-identical
/// to the pre-anytime system.
///
/// Stages are 1-based; `cum_frac[k-1]` / `cum_accuracy[k-1]` give the
/// fraction of total execution time spent and the accuracy credit banked
/// once stage `k` completes. The final entries are exactly `1.0` and the
/// rung's accuracy, so an uncut staged run is indistinguishable from a
/// monolithic one in every ledger.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StagePlan {
    /// Number of stages (`0` = no plan, monolithic execution).
    pub n_stages: u8,
    /// Leading stages that can never be truncated (`>= 1` when staged).
    pub mandatory: u8,
    /// Cumulative fraction of the total execution time completed after
    /// each stage; entry `n_stages - 1` is `1.0`.
    pub cum_frac: [f64; MAX_STAGES],
    /// Cumulative accuracy credit after each stage; nondecreasing, entry
    /// `n_stages - 1` equals the rung's full accuracy.
    pub cum_accuracy: [f64; MAX_STAGES],
}

impl StagePlan {
    /// The empty (monolithic) plan.
    pub const NONE: StagePlan = StagePlan {
        n_stages: 0,
        mandatory: 0,
        cum_frac: [0.0; MAX_STAGES],
        cum_accuracy: [0.0; MAX_STAGES],
    };

    /// Does this rung carry a stage plan at all?
    pub fn is_staged(&self) -> bool {
        self.n_stages > 0
    }

    /// Does the plan expose at least one cut point (an optional stage)?
    pub fn cuttable(&self) -> bool {
        self.is_staged() && self.mandatory < self.n_stages
    }

    /// Fraction of total execution time completed after `stage` (1-based).
    pub fn frac_after(&self, stage: u8) -> f64 {
        self.cum_frac[stage as usize - 1]
    }

    /// Accuracy credit banked after `stage` (1-based) completes.
    pub fn accuracy_after(&self, stage: u8) -> f64 {
        self.cum_accuracy[stage as usize - 1]
    }
}

/// Application configuration: each has its own fixed processing time and
/// core requirement, and each device keeps one resource-availability list
/// per configuration (Section IV-A1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskConfig {
    /// Stage 1+2, local, tight deadline.
    HighPriority,
    /// Stage 3 on two cores (slower).
    LowTwoCore,
    /// Stage 3 on four cores (faster).
    LowFourCore,
}

/// All configurations, in the order device state stores their lists.
pub const ALL_CONFIGS: [TaskConfig; 3] = [
    TaskConfig::HighPriority,
    TaskConfig::LowTwoCore,
    TaskConfig::LowFourCore,
];

impl TaskConfig {
    /// Cores the configuration occupies on a device.
    pub fn cores(self, cfg: &SystemConfig) -> u32 {
        match self {
            TaskConfig::HighPriority => cfg.hp_cores,
            TaskConfig::LowTwoCore => 2,
            TaskConfig::LowFourCore => 4,
        }
    }

    /// Fixed processing duration for the configuration (µs).
    pub fn proc_time(self, cfg: &SystemConfig) -> SimDuration {
        match self {
            TaskConfig::HighPriority => cfg.hp_proc(),
            TaskConfig::LowTwoCore => cfg.lp2_proc(),
            TaskConfig::LowFourCore => cfg.lp4_proc(),
        }
    }

    /// Index into per-device list arrays.
    pub fn index(self) -> usize {
        match self {
            TaskConfig::HighPriority => 0,
            TaskConfig::LowTwoCore => 1,
            TaskConfig::LowFourCore => 2,
        }
    }

    pub fn priority(self) -> Priority {
        match self {
            TaskConfig::HighPriority => Priority::High,
            _ => Priority::Low,
        }
    }
}

/// A schedulable task as seen by the controller. Plain-old-data and
/// `Copy`: the simulation hot path passes `&Task` through the scheduler
/// API and never clones task state per event.
///
/// Tasks carry their own per-configuration processing durations
/// (`proc_us`): the schedulers plan with what the *task* says it costs,
/// not with a fixed per-system constant. The conveyor workload fills
/// these from the paper's benchmark times ([`SystemConfig`]), so its
/// behaviour is unchanged; the generative workload subsystem
/// ([`crate::workload::gen`]) fills them per [`crate::workload::gen::TaskClass`].
#[derive(Debug, Clone, Copy)]
pub struct Task {
    pub id: TaskId,
    pub frame: FrameId,
    /// Device whose camera produced the frame (tasks prefer to run here).
    pub source: DeviceId,
    pub priority: Priority,
    /// Creation time (frame generation for HP; HP completion for LP).
    pub created_at: SimTime,
    /// Absolute completion deadline.
    pub deadline: SimTime,
    /// Input size in bytes (what an offload must transfer).
    pub input_bytes: u64,
    /// Per-configuration processing durations in µs:
    /// `[two-core, four-core]` for low-priority tasks; high-priority
    /// tasks hold their (single) stage duration in both entries.
    pub proc_us: [SimDuration; 2],
    /// Deterministic service time on the cloud tier, µs (`0` = the task
    /// never runs there — all high-priority tasks, and every task when
    /// the cloud tier is disabled). The server tier is provisioned, so
    /// cloud executions take exactly this long: no Pi load jitter, and
    /// degraded rungs keep the class's cloud time (degradation is an
    /// edge-side compute lever; the transfer still shrinks with the rung).
    pub cloud_us: SimDuration,
}

impl Task {
    pub fn high(id: TaskId, frame: FrameId, source: DeviceId, now: SimTime, cfg: &SystemConfig) -> Self {
        Self {
            id,
            frame,
            source,
            priority: Priority::High,
            created_at: now,
            deadline: now + cfg.hp_deadline(),
            input_bytes: 0, // HP never offloads, nothing to transfer
            proc_us: [cfg.hp_proc(); 2],
            cloud_us: 0, // HP stays at the edge: the WAN RTT alone blows its budget
        }
    }

    pub fn low(
        id: TaskId,
        frame: FrameId,
        source: DeviceId,
        now: SimTime,
        frame_deadline: SimTime,
        cfg: &SystemConfig,
    ) -> Self {
        Self {
            id,
            frame,
            source,
            priority: Priority::Low,
            created_at: now,
            deadline: frame_deadline,
            input_bytes: cfg.image_bytes,
            proc_us: [cfg.lp2_proc(), cfg.lp4_proc()],
            cloud_us: default_cloud_us(cfg.lp4_proc_s, cfg),
        }
    }

    /// A task of an arbitrary class (generative workloads): explicit
    /// priority, relative deadline, input size, per-configuration
    /// processing durations, and cloud-tier service time.
    #[allow(clippy::too_many_arguments)]
    pub fn of_class(
        id: TaskId,
        frame: FrameId,
        source: DeviceId,
        now: SimTime,
        priority: Priority,
        deadline_us: SimDuration,
        input_bytes: u64,
        proc_us: [SimDuration; 2],
        cloud_us: SimDuration,
    ) -> Self {
        Self {
            id,
            frame,
            source,
            priority,
            created_at: now,
            deadline: now + deadline_us,
            input_bytes: if priority == Priority::High { 0 } else { input_bytes },
            proc_us,
            cloud_us: if priority == Priority::High { 0 } else { cloud_us },
        }
    }

    /// Planned processing duration under `config` (µs).
    pub fn proc_for(&self, config: TaskConfig) -> SimDuration {
        match config {
            TaskConfig::HighPriority | TaskConfig::LowTwoCore => self.proc_us[0],
            TaskConfig::LowFourCore => self.proc_us[1],
        }
    }

    /// Slack between now and the deadline (0 if already past).
    pub fn slack(&self, now: SimTime) -> SimDuration {
        self.deadline.saturating_sub(now)
    }

    /// The same task re-specced at a degraded model-variant rung: a
    /// smaller input and cheaper stage durations, with identity,
    /// deadline, and source untouched. The shared degradation policy
    /// ([`crate::coordinator::scheduler::place_degrading`]) builds these
    /// copies when the full-accuracy rung is infeasible.
    pub fn at_rung(&self, rung: &VariantRung) -> Task {
        Task {
            input_bytes: if self.priority == Priority::High { 0 } else { rung.input_bytes },
            proc_us: rung.proc_us,
            ..*self
        }
    }
}

/// The default cloud service time for a class whose four-core edge time
/// is `proc4_s` seconds: `proc4_s / cloud_speedup`, unpadded (the server
/// tier is deterministic, there is no benchmark deviation to pad
/// against). `0` when the speedup is degenerate or the result would
/// round below a microsecond.
pub fn default_cloud_us(proc4_s: f64, cfg: &SystemConfig) -> SimDuration {
    if !(cfg.cloud_speedup > 0.0) || !(proc4_s > 0.0) {
        return 0;
    }
    crate::time::secs(proc4_s / cfg.cloud_speedup).max(1)
}

/// A committed placement: task `id` occupies `cores` on `device` over
/// `[start, end)`. This is the exact state WPS searches over, and what RAS
/// replays when reconstructing availability lists after a preemption.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Allocation {
    pub task: TaskId,
    pub frame: FrameId,
    pub device: DeviceId,
    pub config: TaskConfig,
    pub cores: u32,
    pub start: SimTime,
    pub end: SimTime,
    pub deadline: SimTime,
    /// Whether the task was offloaded (device != source).
    pub offloaded: bool,
    /// Communication window reserved on the link for the input transfer
    /// (None for local placements).
    pub comm: Option<(SimTime, SimTime)>,
}

impl Allocation {
    /// Does this allocation overlap the half-open interval `[t1, t2)`?
    pub fn overlaps(&self, t1: SimTime, t2: SimTime) -> bool {
        self.start < t2 && t1 < self.end
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn config_cores_and_durations() {
        let c = cfg();
        assert_eq!(TaskConfig::HighPriority.cores(&c), 4);
        assert_eq!(TaskConfig::LowTwoCore.cores(&c), 2);
        assert_eq!(TaskConfig::LowFourCore.cores(&c), 4);
        // Four-core config is strictly faster than two-core (the paper's
        // conservative allocation rationale).
        assert!(TaskConfig::LowFourCore.proc_time(&c) < TaskConfig::LowTwoCore.proc_time(&c));
    }

    #[test]
    fn deadlines() {
        let c = cfg();
        let hp = Task::high(1, 1, 0, 1000, &c);
        assert_eq!(hp.deadline, 1000 + c.hp_deadline());
        let frame_deadline = 1000 + c.frame_period();
        let lp = Task::low(2, 1, 0, 2000, frame_deadline, &c);
        assert_eq!(lp.deadline, frame_deadline);
        assert_eq!(lp.input_bytes, c.image_bytes);
    }

    #[test]
    fn allocation_overlap() {
        let a = Allocation {
            task: 1,
            frame: 1,
            device: 0,
            config: TaskConfig::LowTwoCore,
            cores: 2,
            start: 100,
            end: 200,
            deadline: 300,
            offloaded: false,
            comm: None,
        };
        assert!(a.overlaps(150, 160));
        assert!(a.overlaps(0, 101));
        assert!(a.overlaps(199, 500));
        assert!(!a.overlaps(200, 300)); // half-open: end not included
        assert!(!a.overlaps(0, 100));
    }

    #[test]
    fn tasks_carry_class_processing_times() {
        let c = cfg();
        let hp = Task::high(1, 1, 0, 0, &c);
        assert_eq!(hp.proc_for(TaskConfig::HighPriority), c.hp_proc());
        let lp = Task::low(2, 1, 0, 0, c.frame_period(), &c);
        assert_eq!(lp.proc_for(TaskConfig::LowTwoCore), c.lp2_proc());
        assert_eq!(lp.proc_for(TaskConfig::LowFourCore), c.lp4_proc());
        // A custom class overrides every per-system constant.
        let t =
            Task::of_class(3, 1, 2, 1000, Priority::Low, 5_000_000, 42_000, [400_000, 250_000], 50_000);
        assert_eq!(t.deadline, 1000 + 5_000_000);
        assert_eq!(t.input_bytes, 42_000);
        assert_eq!(t.proc_for(TaskConfig::LowTwoCore), 400_000);
        assert_eq!(t.proc_for(TaskConfig::LowFourCore), 250_000);
        assert_eq!(t.cloud_us, 50_000);
        // HP classes never offload: input and cloud time are forced to zero.
        let h = Task::of_class(4, 1, 2, 0, Priority::High, 1_000_000, 9_999, [300_000; 2], 50_000);
        assert_eq!(h.input_bytes, 0);
        assert_eq!(h.cloud_us, 0);
    }

    #[test]
    fn cloud_service_time_defaults_from_four_core_speedup() {
        let c = cfg();
        // 11.611 s / 8 ≈ 1.451 s, unpadded.
        assert_eq!(default_cloud_us(c.lp4_proc_s, &c), crate::time::secs(c.lp4_proc_s / 8.0));
        let lp = Task::low(2, 1, 0, 0, c.frame_period(), &c);
        assert_eq!(lp.cloud_us, default_cloud_us(c.lp4_proc_s, &c));
        assert!(lp.cloud_us < lp.proc_us[1], "the cloud tier must beat four edge cores");
        // HP never runs on the cloud; degenerate speedups disable it.
        assert_eq!(Task::high(1, 1, 0, 0, &c).cloud_us, 0);
        let no_cloud = SystemConfig { cloud_speedup: 0.0, ..cfg() };
        assert_eq!(default_cloud_us(11.6, &no_cloud), 0);
    }

    #[test]
    fn at_rung_respecs_cost_but_not_identity() {
        let c = cfg();
        let t = Task::low(7, 3, 1, 500, 500 + c.frame_period(), &c);
        let rung = VariantRung {
            accuracy: 0.8,
            input_bytes: c.image_bytes / 4,
            proc_us: [4_000_000, 3_000_000],
        };
        let d = t.at_rung(&rung);
        assert_eq!(d.id, t.id);
        assert_eq!(d.frame, t.frame);
        assert_eq!(d.source, t.source);
        assert_eq!(d.deadline, t.deadline);
        assert_eq!(d.created_at, t.created_at);
        assert_eq!(d.input_bytes, c.image_bytes / 4);
        assert_eq!(d.proc_us, [4_000_000, 3_000_000]);
        assert_eq!(d.cloud_us, t.cloud_us, "rungs keep the class cloud service time");
        // HP tasks never ship input, whatever the rung says.
        let h = Task::high(9, 3, 1, 0, &c);
        assert_eq!(h.at_rung(&rung).input_bytes, 0);
        assert_eq!(h.at_rung(&rung).proc_us, rung.proc_us);
    }

    #[test]
    fn stage_plan_defaults_off_and_indexes_one_based() {
        let none = StagePlan::NONE;
        assert!(!none.is_staged() && !none.cuttable());
        assert_eq!(StagePlan::default(), none);
        let mut p = StagePlan { n_stages: 3, mandatory: 1, ..StagePlan::NONE };
        p.cum_frac[..3].copy_from_slice(&[0.5, 0.8, 1.0]);
        p.cum_accuracy[..3].copy_from_slice(&[0.6, 0.9, 0.97]);
        assert!(p.is_staged() && p.cuttable());
        assert_eq!(p.frac_after(2), 0.8);
        assert_eq!(p.accuracy_after(3), 0.97);
        // A plan whose stages are all mandatory exposes no cut point.
        let solid = StagePlan { mandatory: 3, ..p };
        assert!(solid.is_staged() && !solid.cuttable());
    }

    #[test]
    fn slack_saturates() {
        let c = cfg();
        let t = Task::high(1, 1, 0, 0, &c);
        assert_eq!(t.slack(t.deadline + 10), 0);
        assert_eq!(t.slack(0), c.hp_deadline());
    }
}
