//! The controller (Layer 3): the paper's coordination contribution.
//!
//! The system is centralised — scheduling decisions are made by a
//! controller that maintains the state of communication and computation
//! resources based on information received from the edge devices
//! (Section III). This module holds:
//!
//! * [`task`] — the task/allocation model;
//! * [`ras`] — the resource-availability abstraction (Section IV-A1);
//! * [`netlink`] — the discretised network link (Section IV-A2);
//! * [`bandwidth`] — the EWMA dynamic bandwidth estimator (Section V);
//! * [`fleet`] — the sharded fleet hierarchy (cells, per-cell
//!   availability aggregates, top-k candidate index, lazy shuffle) that
//!   lets placement descend cell → device instead of scanning the fleet;
//! * [`scheduler`] — the RAS scheduler, the WPS baseline, and the
//!   future-work contextual multi-scheduler;
//! * [`cost`] — scheduler-latency accounting for the simulator.

pub mod bandwidth;
pub mod cost;
pub mod fleet;
pub mod netlink;
pub mod ras;
pub mod scheduler;
pub mod task;

pub use scheduler::{Decision, HpOutcome, LpOutcome, Outcome, SchedEvent, Scheduler, SchedulerCompat};
pub use task::{Allocation, DeviceId, FrameId, Priority, Task, TaskConfig, TaskId};
