//! Heartbeat/suspicion failure detection (imperfect availability belief).
//!
//! The baseline engine tells schedulers about crashes through an oracle:
//! `DeviceCrashed` arrives the instant the device dies. Real controllers
//! only *infer* liveness — here, from the bandwidth probe rounds the
//! controller already runs. Every round that reaches a device is a
//! heartbeat; every round that cannot (device crashed, partitioned, or
//! the whole round lost to probe loss) is a miss. After
//! `suspect_after` consecutive misses the device is [`Belief::Suspected`]
//! and schedulers receive
//! [`crate::coordinator::scheduler::SchedEvent::DeviceSuspected`]; after
//! `confirm_after` further misses it is [`Belief::Confirmed`]
//! (diagnostic only — placement already routed around the suspicion).
//! A later heartbeat clears the device
//! ([`crate::coordinator::scheduler::SchedEvent::DeviceCleared`]).
//!
//! Detection latency is therefore `suspect_after × bandwidth_interval`
//! in the best case, and fully-lost probe rounds make *every* device
//! miss at once — the seed-deterministic false-positive mechanism: under
//! heavy probe loss the controller suspects healthy devices, exactly the
//! stale-knowledge failure mode the paper's contended-medium experiments
//! (Figs. 6–8) exhibit.
//!
//! The detector itself is pure bookkeeping: no RNG, no clock, no truth.
//! The engine feeds it observations and owns truth-vs-belief accounting
//! (`false_suspicions`, `detection_lag_us`).

use crate::coordinator::task::DeviceId;

/// Controller belief about one device's liveness.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Belief {
    /// Heartbeats arriving normally (or never observed yet).
    #[default]
    Alive,
    /// `suspect_after` consecutive misses: schedulers place around it.
    Suspected,
    /// `confirm_after` further misses: written off until a heartbeat.
    Confirmed,
}

/// Per-device missed-heartbeat counters and the resulting beliefs.
#[derive(Debug, Clone)]
pub struct SuspicionDetector {
    suspect_after: u32,
    confirm_threshold: u32,
    missed: Vec<u32>,
    belief: Vec<Belief>,
}

impl SuspicionDetector {
    /// `suspect_after` misses ⇒ `Suspected`; `confirm_after.max(1)` more
    /// ⇒ `Confirmed`. `suspect_after == 0` builds an inert detector that
    /// never transitions (the engine additionally gates all feeding on
    /// the knob, so a disabled run does no work here at all).
    pub fn new(n_devices: usize, suspect_after: u32, confirm_after: u32) -> Self {
        Self {
            suspect_after,
            confirm_threshold: suspect_after.saturating_add(confirm_after.max(1)),
            missed: vec![0; n_devices],
            belief: vec![Belief::Alive; n_devices],
        }
    }

    pub fn enabled(&self) -> bool {
        self.suspect_after > 0
    }

    pub fn belief(&self, device: DeviceId) -> Belief {
        self.belief.get(device).copied().unwrap_or_default()
    }

    /// Suspected or Confirmed — the controller is placing around it.
    pub fn is_suspected(&self, device: DeviceId) -> bool {
        self.belief(device) != Belief::Alive
    }

    /// A probe round reached `device`: reset its miss count. Returns
    /// `true` if the device was Suspected/Confirmed and is now cleared
    /// (the caller emits `DeviceCleared`).
    pub fn heartbeat(&mut self, device: DeviceId) -> bool {
        if device >= self.missed.len() {
            return false;
        }
        self.missed[device] = 0;
        if self.belief[device] != Belief::Alive {
            self.belief[device] = Belief::Alive;
            return true;
        }
        false
    }

    /// A probe round failed to reach `device`. Returns the new belief on
    /// a transition (`Alive → Suspected` or `Suspected → Confirmed`),
    /// `None` otherwise.
    pub fn miss(&mut self, device: DeviceId) -> Option<Belief> {
        if !self.enabled() || device >= self.missed.len() {
            return None;
        }
        self.missed[device] = self.missed[device].saturating_add(1);
        let missed = self.missed[device];
        match self.belief[device] {
            Belief::Alive if missed >= self.suspect_after => {
                self.belief[device] = Belief::Suspected;
                Some(Belief::Suspected)
            }
            Belief::Suspected if missed >= self.confirm_threshold => {
                self.belief[device] = Belief::Confirmed;
                Some(Belief::Confirmed)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suspects_after_threshold_and_confirms_later() {
        let mut d = SuspicionDetector::new(2, 2, 2);
        assert!(d.enabled());
        assert_eq!(d.miss(0), None, "first miss is below the threshold");
        assert_eq!(d.miss(0), Some(Belief::Suspected));
        assert!(d.is_suspected(0));
        assert!(!d.is_suspected(1), "per-device state");
        assert_eq!(d.miss(0), None, "between suspect and confirm");
        assert_eq!(d.miss(0), Some(Belief::Confirmed));
        assert_eq!(d.belief(0), Belief::Confirmed);
        assert_eq!(d.miss(0), None, "already confirmed: no more transitions");
    }

    #[test]
    fn heartbeat_clears_and_resets_the_count() {
        let mut d = SuspicionDetector::new(1, 2, 1);
        assert!(!d.heartbeat(0), "clearing an alive device reports nothing");
        d.miss(0);
        assert!(!d.heartbeat(0), "below threshold: nothing to clear");
        d.miss(0);
        assert_eq!(d.miss(0), Some(Belief::Suspected));
        assert!(d.heartbeat(0), "suspected device clears on heartbeat");
        assert_eq!(d.belief(0), Belief::Alive);
        // The count restarted: one miss is not enough again.
        assert_eq!(d.miss(0), None);
    }

    #[test]
    fn zero_suspect_after_is_inert() {
        let mut d = SuspicionDetector::new(1, 0, 2);
        assert!(!d.enabled());
        for _ in 0..100 {
            assert_eq!(d.miss(0), None);
        }
        assert_eq!(d.belief(0), Belief::Alive);
    }

    #[test]
    fn confirm_after_zero_still_leaves_a_suspected_step() {
        let mut d = SuspicionDetector::new(1, 1, 0);
        assert_eq!(d.miss(0), Some(Belief::Suspected));
        assert_eq!(d.miss(0), Some(Belief::Confirmed), "confirm_after 0 acts as 1");
    }

    #[test]
    fn out_of_range_devices_are_ignored() {
        let mut d = SuspicionDetector::new(2, 1, 1);
        assert_eq!(d.miss(7), None);
        assert!(!d.heartbeat(7));
        assert_eq!(d.belief(7), Belief::Alive);
    }
}
