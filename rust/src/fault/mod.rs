//! Fault injection: lossy links, device crashes, and probe failure.
//!
//! The paper's testbed is unreliable by nature — a contended 802.11n
//! medium, probe-based bandwidth estimates that degrade under congestion
//! (Figs. 6–8) — yet the baseline simulator only models *graceful* churn
//! and background traffic. This module expresses the harsher regimes the
//! related work evaluates (preemption-aware offloading under node loss,
//! adaptive serving over lossy links): devices that crash with work in
//! flight, links that lose packets rather than merely slowing, and probe
//! rounds that come back partial or empty.
//!
//! A [`FaultPlan`] is the scenario-level specification. It compiles into
//! the engine-level knobs on [`RunExtras`]:
//!
//! * a crash/recover schedule — [`crate::sim::events::Event::DeviceCrash`]
//!   loses in-flight tasks (flows aborted on the medium, survivors
//!   re-offered to the scheduler as
//!   [`crate::coordinator::scheduler::SchedEvent::Reoffer`]), unlike the
//!   graceful `DeviceLeave`;
//! * a per-packet loss rate — [`crate::sim::netsim::LossyMedium`] re-queues
//!   the lost fraction of every transfer as retransmitted bits;
//! * a per-ping probe-loss rate — probe rounds shrink or vanish, which the
//!   bandwidth estimator must survive (see
//!   [`crate::coordinator::bandwidth::BandwidthEstimator::next_due`]).
//!
//! Everything is seed-deterministic: the random-fault generator and the
//! loss sampling draw from RNG streams derived from the scenario seed,
//! never from ambient randomness — the same scenario produces the same
//! fault trace, run after run and thread count after thread count.

pub mod detector;

use crate::coordinator::task::DeviceId;
use crate::sim::engine::RunExtras;
use crate::time::{secs, SimTime};
use crate::util::Rng;

/// Highest injectable loss probability. Retransmission inflation diverges
/// as p → 1 (every packet re-queued forever); capping keeps expected
/// inflation ≤ 20× and the sampling loop trivially terminating.
pub const MAX_LOSS_RATE: f64 = 0.95;

/// RNG domain tag for the random-fault generator ("FLT").
const FAULT_SEED_TAG: u64 = 0x46_4c54;

/// RNG domain tag for the random-partition generator ("PRT") — a
/// separate stream so adding partitions never perturbs the crash trace.
const PARTITION_SEED_TAG: u64 = 0x50_5254;

/// A fluent fault specification for one scenario run.
///
/// Compose with the builder methods and attach via
/// [`crate::scenario::ScenarioBuilder::faults`] (or the builder's
/// per-knob shorthands), or compile directly into [`RunExtras`] with
/// [`FaultPlan::compile_into`] when driving the engine by hand.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Explicit fault schedule: (time, device, recover?). `false` is a
    /// crash, `true` a recovery.
    pub crashes: Vec<(SimTime, DeviceId, bool)>,
    /// Explicit partition schedule: (time, device, heal?). `false` cuts
    /// the device off the medium (unreachable-but-alive: flows stall,
    /// in-progress compute finishes but results are held until heal),
    /// `true` heals it. Distinct from a crash — nothing is lost.
    pub partitions: Vec<(SimTime, DeviceId, bool)>,
    /// Per-packet loss probability on task transfers, in
    /// `[0, MAX_LOSS_RATE]`.
    pub loss_rate: f64,
    /// Per-ping loss probability on bandwidth-probe rounds, in
    /// `[0, MAX_LOSS_RATE]`.
    pub probe_loss: f64,
    /// Random crash/recover generator: (mean time between failures,
    /// mean time to recovery), seconds. Expanded at compile time from the
    /// scenario seed.
    pub random: Option<(f64, f64)>,
    /// Random partition/heal generator: (mean time between partitions,
    /// mean time to heal), seconds. Expanded from its own seed stream so
    /// it composes with `random` without perturbing the crash trace.
    pub random_partitions: Option<(f64, f64)>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// No faults of any kind (the default plan compiles to a no-op).
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty()
            && self.partitions.is_empty()
            && self.random.is_none()
            && self.random_partitions.is_none()
            && self.loss_rate == 0.0
            && self.probe_loss == 0.0
    }

    /// Device `device` crashes at `at_s` seconds: its in-flight tasks are
    /// lost (not completed) and its flows aborted on the medium.
    pub fn crash_at(mut self, at_s: f64, device: DeviceId) -> Self {
        self.crashes.push((secs(at_s), device, false));
        self
    }

    /// Device `device` comes back at `at_s` seconds with fresh, empty
    /// availability (everything it was running died with the crash).
    pub fn recover_at(mut self, at_s: f64, device: DeviceId) -> Self {
        self.crashes.push((secs(at_s), device, true));
        self
    }

    /// Device `device` becomes unreachable at `at_s` seconds: its flows
    /// stall on the medium (captured, not aborted) and any results it
    /// computes are held undeliverable until the partition heals.
    pub fn partition_at(mut self, at_s: f64, device: DeviceId) -> Self {
        self.partitions.push((secs(at_s), device, false));
        self
    }

    /// Device `device`'s partition heals at `at_s` seconds: stalled
    /// flows resume from their captured progress and held results are
    /// delivered (subject to their original deadlines).
    pub fn heal_at(mut self, at_s: f64, device: DeviceId) -> Self {
        self.partitions.push((secs(at_s), device, true));
        self
    }

    /// Per-packet loss probability on task transfers. The lost fraction
    /// is re-queued as retransmitted bits, inflating transfer times.
    pub fn loss_rate(mut self, p: f64) -> Self {
        self.loss_rate = p.clamp(0.0, MAX_LOSS_RATE);
        self
    }

    /// Per-ping loss probability on probe rounds: rounds come back
    /// partial, or empty (a failed round — no estimator update).
    pub fn probe_loss(mut self, p: f64) -> Self {
        self.probe_loss = p.clamp(0.0, MAX_LOSS_RATE);
        self
    }

    /// Seed-deterministic random crash/recover process: every device
    /// independently alternates exponential up-times (mean `mtbf_s`) and
    /// down-times (mean `mttr_s`). Expanded over the run horizon when the
    /// plan compiles.
    pub fn random_faults(mut self, mtbf_s: f64, mttr_s: f64) -> Self {
        self.random = Some((mtbf_s.max(1.0), mttr_s.max(0.1)));
        self
    }

    /// Seed-deterministic random partition/heal process, analogous to
    /// [`Self::random_faults`] but on its own RNG stream: every device
    /// independently alternates exponential reachable times (mean
    /// `mtbp_s`) and partitioned times (mean `mtth_s`).
    pub fn random_partitions(mut self, mtbp_s: f64, mtth_s: f64) -> Self {
        self.random_partitions = Some((mtbp_s.max(1.0), mtth_s.max(0.1)));
        self
    }

    /// Concrete crash/recover schedule for a fleet of `n_devices` over
    /// `horizon_s` seconds: explicit entries plus the expanded random
    /// process (seeded from `seed` — same seed, same fault trace).
    pub fn schedule(&self, seed: u64, n_devices: usize, horizon_s: f64) -> Vec<(SimTime, DeviceId, bool)> {
        let mut out = self.crashes.clone();
        if let Some((mtbf_s, mttr_s)) = self.random {
            expand_random(&mut out, seed ^ FAULT_SEED_TAG, n_devices, horizon_s, mtbf_s, mttr_s);
        }
        // Stable order: time, then device, crashes before recoveries.
        out.sort_unstable();
        out
    }

    /// Concrete partition/heal schedule, analogous to [`Self::schedule`]
    /// but expanded from the partition seed stream.
    pub fn partition_schedule(
        &self,
        seed: u64,
        n_devices: usize,
        horizon_s: f64,
    ) -> Vec<(SimTime, DeviceId, bool)> {
        let mut out = self.partitions.clone();
        if let Some((mtbp_s, mtth_s)) = self.random_partitions {
            expand_random(&mut out, seed ^ PARTITION_SEED_TAG, n_devices, horizon_s, mtbp_s, mtth_s);
        }
        out.sort_unstable();
        out
    }

    /// Reject malformed *explicit* schedules before they compile: device
    /// IDs past the fleet, and double-crash/double-recover (or
    /// double-partition/double-heal) sequences — a recover without a
    /// preceding crash, or a second crash of an already-down device,
    /// would be silently absorbed by the engine's runtime guards and the
    /// scenario would not mean what it says. Random generators alternate
    /// by construction and are not re-checked here.
    pub fn validate(&self, n_devices: usize) -> anyhow::Result<()> {
        for (what, down_word, up_word, list) in [
            ("crash schedule", "crash", "recover", &self.crashes),
            ("partition schedule", "partition", "heal", &self.partitions),
        ] {
            let mut sorted = list.clone();
            sorted.sort_unstable();
            let mut down = vec![false; n_devices];
            for &(t, device, up) in &sorted {
                if device >= n_devices {
                    anyhow::bail!(
                        "fault plan {what}: device {device} at {t} µs is out of range \
                         (fleet has {n_devices} devices)"
                    );
                }
                if up && !down[device] {
                    anyhow::bail!(
                        "fault plan {what}: {up_word} of device {device} at {t} µs \
                         without a preceding {down_word}"
                    );
                }
                if !up && down[device] {
                    anyhow::bail!(
                        "fault plan {what}: double {down_word} of device {device} at {t} µs \
                         (already down)"
                    );
                }
                down[device] = !up;
            }
        }
        Ok(())
    }

    /// Compile into the engine-level knobs: the concrete fault and
    /// partition schedules plus the medium loss rates. Fails if the
    /// explicit schedules are malformed (see [`Self::validate`]).
    pub fn compile_into(
        &self,
        extras: &mut RunExtras,
        seed: u64,
        n_devices: usize,
        horizon_s: f64,
    ) -> anyhow::Result<()> {
        self.validate(n_devices)?;
        extras.faults = self.schedule(seed, n_devices, horizon_s);
        extras.partitions = self.partition_schedule(seed, n_devices, horizon_s);
        extras.loss_rate = self.loss_rate;
        extras.probe_loss = self.probe_loss;
        Ok(())
    }
}

/// Expand one alternating exponential down/up process per device into
/// `out`, from its own seeded stream.
fn expand_random(
    out: &mut Vec<(SimTime, DeviceId, bool)>,
    stream_seed: u64,
    n_devices: usize,
    horizon_s: f64,
    mean_up_s: f64,
    mean_down_s: f64,
) {
    let mut rng = Rng::seed_from_u64(stream_seed);
    for device in 0..n_devices {
        let mut t = exp_sample(&mut rng, mean_up_s);
        while t < horizon_s {
            out.push((secs(t), device, false));
            let down = exp_sample(&mut rng, mean_down_s);
            if t + down >= horizon_s {
                break; // stays down past the end of input
            }
            t += down;
            out.push((secs(t), device, true));
            t += exp_sample(&mut rng, mean_up_s);
        }
    }
}

/// Inverse-CDF exponential sample with mean `mean_s` (1 − u avoids ln 0).
fn exp_sample(rng: &mut Rng, mean_s: f64) -> f64 {
    -(1.0 - rng.gen_f64()).ln() * mean_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_compiles_to_noop_extras() {
        let mut extras = RunExtras::default();
        let plan = FaultPlan::new();
        assert!(plan.is_empty());
        plan.compile_into(&mut extras, 42, 4, 600.0).unwrap();
        assert!(extras.faults.is_empty());
        assert!(extras.partitions.is_empty());
        assert_eq!(extras.loss_rate, 0.0);
        assert_eq!(extras.probe_loss, 0.0);
    }

    #[test]
    fn validate_rejects_out_of_range_devices() {
        let plan = FaultPlan::new().crash_at(10.0, 4);
        assert!(plan.validate(4).is_err(), "device 4 in a 4-device fleet");
        assert!(plan.validate(5).is_ok());
        let plan = FaultPlan::new().partition_at(10.0, 9);
        assert!(plan.validate(4).is_err());
    }

    #[test]
    fn validate_rejects_double_crash_and_orphan_recover() {
        let double = FaultPlan::new().crash_at(10.0, 1).crash_at(20.0, 1);
        assert!(double.validate(4).is_err(), "double crash without recover");
        let orphan = FaultPlan::new().recover_at(10.0, 1);
        assert!(orphan.validate(4).is_err(), "recover without crash");
        let double_rec =
            FaultPlan::new().crash_at(5.0, 1).recover_at(10.0, 1).recover_at(15.0, 1);
        assert!(double_rec.validate(4).is_err(), "double recover");
        let ok = FaultPlan::new()
            .crash_at(5.0, 1)
            .recover_at(10.0, 1)
            .crash_at(15.0, 1)
            .partition_at(3.0, 0)
            .heal_at(8.0, 0);
        assert!(ok.validate(4).is_ok(), "alternating sequences are fine");
        // Order of builder calls must not matter: validation sorts.
        let unordered = FaultPlan::new().recover_at(10.0, 1).crash_at(5.0, 1);
        assert!(unordered.validate(4).is_ok());
        // Crash and partition streams are independent: partitioning a
        // crashed device is a legal (if cruel) scenario.
        let mixed = FaultPlan::new().crash_at(5.0, 2).partition_at(6.0, 2);
        assert!(mixed.validate(4).is_ok());
        // compile_into surfaces the failure.
        let mut extras = RunExtras::default();
        assert!(double.compile_into(&mut extras, 42, 4, 600.0).is_err());
    }

    #[test]
    fn partition_schedule_is_ordered_and_separate_from_crashes() {
        let plan = FaultPlan::new()
            .crash_at(50.0, 0)
            .heal_at(200.0, 1)
            .partition_at(100.0, 1);
        let crashes = plan.schedule(7, 4, 600.0);
        let parts = plan.partition_schedule(7, 4, 600.0);
        assert_eq!(crashes, vec![(secs(50.0), 0, false)]);
        assert_eq!(parts, vec![(secs(100.0), 1, false), (secs(200.0), 1, true)]);
    }

    #[test]
    fn random_partitions_are_seed_deterministic_and_independent() {
        let plan = FaultPlan::new().random_faults(120.0, 30.0).random_partitions(150.0, 40.0);
        let a = plan.partition_schedule(42, 4, 1800.0);
        let b = plan.partition_schedule(42, 4, 1800.0);
        assert_eq!(a, b, "same seed must give the same partition trace");
        assert!(!a.is_empty());
        // Adding partitions must not perturb the crash trace (separate
        // RNG streams).
        let crashes_with = plan.schedule(42, 4, 1800.0);
        let crashes_without =
            FaultPlan::new().random_faults(120.0, 30.0).schedule(42, 4, 1800.0);
        assert_eq!(crashes_with, crashes_without);
        // And the partition stream alternates per device.
        for d in 0..4usize {
            let mine: Vec<bool> =
                a.iter().filter(|&&(_, dev, _)| dev == d).map(|&(_, _, h)| h).collect();
            for (i, &heal) in mine.iter().enumerate() {
                assert_eq!(heal, i % 2 == 1, "device {d} must alternate: {mine:?}");
            }
        }
    }

    #[test]
    fn explicit_schedule_is_time_ordered() {
        let plan = FaultPlan::new()
            .recover_at(200.0, 1)
            .crash_at(50.0, 1)
            .crash_at(50.0, 0);
        let s = plan.schedule(7, 4, 600.0);
        assert_eq!(
            s,
            vec![
                (secs(50.0), 0, false),
                (secs(50.0), 1, false),
                (secs(200.0), 1, true),
            ]
        );
    }

    #[test]
    fn loss_rates_are_clamped() {
        let plan = FaultPlan::new().loss_rate(2.0).probe_loss(-0.5);
        assert_eq!(plan.loss_rate, MAX_LOSS_RATE);
        assert_eq!(plan.probe_loss, 0.0);
    }

    #[test]
    fn random_faults_are_seed_deterministic() {
        let plan = FaultPlan::new().random_faults(120.0, 30.0);
        let a = plan.schedule(42, 4, 1800.0);
        let b = plan.schedule(42, 4, 1800.0);
        assert_eq!(a, b, "same seed must give the same fault trace");
        let c = plan.schedule(43, 4, 1800.0);
        assert_ne!(a, c, "different seeds should give different traces");
        assert!(!a.is_empty(), "30 min at 2 min MTBF should produce faults");
    }

    #[test]
    fn random_faults_alternate_crash_then_recover_per_device() {
        let plan = FaultPlan::new().random_faults(100.0, 20.0);
        let s = plan.schedule(11, 3, 2000.0);
        for d in 0..3usize {
            let mine: Vec<bool> =
                s.iter().filter(|&&(_, dev, _)| dev == d).map(|&(_, _, r)| r).collect();
            for (i, &recover) in mine.iter().enumerate() {
                assert_eq!(recover, i % 2 == 1, "device {d} sequence must alternate: {mine:?}");
            }
        }
        // Time-ordered overall.
        assert!(s.windows(2).all(|w| w[0].0 <= w[1].0));
    }
}
