//! Fault injection: lossy links, device crashes, and probe failure.
//!
//! The paper's testbed is unreliable by nature — a contended 802.11n
//! medium, probe-based bandwidth estimates that degrade under congestion
//! (Figs. 6–8) — yet the baseline simulator only models *graceful* churn
//! and background traffic. This module expresses the harsher regimes the
//! related work evaluates (preemption-aware offloading under node loss,
//! adaptive serving over lossy links): devices that crash with work in
//! flight, links that lose packets rather than merely slowing, and probe
//! rounds that come back partial or empty.
//!
//! A [`FaultPlan`] is the scenario-level specification. It compiles into
//! the engine-level knobs on [`RunExtras`]:
//!
//! * a crash/recover schedule — [`crate::sim::events::Event::DeviceCrash`]
//!   loses in-flight tasks (flows aborted on the medium, survivors
//!   re-offered to the scheduler as
//!   [`crate::coordinator::scheduler::SchedEvent::Reoffer`]), unlike the
//!   graceful `DeviceLeave`;
//! * a per-packet loss rate — [`crate::sim::netsim::LossyMedium`] re-queues
//!   the lost fraction of every transfer as retransmitted bits;
//! * a per-ping probe-loss rate — probe rounds shrink or vanish, which the
//!   bandwidth estimator must survive (see
//!   [`crate::coordinator::bandwidth::BandwidthEstimator::next_due`]).
//!
//! Everything is seed-deterministic: the random-fault generator and the
//! loss sampling draw from RNG streams derived from the scenario seed,
//! never from ambient randomness — the same scenario produces the same
//! fault trace, run after run and thread count after thread count.

use crate::coordinator::task::DeviceId;
use crate::sim::engine::RunExtras;
use crate::time::{secs, SimTime};
use crate::util::Rng;

/// Highest injectable loss probability. Retransmission inflation diverges
/// as p → 1 (every packet re-queued forever); capping keeps expected
/// inflation ≤ 20× and the sampling loop trivially terminating.
pub const MAX_LOSS_RATE: f64 = 0.95;

/// RNG domain tag for the random-fault generator ("FLT").
const FAULT_SEED_TAG: u64 = 0x46_4c54;

/// A fluent fault specification for one scenario run.
///
/// Compose with the builder methods and attach via
/// [`crate::scenario::ScenarioBuilder::faults`] (or the builder's
/// per-knob shorthands), or compile directly into [`RunExtras`] with
/// [`FaultPlan::compile_into`] when driving the engine by hand.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Explicit fault schedule: (time, device, recover?). `false` is a
    /// crash, `true` a recovery.
    pub crashes: Vec<(SimTime, DeviceId, bool)>,
    /// Per-packet loss probability on task transfers, in
    /// `[0, MAX_LOSS_RATE]`.
    pub loss_rate: f64,
    /// Per-ping loss probability on bandwidth-probe rounds, in
    /// `[0, MAX_LOSS_RATE]`.
    pub probe_loss: f64,
    /// Random crash/recover generator: (mean time between failures,
    /// mean time to recovery), seconds. Expanded at compile time from the
    /// scenario seed.
    pub random: Option<(f64, f64)>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// No faults of any kind (the default plan compiles to a no-op).
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty()
            && self.random.is_none()
            && self.loss_rate == 0.0
            && self.probe_loss == 0.0
    }

    /// Device `device` crashes at `at_s` seconds: its in-flight tasks are
    /// lost (not completed) and its flows aborted on the medium.
    pub fn crash_at(mut self, at_s: f64, device: DeviceId) -> Self {
        self.crashes.push((secs(at_s), device, false));
        self
    }

    /// Device `device` comes back at `at_s` seconds with fresh, empty
    /// availability (everything it was running died with the crash).
    pub fn recover_at(mut self, at_s: f64, device: DeviceId) -> Self {
        self.crashes.push((secs(at_s), device, true));
        self
    }

    /// Per-packet loss probability on task transfers. The lost fraction
    /// is re-queued as retransmitted bits, inflating transfer times.
    pub fn loss_rate(mut self, p: f64) -> Self {
        self.loss_rate = p.clamp(0.0, MAX_LOSS_RATE);
        self
    }

    /// Per-ping loss probability on probe rounds: rounds come back
    /// partial, or empty (a failed round — no estimator update).
    pub fn probe_loss(mut self, p: f64) -> Self {
        self.probe_loss = p.clamp(0.0, MAX_LOSS_RATE);
        self
    }

    /// Seed-deterministic random crash/recover process: every device
    /// independently alternates exponential up-times (mean `mtbf_s`) and
    /// down-times (mean `mttr_s`). Expanded over the run horizon when the
    /// plan compiles.
    pub fn random_faults(mut self, mtbf_s: f64, mttr_s: f64) -> Self {
        self.random = Some((mtbf_s.max(1.0), mttr_s.max(0.1)));
        self
    }

    /// Concrete crash/recover schedule for a fleet of `n_devices` over
    /// `horizon_s` seconds: explicit entries plus the expanded random
    /// process (seeded from `seed` — same seed, same fault trace).
    pub fn schedule(&self, seed: u64, n_devices: usize, horizon_s: f64) -> Vec<(SimTime, DeviceId, bool)> {
        let mut out = self.crashes.clone();
        if let Some((mtbf_s, mttr_s)) = self.random {
            let mut rng = Rng::seed_from_u64(seed ^ FAULT_SEED_TAG);
            for device in 0..n_devices {
                let mut t = exp_sample(&mut rng, mtbf_s);
                while t < horizon_s {
                    out.push((secs(t), device, false));
                    let down = exp_sample(&mut rng, mttr_s);
                    if t + down >= horizon_s {
                        break; // stays down past the end of input
                    }
                    t += down;
                    out.push((secs(t), device, true));
                    t += exp_sample(&mut rng, mtbf_s);
                }
            }
        }
        // Stable order: time, then device, crashes before recoveries.
        out.sort_unstable();
        out
    }

    /// Compile into the engine-level knobs: the concrete fault schedule
    /// plus the medium loss rates.
    pub fn compile_into(&self, extras: &mut RunExtras, seed: u64, n_devices: usize, horizon_s: f64) {
        extras.faults = self.schedule(seed, n_devices, horizon_s);
        extras.loss_rate = self.loss_rate;
        extras.probe_loss = self.probe_loss;
    }
}

/// Inverse-CDF exponential sample with mean `mean_s` (1 − u avoids ln 0).
fn exp_sample(rng: &mut Rng, mean_s: f64) -> f64 {
    -(1.0 - rng.gen_f64()).ln() * mean_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_compiles_to_noop_extras() {
        let mut extras = RunExtras::default();
        let plan = FaultPlan::new();
        assert!(plan.is_empty());
        plan.compile_into(&mut extras, 42, 4, 600.0);
        assert!(extras.faults.is_empty());
        assert_eq!(extras.loss_rate, 0.0);
        assert_eq!(extras.probe_loss, 0.0);
    }

    #[test]
    fn explicit_schedule_is_time_ordered() {
        let plan = FaultPlan::new()
            .recover_at(200.0, 1)
            .crash_at(50.0, 1)
            .crash_at(50.0, 0);
        let s = plan.schedule(7, 4, 600.0);
        assert_eq!(
            s,
            vec![
                (secs(50.0), 0, false),
                (secs(50.0), 1, false),
                (secs(200.0), 1, true),
            ]
        );
    }

    #[test]
    fn loss_rates_are_clamped() {
        let plan = FaultPlan::new().loss_rate(2.0).probe_loss(-0.5);
        assert_eq!(plan.loss_rate, MAX_LOSS_RATE);
        assert_eq!(plan.probe_loss, 0.0);
    }

    #[test]
    fn random_faults_are_seed_deterministic() {
        let plan = FaultPlan::new().random_faults(120.0, 30.0);
        let a = plan.schedule(42, 4, 1800.0);
        let b = plan.schedule(42, 4, 1800.0);
        assert_eq!(a, b, "same seed must give the same fault trace");
        let c = plan.schedule(43, 4, 1800.0);
        assert_ne!(a, c, "different seeds should give different traces");
        assert!(!a.is_empty(), "30 min at 2 min MTBF should produce faults");
    }

    #[test]
    fn random_faults_alternate_crash_then_recover_per_device() {
        let plan = FaultPlan::new().random_faults(100.0, 20.0);
        let s = plan.schedule(11, 3, 2000.0);
        for d in 0..3usize {
            let mine: Vec<bool> =
                s.iter().filter(|&&(_, dev, _)| dev == d).map(|&(_, _, r)| r).collect();
            for (i, &recover) in mine.iter().enumerate() {
                assert_eq!(recover, i % 2 == 1, "device {d} sequence must alternate: {mine:?}");
            }
        }
        // Time-ordered overall.
        assert!(s.windows(2).all(|w| w[0].0 <= w[1].0));
    }
}
