//! Renderers that print each paper figure/table as text rows, using the
//! same series the paper plots (Table I labels: WPS_N, RAS_N, BIT_N),
//! plus a machine-readable JSON export ([`json_rows`]) for sweep results
//! and bench trajectory files (`BENCH_*.json`). JSON is emitted by hand —
//! the offline build has no serde.

use super::Metrics;
use crate::metrics::LatencyStat;

/// Version of the `json_row` field schema. Bump it in the SAME change
/// that adds, removes, or renames a row field — the field-inventory
/// test below fails otherwise, so schema drift can never land silently
/// again (14 fields did exactly that in PR 8). Downstream consumers
/// key their parsers on this.
pub const SCHEMA_VERSION: u32 = 3;

fn header(title: &str) -> String {
    format!("\n=== {title} ===\n")
}

/// Fig. 4 — "Task Completion across various categories": one row per
/// scenario, the completion/violation series the paper plots.
pub fn fig4(runs: &[Metrics]) -> String {
    let mut s = header("Fig. 4 — Task completion across categories");
    s += &format!(
        "{:<8} {:>7} {:>7} {:>6} | {:>9} {:>9} {:>6} | {:>8} {:>8} {:>7} {:>6} | {:>9} {:>9}\n",
        "scenario", "frames", "done", "rate%",
        "hp_alloc", "hp_preempt", "hp_rej",
        "lp_init", "lp_reall", "lp_fail", "viol",
        "off_total", "off_done",
    );
    for m in runs {
        s += &format!(
            "{:<8} {:>7} {:>7} {:>6.1} | {:>9} {:>9} {:>6} | {:>8} {:>8} {:>7} {:>6} | {:>9} {:>9}\n",
            m.label,
            m.frames_total,
            m.frames_completed,
            m.frame_completion_rate() * 100.0,
            m.hp_allocated_no_preempt,
            m.hp_allocated_with_preempt,
            m.hp_rejected,
            m.lp_completed_initial,
            m.lp_completed_realloc,
            m.lp_alloc_failures,
            m.lp_violations,
            m.offloaded_total,
            m.offloaded_completed,
        );
    }
    s
}

/// Fig. 5 — "Scheduling latency by initial allocation and
/// pre-emption/reallocation scenarios for both schedulers".
pub fn fig5(runs: &[Metrics]) -> String {
    let mut s = header("Fig. 5 — Scheduling latency (ms, mean [count])");
    s += &format!(
        "{:<8} {:>16} {:>18} {:>16} {:>18}\n",
        "scenario", "hp_alloc", "hp_preempt", "lp_alloc", "lp_realloc",
    );
    for m in runs {
        s += &format!(
            "{:<8} {:>9.2} [{:>4}] {:>11.2} [{:>4}] {:>9.2} [{:>4}] {:>11.2} [{:>4}]\n",
            m.label,
            m.lat_hp_alloc.mean_ms(),
            m.lat_hp_alloc.count,
            m.lat_hp_preempt.mean_ms(),
            m.lat_hp_preempt.count,
            m.lat_lp_alloc.mean_ms(),
            m.lat_lp_alloc.count,
            m.lat_lp_realloc.mean_ms(),
            m.lat_lp_realloc.count,
        );
    }
    s
}

/// Fig. 6 — "Low-priority high-complexity completion by mechanism"
/// (initial allocation vs reallocation, per bandwidth-interval scenario).
pub fn fig6(runs: &[Metrics]) -> String {
    let mut s = header("Fig. 6 — LP (stage-3) completion by mechanism");
    s += &format!(
        "{:<8} {:>8} {:>9} {:>9} {:>6} {:>7}\n",
        "scenario", "lp_init", "lp_reall", "lp_total", "viol", "fail",
    );
    for m in runs {
        s += &format!(
            "{:<8} {:>8} {:>9} {:>9} {:>6} {:>7}\n",
            m.label,
            m.lp_completed_initial,
            m.lp_completed_realloc,
            m.lp_completed_total(),
            m.lp_violations,
            m.lp_alloc_failures,
        );
    }
    s
}

/// Fig. 7 — "Bandwidth Interval Tests: Task completion across various
/// categories" (same columns as Fig. 4, BIT_N scenarios).
pub fn fig7(runs: &[Metrics]) -> String {
    let mut s = fig4(runs);
    s = s.replace(
        "Fig. 4 — Task completion across categories",
        "Fig. 7 — Bandwidth interval tests: task completion across categories",
    );
    s += &format!(
        "{:<8} {:>9} {:>14} {:>14}\n",
        "scenario", "bw_updates", "rebuild_ops", "busy_ms",
    );
    for m in runs {
        s += &format!(
            "{:<8} {:>9} {:>14} {:>14.1}\n",
            m.label,
            m.bandwidth_updates,
            m.link_rebuild_ops,
            m.controller_busy_us as f64 / 1000.0,
        );
    }
    s
}

/// Fig. 8 — "Network Traffic Test: Task completion across various
/// categories" (duty-cycle scenarios).
pub fn fig8(runs: &[Metrics]) -> String {
    let mut s = fig4(runs);
    s = s.replace(
        "Fig. 4 — Task completion across categories",
        "Fig. 8 — Network traffic test: task completion across categories",
    );
    s += &format!("{:<8} {:>10} {:>12}\n", "scenario", "off_rate%", "est_Mbps");
    for m in runs {
        s += &format!(
            "{:<8} {:>10.1} {:>12.1}\n",
            m.label,
            m.offloaded_completion_rate() * 100.0,
            m.final_bandwidth_estimate_bps / 1e6,
        );
    }
    s
}

/// Table II — "Network traffic test: core allocation of successfully
/// allocated tasks".
pub fn table2(runs: &[Metrics]) -> String {
    let mut s = header("Table II — Core allocation of successfully allocated tasks");
    s += &format!("{:<12}", "Duty Cycle");
    for m in runs {
        s += &format!(" {:>9}", m.label);
    }
    s += "\n";
    s += &format!("{:<12}", "Two Core");
    for m in runs {
        s += &format!(" {:>8.2}%", m.core_mix().0);
    }
    s += "\n";
    s += &format!("{:<12}", "Four Core");
    for m in runs {
        s += &format!(" {:>8.2}%", m.core_mix().1);
    }
    s += "\n";
    s
}

/// Fault-injection summary — one row per scenario with the crash/loss
/// counters (all zero for runs without a `FaultPlan`).
pub fn faults(runs: &[Metrics]) -> String {
    let mut s = header("Faults — crash / loss injection summary");
    s += &format!(
        "{:<10} {:>7} {:>6} {:>6} {:>6} {:>7} {:>8} {:>6} {:>9} | {:>10} {:>10} {:>8}\n",
        "scenario", "crashes", "recov", "lost", "reoff", "placed", "dropped", "in_dl", "mttr_s",
        "probe_lost", "pings_lost", "retx_Mb",
    );
    for m in runs {
        s += &format!(
            "{:<10} {:>7} {:>6} {:>6} {:>6} {:>7} {:>8} {:>6} {:>9.1} | {:>10} {:>10} {:>8.1}\n",
            m.label,
            m.device_crashes,
            m.device_recoveries,
            m.crash_tasks_lost,
            m.crash_tasks_reoffered,
            m.crash_reoffer_placed,
            m.crash_reoffer_dropped,
            m.crash_recovered_in_deadline,
            m.lat_crash_recovery.mean_ms() / 1000.0,
            m.probe_rounds_lost,
            m.probe_pings_lost,
            m.retransmitted_mbits,
        );
    }
    s
}

/// Robustness summary — imperfect failure detection, partitions, and
/// the recovery policy (retries/hedges). All zero on runs with the
/// robustness knobs off (the zero-knob equivalence contract).
pub fn robustness(runs: &[Metrics]) -> String {
    let mut s = header("Robustness — detection, partitions, recovery policy");
    s += &format!(
        "{:<12} {:>5} {:>5} {:>6} {:>9} | {:>5} {:>5} {:>6} {:>6} | {:>6} {:>6} {:>6} {:>6} | {:>7} {:>9}\n",
        "scenario", "susp", "clear", "false", "det_ms",
        "part", "heal", "stall", "held",
        "retry", "hedge", "won", "waste",
        "lp_lost", "stale_ms",
    );
    for m in runs {
        s += &format!(
            "{:<12} {:>5} {:>5} {:>6} {:>9.1} | {:>5} {:>5} {:>6} {:>6} | {:>6} {:>6} {:>6} {:>6} | {:>7} {:>9.1}\n",
            m.label,
            m.devices_suspected,
            m.devices_cleared,
            m.false_suspicions,
            m.lat_detection.mean_ms(),
            m.partitions_started,
            m.partitions_healed,
            m.partition_stalled_flows,
            m.partition_held_results,
            m.retries,
            m.hedges_launched,
            m.hedges_won,
            m.hedges_wasted,
            m.lp_lost,
            m.bw_stale_us as f64 / 1000.0,
        );
    }
    s
}

/// Latency percentiles per priority class — scheduling and end-to-end,
/// p50/p95/p99 in ms. Means alone hide the tail under bursty arrivals;
/// this is the table that shows it.
pub fn percentiles(runs: &[Metrics]) -> String {
    let mut s = header("Latency percentiles (ms) — p50 / p95 / p99 per priority class");
    s += &format!(
        "{:<12} {:>24} {:>24} {:>26} {:>26}\n",
        "scenario", "hp_sched", "lp_sched", "hp_e2e", "lp_e2e",
    );
    let trio = |l: &LatencyStat| format!("{:.1}/{:.1}/{:.1}", l.p50_ms(), l.p95_ms(), l.p99_ms());
    for m in runs {
        s += &format!(
            "{:<12} {:>24} {:>24} {:>26} {:>26}\n",
            m.label,
            trio(&m.lat_hp_alloc),
            trio(&m.lat_lp_alloc),
            trio(&m.lat_hp_e2e),
            trio(&m.lat_lp_e2e),
        );
    }
    s
}

/// Delivered-accuracy summary — the degraded-inference axis: deadline-met
/// counts, degradation traffic, per-rung completions, and the two
/// accuracy ratios the frontier trades against each other. On ladder-free
/// runs every completion sits on rung 0 at accuracy 1.0.
pub fn accuracy(runs: &[Metrics]) -> String {
    let mut s = header("Accuracy — delivered inference accuracy under deadline pressure");
    s += &format!(
        "{:<14} {:>7} {:>7} {:>9} {:>9} {:>20} {:>9} {:>9}\n",
        "scenario", "lp_gen", "dl_met", "degr_pl", "degr_done", "per-rung", "mean_acc", "acc_rate",
    );
    for m in runs {
        // Compact per-rung completion counts: trailing zero rungs are
        // dropped, rung 0 always shown.
        let depth = m
            .rung_completions
            .iter()
            .rposition(|&c| c > 0)
            .map(|i| i + 1)
            .unwrap_or(1);
        let rungs = m.rung_completions[..depth]
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join("/");
        s += &format!(
            "{:<14} {:>7} {:>7} {:>9} {:>9} {:>20} {:>9.3} {:>9.3}\n",
            m.label,
            m.lp_generated,
            m.lp_deadline_met(),
            m.degraded_placements,
            m.degraded_completions,
            rungs,
            m.accuracy_per_deadline_met(),
            m.delivered_accuracy_rate(),
        );
    }
    s
}

/// Anytime-inference summary — the imprecise-computation axis: how often
/// the deadline-pressure controller cut running executions short, how
/// much refinement was skipped, and the deadline/accuracy headline the
/// truncation traded. All zero on runs without stage plans (and with
/// `pressure_check_s` at its 0.0 default) — the zero-knob contract.
pub fn anytime(runs: &[Metrics]) -> String {
    let mut s = header("Anytime — mid-flight stage truncation under deadline pressure");
    s += &format!(
        "{:<16} {:>7} {:>7} {:>6} {:>7} | {:>7} {:>7} {:>7} | {:>9} {:>9}\n",
        "scenario", "lp_gen", "dl_met", "viol", "lost", "surveys", "cuts", "trunc", "stages_sk", "acc_rate",
    );
    for m in runs {
        s += &format!(
            "{:<16} {:>7} {:>7} {:>6} {:>7} | {:>7} {:>7} {:>7} | {:>9} {:>9.3}\n",
            m.label,
            m.lp_generated,
            m.lp_deadline_met(),
            m.lp_violations,
            m.lp_lost,
            m.pressure_events,
            m.pressure_cuts,
            m.truncated_completions,
            m.stages_skipped,
            m.delivered_accuracy_rate(),
        );
    }
    s
}

/// Energy & cloud-tier summary — fleet joules by component, the
/// efficiency ratios the energy-aware scheduler optimises, battery
/// depletions, and cloud offload traffic. All zero on runs without an
/// [`crate::energy::EnergyModel`] / cloud tier.
pub fn energy(runs: &[Metrics]) -> String {
    let mut s = header("Energy — fleet joules, battery budgets, cloud tier");
    s += &format!(
        "{:<14} {:>9} {:>9} {:>7} {:>7} {:>9} {:>7} {:>9} | {:>6} {:>8} | {:>7} {:>7} {:>6}\n",
        "scenario", "idle_J", "active_J", "tx_J", "rx_J", "total_J", "J/task", "met/kJ",
        "drain", "min_batJ",
        "cl_off", "cl_done", "cl%",
    );
    for m in runs {
        // Lowest remaining battery in the fleet ("mains" when no
        // capacity was configured).
        let min_bat = m
            .battery_final_j
            .iter()
            .copied()
            .reduce(f64::min)
            .map(|j| format!("{j:.0}J"))
            .unwrap_or_else(|| "mains".into());
        s += &format!(
            "{:<14} {:>9.1} {:>9.1} {:>7.1} {:>7.1} {:>9.1} {:>7.2} {:>9.3} | {:>6} {:>8} | {:>7} {:>7} {:>6.1}\n",
            m.label,
            m.energy_idle_j,
            m.energy_active_j,
            m.energy_tx_j,
            m.energy_rx_j,
            m.energy_total_j,
            m.joules_per_task(),
            m.deadline_met_per_kj(),
            m.battery_depletions,
            min_bat,
            m.cloud_offloads,
            m.cloud_completions,
            m.cloud_offload_rate() * 100.0,
        );
    }
    s
}

/// Generative-workload summary — offered load, admission drops, and the
/// completion headline (all zero on trace-only runs).
pub fn loadgen(runs: &[Metrics]) -> String {
    let mut s = header("Loadgen — offered load and admission accounting");
    s += &format!(
        "{:<12} {:>8} {:>9} {:>11} {:>7} {:>7} | {:>7} {:>6} {:>6} {:>8}\n",
        "scenario", "arrivals", "offered", "offered_Mb", "drops", "drop%",
        "units", "done", "rate%", "lp_viol",
    );
    for m in runs {
        s += &format!(
            "{:<12} {:>8} {:>9} {:>11.1} {:>7} {:>7.1} | {:>7} {:>6} {:>6.1} {:>8}\n",
            m.label,
            m.gen_arrivals,
            m.offered_tasks,
            m.offered_mbits,
            m.admission_dropped,
            m.admission_drop_rate() * 100.0,
            m.frames_total,
            m.frames_completed,
            m.frame_completion_rate() * 100.0,
            m.lp_violations,
        );
    }
    s
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Finite-only f64 rendering (rust's `{}` for finite f64 is valid JSON).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn json_latency(s: &LatencyStat) -> String {
    format!(
        "{{\"count\": {}, \"mean_ms\": {}, \"p50_ms\": {}, \"p95_ms\": {}, \
         \"p99_ms\": {}, \"max_ms\": {}}}",
        s.count,
        json_f64(s.mean_ms()),
        json_f64(s.p50_ms()),
        json_f64(s.p95_ms()),
        json_f64(s.p99_ms()),
        json_f64(s.max_ms())
    )
}

/// One metrics row as a JSON object (every counter the figures use).
pub fn json_row(m: &Metrics) -> String {
    let mut f = Vec::new();
    f.push(format!("\"schema_version\": {SCHEMA_VERSION}"));
    f.push(format!("\"label\": \"{}\"", json_escape(&m.label)));
    f.push(format!("\"frames_total\": {}", m.frames_total));
    f.push(format!("\"frames_completed\": {}", m.frames_completed));
    f.push(format!("\"frame_completion_rate\": {}", json_f64(m.frame_completion_rate())));
    f.push(format!("\"hp_generated\": {}", m.hp_generated));
    f.push(format!("\"hp_allocated_no_preempt\": {}", m.hp_allocated_no_preempt));
    f.push(format!("\"hp_allocated_with_preempt\": {}", m.hp_allocated_with_preempt));
    f.push(format!("\"hp_rejected\": {}", m.hp_rejected));
    f.push(format!("\"hp_completed\": {}", m.hp_completed));
    f.push(format!("\"hp_violations\": {}", m.hp_violations));
    f.push(format!("\"lp_generated\": {}", m.lp_generated));
    f.push(format!("\"lp_allocated_initial\": {}", m.lp_allocated_initial));
    f.push(format!("\"lp_alloc_failures\": {}", m.lp_alloc_failures));
    f.push(format!("\"lp_completed_initial\": {}", m.lp_completed_initial));
    f.push(format!("\"lp_completed_realloc\": {}", m.lp_completed_realloc));
    f.push(format!("\"lp_violations\": {}", m.lp_violations));
    f.push(format!("\"lp_preempted\": {}", m.lp_preempted));
    f.push(format!("\"lp_realloc_attempts\": {}", m.lp_realloc_attempts));
    f.push(format!("\"lp_realloc_success\": {}", m.lp_realloc_success));
    f.push(format!("\"offloaded_total\": {}", m.offloaded_total));
    f.push(format!("\"offloaded_completed\": {}", m.offloaded_completed));
    f.push(format!("\"lat_hp_alloc\": {}", json_latency(&m.lat_hp_alloc)));
    f.push(format!("\"lat_hp_preempt\": {}", json_latency(&m.lat_hp_preempt)));
    f.push(format!("\"lat_lp_alloc\": {}", json_latency(&m.lat_lp_alloc)));
    f.push(format!("\"lat_lp_realloc\": {}", json_latency(&m.lat_lp_realloc)));
    f.push(format!("\"lat_hp_e2e\": {}", json_latency(&m.lat_hp_e2e)));
    f.push(format!("\"lat_lp_e2e\": {}", json_latency(&m.lat_lp_e2e)));
    f.push(format!("\"gen_arrivals\": {}", m.gen_arrivals));
    f.push(format!("\"offered_tasks\": {}", m.offered_tasks));
    f.push(format!("\"offered_mbits\": {}", json_f64(m.offered_mbits)));
    f.push(format!("\"admission_dropped\": {}", m.admission_dropped));
    f.push(format!("\"offline_dropped\": {}", m.offline_dropped));
    f.push(format!("\"accuracy_sum\": {}", json_f64(m.accuracy_sum)));
    f.push(format!(
        "\"accuracy_per_deadline_met\": {}",
        json_f64(m.accuracy_per_deadline_met())
    ));
    f.push(format!(
        "\"delivered_accuracy_rate\": {}",
        json_f64(m.delivered_accuracy_rate())
    ));
    f.push(format!("\"degraded_placements\": {}", m.degraded_placements));
    f.push(format!("\"degraded_completions\": {}", m.degraded_completions));
    f.push(format!(
        "\"rung_completions\": [{}]",
        m.rung_completions.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(", ")
    ));
    f.push(format!("\"two_core_allocs\": {}", m.two_core_allocs));
    f.push(format!("\"four_core_allocs\": {}", m.four_core_allocs));
    f.push(format!("\"churn_joins\": {}", m.churn_joins));
    f.push(format!("\"churn_leaves\": {}", m.churn_leaves));
    f.push(format!("\"churn_evicted\": {}", m.churn_evicted));
    f.push(format!("\"device_crashes\": {}", m.device_crashes));
    f.push(format!("\"device_recoveries\": {}", m.device_recoveries));
    f.push(format!("\"crash_tasks_lost\": {}", m.crash_tasks_lost));
    f.push(format!("\"crash_tasks_reoffered\": {}", m.crash_tasks_reoffered));
    f.push(format!("\"crash_reoffer_placed\": {}", m.crash_reoffer_placed));
    f.push(format!("\"crash_reoffer_dropped\": {}", m.crash_reoffer_dropped));
    f.push(format!("\"crash_recovered_in_deadline\": {}", m.crash_recovered_in_deadline));
    f.push(format!("\"lat_crash_recovery\": {}", json_latency(&m.lat_crash_recovery)));
    f.push(format!("\"probe_rounds_lost\": {}", m.probe_rounds_lost));
    f.push(format!("\"probe_pings_lost\": {}", m.probe_pings_lost));
    f.push(format!("\"retransmitted_mbits\": {}", json_f64(m.retransmitted_mbits)));
    f.push(format!("\"bandwidth_updates\": {}", m.bandwidth_updates));
    f.push(format!("\"link_rebuild_ops\": {}", m.link_rebuild_ops));
    f.push(format!(
        "\"final_bandwidth_estimate_bps\": {}",
        json_f64(m.final_bandwidth_estimate_bps)
    ));
    f.push(format!("\"controller_busy_us\": {}", m.controller_busy_us));
    f.push(format!(
        "\"reject_reasons\": [{}, {}, {}, {}]",
        m.reject_reasons[0], m.reject_reasons[1], m.reject_reasons[2], m.reject_reasons[3]
    ));
    f.push(format!("\"energy_idle_j\": {}", json_f64(m.energy_idle_j)));
    f.push(format!("\"energy_active_j\": {}", json_f64(m.energy_active_j)));
    f.push(format!("\"energy_tx_j\": {}", json_f64(m.energy_tx_j)));
    f.push(format!("\"energy_rx_j\": {}", json_f64(m.energy_rx_j)));
    f.push(format!("\"energy_total_j\": {}", json_f64(m.energy_total_j)));
    f.push(format!("\"joules_per_task\": {}", json_f64(m.joules_per_task())));
    f.push(format!("\"deadline_met_per_kj\": {}", json_f64(m.deadline_met_per_kj())));
    f.push(format!("\"battery_depletions\": {}", m.battery_depletions));
    f.push(format!(
        "\"battery_final_j\": [{}]",
        m.battery_final_j.iter().map(|j| json_f64(*j)).collect::<Vec<_>>().join(", ")
    ));
    f.push(format!("\"cloud_offloads\": {}", m.cloud_offloads));
    f.push(format!("\"cloud_completions\": {}", m.cloud_completions));
    f.push(format!("\"retries\": {}", m.retries));
    f.push(format!("\"hedges_launched\": {}", m.hedges_launched));
    f.push(format!("\"hedges_won\": {}", m.hedges_won));
    f.push(format!("\"hedges_wasted\": {}", m.hedges_wasted));
    f.push(format!("\"false_suspicions\": {}", m.false_suspicions));
    f.push(format!("\"devices_suspected\": {}", m.devices_suspected));
    f.push(format!("\"devices_cleared\": {}", m.devices_cleared));
    f.push(format!("\"lat_detection\": {}", json_latency(&m.lat_detection)));
    f.push(format!("\"partitions_started\": {}", m.partitions_started));
    f.push(format!("\"partitions_healed\": {}", m.partitions_healed));
    f.push(format!("\"partition_stalled_flows\": {}", m.partition_stalled_flows));
    f.push(format!("\"partition_held_results\": {}", m.partition_held_results));
    f.push(format!("\"lp_lost\": {}", m.lp_lost));
    f.push(format!("\"bw_stale_us\": {}", m.bw_stale_us));
    f.push(format!("\"trace_events\": {}", m.trace_events));
    f.push(format!("\"medium_drain_ops\": {}", m.medium_drain_ops));
    f.push(format!("\"queue_compactions\": {}", m.queue_compactions));
    f.push(format!("\"phase_dispatch_ns\": {}", m.phase_dispatch_ns));
    f.push(format!("\"phase_sched_ns\": {}", m.phase_sched_ns));
    f.push(format!("\"phase_medium_ns\": {}", m.phase_medium_ns));
    f.push(format!("\"phase_compact_ns\": {}", m.phase_compact_ns));
    f.push(format!("\"truncated_completions\": {}", m.truncated_completions));
    f.push(format!("\"stages_skipped\": {}", m.stages_skipped));
    f.push(format!("\"pressure_events\": {}", m.pressure_events));
    f.push(format!("\"pressure_cuts\": {}", m.pressure_cuts));
    format!("{{{}}}", f.join(", "))
}

/// A sweep result as a JSON array of row objects (stable field order, one
/// row per line — diffable and trivially parseable).
pub fn json_rows(runs: &[Metrics]) -> String {
    let mut s = String::from("[\n");
    for (i, m) in runs.iter().enumerate() {
        s.push_str("  ");
        s.push_str(&json_row(m));
        if i + 1 < runs.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("]\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(label: &str) -> Metrics {
        let mut m = Metrics::new(label);
        m.frames_total = 100;
        m.frames_completed = 73;
        m.two_core_allocs = 96;
        m.four_core_allocs = 4;
        m.lat_hp_alloc.record(1200);
        m
    }

    #[test]
    fn renders_contain_labels_and_rates() {
        let runs = vec![sample("WPS_1"), sample("RAS_1")];
        let f4 = fig4(&runs);
        assert!(f4.contains("WPS_1"));
        assert!(f4.contains("RAS_1"));
        assert!(f4.contains("73.0"));
        let f5 = fig5(&runs);
        assert!(f5.contains("1.20"));
        let t2 = table2(&runs);
        assert!(t2.contains("96.00%"));
        assert!(t2.contains("Four Core"));
        assert!(fig6(&runs).contains("lp_total"));
        assert!(fig7(&runs).contains("bw_updates"));
        assert!(fig8(&runs).contains("est_Mbps"));
    }

    #[test]
    fn percentile_and_loadgen_tables_render() {
        let mut m = sample("RAS_poisson6");
        for v in [5_000u64, 50_000, 900_000] {
            m.lat_lp_e2e.record(v);
        }
        m.gen_arrivals = 40;
        m.offered_tasks = 120;
        m.offered_mbits = 880.0;
        m.admission_dropped = 30;
        let p = percentiles(&[m.clone()]);
        assert!(p.contains("RAS_poisson6"));
        assert!(p.contains("p50 / p95 / p99"));
        let l = loadgen(&[m]);
        assert!(l.contains("offered_Mb"));
        assert!(l.contains("120"));
        assert!(l.contains("25.0"), "drop rate column: {l}");
    }

    #[test]
    fn accuracy_table_renders_rungs_and_ratios() {
        let mut m = sample("RAS_r24d3");
        m.lp_generated = 40;
        m.lp_completed_initial = 8;
        m.lp_completed_realloc = 2;
        m.accuracy_sum = 0.97 * 6.0 + 0.78 * 4.0;
        m.rung_completions[0] = 6;
        m.rung_completions[2] = 4;
        m.degraded_completions = 4;
        m.degraded_placements = 5;
        let a = accuracy(&[m.clone()]);
        assert!(a.contains("RAS_r24d3"));
        assert!(a.contains("6/0/4"), "per-rung column lost: {a}");
        assert!(a.contains("mean_acc"));
        // Ladder-free rows render a single rung-0 count.
        let plain = sample("WPS_1");
        let a = accuracy(&[plain]);
        assert!(a.contains(" 0 "), "{a}");
    }

    #[test]
    fn faults_table_renders_counters() {
        let mut m = sample("RAS_4F");
        m.device_crashes = 2;
        m.crash_tasks_lost = 5;
        m.crash_tasks_reoffered = 3;
        m.probe_rounds_lost = 1;
        let f = faults(&[m]);
        assert!(f.contains("RAS_4F"));
        assert!(f.contains("crash / loss injection"));
        assert!(f.contains("in_dl"));
    }

    #[test]
    fn robustness_table_renders_counters() {
        let mut m = sample("RAS_chaos");
        m.devices_suspected = 3;
        m.false_suspicions = 1;
        m.lat_detection.record(250_000);
        m.partitions_started = 2;
        m.partitions_healed = 2;
        m.retries = 7;
        m.hedges_launched = 4;
        m.hedges_won = 1;
        m.hedges_wasted = 3;
        m.lp_lost = 2;
        m.bw_stale_us = 1_500_000;
        let r = robustness(&[m]);
        assert!(r.contains("RAS_chaos"));
        assert!(r.contains("det_ms"));
        assert!(r.contains("250.0"), "detection lag column: {r}");
        assert!(r.contains("1500.0"), "stale_ms column: {r}");
    }

    #[test]
    fn json_rows_are_wellformed_and_complete() {
        let runs = vec![sample("WPS_1"), sample("RAS \"odd\"\\label")];
        let j = json_rows(&runs);
        // Structure: an array with one object per row.
        assert!(j.trim_start().starts_with('['));
        assert!(j.trim_end().ends_with(']'));
        assert_eq!(j.matches("\"label\"").count(), 2);
        // Escaping: the quote and backslash survive as JSON escapes.
        assert!(j.contains("RAS \\\"odd\\\"\\\\label"));
        // Field spot checks.
        assert!(j.contains("\"frames_total\": 100"));
        assert!(j.contains("\"frame_completion_rate\": 0.73"));
        assert!(j.contains("\"lat_hp_alloc\": {\"count\": 1, \"mean_ms\": 1.2"));
        assert!(j.contains("\"p95_ms\":"));
        assert!(j.contains("\"lat_lp_e2e\":"));
        assert!(j.contains("\"offered_tasks\": 0"));
        assert!(j.contains("\"admission_dropped\": 0"));
        assert!(j.contains("\"offline_dropped\": 0"));
        assert!(j.contains("\"accuracy_sum\": 0"));
        assert!(j.contains("\"accuracy_per_deadline_met\": 0"));
        assert!(j.contains("\"delivered_accuracy_rate\": 0"));
        assert!(j.contains("\"degraded_completions\": 0"));
        assert!(j.contains("\"rung_completions\": [0, 0, 0, 0, 0, 0, 0, 0]"));
        assert!(j.contains("\"reject_reasons\": [0, 0, 0, 0]"));
        assert!(j.contains("\"device_crashes\": 0"));
        assert!(j.contains("\"crash_recovered_in_deadline\": 0"));
        assert!(j.contains("\"retransmitted_mbits\": 0"));
        // Energy/cloud fields render as zeros/empty on energy-less runs
        // (the zero-model byte-identity contract).
        assert!(j.contains("\"energy_total_j\": 0"));
        assert!(j.contains("\"joules_per_task\": 0"));
        assert!(j.contains("\"deadline_met_per_kj\": 0"));
        assert!(j.contains("\"battery_depletions\": 0"));
        assert!(j.contains("\"battery_final_j\": []"));
        assert!(j.contains("\"cloud_offloads\": 0"));
        assert!(j.contains("\"cloud_completions\": 0"));
        // Robustness fields render as zeros on knob-off runs (the
        // zero-knob byte-identity contract).
        assert!(j.contains("\"retries\": 0"));
        assert!(j.contains("\"hedges_launched\": 0"));
        assert!(j.contains("\"false_suspicions\": 0"));
        assert!(j.contains("\"lat_detection\": {\"count\": 0"));
        assert!(j.contains("\"partitions_started\": 0"));
        assert!(j.contains("\"partition_held_results\": 0"));
        assert!(j.contains("\"lp_lost\": 0"));
        assert!(j.contains("\"bw_stale_us\": 0"));
        // Anytime fields render as zeros on plan-less runs (same contract).
        assert!(j.contains("\"truncated_completions\": 0"));
        assert!(j.contains("\"stages_skipped\": 0"));
        assert!(j.contains("\"pressure_events\": 0"));
        assert!(j.contains("\"pressure_cuts\": 0"));
        // Balanced braces (cheap well-formedness proxy without a parser).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn anytime_table_renders_truncation_counters() {
        let mut m = sample("GREEDY_r24d3");
        m.lp_generated = 50;
        m.lp_completed_initial = 30;
        m.pressure_events = 9;
        m.pressure_cuts = 7;
        m.truncated_completions = 6;
        m.stages_skipped = 11;
        m.accuracy_sum = 24.5;
        let a = anytime(&[m]);
        assert!(a.contains("GREEDY_r24d3"));
        assert!(a.contains("stages_sk"));
        assert!(a.contains("11"), "stages skipped column: {a}");
        assert!(a.contains("0.490"), "accuracy goodput column: {a}");
    }

    #[test]
    fn energy_table_renders_components_and_battery() {
        let mut m = sample("ENERGY_b1500");
        m.energy_idle_j = 400.0;
        m.energy_active_j = 90.0;
        m.energy_tx_j = 7.5;
        m.energy_rx_j = 5.0;
        m.energy_total_j = 502.5;
        m.battery_depletions = 1;
        m.battery_final_j = vec![0.0, 812.0, 640.5, 990.0];
        m.cloud_offloads = 12;
        m.cloud_completions = 10;
        m.lp_allocated_initial = 24;
        let e = energy(&[m.clone()]);
        assert!(e.contains("ENERGY_b1500"));
        assert!(e.contains("502.5"));
        assert!(e.contains("0J"), "min battery column: {e}");
        assert!(e.contains("met/kJ"));
        // Mains-powered rows say so instead of faking a level.
        m.battery_final_j.clear();
        assert!(energy(&[m]).contains("mains"));
    }

    /// Top-level key names of a `json_row` object, in emission order.
    /// Depth-tracked so nested object keys (the latency stats) and any
    /// string *values* are skipped.
    fn top_level_keys(row: &str) -> Vec<String> {
        let mut keys = Vec::new();
        let mut depth = 0i32;
        let mut chars = row.char_indices().peekable();
        while let Some((_, c)) = chars.next() {
            match c {
                '{' | '[' => depth += 1,
                '}' | ']' => depth -= 1,
                '"' => {
                    // Collect the string literal (json_escape never emits
                    // a lone backslash, so \" is the only escape to skip).
                    let mut lit = String::new();
                    let mut esc = false;
                    for (_, d) in chars.by_ref() {
                        if esc {
                            esc = false;
                            lit.push(d);
                        } else if d == '\\' {
                            esc = true;
                        } else if d == '"' {
                            break;
                        } else {
                            lit.push(d);
                        }
                    }
                    // A key is a depth-1 string followed by a colon.
                    let is_key = depth == 1
                        && matches!(chars.peek(), Some(&(_, next)) if next == ':');
                    if is_key {
                        keys.push(lit);
                    }
                }
                _ => {}
            }
        }
        keys
    }

    #[test]
    fn field_inventory_matches_schema_version() {
        // The contract: adding, removing, renaming, or reordering a
        // `json_row` field REQUIRES bumping `SCHEMA_VERSION` and
        // updating this inventory in the same change. If this test just
        // failed on you: append/edit the inventory below AND bump the
        // version — both, together, nothing else makes it pass.
        assert_eq!(SCHEMA_VERSION, 3, "the inventory below describes schema v3");
        const EXPECTED: &[&str] = &[
            "schema_version",
            "label",
            "frames_total",
            "frames_completed",
            "frame_completion_rate",
            "hp_generated",
            "hp_allocated_no_preempt",
            "hp_allocated_with_preempt",
            "hp_rejected",
            "hp_completed",
            "hp_violations",
            "lp_generated",
            "lp_allocated_initial",
            "lp_alloc_failures",
            "lp_completed_initial",
            "lp_completed_realloc",
            "lp_violations",
            "lp_preempted",
            "lp_realloc_attempts",
            "lp_realloc_success",
            "offloaded_total",
            "offloaded_completed",
            "lat_hp_alloc",
            "lat_hp_preempt",
            "lat_lp_alloc",
            "lat_lp_realloc",
            "lat_hp_e2e",
            "lat_lp_e2e",
            "gen_arrivals",
            "offered_tasks",
            "offered_mbits",
            "admission_dropped",
            "offline_dropped",
            "accuracy_sum",
            "accuracy_per_deadline_met",
            "delivered_accuracy_rate",
            "degraded_placements",
            "degraded_completions",
            "rung_completions",
            "two_core_allocs",
            "four_core_allocs",
            "churn_joins",
            "churn_leaves",
            "churn_evicted",
            "device_crashes",
            "device_recoveries",
            "crash_tasks_lost",
            "crash_tasks_reoffered",
            "crash_reoffer_placed",
            "crash_reoffer_dropped",
            "crash_recovered_in_deadline",
            "lat_crash_recovery",
            "probe_rounds_lost",
            "probe_pings_lost",
            "retransmitted_mbits",
            "bandwidth_updates",
            "link_rebuild_ops",
            "final_bandwidth_estimate_bps",
            "controller_busy_us",
            "reject_reasons",
            "energy_idle_j",
            "energy_active_j",
            "energy_tx_j",
            "energy_rx_j",
            "energy_total_j",
            "joules_per_task",
            "deadline_met_per_kj",
            "battery_depletions",
            "battery_final_j",
            "cloud_offloads",
            "cloud_completions",
            "retries",
            "hedges_launched",
            "hedges_won",
            "hedges_wasted",
            "false_suspicions",
            "devices_suspected",
            "devices_cleared",
            "lat_detection",
            "partitions_started",
            "partitions_healed",
            "partition_stalled_flows",
            "partition_held_results",
            "lp_lost",
            "bw_stale_us",
            "trace_events",
            "medium_drain_ops",
            "queue_compactions",
            "phase_dispatch_ns",
            "phase_sched_ns",
            "phase_medium_ns",
            "phase_compact_ns",
            "truncated_completions",
            "stages_skipped",
            "pressure_events",
            "pressure_cuts",
        ];
        // An awkward label exercises the key/value discrimination: its
        // escaped quotes and colons must not read as keys.
        let mut m = sample("odd \"label\": tricky");
        m.battery_final_j = vec![1.0, 2.0];
        let got = top_level_keys(&json_row(&m));
        assert_eq!(
            got,
            EXPECTED.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
            "json_row fields drifted from the schema inventory — update \
             EXPECTED and bump SCHEMA_VERSION together"
        );
    }

    #[test]
    fn json_f64_handles_non_finite() {
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
        assert_eq!(json_f64(0.5), "0.5");
    }
}
