//! Experiment metrics: everything the paper's figures and tables report.
//!
//! The evaluation (Section VI) tracks frame completion rate, per-category
//! task completion (high/low priority, with/without preemption or
//! reallocation, offloaded), deadline violations, scheduling latencies by
//! scenario, and the core-allocation mix of Table II.

pub mod report;


use std::collections::BTreeMap;

use crate::coordinator::task::MAX_RUNGS;
use crate::time::{as_millis, SimDuration};

/// Log-linear sub-bucket bits: each power-of-two octave splits into
/// 2^SUB = 16 sub-buckets, bounding the relative quantile error at
/// 1/16 ≈ 6 % (values below 2^(SUB+1) are exact).
const SUB: u32 = 4;

/// Bucket index for a µs value (HDR-style log-linear).
fn bucket_of(v: u64) -> u32 {
    let linear_max = 1u64 << (SUB + 1); // 32: exact region
    if v < linear_max {
        return v as u32;
    }
    let msb = 63 - v.leading_zeros(); // ≥ SUB + 1
    let sub = ((v >> (msb - SUB)) & ((1 << SUB) - 1)) as u32;
    linear_max as u32 + (msb - SUB - 1) * (1 << SUB) + sub
}

/// Representative (midpoint) µs value of a bucket.
fn bucket_value(b: u32) -> u64 {
    let linear_max = 1u32 << (SUB + 1);
    if b < linear_max {
        return b as u64;
    }
    let rel = b - linear_max;
    let octave = rel / (1 << SUB) + SUB + 1;
    let sub = (rel % (1 << SUB)) as u64;
    let width = 1u64 << (octave - SUB);
    (1u64 << octave) + sub * width + width / 2
}

/// Streaming latency statistics, in µs: count / mean / min / max plus a
/// sparse log-linear histogram for tail quantiles (p50/p95/p99 within
/// ≈6 % relative error) — means alone hide tail behaviour under bursty
/// arrivals.
#[derive(Debug, Clone, Default)]
pub struct LatencyStat {
    pub count: u64,
    pub sum_us: u64,
    pub min_us: u64,
    pub max_us: u64,
    /// bucket index → count (sparse; deterministic iteration order).
    hist: BTreeMap<u32, u64>,
}

impl LatencyStat {
    pub fn record(&mut self, lat: SimDuration) {
        if self.count == 0 {
            self.min_us = lat;
            self.max_us = lat;
        } else {
            self.min_us = self.min_us.min(lat);
            self.max_us = self.max_us.max(lat);
        }
        self.count += 1;
        self.sum_us += lat;
        *self.hist.entry(bucket_of(lat)).or_insert(0) += 1;
    }

    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        as_millis(self.sum_us / self.count)
    }

    pub fn max_ms(&self) -> f64 {
        as_millis(self.max_us)
    }

    /// Nearest-rank quantile in µs, `q` in [0, 1]. Exact below 32 µs,
    /// within ≈6 % relative error above; clamped to the observed
    /// min/max so p0/p100 are exact.
    pub fn percentile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (&b, &c) in &self.hist {
            seen += c;
            if seen >= rank {
                return bucket_value(b).clamp(self.min_us, self.max_us);
            }
        }
        self.max_us
    }

    pub fn p50_ms(&self) -> f64 {
        as_millis(self.percentile_us(0.50))
    }

    pub fn p95_ms(&self) -> f64 {
        as_millis(self.percentile_us(0.95))
    }

    pub fn p99_ms(&self) -> f64 {
        as_millis(self.percentile_us(0.99))
    }
}

/// All counters for one experiment run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Scenario label (Table I: WPS_N / RAS_N / BIT_N ...).
    pub label: String,

    // ---- frames (Fig. 4 / 7 / 8 headline) ----
    /// Frames that generated work (trace value ≥ 0).
    pub frames_total: u64,
    /// Frames whose HP task and all LP tasks completed in time.
    pub frames_completed: u64,

    // ---- high-priority tasks ----
    pub hp_generated: u64,
    pub hp_allocated_no_preempt: u64,
    pub hp_allocated_with_preempt: u64,
    pub hp_rejected: u64,
    pub hp_completed: u64,
    pub hp_violations: u64,

    // ---- low-priority tasks ----
    pub lp_generated: u64,
    pub lp_allocated_initial: u64,
    pub lp_alloc_failures: u64,
    pub lp_completed_initial: u64,
    pub lp_completed_realloc: u64,
    pub lp_violations: u64,
    pub lp_preempted: u64,
    pub lp_realloc_attempts: u64,
    pub lp_realloc_success: u64,

    // ---- offloading (Fig. 4/7/8 offloaded-completion series) ----
    pub offloaded_total: u64,
    pub offloaded_completed: u64,

    // ---- scheduling latency (Fig. 5) ----
    pub lat_hp_alloc: LatencyStat,
    pub lat_hp_preempt: LatencyStat,
    pub lat_lp_alloc: LatencyStat,
    pub lat_lp_realloc: LatencyStat,

    // ---- end-to-end latency per priority class (arrival → completion;
    // percentiles expose the tail under bursty arrivals) ----
    pub lat_hp_e2e: LatencyStat,
    pub lat_lp_e2e: LatencyStat,

    // ---- generative workload (zero for trace-only runs) ----
    /// Arrival events fired from a compiled generative plan.
    pub gen_arrivals: u64,
    /// Tasks the generator offered (before admission control).
    pub offered_tasks: u64,
    /// Input megabits the offered tasks would transfer on offload.
    pub offered_mbits: f64,
    /// Offered tasks dropped at admission (in-flight cap exceeded).
    pub admission_dropped: u64,
    /// Offered tasks dropped because their source device was out of the
    /// fleet at arrival (churn/crash outage) — distinct from cap drops.
    pub offline_dropped: u64,

    // ---- delivered inference accuracy (model-variant ladders; on a
    // ladder-free run these reduce to: accuracy_sum == LP completions,
    // rung_completions[0] == LP completions, degraded_* == 0) ----
    /// Sum of delivered accuracy over completed low-priority inferences
    /// (each completion credits its model-variant rung's accuracy;
    /// ladder-less tasks credit 1.0). Violations and drops credit 0 and
    /// are not counted.
    pub accuracy_sum: f64,
    /// Completions by ladder rung (0 = full accuracy; ladder-less
    /// completions count as rung 0). `Σ rung_completions ==
    /// lp_completed_total` — asserted by `rust/tests/accuracy_props.rs`.
    pub rung_completions: [u64; MAX_RUNGS],
    /// Completions that ran a degraded rung (> 0).
    pub degraded_completions: u64,
    /// Low-priority placements that stepped down at least one rung
    /// (counted per task at placement; a task re-placed and degraded
    /// twice counts twice).
    pub degraded_placements: u64,

    // ---- core allocation mix (Table II) ----
    pub two_core_allocs: u64,
    pub four_core_allocs: u64,

    // ---- fleet churn (scenario API; zero in the paper's fixed testbed) ----
    pub churn_joins: u64,
    pub churn_leaves: u64,
    /// Live allocations evicted because their device left the fleet.
    pub churn_evicted: u64,

    // ---- fault injection (all zero without a FaultPlan) ----
    /// Devices that crashed (fault schedule).
    pub device_crashes: u64,
    /// Crashed devices that recovered.
    pub device_recoveries: u64,
    /// In-flight tasks lost to a crash (work discarded, not completed).
    pub crash_tasks_lost: u64,
    /// Lost tasks whose input survived elsewhere and were re-offered to
    /// the scheduler ([`crate::coordinator::scheduler::SchedEvent::Reoffer`]).
    pub crash_tasks_reoffered: u64,
    /// Re-offered tasks the scheduler placed again. Also counted in
    /// `lp_realloc_success` (a re-offer *is* an involuntary reallocation)
    /// so the core-mix identity `two+four == initial + realloc_success`
    /// keeps holding under faults.
    pub crash_reoffer_placed: u64,
    /// Re-offered tasks dropped (no placement in the remaining budget, or
    /// their frame had already failed by re-offer time).
    pub crash_reoffer_dropped: u64,
    /// Re-offered tasks that still completed within their original
    /// deadline (the "recovered in deadline" series).
    pub crash_recovered_in_deadline: u64,
    /// Device downtime, crash → recovery.
    pub lat_crash_recovery: LatencyStat,
    /// Probe rounds that came back completely empty under probe loss
    /// (failed rounds: no estimator update).
    pub probe_rounds_lost: u64,
    /// Individual probe pings lost (partial rounds).
    pub probe_pings_lost: u64,
    /// Extra megabits re-queued on the medium by loss retransmission.
    pub retransmitted_mbits: f64,

    // ---- bandwidth mechanism diagnostics (Fig. 6/7) ----
    pub bandwidth_updates: u64,
    pub link_rebuild_ops: u64,
    pub final_bandwidth_estimate_bps: f64,
    /// Virtual time the controller spent busy (scheduling + rebuilds), µs.
    pub controller_busy_us: u64,
    /// LP placement-attempt failure reasons [no config, link, windows,
    /// commit] (RAS only). Per failed attempt, not per rejected batch:
    /// config fallbacks and failed ladder-rung probes count even when
    /// the batch ultimately places, so laddered runs report more
    /// attempt failures as degradation probes deeper rungs.
    pub reject_reasons: [u64; 4],

    // ---- energy & battery (all zero without an EnergyModel; see
    // `crate::energy` — idle + active + tx + rx ≈ total is the
    // conservation identity `rust/tests/energy_props.rs` pins) ----
    /// Fleet idle-baseline joules (online time × idle watts).
    pub energy_idle_j: f64,
    /// Joules burned by committed task execution windows.
    pub energy_active_j: f64,
    /// Radio transmit joules (source side of transfers).
    pub energy_tx_j: f64,
    /// Radio receive joules (destination side of transfers).
    pub energy_rx_j: f64,
    /// Total fleet joules (sum of the four components).
    pub energy_total_j: f64,
    /// Devices whose battery hit zero (each routes through the crash
    /// path and stays down for the rest of the run).
    pub battery_depletions: u64,
    /// Remaining battery joules per device at end of run (empty when
    /// mains powered — i.e. no battery capacity configured).
    pub battery_final_j: Vec<f64>,

    // ---- cloud tier (all zero without `cloud_wan_bps`) ----
    /// Low-priority placements sent to the cloud tier.
    pub cloud_offloads: u64,
    /// Cloud placements that delivered within their deadline.
    pub cloud_completions: u64,

    // ---- robustness layer (PR 8; all zero with the detector, timeout,
    // hedge, partition, and staleness knobs off) ----
    /// Offload-timeout reallocation attempts (bounded exponential-backoff
    /// retry; also counted in `lp_realloc_attempts`).
    pub retries: u64,
    /// Hedged duplicate placements launched for deadline-pressed tasks.
    pub hedges_launched: u64,
    /// Hedges whose duplicate finished first (the hedge paid off).
    pub hedges_won: u64,
    /// Hedges whose primary finished first (duplicate work discarded).
    pub hedges_wasted: u64,
    /// `DeviceSuspected` events whose device was actually alive and
    /// reachable at suspicion time (probe loss fooled the detector).
    pub false_suspicions: u64,
    /// `DeviceSuspected` events dispatched to the scheduler.
    pub devices_suspected: u64,
    /// `DeviceCleared` events dispatched (heartbeat ended a suspicion).
    pub devices_cleared: u64,
    /// Truth-to-belief lag for *correct* suspicions: device actually
    /// down (crash/partition) → detector suspects it.
    pub lat_detection: LatencyStat,
    /// Partition fault events started (device unreachable but alive).
    pub partitions_started: u64,
    /// Partitions healed (stalled flows resume, held results deliver).
    pub partitions_healed: u64,
    /// In-flight transfers stalled by a partition (resume on heal).
    pub partition_stalled_flows: u64,
    /// Finished computations whose result was undeliverable across a
    /// partition and was held until heal (or lost to crash/run end).
    pub partition_held_results: u64,
    /// Low-priority tasks lost without completing or violating: rejected
    /// (re)placements, crash/churn eviction failures, orphaned transfers,
    /// dropped re-offers, exhausted retries, and partition-held work the
    /// run ended on. Closes the conservation identity `lp_generated ==
    /// lp_completed_total + lp_violations + lp_lost`, which `medge chaos`
    /// hard-asserts on every run.
    pub lp_lost: u64,
    /// Virtual µs the bandwidth estimator spent stale (consecutive probe
    /// failures ≥ `bw_stale_after`); 0 with the knob off.
    pub bw_stale_us: u64,

    // ---- anytime inference (all zero without stage plans; a truncated
    // completion still counts in lp_completed_* and rung_completions,
    // so every conservation identity above keeps holding) ----
    /// Completions cut short at a stage boundary by the deadline-pressure
    /// controller (delivered partial accuracy instead of violating).
    pub truncated_completions: u64,
    /// Optional refinement stages skipped across all truncated
    /// completions (each cut at stage k of an n-stage plan skips n−k).
    pub stages_skipped: u64,
    /// Pressure surveys that found at least one cuttable execution and
    /// were dispatched to the scheduler's rescue policy.
    pub pressure_events: u64,
    /// Truncation cuts the rescue policy armed (≥ truncated_completions
    /// is *not* guaranteed: a cut task can still crash, be evicted, or
    /// get lost behind a partition before its boundary delivers).
    pub pressure_cuts: u64,

    // ---- observability (PR 9) ----
    /// Span events the flight recorder saw over the run, including any
    /// the ring overwrote; 0 with tracing off.
    pub trace_events: u64,
    /// Fluid-model medium advances that did real work. A deterministic
    /// hot-path gauge: counted whether or not tracing is on.
    pub medium_drain_ops: u64,
    /// Event-queue compaction sweeps (deterministic hot-path gauge).
    pub queue_compactions: u64,
    /// Wall-clock nanoseconds spent in event dispatch (inclusive of the
    /// nested scheduler share), measured only when the off-by-default
    /// `timing` knob is on. Wall-clock is non-deterministic by nature:
    /// the knob stays off in the determinism and golden grids, where
    /// these report 0.
    pub phase_dispatch_ns: u64,
    /// Wall-clock ns inside scheduler dispatch (subset of dispatch).
    pub phase_sched_ns: u64,
    /// Wall-clock ns arming/advancing the shared-medium fluid model.
    pub phase_medium_ns: u64,
    /// Wall-clock ns in event-queue compaction sweeps.
    pub phase_compact_ns: u64,
}

impl Metrics {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), ..Default::default() }
    }

    /// Frame completion rate in [0, 1].
    pub fn frame_completion_rate(&self) -> f64 {
        if self.frames_total == 0 {
            return 0.0;
        }
        self.frames_completed as f64 / self.frames_total as f64
    }

    /// Total low-priority completions (initial + reallocated).
    pub fn lp_completed_total(&self) -> u64 {
        self.lp_completed_initial + self.lp_completed_realloc
    }

    /// Offloaded completion rate in [0, 1].
    pub fn offloaded_completion_rate(&self) -> f64 {
        if self.offloaded_total == 0 {
            return 0.0;
        }
        self.offloaded_completed as f64 / self.offloaded_total as f64
    }

    /// Fraction of offered tasks dropped at admission, in [0, 1].
    pub fn admission_drop_rate(&self) -> f64 {
        if self.offered_tasks == 0 {
            return 0.0;
        }
        self.admission_dropped as f64 / self.offered_tasks as f64
    }

    /// Low-priority deadline-met count (completions are deadline-met by
    /// construction — a late finish is a violation, not a completion).
    pub fn lp_deadline_met(&self) -> u64 {
        self.lp_completed_total()
    }

    /// Mean delivered inference accuracy per deadline met, in [0, 1]:
    /// `accuracy_sum / lp_deadline_met`. Bounded by the ladder's
    /// min/max rung accuracies; exactly 1.0 on a ladder-free run with
    /// any completions. The "accuracy" half of the frontier —
    /// degradation raises `lp_deadline_met` and lowers this.
    pub fn accuracy_per_deadline_met(&self) -> f64 {
        let met = self.lp_deadline_met();
        if met == 0 {
            return 0.0;
        }
        self.accuracy_sum / met as f64
    }

    /// Delivered accuracy mass per *generated* low-priority inference,
    /// in [0, 1]: rejected/violated/dropped work delivers 0, so this is
    /// the accuracy goodput the frontier actually optimises (a ladder
    /// can raise it even while the per-completion mean falls).
    pub fn delivered_accuracy_rate(&self) -> f64 {
        if self.lp_generated == 0 {
            return 0.0;
        }
        self.accuracy_sum / self.lp_generated as f64
    }

    /// Mean joules per completed task (HP + LP); 0.0 when nothing
    /// completed or energy accounting is off.
    pub fn joules_per_task(&self) -> f64 {
        let done = self.hp_completed + self.lp_completed_total();
        if done == 0 || self.energy_total_j <= 0.0 {
            return 0.0;
        }
        self.energy_total_j / done as f64
    }

    /// Low-priority deadlines met per kilojoule of fleet energy — the
    /// figure of merit the energy-aware scheduler optimises (0.0 when
    /// energy accounting is off, so it never divides by zero).
    pub fn deadline_met_per_kj(&self) -> f64 {
        if self.energy_total_j <= 0.0 {
            return 0.0;
        }
        self.lp_deadline_met() as f64 / (self.energy_total_j / 1e3)
    }

    /// Fraction of LP placements that went to the cloud tier, in [0, 1].
    pub fn cloud_offload_rate(&self) -> f64 {
        let placed = self.lp_allocated_initial + self.lp_realloc_success;
        if placed == 0 {
            return 0.0;
        }
        self.cloud_offloads as f64 / placed as f64
    }

    /// Debug-build audit of the ordering identities the saturating adds
    /// protect. Called once per run at drain time: a wrapped (or
    /// saturated) counter silently corrupts every derived rate, so
    /// debug builds fail loudly instead. Release builds compile this
    /// to nothing.
    pub fn debug_audit(&self) {
        debug_assert!(
            self.frames_completed <= self.frames_total,
            "frames_completed {} > frames_total {}",
            self.frames_completed,
            self.frames_total
        );
        debug_assert!(
            self.hp_completed.saturating_add(self.hp_violations) <= self.hp_generated,
            "HP outcomes exceed hp_generated {}",
            self.hp_generated
        );
        debug_assert!(self.hp_rejected <= self.hp_generated);
        debug_assert!(
            self.lp_completed_total() <= self.lp_generated,
            "LP completions {} > lp_generated {}",
            self.lp_completed_total(),
            self.lp_generated
        );
        debug_assert!(self.lp_violations <= self.lp_generated);
        debug_assert!(self.lp_lost <= self.lp_generated);
        debug_assert!(self.offloaded_completed <= self.offloaded_total);
        debug_assert!(
            self.admission_dropped.saturating_add(self.offline_dropped) <= self.offered_tasks
        );
        debug_assert!(self.devices_cleared <= self.devices_suspected);
        debug_assert!(self.degraded_completions <= self.lp_completed_total());
        debug_assert!(
            self.truncated_completions <= self.lp_completed_total(),
            "truncated {} > LP completions {}",
            self.truncated_completions,
            self.lp_completed_total()
        );
        debug_assert!(self.stages_skipped >= self.truncated_completions);
        // None of the run-length counters may sit at the saturation
        // ceiling: reaching it means the run genuinely overflowed u64
        // and every identity above is suspect.
        debug_assert!(self.frames_total < u64::MAX);
        debug_assert!(self.offered_tasks < u64::MAX);
        debug_assert!(self.lp_generated < u64::MAX);
    }

    /// Table II row: fraction of successful LP allocations per core config.
    pub fn core_mix(&self) -> (f64, f64) {
        let total = (self.two_core_allocs + self.four_core_allocs) as f64;
        if total == 0.0 {
            return (0.0, 0.0);
        }
        (
            self.two_core_allocs as f64 / total * 100.0,
            self.four_core_allocs as f64 / total * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_stat_tracks_extremes_and_mean() {
        let mut s = LatencyStat::default();
        s.record(1000);
        s.record(3000);
        s.record(2000);
        assert_eq!(s.count, 3);
        assert_eq!(s.min_us, 1000);
        assert_eq!(s.max_us, 3000);
        assert!((s.mean_ms() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles_are_ordered_and_accurate() {
        let mut s = LatencyStat::default();
        // 1..=1000 ms in µs: exact quantiles are 500/950/990 ms.
        for v in 1..=1000u64 {
            s.record(v * 1000);
        }
        let (p50, p95, p99) = (s.p50_ms(), s.p95_ms(), s.p99_ms());
        assert!(p50 <= p95 && p95 <= p99 && p99 <= s.max_ms());
        assert!((p50 - 500.0).abs() / 500.0 < 0.07, "p50 {p50}");
        assert!((p95 - 950.0).abs() / 950.0 < 0.07, "p95 {p95}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.07, "p99 {p99}");
        // Small exact region: values < 32 µs come back exactly.
        let mut t = LatencyStat::default();
        for v in [3u64, 7, 9, 31] {
            t.record(v);
        }
        assert_eq!(t.percentile_us(0.5), 7);
        assert_eq!(t.percentile_us(1.0), 31);
        assert_eq!(t.percentile_us(0.0), 3);
        // Empty stat: everything is zero, nothing panics.
        assert_eq!(LatencyStat::default().percentile_us(0.99), 0);
    }

    #[test]
    fn percentiles_expose_a_tail_the_mean_hides() {
        // 99 fast samples + 1 slow one: the mean barely moves, p99 jumps.
        let mut s = LatencyStat::default();
        for _ in 0..99 {
            s.record(10_000); // 10 ms
        }
        s.record(2_000_000); // one 2 s straggler
        assert!(s.mean_ms() < 40.0);
        assert!(s.p50_ms() < 12.0);
        assert!(s.p99_ms() > 1500.0, "p99 {} must surface the straggler", s.p99_ms());
    }

    #[test]
    fn percentile_octave_boundaries_are_tight() {
        // The exact region: every value below 32 µs must come back
        // exactly at every rank (one bucket per integer value).
        let mut s = LatencyStat::default();
        for v in 0..32u64 {
            s.record(v);
        }
        for v in 0..32u64 {
            let q = (v + 1) as f64 / 32.0;
            assert_eq!(s.percentile_us(q), v, "exact region drifted at {v}");
        }
        // Octave boundaries: 32 is the first approximated value; its
        // bucket midpoint (33) may be reported, but never outside the
        // ≈6 % log-linear error bound — and the min/max clamp keeps
        // p0/p100 exact. Check the first sub-bucket of several octaves.
        for base in [32u64, 64, 128, 1 << 20, 1 << 40] {
            let mut t = LatencyStat::default();
            t.record(base);
            t.record(base * 10); // second sample so the clamp can't hide errors
            let p = t.percentile_us(0.5);
            let err = (p as f64 - base as f64).abs() / base as f64;
            assert!(err <= 1.0 / 16.0 + 1e-9, "octave {base}: p50 {p} off by {err}");
            // p0 reports the min's bucket midpoint: never below the
            // observed min, never past the error bound above it.
            let p0 = t.percentile_us(0.0);
            assert!(p0 >= base, "octave {base}: p0 {p0} fell below the observed min");
            assert!((p0 - base) as f64 / base as f64 <= 1.0 / 16.0 + 1e-9);
            // p100's bucket midpoint overshoots the max, so the clamp
            // makes it exact.
            assert_eq!(t.percentile_us(1.0), base * 10, "p100 must clamp to the observed max");
        }
        // Single sample: every quantile is that sample, exactly — even
        // at an approximated magnitude.
        let mut one = LatencyStat::default();
        one.record(1_048_577); // 2^20 + 1: mid-octave, non-representable
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(one.percentile_us(q), 1_048_577);
        }
        // Empty stat: zero everywhere, no panic, at any quantile.
        let empty = LatencyStat::default();
        for q in [-1.0, 0.0, 0.5, 1.0, 2.0] {
            assert_eq!(empty.percentile_us(q), 0);
        }
        assert_eq!(empty.p99_ms(), 0.0);
    }

    #[test]
    fn accuracy_accessors_guard_zero_and_average() {
        let mut m = Metrics::new("acc");
        assert_eq!(m.accuracy_per_deadline_met(), 0.0);
        assert_eq!(m.delivered_accuracy_rate(), 0.0);
        m.lp_generated = 10;
        m.lp_completed_initial = 3;
        m.lp_completed_realloc = 1;
        m.accuracy_sum = 0.97 * 2.0 + 0.78 * 2.0;
        m.rung_completions[0] = 2;
        m.rung_completions[2] = 2;
        m.degraded_completions = 2;
        assert_eq!(m.lp_deadline_met(), 4);
        assert_eq!(m.rung_completions.iter().sum::<u64>(), m.lp_deadline_met());
        assert!((m.accuracy_per_deadline_met() - 0.875).abs() < 1e-12);
        assert!((m.delivered_accuracy_rate() - 0.35).abs() < 1e-12);
    }

    #[test]
    fn energy_accessors_guard_zero_and_average() {
        let mut m = Metrics::new("e");
        assert_eq!(m.joules_per_task(), 0.0);
        assert_eq!(m.deadline_met_per_kj(), 0.0);
        assert_eq!(m.cloud_offload_rate(), 0.0);
        m.hp_completed = 6;
        m.lp_completed_initial = 4;
        m.energy_total_j = 500.0;
        assert!((m.joules_per_task() - 50.0).abs() < 1e-12);
        assert!((m.deadline_met_per_kj() - 8.0).abs() < 1e-12);
        m.lp_allocated_initial = 8;
        m.lp_realloc_success = 2;
        m.cloud_offloads = 5;
        assert!((m.cloud_offload_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn admission_drop_rate_guards_zero() {
        let mut m = Metrics::new("g");
        assert_eq!(m.admission_drop_rate(), 0.0);
        m.offered_tasks = 200;
        m.admission_dropped = 50;
        assert!((m.admission_drop_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn rates() {
        let mut m = Metrics::new("RAS_4");
        m.frames_total = 100;
        m.frames_completed = 80;
        assert!((m.frame_completion_rate() - 0.8).abs() < 1e-12);
        m.two_core_allocs = 96;
        m.four_core_allocs = 4;
        let (two, four) = m.core_mix();
        assert!((two - 96.0).abs() < 1e-9);
        assert!((four - 4.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_dont_divide_by_zero() {
        let m = Metrics::new("x");
        assert_eq!(m.frame_completion_rate(), 0.0);
        assert_eq!(m.offloaded_completion_rate(), 0.0);
        assert_eq!(m.core_mix(), (0.0, 0.0));
        assert_eq!(m.lat_hp_alloc.mean_ms(), 0.0);
    }
}
