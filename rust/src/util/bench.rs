//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Auto-calibrates iteration counts to a target measurement time, reports
//! median / mean / p95 per-iteration latency, and prints one stable line
//! per benchmark so `cargo bench` output can be diffed across runs. Used
//! by every target under `rust/benches/`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::hint::black_box;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

/// One row of a `BENCH_*.json` trajectory file: either a timing row
/// (`unit == "ns/op"`, `value` = median ns, `throughput_per_s` derived)
/// or a gauge row (e.g. `unit == "allocs/event"`, timing fields zero).
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub name: String,
    pub unit: String,
    pub iters: u64,
    pub value: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub throughput_per_s: f64,
}

impl BenchRow {
    pub fn gauge(name: impl Into<String>, unit: impl Into<String>, iters: u64, value: f64) -> Self {
        Self {
            name: name.into(),
            unit: unit.into(),
            iters,
            value,
            mean_ns: 0.0,
            p95_ns: 0.0,
            throughput_per_s: 0.0,
        }
    }

    pub fn report(&self) -> String {
        if self.unit == "ns/op" {
            format!(
                "{:<52} {:>12} iters  {:>12}  ({:.0}/s)",
                self.name,
                self.iters,
                fmt_ns(self.value),
                self.throughput_per_s
            )
        } else {
            format!("{:<52} {:>12} iters  {:>12.4} {}", self.name, self.iters, self.value, self.unit)
        }
    }
}

impl From<&BenchResult> for BenchRow {
    fn from(r: &BenchResult) -> Self {
        Self {
            name: r.name.clone(),
            unit: "ns/op".to_string(),
            iters: r.iters,
            value: r.median_ns,
            mean_ns: r.mean_ns,
            p95_ns: r.p95_ns,
            throughput_per_s: if r.median_ns > 0.0 { 1e9 / r.median_ns } else { 0.0 },
        }
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Serialise bench rows to the `BENCH_*.json` schema (hand-rolled: the
/// offline build has no serde). Stable field order so files diff cleanly
/// across runs — that is the whole point of the trajectory.
pub fn json_report(suite: &str, provenance: &str, rows: &[BenchRow]) -> String {
    let mut s = String::from("{\n");
    s.push_str(&format!("  \"suite\": \"{}\",\n", json_escape(suite)));
    s.push_str(&format!("  \"provenance\": \"{}\",\n", json_escape(provenance)));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"unit\": \"{}\", \"iters\": {}, \"value\": {:.3}, \
             \"mean_ns\": {:.3}, \"p95_ns\": {:.3}, \"throughput_per_s\": {:.3}}}{}\n",
            json_escape(&r.name),
            json_escape(&r.unit),
            r.iters,
            r.value,
            r.mean_ns,
            r.p95_ns,
            r.throughput_per_s,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Allocation-counting wrapper around the system allocator. Bench
/// binaries install it as `#[global_allocator]` to measure steady-state
/// allocations per simulated event (the hot-path target is zero); one
/// relaxed atomic increment per allocation, negligible otherwise.
pub struct CountingAlloc {
    count: AtomicU64,
}

impl CountingAlloc {
    pub const fn new() -> Self {
        Self { count: AtomicU64::new(0) }
    }

    /// Allocations observed since process start.
    pub fn allocations(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        Self::new()
    }
}

// SAFETY: delegates verbatim to `System`; the counter is a relaxed
// atomic with no allocation inside the allocator itself.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        self.count.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        self.count.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<52} {:>12} iters  median {:>12}  mean {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, auto-scaling iterations to ~`target` of total sampling.
/// The closure should return a value, which is black-boxed to keep the
/// optimiser honest.
pub fn bench<T>(name: &str, target: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    // Warm-up + calibration: find an iteration count that takes ≥ ~1 ms.
    let mut calibration_iters = 1u64;
    let per_iter_ns = loop {
        let t0 = Instant::now();
        for _ in 0..calibration_iters {
            black_box(f());
        }
        let el = t0.elapsed();
        if el >= Duration::from_millis(1) || calibration_iters >= 1 << 24 {
            break (el.as_nanos() as f64 / calibration_iters as f64).max(0.1);
        }
        calibration_iters *= 4;
    };
    // Sample in ~20 batches within the target time.
    let total_iters = ((target.as_nanos() as f64 / per_iter_ns) as u64).clamp(20, 5_000_000);
    let batches = 20u64;
    let batch_iters = (total_iters / batches).max(1);
    let mut samples: Vec<f64> = Vec::with_capacity(batches as usize);
    for _ in 0..batches {
        let t0 = Instant::now();
        for _ in 0..batch_iters {
            black_box(f());
        }
        samples.push(t0.elapsed().as_nanos() as f64 / batch_iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_ns = samples[samples.len() / 2];
    let mean_ns = samples.iter().sum::<f64>() / samples.len() as f64;
    let p95_ns = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
    let r = BenchResult {
        name: name.to_string(),
        iters: batch_iters * batches,
        median_ns,
        mean_ns,
        p95_ns,
    };
    println!("{}", r.report());
    r
}

/// Run a whole-scenario benchmark once (for end-to-end figure harnesses
/// where one run is seconds long) and report wall time plus a metric line.
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    let el = t0.elapsed();
    println!("{:<52} {:>12}  (single run)", name, fmt_ns(el.as_nanos() as f64));
    (out, el)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_cheap_op() {
        let r = bench("noop_add", Duration::from_millis(20), || 1u64 + 2);
        assert!(r.median_ns < 1_000.0, "trivial op should be ns-scale: {}", r.median_ns);
        assert!(r.iters >= 20);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2_000_000_000.0).contains(" s"));
    }

    #[test]
    fn bench_once_returns_value() {
        let (v, d) = bench_once("once", || 7);
        assert_eq!(v, 7);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn json_report_is_wellformed_and_stable() {
        let r = BenchResult {
            name: "x/\"quoted\"".into(),
            iters: 100,
            median_ns: 12.5,
            mean_ns: 13.0,
            p95_ns: 20.0,
        };
        let rows = vec![BenchRow::from(&r), BenchRow::gauge("allocs", "allocs/event", 5000, 0.0)];
        let a = json_report("hot_path", "test", &rows);
        let b = json_report("hot_path", "test", &rows);
        assert_eq!(a, b, "serialisation must be byte-stable");
        assert!(a.contains("\"suite\": \"hot_path\""));
        assert!(a.contains("\\\"quoted\\\""));
        assert!(a.contains("\"unit\": \"allocs/event\""));
        assert!(a.ends_with("]\n}\n"));
        // Throughput derives from the median.
        assert!((rows[0].throughput_per_s - 8e7).abs() < 1e3);
    }

    #[test]
    fn counting_alloc_counts() {
        // Not installed as the global allocator here — exercise the raw
        // interface through a manual alloc/dealloc round-trip.
        let a = CountingAlloc::new();
        assert_eq!(a.allocations(), 0);
        unsafe {
            let layout = std::alloc::Layout::from_size_align(64, 8).unwrap();
            let p = a.alloc(layout);
            assert!(!p.is_null());
            a.dealloc(p, layout);
        }
        assert_eq!(a.allocations(), 1);
    }
}
