//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Auto-calibrates iteration counts to a target measurement time, reports
//! median / mean / p95 per-iteration latency, and prints one stable line
//! per benchmark so `cargo bench` output can be diffed across runs. Used
//! by every target under `rust/benches/`.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<52} {:>12} iters  median {:>12}  mean {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, auto-scaling iterations to ~`target` of total sampling.
/// The closure should return a value, which is black-boxed to keep the
/// optimiser honest.
pub fn bench<T>(name: &str, target: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    // Warm-up + calibration: find an iteration count that takes ≥ ~1 ms.
    let mut calibration_iters = 1u64;
    let per_iter_ns = loop {
        let t0 = Instant::now();
        for _ in 0..calibration_iters {
            black_box(f());
        }
        let el = t0.elapsed();
        if el >= Duration::from_millis(1) || calibration_iters >= 1 << 24 {
            break (el.as_nanos() as f64 / calibration_iters as f64).max(0.1);
        }
        calibration_iters *= 4;
    };
    // Sample in ~20 batches within the target time.
    let total_iters = ((target.as_nanos() as f64 / per_iter_ns) as u64).clamp(20, 5_000_000);
    let batches = 20u64;
    let batch_iters = (total_iters / batches).max(1);
    let mut samples: Vec<f64> = Vec::with_capacity(batches as usize);
    for _ in 0..batches {
        let t0 = Instant::now();
        for _ in 0..batch_iters {
            black_box(f());
        }
        samples.push(t0.elapsed().as_nanos() as f64 / batch_iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_ns = samples[samples.len() / 2];
    let mean_ns = samples.iter().sum::<f64>() / samples.len() as f64;
    let p95_ns = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
    let r = BenchResult {
        name: name.to_string(),
        iters: batch_iters * batches,
        median_ns,
        mean_ns,
        p95_ns,
    };
    println!("{}", r.report());
    r
}

/// Run a whole-scenario benchmark once (for end-to-end figure harnesses
/// where one run is seconds long) and report wall time plus a metric line.
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    let el = t0.elapsed();
    println!("{:<52} {:>12}  (single run)", name, fmt_ns(el.as_nanos() as f64));
    (out, el)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_cheap_op() {
        let r = bench("noop_add", Duration::from_millis(20), || 1u64 + 2);
        assert!(r.median_ns < 1_000.0, "trivial op should be ns-scale: {}", r.median_ns);
        assert!(r.iters >= 20);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2_000_000_000.0).contains(" s"));
    }

    #[test]
    fn bench_once_returns_value() {
        let (v, d) = bench_once("once", || 7);
        assert_eq!(v, 7);
        assert!(d.as_nanos() > 0);
    }
}
