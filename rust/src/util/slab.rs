//! Generational slab: index-based storage for the simulation hot path.
//!
//! The discrete-event engine keys every in-flight task by a [`SlotRef`]
//! — a slot index plus the slot's *generation* word. Lookup is an array
//! index (no hashing on the per-event path), removal recycles the slot
//! through a LIFO free list, and every removal bumps the slot's
//! generation, so a handle taken before the removal can never resolve to
//! whatever reuses the slot later.
//!
//! That last property is what lets the engine fold its placement
//! generations into the slab: a cancelled placement is expressed by
//! re-slotting the task (remove + insert, which the LIFO free list turns
//! into "same index, next generation"), and every finish/transfer event
//! queued under the dead placement carries a handle that no longer
//! resolves. See `sim::engine` for the event-side contract and
//! `stale_handles_never_resolve_after_reuse` below for the randomized
//! proof.

/// A generational handle into a [`Slab`]. `Copy`, 8 bytes, and safe to
/// hold across arbitrary slab mutations: a stale handle simply stops
/// resolving.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SlotRef {
    idx: u32,
    gen: u32,
}

impl SlotRef {
    /// The never-resolving handle (generation 0 is never issued).
    pub const NULL: SlotRef = SlotRef { idx: u32::MAX, gen: 0 };

    pub fn is_null(self) -> bool {
        self.gen == 0
    }

    /// Slot index (stable for the lifetime of one insertion).
    pub fn index(self) -> usize {
        self.idx as usize
    }

    /// Generation word the handle was issued under.
    pub fn generation(self) -> u32 {
        self.gen
    }
}

#[derive(Debug, Clone)]
struct Slot<T> {
    /// Current generation of this slot. Live generations are odd-or-even
    /// indifferent but always ≥ 1; a handle resolves iff its generation
    /// equals the slot's *and* the slot is occupied.
    gen: u32,
    val: Option<T>,
}

/// Generational slab with LIFO slot reuse. All operations are O(1)
/// except iteration (O(capacity)).
#[derive(Debug, Clone)]
pub struct Slab<T> {
    slots: Vec<Slot<T>>,
    free: Vec<u32>,
    live: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Self {
        Self { slots: Vec::new(), free: Vec::new(), live: 0 }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self { slots: Vec::with_capacity(cap), free: Vec::new(), live: 0 }
    }

    /// Insert a value, reusing the most recently freed slot if any.
    /// Reuse keeps the slot's bumped generation, so handles issued
    /// before the free cannot alias the new occupant.
    pub fn insert(&mut self, val: T) -> SlotRef {
        self.live += 1;
        if let Some(idx) = self.free.pop() {
            let s = &mut self.slots[idx as usize];
            debug_assert!(s.val.is_none(), "free-listed slot still occupied");
            s.val = Some(val);
            SlotRef { idx, gen: s.gen }
        } else {
            let idx = u32::try_from(self.slots.len()).expect("slab capacity exceeds u32 indices");
            self.slots.push(Slot { gen: 1, val: Some(val) });
            SlotRef { idx, gen: 1 }
        }
    }

    /// Resolve a handle. `None` for stale (removed / reused) handles.
    pub fn get(&self, r: SlotRef) -> Option<&T> {
        match self.slots.get(r.idx as usize) {
            Some(s) if s.gen == r.gen => s.val.as_ref(),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, r: SlotRef) -> Option<&mut T> {
        match self.slots.get_mut(r.idx as usize) {
            Some(s) if s.gen == r.gen => s.val.as_mut(),
            _ => None,
        }
    }

    pub fn contains(&self, r: SlotRef) -> bool {
        self.get(r).is_some()
    }

    /// Remove the value behind `r` (if the handle is still live), bumping
    /// the slot's generation so `r` — and every copy of it — goes stale
    /// before the slot can be reused.
    pub fn remove(&mut self, r: SlotRef) -> Option<T> {
        let s = self.slots.get_mut(r.idx as usize)?;
        if s.gen != r.gen || s.val.is_none() {
            return None;
        }
        let v = s.val.take();
        // Generation 0 is reserved for NULL; skipping it on wrap keeps
        // the invariant at the cost of one theoretical ABA per 2^32 - 1
        // reuses of a single slot.
        s.gen = if s.gen == u32::MAX { 1 } else { s.gen + 1 };
        self.free.push(r.idx);
        self.live -= 1;
        v
    }

    /// Live value count.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Dense iteration in slot-index order. Deterministic (the order is
    /// a pure function of the operation history), but *not* insertion
    /// order once slots recycle — callers that need a semantic order
    /// must impose it themselves.
    pub fn iter(&self) -> impl Iterator<Item = (SlotRef, &T)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| {
            s.val.as_ref().map(|v| (SlotRef { idx: i as u32, gen: s.gen }, v))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::collections::HashMap;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s: Slab<&'static str> = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a), Some(&"a"));
        assert_eq!(s.get(b), Some(&"b"));
        assert_eq!(s.remove(a), Some("a"));
        assert_eq!(s.get(a), None);
        assert_eq!(s.remove(a), None, "double remove is a no-op");
        assert_eq!(s.len(), 1);
        *s.get_mut(b).unwrap() = "b2";
        assert_eq!(s.get(b), Some(&"b2"));
    }

    #[test]
    fn lifo_reuse_recycles_index_with_fresh_generation() {
        let mut s: Slab<u64> = Slab::new();
        let h0 = s.insert(10);
        s.insert(11);
        let old = h0;
        assert_eq!(s.remove(h0), Some(10));
        let h1 = s.insert(20);
        // LIFO free list: same physical slot, new generation — this is
        // exactly the engine's placement-generation semantics.
        assert_eq!(h1.index(), old.index());
        assert_ne!(h1.generation(), old.generation());
        assert_eq!(s.get(old), None, "stale handle must not see the new occupant");
        assert_eq!(s.get(h1), Some(&20));
    }

    #[test]
    fn null_handle_never_resolves() {
        let mut s: Slab<u64> = Slab::new();
        assert!(SlotRef::NULL.is_null());
        assert_eq!(s.get(SlotRef::NULL), None);
        assert_eq!(s.remove(SlotRef::NULL), None);
        let h = s.insert(1);
        assert!(!h.is_null());
        assert_eq!(s.get(SlotRef::NULL), None);
    }

    #[test]
    fn iteration_is_dense_and_skips_freed_slots() {
        let mut s: Slab<u64> = Slab::new();
        let hs: Vec<SlotRef> = (0..6).map(|v| s.insert(v)).collect();
        s.remove(hs[1]);
        s.remove(hs[4]);
        let seen: Vec<u64> = s.iter().map(|(_, &v)| v).collect();
        assert_eq!(seen, vec![0, 2, 3, 5]);
        for (h, &v) in s.iter() {
            assert_eq!(s.get(h), Some(&v), "iterated handles must resolve");
        }
    }

    /// Satellite requirement: a randomized schedule of ≥ 1k
    /// insert/remove/reuse operations during which **no retired handle
    /// ever resolves again**, checked against a mirror model.
    #[test]
    fn stale_handles_never_resolve_after_reuse() {
        prop::forall("slab stale-handle soundness", 8, |rng| {
            let mut slab: Slab<u64> = Slab::new();
            let mut mirror: HashMap<u64, (SlotRef, u64)> = HashMap::new(); // key → (handle, value)
            let mut live_keys: Vec<u64> = Vec::new();
            let mut retired: Vec<SlotRef> = Vec::new();
            let mut next_key = 0u64;
            for step in 0..1500u64 {
                if live_keys.is_empty() || rng.index(3) > 0 {
                    let key = next_key;
                    next_key += 1;
                    let h = slab.insert(key);
                    if retired.contains(&h) {
                        return Err(format!("step {step}: fresh handle {h:?} equals a retired one"));
                    }
                    mirror.insert(key, (h, key));
                    live_keys.push(key);
                } else {
                    let key = live_keys.swap_remove(rng.index(live_keys.len()));
                    let (h, v) = mirror.remove(&key).expect("mirror tracks live keys");
                    if slab.remove(h) != Some(v) {
                        return Err(format!("step {step}: live handle {h:?} failed to remove"));
                    }
                    if slab.remove(h).is_some() || slab.get(h).is_some() {
                        return Err(format!("step {step}: handle {h:?} survived its removal"));
                    }
                    retired.push(h);
                }
                // Every live handle resolves to its value.
                for key in &live_keys {
                    let (h, v) = mirror[key];
                    if slab.get(h) != Some(&v) {
                        return Err(format!("step {step}: live handle {h:?} lost value {v}"));
                    }
                }
                // Periodically (and at the end) audit every handle ever
                // retired: none may resolve, however many times its slot
                // has been recycled since.
                if step % 25 == 0 || step == 1499 {
                    for h in &retired {
                        if slab.get(*h).is_some() {
                            return Err(format!("step {step}: retired handle {h:?} resolved"));
                        }
                    }
                }
                if slab.len() != live_keys.len() {
                    return Err(format!("step {step}: len {} != model {}", slab.len(), live_keys.len()));
                }
            }
            Ok(())
        });
    }
}
