//! Lightweight property-test driver (proptest is unavailable offline).
//!
//! Runs a property over many deterministically-seeded random cases and, on
//! failure, reports the seed so the case can be replayed exactly:
//!
//! ```no_run
//! use medge::util::prop::forall;
//! forall("sorted after sort", 200, |rng| {
//!     let mut v: Vec<u64> = (0..rng.index(50)).map(|_| rng.next_u64()).collect();
//!     v.sort_unstable();
//!     if v.windows(2).any(|w| w[0] > w[1]) {
//!         return Err("not sorted".to_string());
//!     }
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Run `cases` random cases of `prop`. Panics (with the failing seed) on
/// the first counterexample. The per-case RNG is seeded as
/// `base_seed + case_index`, so failures replay with `replay(name, seed)`.
pub fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    let base_seed = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case);
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay(seed: u64, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    let mut rng = Rng::seed_from_u64(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replayed case (seed {seed}) failed: {msg}");
    }
}

/// Stable name → seed hash (FNV-1a) so each property gets its own stream
/// but results stay reproducible across runs.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        forall("always true", 50, |_| {
            ran += 1;
            Ok(())
        });
        assert_eq!(ran, 50);
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn failing_property_reports_seed() {
        forall("fails on big", 100, |rng| {
            if rng.index(10) == 3 {
                Err("hit 3".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn seeds_are_stable_across_runs() {
        assert_eq!(fnv1a(b"x"), fnv1a(b"x"));
        assert_ne!(fnv1a(b"x"), fnv1a(b"y"));
    }
}
