//! Deterministic pseudo-random number generation (in-tree substrate — the
//! build environment is offline, so no external `rand`).
//!
//! `SplitMix64` for seeding + `Xoshiro256**` for the stream: fast,
//! well-tested generators with excellent statistical properties, more than
//! adequate for workload generation, device shuffling, and probe-host
//! selection. Same seed ⇒ same experiment, bit for bit.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a `u64`.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = splitmix64(&mut sm);
        }
        // All-zero state is invalid (can't happen from splitmix, but be safe).
        if s == [0; 4] {
            s[0] = 1;
        }
        Self { s }
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    /// Lemire-style rejection for unbiased output.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (n as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform `usize` index in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Approximate standard normal via Irwin–Hall (sum of 12 uniforms).
    pub fn gen_gauss(&mut self) -> f64 {
        let mut acc = 0.0;
        for _ in 0..12 {
            acc += self.gen_f64();
        }
        acc - 6.0
    }

    /// Binomial(n, p) sample: exact Bernoulli sum for small `n`, clamped
    /// normal approximation beyond (the loss-sampling hot path hands in
    /// packet counts in the hundreds, where the approximation error is
    /// far below the fluid model's own tolerance).
    pub fn gen_binomial(&mut self, n: u64, p: f64) -> u64 {
        if n == 0 || p <= 0.0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        if n <= 64 {
            return (0..n).filter(|_| self.gen_f64() < p).count() as u64;
        }
        let nf = n as f64;
        let mean = nf * p;
        let sd = (nf * p * (1.0 - p)).sqrt();
        (mean + self.gen_gauss() * sd).round().clamp(0.0, nf) as u64
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: zero total weight");
        let mut x = self.gen_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if x < w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.gen_range(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values hit: {seen:?}");
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(2);
        for _ in 0..1000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::seed_from_u64(3);
        let mut xs: Vec<u32> = (0..20).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        // Overwhelmingly unlikely to be identity.
        assert_ne!(xs, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_mass() {
        let mut r = Rng::seed_from_u64(4);
        let w = [0.05, 0.9, 0.05];
        let mut counts = [0u32; 3];
        for _ in 0..2000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert!(counts[1] > 1500, "dominant weight should dominate: {counts:?}");
        assert!(counts[0] > 0 && counts[2] > 0);
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::seed_from_u64(6);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gen_gauss();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn binomial_moments_and_bounds() {
        let mut r = Rng::seed_from_u64(9);
        // Small-n exact path.
        for _ in 0..500 {
            let v = r.gen_binomial(10, 0.3);
            assert!(v <= 10);
        }
        // Large-n approximate path: mean within a few SDs over many draws.
        let n = 1000u64;
        let p = 0.2;
        let draws = 400;
        let mut sum = 0u64;
        for _ in 0..draws {
            let v = r.gen_binomial(n, p);
            assert!(v <= n);
            sum += v;
        }
        let mean = sum as f64 / draws as f64;
        assert!((mean - 200.0).abs() < 10.0, "binomial mean drifted: {mean}");
        // Degenerate probabilities.
        assert_eq!(r.gen_binomial(100, 0.0), 0);
        assert_eq!(r.gen_binomial(100, 1.0), 100);
        assert_eq!(r.gen_binomial(0, 0.5), 0);
    }

    #[test]
    fn uniformity_chi_square_sanity() {
        // Coarse uniformity check over 16 buckets.
        let mut r = Rng::seed_from_u64(5);
        let n = 16_000;
        let mut counts = [0f64; 16];
        for _ in 0..n {
            counts[r.index(16)] += 1.0;
        }
        let expected = n as f64 / 16.0;
        let chi2: f64 = counts.iter().map(|c| (c - expected).powi(2) / expected).sum();
        // 15 dof; 99.9th percentile ≈ 37.7.
        assert!(chi2 < 37.7, "chi2 = {chi2}");
    }
}
