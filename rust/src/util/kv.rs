//! Minimal `key value` text format for configs and simple records.
//!
//! One entry per line, `#` comments, whitespace-separated. Used by
//! [`crate::config::SystemConfig`] file loading and the trace file format.

use std::collections::BTreeMap;

/// Parse `key value` lines into an ordered map. Later duplicates win.
pub fn parse(text: &str) -> BTreeMap<String, String> {
    let mut map = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some((k, v)) = line.split_once(char::is_whitespace) {
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
    }
    map
}

/// Render a map back to the text format (sorted keys, stable output).
pub fn render(map: &BTreeMap<String, String>) -> String {
    let mut out = String::new();
    for (k, v) in map {
        out.push_str(k);
        out.push(' ');
        out.push_str(v);
        out.push('\n');
    }
    out
}

/// Fetch + parse helper.
pub fn get<T: std::str::FromStr>(map: &BTreeMap<String, String>, key: &str) -> Option<T> {
    map.get(key).and_then(|v| v.parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_skips_comments_and_blanks() {
        let m = parse("# header\n\nn_devices 4\nlink_bps 40e6\n  seed   7  \n");
        assert_eq!(m.get("n_devices").unwrap(), "4");
        assert_eq!(m.get("seed").unwrap(), "7");
        assert_eq!(m.len(), 3);
    }

    #[test]
    fn typed_get() {
        let m = parse("x 4\ny 2.5\nz hello");
        assert_eq!(get::<u32>(&m, "x"), Some(4));
        assert_eq!(get::<f64>(&m, "y"), Some(2.5));
        assert_eq!(get::<u32>(&m, "z"), None);
        assert_eq!(get::<u32>(&m, "missing"), None);
    }

    #[test]
    fn roundtrip() {
        let m = parse("a 1\nb two words here\n");
        assert_eq!(m.get("b").unwrap(), "two words here");
        let m2 = parse(&render(&m));
        assert_eq!(m, m2);
    }

    #[test]
    fn duplicate_keys_last_wins() {
        let m = parse("k 1\nk 2\n");
        assert_eq!(m.get("k").unwrap(), "2");
    }
}
