//! In-tree substrates for an offline build: PRNG, key-value config format,
//! micro-benchmark harness, and a property-test driver. (The build
//! environment has no crates.io access beyond the `xla` closure, so these
//! replace `rand`, `serde`, `criterion`, and `proptest`.)

pub mod bench;
pub mod kv;
pub mod prop;
pub mod rng;
pub mod slab;

pub use rng::Rng;
