//! Per-device energy accounting and battery budgets.
//!
//! The paper's fleet is battery-powered Raspberry Pis, but its evaluation
//! never accounts for what a placement decision *costs* in joules. This
//! module adds that axis (ROADMAP item 2, modeled on EdgeCloudSim's
//! per-device idle/active/transmit power):
//!
//! * [`EnergyModel`] — per-device power draw: an `idle_w` baseline while
//!   online plus an *additional* `active_w[config]` per running task
//!   (indexed by [`crate::coordinator::task::TaskConfig::index`]) and
//!   `tx_w`/`rx_w` per active transfer endpoint.
//! * [`FleetEnergy`] — the engine-side integrator: piecewise-constant
//!   power settled at every state transition the engine observes (task
//!   commit/finish/cancel, transfer start/end, churn/crash/recover, and
//!   the idle gaps in between). Components accumulate separately
//!   (`idle_j + active_j + tx_j + rx_j ≈ total_j`, the conservation
//!   identity the property suite pins).
//! * An optional battery: every device starts with `capacity_j` joules
//!   and drains at its current power. Depletion is *predicted* from the
//!   piecewise-constant power (the engine schedules a `BatteryDeplete`
//!   event, invalidated by an epoch counter whenever the power changes)
//!   and routes through the existing crash machinery: a drained device
//!   goes offline like a crash — in-flight work lost or re-offered — and
//!   never recovers.
//!
//! Accounting semantics: a committed allocation powers its device from
//! the commitment event to its finish/cancel event (the engine has no
//! "task actually started" event; the reserved window is treated as
//! active). Probe traffic is controller overhead and draws nothing.
//! A run with *no* [`EnergyModel`] configured takes none of these paths:
//! no extra events, no RNG draws, byte-identical output — and a
//! zero-watt model is numerically inert (all accumulators stay 0.0).

use crate::time::SimTime;

/// Number of task power configs (mirrors `TaskConfig`: high-priority,
/// two-core, four-core — in `TaskConfig::index()` order).
pub const N_CONFIGS: usize = 3;

/// Per-device power draw, watts (joules per second).
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyModel {
    /// Baseline draw while the device is online (even when idle).
    pub idle_w: f64,
    /// Additional draw per running task, by `TaskConfig::index()`:
    /// `[high-priority, two-core, four-core]`.
    pub active_w: [f64; N_CONFIGS],
    /// Additional draw per outbound transfer in flight (source side).
    pub tx_w: f64,
    /// Additional draw per inbound transfer in flight (destination side).
    pub rx_w: f64,
}

impl EnergyModel {
    /// A Raspberry Pi 2B-class profile: ~1.1 W idle, 2.0–3.6 W under the
    /// detector / stage-3 loads, sub-watt WiFi deltas. Values follow the
    /// published Pi power envelopes, not a new measurement.
    pub fn pi2b() -> Self {
        Self { idle_w: 1.1, active_w: [0.9, 1.5, 2.5], tx_w: 0.45, rx_w: 0.35 }
    }

    /// The zero-watt model: energy accounting runs but every accumulator
    /// stays 0.0 — the equivalence suites use it to prove the hooks are
    /// free when they measure nothing.
    pub fn zero() -> Self {
        Self { idle_w: 0.0, active_w: [0.0; N_CONFIGS], tx_w: 0.0, rx_w: 0.0 }
    }

    /// Parse a CLI power profile:
    ///
    /// * `pi2b` | `zero` — named profiles
    /// * `IDLE:HP:TWO:FOUR:TX:RX` — explicit watts
    ///
    /// Strict, mirroring [`crate::workload::gen::ArrivalProcess::parse`]:
    /// wrong field counts, non-numeric, non-finite, or negative fields
    /// are errors — never a panic and never a silently-degenerate model.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "pi2b" => return Ok(Self::pi2b()),
            "zero" => return Ok(Self::zero()),
            _ => {}
        }
        let parts: Vec<&str> = s.split(':').collect();
        anyhow::ensure!(
            parts.len() == 6,
            "power profile '{s}' has {} fields, expected 6 (IDLE:HP:TWO:FOUR:TX:RX) \
             or a named profile (pi2b | zero)",
            parts.len()
        );
        let num = |i: usize, what: &str| -> anyhow::Result<f64> {
            let v = parts[i]
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("power profile '{s}': bad {what}"))?;
            anyhow::ensure!(v.is_finite(), "power profile '{s}': {what} must be finite");
            anyhow::ensure!(v >= 0.0, "power profile '{s}': {what} must be >= 0");
            Ok(v)
        };
        let m = Self {
            idle_w: num(0, "idle watts")?,
            active_w: [num(1, "hp watts")?, num(2, "two-core watts")?, num(3, "four-core watts")?],
            tx_w: num(4, "tx watts")?,
            rx_w: num(5, "rx watts")?,
        };
        Ok(m)
    }

    /// Structural validity (programmatic construction path).
    pub fn validate(&self) -> anyhow::Result<()> {
        let all = [self.idle_w, self.tx_w, self.rx_w]
            .into_iter()
            .chain(self.active_w);
        for v in all {
            anyhow::ensure!(v.is_finite() && v >= 0.0, "power values must be finite and >= 0");
        }
        Ok(())
    }

    /// Estimated joules a placement will burn on its device: compute at
    /// `active_w[config]` for `proc_us`, plus the tx airtime at `tx_w`
    /// when `transfer_bytes` move at `bps` (0 bytes = local, no tx).
    /// The energy-aware scheduler ranks feasible candidates with this.
    pub fn placement_joules(
        &self,
        config_index: usize,
        proc_us: u64,
        transfer_bytes: u64,
        bps: f64,
    ) -> f64 {
        let compute = self.active_w[config_index.min(N_CONFIGS - 1)] * proc_us as f64 / 1e6;
        let tx = if transfer_bytes > 0 && bps > 0.0 {
            self.tx_w * (transfer_bytes as f64 * 8.0 / bps)
        } else {
            0.0
        };
        compute + tx
    }
}

/// Parse a battery capacity flag (joules): strictly positive and finite.
pub fn parse_battery_j(s: &str) -> anyhow::Result<f64> {
    let v = s
        .parse::<f64>()
        .map_err(|_| anyhow::anyhow!("battery capacity '{s}' is not a number"))?;
    anyhow::ensure!(v.is_finite() && v > 0.0, "battery capacity must be a finite positive joule count, got '{s}'");
    Ok(v)
}

/// One device's power state and integrated energy.
#[derive(Debug, Clone)]
struct DevEnergy {
    last_t: SimTime,
    online: bool,
    /// Running (committed) tasks per `TaskConfig::index()`.
    active: [u32; N_CONFIGS],
    /// Active transfer endpoints on this device.
    tx: u32,
    rx: u32,
    idle_j: f64,
    active_j: f64,
    tx_j: f64,
    rx_j: f64,
    total_j: f64,
    /// Remaining battery joules (`f64::INFINITY` = mains powered).
    remaining_j: f64,
    depleted: bool,
    /// Bumped on every power change; outstanding depletion predictions
    /// carry the epoch they were computed under and die on mismatch.
    epoch: u64,
}

/// Depletion horizons at or beyond this are "never" (no event worth
/// scheduling): ~285 simulated years in µs, exactly representable in
/// `f64`, and far enough below `u64::MAX` that `now + horizon` cannot
/// overflow for any reachable `now`.
pub const DEPLETION_HORIZON_US: u64 = 1 << 53;

/// The fleet-wide energy integrator the engine drives.
#[derive(Debug, Clone)]
pub struct FleetEnergy {
    model: EnergyModel,
    capacity_j: Option<f64>,
    devs: Vec<DevEnergy>,
}

impl FleetEnergy {
    pub fn new(model: EnergyModel, capacity_j: Option<f64>, n_devices: usize) -> Self {
        let remaining = capacity_j.unwrap_or(f64::INFINITY);
        Self {
            model,
            capacity_j,
            devs: vec![
                DevEnergy {
                    last_t: 0,
                    online: true,
                    active: [0; N_CONFIGS],
                    tx: 0,
                    rx: 0,
                    idle_j: 0.0,
                    active_j: 0.0,
                    tx_j: 0.0,
                    rx_j: 0.0,
                    total_j: 0.0,
                    remaining_j: remaining,
                    depleted: false,
                    epoch: 0,
                };
                n_devices
            ],
        }
    }

    pub fn model(&self) -> &EnergyModel {
        &self.model
    }

    pub fn has_battery(&self) -> bool {
        self.capacity_j.is_some()
    }

    fn in_fleet(&self, device: usize) -> bool {
        device < self.devs.len()
    }

    /// Current draw of `device`, watts.
    fn power_w(&self, device: usize) -> f64 {
        let d = &self.devs[device];
        if !d.online {
            return 0.0;
        }
        let mut p = self.model.idle_w + self.model.tx_w * d.tx as f64 + self.model.rx_w * d.rx as f64;
        for (i, &n) in d.active.iter().enumerate() {
            p += self.model.active_w[i] * n as f64;
        }
        p
    }

    /// Integrate `device` forward to `now` under its current power.
    fn settle(&mut self, device: usize, now: SimTime) {
        let idle_w = self.model.idle_w;
        let (act_w, tx_w, rx_w) = (self.model.active_w, self.model.tx_w, self.model.rx_w);
        let d = &mut self.devs[device];
        let dt_s = now.saturating_sub(d.last_t) as f64 / 1e6;
        d.last_t = now;
        if dt_s <= 0.0 || !d.online {
            return;
        }
        let i = idle_w * dt_s;
        let a = d.active.iter().enumerate().map(|(k, &n)| act_w[k] * n as f64).sum::<f64>() * dt_s;
        let t = tx_w * d.tx as f64 * dt_s;
        let r = rx_w * d.rx as f64 * dt_s;
        d.idle_j += i;
        d.active_j += a;
        d.tx_j += t;
        d.rx_j += r;
        let drawn = i + a + t + r;
        d.total_j += drawn;
        // Battery level is monotone non-increasing (no recharge model);
        // the clamp absorbs the sub-µs rounding of the depletion event.
        d.remaining_j = (d.remaining_j - drawn).max(0.0);
    }

    /// A power change happened on `device` at `now`: settle the old
    /// regime, apply `mutate`, and return a fresh depletion prediction
    /// `(epoch, delta_us)` for the engine to schedule — `None` when no
    /// battery is configured, the device is off/depleted, or it draws
    /// nothing. Any previously returned epoch is invalidated.
    fn transition(
        &mut self,
        device: usize,
        now: SimTime,
        mutate: impl FnOnce(&mut DevEnergy),
    ) -> Option<(u64, u64)> {
        if !self.in_fleet(device) {
            return None; // cloud tier: mains powered, not accounted
        }
        self.settle(device, now);
        mutate(&mut self.devs[device]);
        self.devs[device].epoch += 1;
        self.predict(device)
    }

    /// Depletion prediction under the *current* power (post-mutation).
    /// Horizons at or past [`DEPLETION_HORIZON_US`] are treated as
    /// "never": returning a finite-but-astronomical `delta_us` invited
    /// `now + delta_us` overflow in the caller late in a long run (the
    /// old clamp was `u64::MAX / 2` *relative to zero*, not to `now`).
    fn predict(&self, device: usize) -> Option<(u64, u64)> {
        self.capacity_j?;
        let d = &self.devs[device];
        if d.depleted || !d.online {
            return None;
        }
        let p = self.power_w(device);
        if p <= 0.0 {
            return None;
        }
        let dt_us = (d.remaining_j / p * 1e6).ceil().min(DEPLETION_HORIZON_US as f64) as u64;
        if dt_us >= DEPLETION_HORIZON_US {
            return None; // effectively infinite: nothing to schedule
        }
        Some((d.epoch, dt_us.max(1)))
    }

    /// Read-only depletion horizon: microseconds from `now` until
    /// `device` runs dry under its *current* draw. Unlike [`predict`]
    /// this settles nothing — the draw since the last transition is
    /// folded in arithmetically (power is piecewise constant, so the
    /// open interval is at exactly `power_w`). `None` when mains
    /// powered, offline/depleted, drawing nothing, or past the horizon.
    /// The pressure controller uses this to flag executions whose
    /// device will die before the full-depth finish ("battery doomed").
    ///
    /// [`predict`]: FleetEnergy::predict
    pub fn depletion_eta_us(&self, now: SimTime, device: usize) -> Option<u64> {
        self.capacity_j?;
        let d = self.devs.get(device)?;
        if d.depleted || !d.online {
            return None;
        }
        let p = self.power_w(device);
        if p <= 0.0 {
            return None;
        }
        let drawn = p * now.saturating_sub(d.last_t) as f64 / 1e6;
        let rem = (d.remaining_j - drawn).max(0.0);
        let dt_us = (rem / p * 1e6).ceil().min(DEPLETION_HORIZON_US as f64) as u64;
        if dt_us >= DEPLETION_HORIZON_US {
            return None;
        }
        Some(dt_us.max(1))
    }

    // ---- engine hooks (each returns a depletion (epoch, delta_us)) ------

    pub fn task_start(&mut self, now: SimTime, device: usize, cfg: usize) -> Option<(u64, u64)> {
        self.transition(device, now, |d| d.active[cfg.min(N_CONFIGS - 1)] += 1)
    }

    pub fn task_end(&mut self, now: SimTime, device: usize, cfg: usize) -> Option<(u64, u64)> {
        self.transition(device, now, |d| {
            let c = &mut d.active[cfg.min(N_CONFIGS - 1)];
            *c = c.saturating_sub(1);
        })
    }

    pub fn transfer_start(&mut self, now: SimTime, src: usize, dst: usize) -> [Option<(u64, u64)>; 2] {
        [
            self.transition(src, now, |d| d.tx += 1),
            self.transition(dst, now, |d| d.rx += 1),
        ]
    }

    pub fn transfer_end(&mut self, now: SimTime, src: usize, dst: usize) -> [Option<(u64, u64)>; 2] {
        [
            self.transition(src, now, |d| d.tx = d.tx.saturating_sub(1)),
            self.transition(dst, now, |d| d.rx = d.rx.saturating_sub(1)),
        ]
    }

    /// Join/leave/crash/recover: offline devices draw nothing (their
    /// run counters are force-cleared — the engine cancels the work).
    pub fn set_online(&mut self, now: SimTime, device: usize, online: bool) -> Option<(u64, u64)> {
        self.transition(device, now, |d| {
            d.online = online;
            if !online {
                d.active = [0; N_CONFIGS];
                d.tx = 0;
                d.rx = 0;
            }
        })
    }

    /// A scheduled depletion event fired. Returns `true` when it is
    /// still valid (matching epoch, battery actually exhausted): the
    /// caller must then take the device down through the crash path.
    pub fn on_deplete(&mut self, now: SimTime, device: usize, epoch: u64) -> bool {
        if !self.in_fleet(device) {
            return false;
        }
        if self.devs[device].epoch != epoch || self.devs[device].depleted {
            return false;
        }
        self.settle(device, now);
        let d = &mut self.devs[device];
        if !d.online {
            return false;
        }
        d.remaining_j = 0.0;
        d.depleted = true;
        true
    }

    /// Current prediction epoch of `device` (`None` outside the fleet).
    /// A queued `BatteryDeplete` carrying any other epoch is dead — the
    /// engine's queue compaction uses this to drop superseded entries.
    pub fn pred_epoch(&self, device: usize) -> Option<u64> {
        self.devs.get(device).map(|d| d.epoch)
    }

    pub fn depleted(&self, device: usize) -> bool {
        self.in_fleet(device) && self.devs[device].depleted
    }

    /// Settle every device (end of run — fold trailing idle draw).
    pub fn settle_all(&mut self, now: SimTime) {
        for d in 0..self.devs.len() {
            self.settle(d, now);
        }
    }

    /// Fleet totals `(idle_j, active_j, tx_j, rx_j, total_j)`.
    pub fn totals(&self) -> (f64, f64, f64, f64, f64) {
        let mut t = (0.0, 0.0, 0.0, 0.0, 0.0);
        for d in &self.devs {
            t.0 += d.idle_j;
            t.1 += d.active_j;
            t.2 += d.tx_j;
            t.3 += d.rx_j;
            t.4 += d.total_j;
        }
        t
    }

    /// Remaining battery joules per device (empty when mains powered).
    pub fn battery_final_j(&self) -> Vec<f64> {
        if self.capacity_j.is_none() {
            return Vec::new();
        }
        self.devs.iter().map(|d| d.remaining_j).collect()
    }

    /// Remaining battery as a fraction of capacity per device (1.0 when
    /// mains powered) — what `SchedEvent::BatteryLevels` carries.
    pub fn levels(&self, out: &mut Vec<f64>) {
        out.clear();
        match self.capacity_j {
            Some(cap) if cap > 0.0 => {
                out.extend(self.devs.iter().map(|d| (d.remaining_j / cap).clamp(0.0, 1.0)))
            }
            _ => out.extend(std::iter::repeat(1.0).take(self.devs.len())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_named_and_explicit_profiles() {
        assert_eq!(EnergyModel::parse("pi2b").unwrap(), EnergyModel::pi2b());
        assert_eq!(EnergyModel::parse("zero").unwrap(), EnergyModel::zero());
        let m = EnergyModel::parse("1.5:1:2:3:0.5:0.25").unwrap();
        assert_eq!(m.idle_w, 1.5);
        assert_eq!(m.active_w, [1.0, 2.0, 3.0]);
        assert_eq!(m.tx_w, 0.5);
        assert_eq!(m.rx_w, 0.25);
        m.validate().unwrap();
    }

    #[test]
    fn parse_rejects_malformed_profiles_with_errors_not_panics() {
        for bad in [
            "",                   // nothing
            "pi3",                // unknown name
            "1:2:3:4:5",          // missing field
            "1:2:3:4:5:6:7",      // extra field
            "1:2:x:4:5:6",        // non-numeric
            "1:2:inf:4:5:6",      // non-finite
            "1:2:nan:4:5:6",      // non-finite
            "-1:2:3:4:5:6",       // negative idle
            "1:2:3:4:-0.5:6",     // negative tx
        ] {
            assert!(EnergyModel::parse(bad).is_err(), "profile '{bad}' should be rejected");
        }
        // Zero watts everywhere is valid (the inert model).
        assert!(EnergyModel::parse("0:0:0:0:0:0").is_ok());
    }

    #[test]
    fn parse_battery_is_strict() {
        assert_eq!(parse_battery_j("1500").unwrap(), 1500.0);
        for bad in ["", "abc", "0", "-10", "inf", "nan"] {
            assert!(parse_battery_j(bad).is_err(), "battery '{bad}' should be rejected");
        }
    }

    #[test]
    fn settle_integrates_each_component_and_conserves() {
        let m = EnergyModel { idle_w: 1.0, active_w: [2.0, 3.0, 5.0], tx_w: 0.5, rx_w: 0.25 };
        let mut f = FleetEnergy::new(m, None, 2);
        // 10 s idle, then 10 s with a four-core task + one tx flow.
        f.task_start(10_000_000, 0, 2);
        f.transfer_start(10_000_000, 0, 1);
        f.task_end(20_000_000, 0, 2);
        f.transfer_end(20_000_000, 0, 1);
        f.settle_all(20_000_000);
        let (idle, active, tx, rx, total) = f.totals();
        // Device 0: 20 s idle + 10 s four-core + 10 s tx.
        // Device 1: 20 s idle + 10 s rx.
        assert!((idle - 40.0).abs() < 1e-9, "idle {idle}");
        assert!((active - 50.0).abs() < 1e-9, "active {active}");
        assert!((tx - 5.0).abs() < 1e-9, "tx {tx}");
        assert!((rx - 2.5).abs() < 1e-9, "rx {rx}");
        assert!((total - (idle + active + tx + rx)).abs() < 1e-6 * total.max(1.0));
    }

    #[test]
    fn offline_devices_draw_nothing() {
        let mut f = FleetEnergy::new(EnergyModel::pi2b(), None, 1);
        f.set_online(5_000_000, 0, false); // 5 s online, then off
        f.settle_all(60_000_000);
        let (idle, active, tx, rx, total) = f.totals();
        assert!((idle - 1.1 * 5.0).abs() < 1e-9);
        assert_eq!((active, tx, rx), (0.0, 0.0, 0.0));
        assert!((total - idle).abs() < 1e-9);
    }

    /// Regression: a near-zero draw used to predict a depletion
    /// `u64::MAX / 2` µs out — clamped relative to zero, not to `now` —
    /// so `now + delta_us` could overflow late in a long run. Such
    /// horizons are "never": no prediction at all, and any finite
    /// prediction stays below [`DEPLETION_HORIZON_US`] so the engine's
    /// saturating add can never wrap.
    #[test]
    fn near_zero_draw_predicts_no_depletion_instead_of_overflowing() {
        let trickle = EnergyModel { idle_w: 1e-12, active_w: [0.0; 3], tx_w: 0.0, rx_w: 0.0 };
        let mut f = FleetEnergy::new(trickle, Some(1000.0), 1);
        // 1000 J / 1e-12 W ≈ 1e21 µs — far past the horizon: no event.
        assert_eq!(f.task_start(0, 0, 0), None, "infinite horizon must not schedule");
        assert_eq!(f.task_end(1_000_000, 0, 0), None);
        // A real draw still predicts, and the delta is overflow-proof by
        // construction (strictly below the horizon cap).
        let mut g = FleetEnergy::new(EnergyModel::pi2b(), Some(1000.0), 1);
        let (_, dt) = g.task_start(0, 0, 2).expect("finite horizon must schedule");
        assert!(dt >= 1 && dt < DEPLETION_HORIZON_US);
        let far_future = u64::MAX - DEPLETION_HORIZON_US;
        assert!(far_future.checked_add(dt).is_some(), "now + delta must not overflow");
    }

    #[test]
    fn battery_is_monotone_under_random_schedules() {
        let mut rng = crate::util::Rng::seed_from_u64(11);
        let mut f = FleetEnergy::new(EnergyModel::pi2b(), Some(500.0), 3);
        let mut t: SimTime = 0;
        let mut last = vec![500.0f64; 3];
        for _ in 0..500 {
            t += 1 + rng.gen_range(2_000_000);
            let d = rng.index(3);
            match rng.index(5) {
                0 => drop(f.task_start(t, d, rng.index(3))),
                1 => drop(f.task_end(t, d, rng.index(3))),
                2 => drop(f.transfer_start(t, d, (d + 1) % 3)),
                3 => drop(f.transfer_end(t, d, (d + 1) % 3)),
                _ => drop(f.set_online(t, d, rng.gen_f64() < 0.8)),
            }
            let now = f.battery_final_j();
            for (i, (&a, &b)) in now.iter().zip(&last).enumerate() {
                assert!(a <= b + 1e-12, "device {i} battery rose: {b} -> {a}");
                assert!(a >= 0.0);
            }
            last = now;
        }
        // Conservation still holds through the churned schedule.
        let (i, a, tx, rx, total) = f.totals();
        assert!((i + a + tx + rx - total).abs() <= 1e-6 * total.max(1.0));
    }

    #[test]
    fn depletion_predictions_die_on_epoch_mismatch_and_fire_once() {
        let m = EnergyModel { idle_w: 1.0, active_w: [0.0; 3], tx_w: 0.0, rx_w: 0.0 };
        let mut f = FleetEnergy::new(m, Some(10.0), 1);
        // Pure idle at 1 W: 10 J lasts 10 s.
        let (e1, dt1) = f.predict(0).unwrap();
        assert_eq!(dt1, 10_000_000);
        // A transition bumps the epoch: the old prediction is dead.
        let (e2, _) = f.task_start(1_000_000, 0, 0).unwrap();
        assert_ne!(e1, e2);
        assert!(!f.on_deplete(10_000_000, 0, e1), "stale epoch must not deplete");
        // Clear the task again and let the fresh prediction fire.
        let (e3, dt3) = f.task_end(2_000_000, 0, 0).unwrap();
        let at = 2_000_000 + dt3;
        assert!(f.on_deplete(at, 0, e3), "matching epoch must deplete");
        assert!(f.depleted(0));
        assert_eq!(f.battery_final_j(), vec![0.0]);
        assert!(!f.on_deplete(at, 0, e3), "a battery depletes once");
    }

    #[test]
    fn depletion_eta_reads_without_settling() {
        let m = EnergyModel { idle_w: 1.0, active_w: [0.0; 3], tx_w: 0.0, rx_w: 0.0 };
        let mut f = FleetEnergy::new(m, Some(10.0), 2);
        // Pure idle at 1 W: 10 J lasts 10 s from t=0.
        assert_eq!(f.depletion_eta_us(0, 0), Some(10_000_000));
        // Mid-interval the horizon shrinks by elapsed time — with no
        // settle and no state change (the read is &self).
        assert_eq!(f.depletion_eta_us(4_000_000, 0), Some(6_000_000));
        let before = f.battery_final_j();
        assert_eq!(before, vec![10.0, 10.0], "reads must not drain the battery");
        // Offline / depleted / mains devices report no horizon.
        f.set_online(0, 1, false);
        assert_eq!(f.depletion_eta_us(0, 1), None);
        assert_eq!(f.depletion_eta_us(0, 9), None, "out of fleet");
        let mains = FleetEnergy::new(EnergyModel::pi2b(), None, 1);
        assert_eq!(mains.depletion_eta_us(0, 0), None);
        // A drained battery clamps to the 1 µs floor, never underflows.
        assert_eq!(f.depletion_eta_us(50_000_000, 0), Some(1));
    }

    #[test]
    fn zero_model_accumulates_nothing() {
        let mut f = FleetEnergy::new(EnergyModel::zero(), None, 4);
        f.task_start(0, 1, 2);
        f.transfer_start(0, 1, 0);
        f.settle_all(3_600_000_000);
        assert_eq!(f.totals(), (0.0, 0.0, 0.0, 0.0, 0.0));
        assert!(f.battery_final_j().is_empty());
    }

    #[test]
    fn placement_joules_ranks_cheaper_work_lower() {
        let m = EnergyModel::pi2b();
        let local = m.placement_joules(1, 16_862_000, 0, 40e6);
        let offload4 = m.placement_joules(2, 11_611_000, 1_100_000, 40e6);
        assert!(local > 0.0 && offload4 > 0.0);
        // Shorter compute on more cores can still win on joules here.
        assert!(m.placement_joules(2, 1_000_000, 0, 40e6) < local);
        // Transfers cost tx airtime.
        assert!(offload4 > m.placement_joules(2, 11_611_000, 0, 40e6));
    }

    #[test]
    fn levels_report_fractions_or_mains() {
        let mut f = FleetEnergy::new(EnergyModel::pi2b(), Some(100.0), 2);
        let mut out = Vec::new();
        f.levels(&mut out);
        assert_eq!(out, vec![1.0, 1.0]);
        f.settle_all(10_000_000); // 10 s idle at 1.1 W
        f.levels(&mut out);
        assert!((out[0] - 0.89).abs() < 1e-9);
        let mains = FleetEnergy::new(EnergyModel::pi2b(), None, 2);
        mains.levels(&mut out);
        assert_eq!(out, vec![1.0, 1.0]);
    }
}
