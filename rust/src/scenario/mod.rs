//! Composable scenario construction and parallel sweep execution.
//!
//! The paper's evaluation is a handful of fixed figures; validating the
//! accuracy-vs-performance claim at scale means running *many* schedulers
//! over *many* scenarios cheaply. This module is the one place that
//! happens:
//!
//! * [`ScenarioBuilder`] — a fluent spec of one experiment: trace
//!   distribution, device fleet (count, per-device speed heterogeneity),
//!   congestion/bandwidth regimes, fleet churn schedule, fault plan
//!   (crashes, lossy links, probe loss — see [`crate::fault`]), scheduler,
//!   seed, duration. `build()` freezes it into a [`Scenario`].
//! * [`Scenario`] — compiles to an [`Engine`] run and produces one
//!   [`Metrics`] row. Cheap to clone, `Send`, fully deterministic from its
//!   config seed.
//! * [`Sweep`] — fans a list of scenarios across `std::thread::scope`
//!   workers and collects the rows in input order (JSON-exportable via
//!   [`crate::metrics::report::json_rows`]).
//!
//! ```no_run
//! use medge::scenario::{ScenarioBuilder, SchedKind, Sweep};
//! use medge::workload::trace::TraceSpec;
//!
//! let mut sweep = Sweep::new();
//! for kind in [SchedKind::Wps, SchedKind::Ras] {
//!     for n in 1..=4u8 {
//!         sweep = sweep.add(
//!             ScenarioBuilder::new()
//!                 .scheduler(kind)
//!                 .trace(TraceSpec::Weighted(n))
//!                 .minutes(30.0)
//!                 .seed(42)
//!                 .leave_at(300.0, 3)       // device 3 drops out at 5 min
//!                 .join_at(600.0, 3)        // ... and returns at 10 min
//!                 .congestion_at(900.0, 36e6, 0.75) // storm from 15 min
//!                 .build(),
//!         );
//!     }
//! }
//! let rows = sweep.run();
//! ```

use crate::config::SystemConfig;
use crate::coordinator::scheduler::energy_sched::EnergyScheduler;
use crate::coordinator::scheduler::greedy::GreedyScheduler;
use crate::coordinator::scheduler::multi::MultiScheduler;
use crate::fault::FaultPlan;
use crate::coordinator::scheduler::ras_sched::RasScheduler;
use crate::coordinator::scheduler::wps::WpsScheduler;
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::task::DeviceId;
use crate::energy::EnergyModel;
use crate::metrics::Metrics;
use crate::sim::engine::RunExtras;
use crate::sim::Engine;
use crate::time::secs;
use crate::workload::gen::{Ladder, Workload};
use crate::workload::trace::{Trace, TraceSpec};

/// Number of trace frames in a wall-clock experiment duration (the single
/// definition — `experiments::frames_for_minutes` delegates here).
pub fn frames_for_minutes(cfg: &SystemConfig, minutes: f64) -> usize {
    ((minutes * 60.0) / cfg.frame_period_s).ceil() as usize
}

/// Which scheduler a scenario runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedKind {
    Wps,
    Ras,
    /// Future-work contextual multi-scheduler (ablation).
    Multi,
    /// Battery-aware variant: deadline feasibility first, joules second
    /// (see [`crate::coordinator::scheduler::energy_sched`]).
    Energy,
    /// Fresa & Champati accuracy-maximizing greedy: ranks ladder rungs
    /// by accuracy density instead of descending from the most accurate
    /// (see [`crate::coordinator::scheduler::greedy`]).
    Greedy,
}

impl SchedKind {
    pub fn build(self, cfg: &SystemConfig) -> Box<dyn Scheduler> {
        self.build_with(cfg, None)
    }

    /// Like [`Self::build`], but lets the caller pass the run's own power
    /// model so the energy-aware score ranks placements by the joules the
    /// engine will actually integrate. Only [`SchedKind::Energy`] consumes
    /// it (falling back to [`EnergyModel::pi2b`] when absent).
    pub fn build_with(
        self,
        cfg: &SystemConfig,
        energy: Option<&EnergyModel>,
    ) -> Box<dyn Scheduler> {
        match self {
            SchedKind::Wps => Box::new(WpsScheduler::new(cfg, 0, cfg.link_bps)),
            SchedKind::Ras => Box::new(RasScheduler::new(cfg, 0, cfg.link_bps)),
            SchedKind::Multi => Box::new(MultiScheduler::new(cfg, 0, cfg.link_bps, 8)),
            SchedKind::Energy => {
                let model = energy.cloned().unwrap_or_else(EnergyModel::pi2b);
                Box::new(EnergyScheduler::new(cfg, 0, cfg.link_bps, model))
            }
            SchedKind::Greedy => Box::new(GreedyScheduler::new(cfg, 0, cfg.link_bps)),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SchedKind::Wps => "WPS",
            SchedKind::Ras => "RAS",
            SchedKind::Multi => "MULTI",
            SchedKind::Energy => "ENERGY",
            SchedKind::Greedy => "GREEDY",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "wps" => Ok(SchedKind::Wps),
            "ras" => Ok(SchedKind::Ras),
            "multi" => Ok(SchedKind::Multi),
            "energy" => Ok(SchedKind::Energy),
            "greedy" => Ok(SchedKind::Greedy),
            other => {
                anyhow::bail!("unknown scheduler: {other} (wps | ras | multi | energy | greedy)")
            }
        }
    }
}

/// A frozen experiment specification: everything an [`Engine`] run needs.
/// The trace is materialised once at [`ScenarioBuilder::build`] time and
/// shared (`Arc`, deduplicated process-wide via [`Trace::shared`]): a
/// sweep grid that varies scheduler or fault axes over the same workload
/// holds one trace allocation per workload point, and repeated
/// `run()`s / clones of one scenario never regenerate it.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub cfg: SystemConfig,
    pub kind: SchedKind,
    /// Conveyor trace distribution (the default [`Workload::Conveyor`]
    /// axis value; retained for generative scenarios but unused there).
    pub spec: TraceSpec,
    /// The workload axis this scenario was built from.
    pub workload: Workload,
    pub frames: usize,
    pub extras: RunExtras,
    pub trace: std::sync::Arc<Trace>,
}

impl Scenario {
    /// Compile to a ready-to-run engine (the shared trace is borrowed,
    /// not regenerated or cloned).
    pub fn engine(&self) -> Engine {
        Engine::with_extras(
            self.cfg.clone(),
            self.kind.build_with(&self.cfg, self.extras.energy.as_ref()),
            std::sync::Arc::clone(&self.trace),
            &self.name,
            self.extras.clone(),
        )
    }

    /// Run to completion and return the metrics row.
    pub fn run(&self) -> Metrics {
        self.engine().run()
    }
}

/// Fluent scenario construction. All knobs default to the paper's testbed
/// (Section V): 4 homogeneous Pi 2B devices, weighted-4 load, RAS
/// scheduler, 30 simulated minutes, no churn, config-static congestion.
#[derive(Debug, Clone)]
pub struct ScenarioBuilder {
    name: Option<String>,
    cfg: SystemConfig,
    kind: SchedKind,
    spec: TraceSpec,
    workload: Workload,
    frames: Option<usize>,
    minutes: f64,
    extras: RunExtras,
    plan: FaultPlan,
    lp_ladder: Option<Ladder>,
}

impl Default for ScenarioBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl ScenarioBuilder {
    pub fn new() -> Self {
        Self {
            name: None,
            cfg: SystemConfig::default(),
            kind: SchedKind::Ras,
            spec: TraceSpec::Weighted(4),
            workload: Workload::Conveyor(TraceSpec::Weighted(4)),
            frames: None,
            minutes: 30.0,
            extras: RunExtras::default(),
            plan: FaultPlan::new(),
            lp_ladder: None,
        }
    }

    /// Replace the whole base config (overrides accumulate on top).
    pub fn config(mut self, cfg: SystemConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Scenario label used in reports (defaults to `KIND_SPEC`).
    pub fn named(mut self, name: impl Into<String>) -> Self {
        self.name = Some(name.into());
        self
    }

    pub fn scheduler(mut self, kind: SchedKind) -> Self {
        self.kind = kind;
        self
    }

    /// The conveyor-belt trace workload (shorthand for
    /// `.workload(Workload::Conveyor(spec))` — the two are one axis).
    pub fn trace(mut self, spec: TraceSpec) -> Self {
        self.spec = spec;
        self.workload = Workload::Conveyor(spec);
        self
    }

    /// The workload axis: the conveyor trace or a generative
    /// (arrival-process × task-class-catalog) spec. See
    /// [`crate::workload::gen`].
    pub fn workload(mut self, w: Workload) -> Self {
        if let Workload::Conveyor(spec) = &w {
            self.spec = *spec;
        }
        self.workload = w;
        self
    }

    /// The model-variant axis: attach a ladder to the conveyor's
    /// low-priority (stage-3) class, letting the scheduler degrade to a
    /// cheaper DNN variant when the full model cannot meet its deadline
    /// (see [`crate::workload::gen::variants`]). A one-rung ladder never
    /// degrades — at accuracy 1.0 it is byte-identical to no ladder at
    /// all, which `rust/tests/golden_trace.rs` pins. Generative classes
    /// carry their ladders in the catalog ([`crate::workload::gen::TaskClass::ladder`]).
    pub fn lp_ladder(mut self, ladder: Ladder) -> Self {
        self.lp_ladder = Some(ladder);
        self
    }

    /// Simulated duration in minutes (converted to trace frames).
    pub fn minutes(mut self, minutes: f64) -> Self {
        self.minutes = minutes;
        self
    }

    /// Exact trace frame count (overrides `minutes`).
    pub fn frames(mut self, frames: usize) -> Self {
        self.frames = Some(frames);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Fleet size at start (the paper uses 4).
    pub fn devices(mut self, n: usize) -> Self {
        self.cfg.n_devices = n;
        self
    }

    pub fn cores_per_device(mut self, cores: u32) -> Self {
        self.cfg.cores_per_device = cores;
        self
    }

    /// Fleet-cell span for the sharded scheduler hierarchy (0 = auto:
    /// one cell for small fleets, ~√n-device cells at scale). Any value
    /// produces identical decisions — the knob only moves work between
    /// the per-cell uniform fast path and the exact per-device path —
    /// which the fleet-scale equivalence suite asserts byte-for-byte.
    pub fn cell_size(mut self, span: usize) -> Self {
        self.cfg.cell_size = span;
        self
    }

    /// Remote-candidate count at or below which RAS keeps the legacy
    /// eager shuffle instead of the lazy cell descent. 0 forces the
    /// descent everywhere (equivalence tests); a huge value forces the
    /// eager path everywhere.
    pub fn lazy_shuffle_cutover(mut self, cutover: usize) -> Self {
        self.cfg.lazy_shuffle_cutover = cutover;
        self
    }

    /// Heterogeneous fleet: `device` runs `slowdown`× the planned
    /// processing time (1.0 = nominal; 1.3 = 30 % slower than the
    /// controller's homogeneous plan believes).
    pub fn device_speed(mut self, device: DeviceId, slowdown: f64) -> Self {
        if self.extras.device_speed.len() <= device {
            self.extras.device_speed.resize(device + 1, 1.0);
        }
        self.extras.device_speed[device] = slowdown;
        self
    }

    /// Static bandwidth probe interval (seconds).
    pub fn bandwidth_interval_s(mut self, s: f64) -> Self {
        self.cfg.bandwidth_interval_s = s;
        self
    }

    /// Static background burst duty cycle in [0, 1] (the paper's Fig. 8
    /// knob); for mid-run changes use [`Self::congestion_at`].
    pub fn duty_cycle(mut self, duty: f64) -> Self {
        self.cfg.duty_cycle = duty;
        self
    }

    // ---- energy & cloud tier --------------------------------------------

    /// Attach a per-device power model: the engine integrates idle /
    /// active / radio joules at every state transition (see
    /// [`crate::energy`]). Without this the run makes no energy
    /// accounting and is byte-identical to the pre-energy engine.
    pub fn energy(mut self, model: EnergyModel) -> Self {
        self.extras.energy = Some(model);
        self
    }

    /// Give every device a finite battery of `capacity_j` joules.
    /// Depletion routes through the crash path (in-flight work lost,
    /// survivors re-offered) and the device never recovers. Requires
    /// [`Self::energy`] — a battery without a power model never drains.
    pub fn battery_j(mut self, capacity_j: f64) -> Self {
        self.extras.battery_j = Some(capacity_j);
        self
    }

    /// Enable the cloud tier: a high-capacity executor behind a WAN
    /// medium of `wan_bps` bits/s with a fixed `rtt_ms` round trip.
    /// Schedulers gain one extra placement target (device id
    /// `n_devices`); per-class cloud service times come from the
    /// workload ([`crate::coordinator::task::Task::cloud_us`]).
    pub fn cloud(mut self, wan_bps: f64, rtt_ms: f64) -> Self {
        self.cfg.cloud_wan_bps = wan_bps;
        self.cfg.cloud_rtt_ms = rtt_ms;
        self
    }

    /// Mid-run congestion regime change: from `at_s` seconds, background
    /// bursts consume `bg_bps` bits/s at `duty` duty cycle.
    pub fn congestion_at(mut self, at_s: f64, bg_bps: f64, duty: f64) -> Self {
        self.extras.regimes.push((secs(at_s), bg_bps, duty));
        self
    }

    /// Device `device` joins (or re-joins) the fleet at `at_s` seconds.
    pub fn join_at(mut self, at_s: f64, device: DeviceId) -> Self {
        self.extras.churn.push((secs(at_s), device, true));
        self
    }

    /// Device `device` leaves the fleet at `at_s` seconds; its live tasks
    /// are evicted (guests re-enter scheduling, its own frames fail).
    pub fn leave_at(mut self, at_s: f64, device: DeviceId) -> Self {
        self.extras.churn.push((secs(at_s), device, false));
        self
    }

    // ---- fault injection ------------------------------------------------

    /// Attach a full [`FaultPlan`] (replaces any fault knobs set so far).
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.plan = plan;
        self
    }

    /// Device `device` crashes at `at_s` seconds: its in-flight tasks are
    /// lost (flows aborted), survivors re-offered — unlike the graceful
    /// [`Self::leave_at`].
    pub fn crash_at(mut self, at_s: f64, device: DeviceId) -> Self {
        self.plan = self.plan.crash_at(at_s, device);
        self
    }

    /// A crashed `device` comes back at `at_s` seconds, empty.
    pub fn recover_at(mut self, at_s: f64, device: DeviceId) -> Self {
        self.plan = self.plan.recover_at(at_s, device);
        self
    }

    /// Per-packet loss probability on task transfers (retransmission
    /// inflation on the medium).
    pub fn loss_rate(mut self, p: f64) -> Self {
        self.plan = self.plan.loss_rate(p);
        self
    }

    /// Per-ping loss probability on probe rounds (partial/empty rounds).
    pub fn probe_loss(mut self, p: f64) -> Self {
        self.plan = self.plan.probe_loss(p);
        self
    }

    /// Seed-deterministic random crash/recover process over the whole
    /// run (exponential up/down times with the given means).
    pub fn random_faults(mut self, mtbf_s: f64, mttr_s: f64) -> Self {
        self.plan = self.plan.random_faults(mtbf_s, mttr_s);
        self
    }

    /// Device `device` becomes unreachable-but-alive at `at_s` seconds:
    /// its flows stall (resuming on heal), finished results are held
    /// undeliverable — unlike [`Self::crash_at`], no work is force-lost.
    pub fn partition_at(mut self, at_s: f64, device: DeviceId) -> Self {
        self.plan = self.plan.partition_at(at_s, device);
        self
    }

    /// The partition around `device` heals at `at_s` seconds.
    pub fn heal_at(mut self, at_s: f64, device: DeviceId) -> Self {
        self.plan = self.plan.heal_at(at_s, device);
        self
    }

    /// Seed-deterministic random partition/heal process over the whole
    /// run (exponential reachable/unreachable times with the given
    /// means) — composes with [`Self::random_faults`].
    pub fn random_partitions(mut self, mtbp_s: f64, mtth_s: f64) -> Self {
        self.plan = self.plan.random_partitions(mtbp_s, mtth_s);
        self
    }

    // ---- robustness knobs (PR 8; all default off) ------------------------

    /// Enable the heartbeat suspicion detector: a device is `Suspected`
    /// (schedulers stop placing on it) after `suspect` consecutive
    /// missed probe heartbeats and `Confirmed` after `confirm` more.
    pub fn detector(mut self, suspect: u32, confirm: u32) -> Self {
        self.cfg.suspect_after = suspect;
        self.cfg.confirm_after = confirm;
        self
    }

    /// Per-placement offload timeout with bounded retry: an undelivered
    /// input past `timeout_s` (doubling per attempt) cancels the
    /// placement and re-enters scheduling, up to `retries` times.
    pub fn offload_timeout(mut self, timeout_s: f64, retries: u32) -> Self {
        self.cfg.offload_timeout_s = timeout_s;
        self.cfg.retry_limit = retries;
        self
    }

    /// Deadline-aware hedged duplicates: an offloaded placement still
    /// unfinished `timeout_s` after its decision races a duplicate;
    /// first completion wins, the loser is suppressed without credit.
    pub fn hedge(mut self, timeout_s: f64) -> Self {
        self.cfg.hedge_timeout_s = timeout_s;
        self
    }

    /// Bandwidth-estimate staleness: after `rounds` consecutive failed
    /// probe rounds the estimate is stale and RAS plans conservatively
    /// until the next successful round.
    pub fn bw_stale_after(mut self, rounds: u32) -> Self {
        self.cfg.bw_stale_after = rounds;
        self
    }

    // ---- anytime inference (PR 10; default off) --------------------------

    /// Enable the deadline-pressure controller: every `check_s` seconds
    /// the engine surveys running staged executions and lets the
    /// scheduler's rescue policy truncate those that would otherwise
    /// miss their deadline (or die with their battery). With `backlog`
    /// > 0 the survey escalates — cuts *every* cuttable execution —
    /// whenever at least that many tasks are live. Only executions
    /// whose rung carries a [`crate::coordinator::task::StagePlan`]
    /// (see [`Ladder::stage3_family_staged`] /
    /// [`crate::workload::gen::ModelVariant::staged`]) can be cut;
    /// without plans, or at the 0.0 default, the run is byte-identical
    /// to the pre-anytime engine.
    pub fn pressure(mut self, check_s: f64, backlog: u32) -> Self {
        self.cfg.pressure_check_s = check_s;
        self.cfg.pressure_backlog = backlog;
        self
    }

    // ---- observability (PR 9; both default off) --------------------------

    /// Attach a flight recorder of `capacity` span records (ring buffer,
    /// overwrite-oldest; 0 = off, the default). With the recorder on,
    /// the engine emits the full task-lifecycle span taxonomy plus one
    /// [`crate::obs::DecisionRecord`] per scheduler decision; read them
    /// back through [`crate::sim::Engine::recorder`] or export with
    /// [`crate::sim::Engine::trace_json`]. Off ⇒ zero events, zero RNG
    /// draws, byte-identical runs (`rust/tests/golden_trace.rs` pins it).
    pub fn record_trace(mut self, capacity: usize) -> Self {
        self.extras.trace_capacity = capacity;
        self
    }

    /// Measure wall-clock time per engine phase (dispatch / scheduler /
    /// medium / compaction), surfaced as the `phase_*_ns` metrics
    /// gauges. Wall-clock is non-deterministic — leave this off (the
    /// default) anywhere byte-identity matters.
    pub fn timing(mut self, on: bool) -> Self {
        self.extras.timing = on;
        self
    }

    /// Freeze into a runnable [`Scenario`]. Everything time-varying
    /// compiles here — the fault plan *and* the generative arrival plan
    /// both expand over the run horizon from the scenario seed (never
    /// ambient randomness), so the frozen scenario is fully
    /// deterministic. A conveyor workload compiles to exactly the
    /// pre-generative construction: same trace allocation, same events,
    /// byte-identical runs.
    ///
    /// # Panics
    ///
    /// On a generative workload whose catalog fails validation (empty,
    /// zero weights, inverted stage times), an invalid
    /// [`ScenarioBuilder::lp_ladder`], or a fault plan that fails
    /// [`FaultPlan::validate`](crate::fault::FaultPlan::validate)
    /// (out-of-range device, unordered crash/recover or
    /// partition/heal pairs) — a programming error in the scenario
    /// definition, not a runtime condition.
    pub fn build(self) -> Scenario {
        let (frames, horizon_s, gen) = match &self.workload {
            Workload::Conveyor(_) => {
                let frames =
                    self.frames.unwrap_or_else(|| frames_for_minutes(&self.cfg, self.minutes));
                (frames, frames as f64 * self.cfg.frame_period_s, None)
            }
            Workload::Generative(g) => {
                // Horizon: explicit frame count (frame-period equivalents)
                // or wall-clock minutes; the trace stays empty — arrivals
                // are the only load source.
                let horizon_s = match self.frames {
                    Some(f) => f as f64 * self.cfg.frame_period_s,
                    None => self.minutes * 60.0,
                };
                let gen = g
                    .compile(&self.cfg, secs(horizon_s))
                    .expect("generative workload failed to compile");
                (0, horizon_s, Some(gen))
            }
        };
        let name = self
            .name
            .unwrap_or_else(|| format!("{}_{}", self.kind.label(), self.workload.label()));
        let mut extras = self.extras;
        extras.gen = gen;
        if let Some(ladder) = &self.lp_ladder {
            ladder.validate().expect("invalid model-variant ladder");
            let compiled = ladder.compile(&self.cfg);
            // Same sync rule Catalog::validate enforces for generative
            // classes: rung 0 IS the model the tasks actually run, so a
            // conveyor ladder whose rung 0 differs from the stage-3 spec
            // would claim accuracy for (and step down relative to) a
            // model the engine never executes.
            let r0 = &compiled[0];
            assert!(
                r0.input_bytes == self.cfg.image_bytes
                    && r0.proc_us == [self.cfg.lp2_proc(), self.cfg.lp4_proc()],
                "invalid model-variant ladder: rung 0 must equal the conveyor stage-3 spec \
                 ({} input bytes, {:?} µs) — got {} bytes, {:?} µs",
                self.cfg.image_bytes,
                [self.cfg.lp2_proc(), self.cfg.lp4_proc()],
                r0.input_bytes,
                r0.proc_us,
            );
            extras.lp_ladder = compiled;
            if ladder.has_stage_plans() {
                extras.lp_stage_plans = ladder.compile_stage_plans();
            }
        }
        self.plan
            .compile_into(&mut extras, self.cfg.seed, self.cfg.n_devices, horizon_s)
            .expect("invalid fault plan");
        let trace = Trace::shared(self.spec, self.cfg.n_devices, frames, self.cfg.seed);
        Scenario {
            name,
            cfg: self.cfg,
            kind: self.kind,
            spec: self.spec,
            workload: self.workload,
            frames,
            extras,
            trace,
        }
    }
}

/// A grid of scenarios executed across worker threads. Rows come back in
/// the order scenarios were added, independent of completion order.
#[derive(Debug, Clone, Default)]
pub struct Sweep {
    scenarios: Vec<Scenario>,
    threads: Option<usize>,
}

impl Sweep {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(mut self, scenario: Scenario) -> Self {
        self.scenarios.push(scenario);
        self
    }

    /// Worker-thread cap (defaults to available parallelism).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n.max(1));
        self
    }

    pub fn len(&self) -> usize {
        self.scenarios.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scenarios.is_empty()
    }

    pub fn scenarios(&self) -> &[Scenario] {
        &self.scenarios
    }

    /// Run every scenario, fanning across scoped worker threads. Each
    /// engine run is single-threaded and deterministic, so the parallel
    /// rows are byte-identical to sequential execution.
    pub fn run(&self) -> Vec<Metrics> {
        let n = self.scenarios.len();
        if n == 0 {
            return Vec::new();
        }
        let workers = self
            .threads
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
            })
            .clamp(1, n);
        if workers == 1 {
            return self.scenarios.iter().map(|s| s.run()).collect();
        }
        let next = std::sync::atomic::AtomicUsize::new(0);
        let (tx, rx) = std::sync::mpsc::channel::<(usize, Metrics)>();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let next = &next;
                let scenarios = &self.scenarios;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= scenarios.len() {
                        break;
                    }
                    // A worker dying (scenario panic) drops its tx; the
                    // collector below then reports the missing row.
                    let _ = tx.send((i, scenarios[i].run()));
                });
            }
            drop(tx);
            let mut rows: Vec<Option<Metrics>> = (0..n).map(|_| None).collect();
            for (i, m) in rx {
                rows[i] = Some(m);
            }
            rows.into_iter()
                .enumerate()
                .map(|(i, m)| m.unwrap_or_else(|| panic!("scenario {i} worker died")))
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(kind: SchedKind, seed: u64) -> Scenario {
        ScenarioBuilder::new()
            .scheduler(kind)
            .trace(TraceSpec::Weighted(2))
            .frames(8)
            .seed(seed)
            .build()
    }

    #[test]
    fn builder_defaults_match_paper_testbed() {
        let s = ScenarioBuilder::new().build();
        assert_eq!(s.cfg.n_devices, 4);
        assert_eq!(s.kind, SchedKind::Ras);
        assert_eq!(s.name, "RAS_4");
        // 30 min at 18.86 s/frame → 96 frames.
        assert_eq!(s.frames, 96);
        assert!(s.extras.churn.is_empty() && s.extras.regimes.is_empty());
    }

    #[test]
    fn robustness_builders_flow_into_cfg_and_extras() {
        let s = ScenarioBuilder::new()
            .trace(TraceSpec::Weighted(2))
            .frames(8)
            .partition_at(5.0, 1)
            .heal_at(9.0, 1)
            .detector(3, 2)
            .offload_timeout(0.5, 2)
            .hedge(0.25)
            .bw_stale_after(4)
            .build();
        assert_eq!(s.cfg.suspect_after, 3);
        assert_eq!(s.cfg.confirm_after, 2);
        assert_eq!(s.cfg.offload_timeout_s, 0.5);
        assert_eq!(s.cfg.retry_limit, 2);
        assert_eq!(s.cfg.hedge_timeout_s, 0.25);
        assert_eq!(s.cfg.bw_stale_after, 4);
        assert_eq!(s.extras.partitions, vec![(secs(5.0), 1, false), (secs(9.0), 1, true)]);
    }

    #[test]
    #[should_panic(expected = "invalid fault plan")]
    fn build_rejects_out_of_range_partition_device() {
        ScenarioBuilder::new()
            .trace(TraceSpec::Weighted(2))
            .frames(8)
            .partition_at(5.0, 99)
            .build();
    }

    #[test]
    fn scenario_run_matches_direct_engine_run() {
        // The builder is sugar, not semantics: compiling through
        // Scenario must equal hand-building the engine.
        let s = quick(SchedKind::Ras, 7);
        let via_scenario = s.run();
        let trace = Trace::generate(s.spec, s.cfg.n_devices, s.frames, s.cfg.seed);
        let direct =
            Engine::new(s.cfg.clone(), s.kind.build(&s.cfg), trace, &s.name).run();
        assert_eq!(format!("{via_scenario:?}"), format!("{direct:?}"));
    }

    #[test]
    fn grid_cells_share_one_trace_allocation() {
        // Scheduler and fault axes vary; the workload point does not — so
        // every cell must hold the *same* Arc'd trace, not a copy.
        let a = quick(SchedKind::Ras, 7);
        let b = quick(SchedKind::Wps, 7);
        assert!(std::sync::Arc::ptr_eq(&a.trace, &b.trace));
        let c = a.clone();
        assert!(std::sync::Arc::ptr_eq(&a.trace, &c.trace));
        let other_seed = quick(SchedKind::Ras, 8);
        assert!(!std::sync::Arc::ptr_eq(&a.trace, &other_seed.trace));
    }

    #[test]
    fn sweep_preserves_input_order_and_determinism() {
        let mut sweep = Sweep::new().threads(4);
        for (i, kind) in [SchedKind::Ras, SchedKind::Wps, SchedKind::Ras, SchedKind::Wps]
            .into_iter()
            .enumerate()
        {
            let mut s = quick(kind, 11 + i as u64);
            s.name = format!("row{i}");
            sweep = sweep.add(s);
        }
        let parallel = sweep.run();
        let sequential = sweep.clone().threads(1).run();
        assert_eq!(parallel.len(), 4);
        for (i, (p, q)) in parallel.iter().zip(&sequential).enumerate() {
            assert_eq!(p.label, format!("row{i}"));
            assert_eq!(format!("{p:?}"), format!("{q:?}"), "row {i} differs");
        }
    }

    #[test]
    fn trace_and_conveyor_workload_are_one_axis() {
        // `.trace(spec)` is sugar for `.workload(Workload::Conveyor(spec))`:
        // both must freeze to identical scenarios (same trace allocation)
        // and identical runs.
        let via_trace = ScenarioBuilder::new()
            .scheduler(SchedKind::Wps)
            .trace(TraceSpec::Weighted(3))
            .frames(10)
            .seed(19)
            .build();
        let via_workload = ScenarioBuilder::new()
            .scheduler(SchedKind::Wps)
            .workload(Workload::conveyor(TraceSpec::Weighted(3)))
            .frames(10)
            .seed(19)
            .build();
        assert_eq!(via_trace.name, via_workload.name);
        assert_eq!(via_trace.spec, via_workload.spec);
        assert!(std::sync::Arc::ptr_eq(&via_trace.trace, &via_workload.trace));
        assert!(via_workload.extras.gen.is_none());
        assert_eq!(format!("{:?}", via_trace.run()), format!("{:?}", via_workload.run()));
    }

    #[test]
    fn generative_scenario_compiles_and_runs_deterministically() {
        use crate::workload::gen::{ArrivalProcess, Catalog};
        let build = || {
            ScenarioBuilder::new()
                .scheduler(SchedKind::Ras)
                .workload(Workload::generative(
                    ArrivalProcess::Poisson { rate_per_min: 10.0 },
                    Catalog::edge_serving(&SystemConfig::default()),
                ))
                .minutes(6.0)
                .seed(77)
                .build()
        };
        let s = build();
        assert_eq!(s.frames, 0, "generative scenarios carry no conveyor frames");
        assert_eq!(s.name, "RAS_poisson10");
        let gen = s.extras.gen.as_ref().expect("compiled plan");
        assert!(!gen.arrivals.is_empty());
        let (a, b) = (s.run(), build().run());
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert!(a.gen_arrivals > 0);
        assert_eq!(a.offered_tasks, gen.offered_tasks());
        assert!(a.frames_total > 0, "arrivals must open pipeline units");
        // The conveyor counters stay closed over the generative path.
        assert_eq!(
            a.two_core_allocs + a.four_core_allocs,
            a.lp_allocated_initial + a.lp_realloc_success
        );
    }

    #[test]
    fn admission_cap_drops_offered_load() {
        use crate::workload::gen::{ArrivalProcess, Catalog};
        let cfg = SystemConfig::default();
        let burst = ArrivalProcess::Mmpp {
            on_rate_per_min: 120.0,
            off_rate_per_min: 0.0,
            mean_on_s: 30.0,
            mean_off_s: 30.0,
        };
        let run = |cap: usize| {
            ScenarioBuilder::new()
                .scheduler(SchedKind::Ras)
                .workload(Workload::Generative(
                    crate::workload::gen::GenSpec {
                        arrivals: burst.clone(),
                        catalog: Catalog::edge_serving(&cfg),
                        admission_cap: cap,
                    },
                ))
                .minutes(5.0)
                .seed(23)
                .build()
                .run()
        };
        let open = run(0);
        let capped = run(6);
        assert_eq!(open.admission_dropped, 0, "no cap ⇒ no admission drops");
        assert!(capped.admission_dropped > 0, "a tight cap under burst must drop");
        assert_eq!(open.offered_tasks, capped.offered_tasks, "offered load is pre-admission");
        assert!(capped.frames_total < open.frames_total);
    }

    #[test]
    fn lp_ladder_axis_compiles_into_extras() {
        use crate::workload::gen::Ladder;
        let cfg = SystemConfig::default();
        let plain = quick(SchedKind::Ras, 7);
        assert!(plain.extras.lp_ladder.is_empty(), "no ladder by default");
        let s = ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(2))
            .frames(8)
            .seed(7)
            .lp_ladder(Ladder::stage3_family(&cfg))
            .build();
        assert_eq!(s.extras.lp_ladder.len(), 3);
        assert_eq!(s.extras.lp_ladder[0].proc_us, [cfg.lp2_proc(), cfg.lp4_proc()]);
        // Same workload point: the ladder axis shares the trace Arc.
        assert!(std::sync::Arc::ptr_eq(&s.trace, &plain.trace));
        // The laddered scenario still runs deterministically.
        assert_eq!(format!("{:?}", s.run()), format!("{:?}", s.run()));
    }

    #[test]
    #[should_panic(expected = "invalid model-variant ladder")]
    fn invalid_ladder_panics_at_build() {
        use crate::workload::gen::{Ladder, ModelVariant};
        // Lower rung more expensive than the one above: rejected.
        let bad = Ladder::new(vec![
            ModelVariant::new("a", 0.9, 1.0, 2.0, 1.5),
            ModelVariant::new("b", 0.8, 1.0, 3.0, 2.0),
        ]);
        let _ = ScenarioBuilder::new().lp_ladder(bad).frames(4).build();
    }

    #[test]
    #[should_panic(expected = "rung 0 must equal the conveyor stage-3 spec")]
    fn desynced_conveyor_ladder_rung_zero_panics_at_build() {
        use crate::workload::gen::{Ladder, ModelVariant};
        // Structurally valid ladder whose rung 0 claims a cheaper model
        // than the stage-3 spec the conveyor tasks actually run: the
        // accuracy credit (and the step-down baseline) would be a lie.
        let desynced = Ladder::new(vec![
            ModelVariant::new("not-stage3", 0.97, 2.0, 4.0, 3.0),
            ModelVariant::new("tiny", 0.8, 1.0, 2.0, 1.5),
        ]);
        let _ = ScenarioBuilder::new().lp_ladder(desynced).frames(4).build();
    }

    #[test]
    fn churn_evicts_and_rejoins() {
        // Device 1 leaves mid-run and returns: the run must record the
        // churn, keep accounting identities, and still complete frames.
        let s = ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(3))
            .frames(20)
            .seed(5)
            .leave_at(60.0, 1)
            .join_at(200.0, 1)
            .build();
        let m = s.run();
        assert_eq!(m.churn_leaves, 1);
        assert_eq!(m.churn_joins, 1);
        assert!(m.frames_completed > 0, "fleet of 3 should still make progress");
        assert_eq!(
            m.hp_generated,
            m.hp_allocated_no_preempt + m.hp_allocated_with_preempt + m.hp_rejected
        );
    }

    #[test]
    fn leave_without_rejoin_drops_the_devices_frames() {
        let base = ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(4))
            .frames(25)
            .seed(9);
        let full = base.clone().build().run();
        let short = base.leave_at(30.0, 2).build().run();
        assert_eq!(short.churn_leaves, 1);
        // The departed device's conveyor stops: its frames never generate.
        assert!(
            short.frames_total < full.frames_total,
            "frames_total should shrink: full={} short={}",
            full.frames_total,
            short.frames_total
        );
        // Accounting identities survive the eviction path.
        assert_eq!(
            short.hp_generated,
            short.hp_allocated_no_preempt + short.hp_allocated_with_preempt + short.hp_rejected
        );
        assert!(short.frames_completed <= short.frames_total);
    }

    #[test]
    fn heterogeneous_slow_device_hurts_its_deadlines() {
        let base = ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(3))
            .frames(25)
            .seed(13);
        let nominal = base.clone().build().run();
        let slow = base.device_speed(0, 1.6).build().run();
        assert!(
            slow.lp_violations + slow.hp_violations
                >= nominal.lp_violations + nominal.hp_violations,
            "a 60% slower device should not reduce violations: nominal={} slow={}",
            nominal.lp_violations + nominal.hp_violations,
            slow.lp_violations + slow.hp_violations
        );
    }

    #[test]
    fn crash_loses_work_and_reoffers_survivors() {
        // A mid-run crash under heavy load: the run must record the
        // crash, lose in-flight work, keep the re-offer accounting
        // closed, and preserve the global identities. The "lost work"
        // assertion aggregates over a few seeds (a single instant could
        // in principle catch an idle device).
        let mut any_lost = false;
        let mut any_reoffered = false;
        for seed in [23u64, 24, 25, 26] {
            let m = ScenarioBuilder::new()
                .scheduler(SchedKind::Ras)
                .trace(TraceSpec::Weighted(4))
                .frames(20)
                .seed(seed)
                .crash_at(45.0, 0)
                .recover_at(165.0, 0)
                .build()
                .run();
            assert_eq!(m.device_crashes, 1);
            assert_eq!(m.device_recoveries, 1);
            assert_eq!(m.lat_crash_recovery.count, 1);
            assert_eq!(m.lat_crash_recovery.max_us, 120_000_000); // 120 s down
            // Re-offer accounting closes once the queue drains: every
            // re-offered task was either placed again or dropped.
            assert_eq!(
                m.crash_tasks_reoffered,
                m.crash_reoffer_placed + m.crash_reoffer_dropped
            );
            assert!(m.crash_tasks_reoffered <= m.crash_tasks_lost);
            assert!(m.crash_recovered_in_deadline <= m.crash_reoffer_placed);
            // Global identities survive the crash path.
            assert_eq!(
                m.hp_generated,
                m.hp_allocated_no_preempt + m.hp_allocated_with_preempt + m.hp_rejected
            );
            assert_eq!(
                m.two_core_allocs + m.four_core_allocs,
                m.lp_allocated_initial + m.lp_realloc_success
            );
            any_lost |= m.crash_tasks_lost > 0;
            any_reoffered |= m.crash_tasks_reoffered > 0;
        }
        assert!(any_lost, "crashing a loaded device should lose in-flight work");
        assert!(any_reoffered, "some lost guests should get re-offered");
    }

    #[test]
    fn crash_and_graceful_leave_use_distinct_mechanisms() {
        // Same departure time, same device — but a graceful leave drains
        // through the churn counters (evicted guests re-enter via
        // LpArrive) while a crash goes through the fault counters (work
        // lost, survivors re-offered). Neither path leaks into the other.
        let base = ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(3))
            .frames(20)
            .seed(31);
        let graceful = base.clone().leave_at(50.0, 1).join_at(170.0, 1).build().run();
        let crashed = base.crash_at(50.0, 1).recover_at(170.0, 1).build().run();
        assert_eq!(graceful.churn_leaves, 1);
        assert_eq!(graceful.device_crashes, 0);
        assert_eq!(graceful.crash_tasks_lost, 0);
        assert_eq!(crashed.device_crashes, 1);
        assert_eq!(crashed.churn_leaves, 0);
        assert_eq!(crashed.churn_evicted, 0);
        // Up to the fault instant the two runs are identical, so the
        // crash loses at least the allocations the leave evicted (plus
        // any in-flight transfers sourced from the dead device).
        assert!(crashed.crash_tasks_lost >= graceful.churn_evicted);
    }

    #[test]
    fn lossy_link_retransmits_and_drops_pings() {
        let base = ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(4))
            .frames(20)
            .seed(37);
        let clean = base.clone().build().run();
        let lossy = base.loss_rate(0.25).probe_loss(0.25).build().run();
        assert_eq!(clean.retransmitted_mbits, 0.0);
        assert_eq!(clean.probe_pings_lost, 0);
        assert!(lossy.retransmitted_mbits > 0.0, "25% loss must retransmit");
        assert!(lossy.probe_pings_lost > 0, "25% probe loss must drop pings");
        // Both runs still drain to completion with intact identities.
        assert_eq!(
            lossy.hp_generated,
            lossy.hp_allocated_no_preempt + lossy.hp_allocated_with_preempt + lossy.hp_rejected
        );
    }

    #[test]
    fn fault_plan_scenarios_are_deterministic() {
        let build = || {
            ScenarioBuilder::new()
                .scheduler(SchedKind::Multi)
                .trace(TraceSpec::Weighted(3))
                .frames(15)
                .seed(41)
                .loss_rate(0.1)
                .probe_loss(0.3)
                .random_faults(90.0, 25.0)
                .build()
        };
        let (a, b) = (build(), build());
        assert_eq!(a.extras.faults, b.extras.faults, "fault schedule must be seed-derived");
        assert_eq!(format!("{:?}", a.run()), format!("{:?}", b.run()));
    }

    #[test]
    fn energy_scenario_integrates_joules_and_batteries_drain() {
        let base = ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(3))
            .frames(12)
            .seed(53);
        let plain = base.clone().build().run();
        assert_eq!(plain.energy_total_j, 0.0, "no model ⇒ no accounting");
        assert!(plain.battery_final_j.is_empty());
        let powered = base.clone().energy(EnergyModel::pi2b()).build().run();
        assert!(powered.energy_total_j > 0.0);
        assert!(powered.energy_idle_j > 0.0, "idle floor always draws");
        assert_eq!(powered.battery_depletions, 0, "mains-powered fleet never depletes");
        assert!(powered.battery_final_j.is_empty(), "mains ⇒ no battery timeline");
        // Energy accounting must not perturb the simulation itself.
        assert_eq!(powered.frames_completed, plain.frames_completed);
        assert_eq!(powered.lp_deadline_met(), plain.lp_deadline_met());
        // A battery too small for the run drains and crashes devices.
        let drained =
            base.energy(EnergyModel::pi2b()).battery_j(150.0).build().run();
        assert!(drained.battery_depletions > 0, "150 J cannot survive 12 frames");
        assert_eq!(drained.battery_final_j.len(), 4);
        assert!(drained.battery_final_j.iter().all(|&j| j >= 0.0));
    }

    #[test]
    fn cloud_tier_is_reachable_and_deterministic() {
        let build = || {
            ScenarioBuilder::new()
                .scheduler(SchedKind::Energy)
                .trace(TraceSpec::Weighted(4))
                .frames(15)
                .seed(59)
                .cloud(20e6, 40.0)
                .energy(EnergyModel::pi2b())
                .build()
        };
        let (a, b) = (build().run(), build().run());
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        // cloud_completions counts only within-deadline deliveries.
        assert!(a.cloud_completions <= a.cloud_offloads);
        // The generalized identity covers the cloud placements.
        assert_eq!(
            a.two_core_allocs + a.four_core_allocs + a.cloud_offloads,
            a.lp_allocated_initial + a.lp_realloc_success
        );
    }

    #[test]
    fn energy_kind_parses_and_labels() {
        assert_eq!(SchedKind::parse("energy").unwrap(), SchedKind::Energy);
        assert_eq!(SchedKind::Energy.label(), "ENERGY");
        let s = ScenarioBuilder::new()
            .scheduler(SchedKind::Energy)
            .trace(TraceSpec::Weighted(2))
            .frames(4)
            .seed(61)
            .build();
        assert_eq!(s.name, "ENERGY_2");
        assert_eq!(s.kind.build(&s.cfg).name(), "ENERGY");
    }

    #[test]
    fn greedy_kind_parses_labels_and_runs() {
        use crate::workload::gen::Ladder;
        assert_eq!(SchedKind::parse("greedy").unwrap(), SchedKind::Greedy);
        assert_eq!(SchedKind::Greedy.label(), "GREEDY");
        let cfg = SystemConfig::default();
        let build = || {
            ScenarioBuilder::new()
                .scheduler(SchedKind::Greedy)
                .trace(TraceSpec::Weighted(3))
                .frames(12)
                .seed(67)
                .lp_ladder(Ladder::stage3_family(&cfg))
                .build()
        };
        let s = build();
        assert_eq!(s.name, "GREEDY_3");
        assert_eq!(s.kind.build(&s.cfg).name(), "GREEDY");
        let (a, b) = (build().run(), build().run());
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(
            a.lp_generated,
            a.lp_completed_total() + a.lp_violations + a.lp_lost
        );
    }

    #[test]
    fn stage_plans_compile_into_extras_only_when_present() {
        use crate::workload::gen::Ladder;
        let cfg = SystemConfig::default();
        let plain = ScenarioBuilder::new()
            .trace(TraceSpec::Weighted(2))
            .frames(4)
            .seed(7)
            .lp_ladder(Ladder::stage3_family(&cfg))
            .build();
        assert!(plain.extras.lp_stage_plans.is_empty(), "monolithic ladder: no plans");
        let staged = ScenarioBuilder::new()
            .trace(TraceSpec::Weighted(2))
            .frames(4)
            .seed(7)
            .lp_ladder(Ladder::stage3_family_staged(&cfg))
            .build();
        assert_eq!(staged.extras.lp_stage_plans.len(), 3);
        assert!(staged.extras.lp_stage_plans[0].cuttable());
        assert!(staged.extras.lp_stage_plans[1].cuttable());
        assert!(!staged.extras.lp_stage_plans[2].is_staged(), "rung 2 stays monolithic");
    }

    #[test]
    fn pressure_knobs_flow_into_cfg() {
        let off = ScenarioBuilder::new().frames(2).build();
        assert_eq!(off.cfg.pressure_check_s, 0.0, "controller defaults off");
        assert_eq!(off.cfg.pressure_backlog, 0);
        let on = ScenarioBuilder::new().frames(2).pressure(0.5, 8).build();
        assert_eq!(on.cfg.pressure_check_s, 0.5);
        assert_eq!(on.cfg.pressure_backlog, 8);
    }

    #[test]
    fn midrun_congestion_regime_kicks_in() {
        let base = ScenarioBuilder::new()
            .scheduler(SchedKind::Ras)
            .trace(TraceSpec::Weighted(4))
            .frames(25)
            .seed(17);
        let quiet = base.clone().build().run();
        let stormy = base.congestion_at(120.0, 36e6, 0.75).build().run();
        // From minute 2 the stormy run's probes measure a link that bursts
        // at 90% background load 75% of the time: the EWMA estimate must
        // end up below the quiet run's (which only sees task transfers).
        assert!(
            stormy.final_bandwidth_estimate_bps < quiet.final_bandwidth_estimate_bps,
            "storm should depress the bandwidth estimate: quiet={:.1}Mb/s stormy={:.1}Mb/s",
            quiet.final_bandwidth_estimate_bps / 1e6,
            stormy.final_bandwidth_estimate_bps / 1e6
        );
    }
}
