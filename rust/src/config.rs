//! System and experiment configuration.
//!
//! Defaults mirror the paper's testbed (Section V): four Raspberry Pi 2B
//! edge devices with four cores each, an 802.11n shared link, fixed
//! per-configuration processing times from the authors' benchmarks, an
//! 18.86 s frame period, and a 30 s bandwidth-update interval with
//! EWMA α = 0.3.


use crate::time::{millis, secs, SimDuration};

/// Full system configuration. Loadable from a `key value` text file
/// (`medge --config cfg.kv ...`) so experiment runs are reproducible.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Number of edge devices (the paper uses 4 Raspberry Pi 2Bs).
    pub n_devices: usize,
    /// Cores per edge device.
    pub cores_per_device: u32,

    /// High-priority (stage 1+2: detector + binary classifier) processing
    /// time, seconds. Paper: 0.98 s.
    pub hp_proc_s: f64,
    /// Low-priority two-core (stage 3 classifier) processing time, seconds.
    /// Paper: 16.862 s.
    pub lp2_proc_s: f64,
    /// Low-priority four-core processing time, seconds. Paper: 11.611 s.
    pub lp4_proc_s: f64,
    /// Padding added to low-priority processing times, as the paper pads
    /// with the benchmark standard deviation. Seconds.
    pub proc_padding_s: f64,
    /// Standard deviation of *actual* low-priority runtimes around the
    /// benchmark mean (the variance the paper's padding defends against:
    /// system load + hardware variation on the Raspberry Pis). The
    /// scheduler plans with mean + padding; the device takes
    /// mean + |N(0, σ)| — so placements whose margin is thinner than the
    /// jitter tail violate their deadlines, exactly the placement-error
    /// mechanism the evaluation studies. Seconds.
    pub proc_jitter_s: f64,
    /// Cores required by a high-priority task. The detector runs
    /// multi-threaded TFLite across the whole device (the paper's 18.86 s
    /// frame period is derived from *sequential* HP → LP completion, and
    /// its reallocation counts show preemption is common at every load —
    /// both imply the HP stage does not co-run with LP tasks).
    pub hp_cores: u32,

    /// Conveyor-belt frame period, seconds. Paper: 18.86 s (minimum viable
    /// completion of detector + HP task + one two-core DNN).
    pub frame_period_s: f64,
    /// Budget for the high-priority stage within the frame deadline,
    /// seconds. HP deadline = generation + hp_deadline_s; the frame (and
    /// all its low-priority tasks) deadline = generation + frame_period_s.
    pub hp_deadline_s: f64,

    /// Maximum image transfer size in bytes (the paper sizes the link
    /// discretisation unit D from the maximum model input image — the
    /// YoloV2-based model's 608×608×3 input, ~1.1 MB, ≈225 ms on an idle
    /// 40 Mb/s link; communication slots are a genuinely scarce resource).
    pub image_bytes: u64,
    /// True initial link bandwidth, bits per second (802.11n effective).
    pub link_bps: f64,
    /// Base one-way control-plane latency over the link, ms.
    pub control_latency_ms: f64,

    /// Number of fixed-capacity base buckets in the link discretisation.
    pub base_buckets: usize,
    /// Number of exponentially-growing buckets after the base region.
    /// Sized so the link horizon comfortably covers one bandwidth-update
    /// interval (the discretisation is only re-anchored on rebuilds).
    pub exp_buckets: usize,

    /// Bandwidth estimation update interval, seconds. Paper default: 30 s.
    pub bandwidth_interval_s: f64,
    /// EWMA smoothing factor for the bandwidth estimate. Paper: 0.3.
    pub ewma_alpha: f64,
    /// Number of pings per probed device. Paper: 10.
    pub ping_count: u32,
    /// Ping payload size in bytes. Paper: 1400.
    pub ping_bytes: u64,
    /// Airtime multiplier for probe traffic: small-frame ping trains on
    /// 802.11 occupy far more airtime than their payload (per-frame
    /// preamble/ACK/backoff overhead), which is how frequent probing
    /// congests the link in the paper's Section VI-B.
    pub probe_airtime_factor: f64,

    /// Scale factor applied to measured wall-clock scheduler latency when
    /// charging it to virtual time (1.0 = charge raw measurement). The
    /// paper's controller is C++17 on an M1; ours is rust on this host —
    /// the *relative* gap between WPS and RAS is what matters.
    pub cost_scale: f64,
    /// Virtual microseconds charged per elementary scheduler operation
    /// (window visit / overlap check / write). Calibrated so the WPS
    /// baseline's low-priority allocation latency lands in the paper's
    /// 140–205 ms band at the paper's workload scale; the RAS/WPS *ratio*
    /// comes from the real operation counts of the two implementations.
    pub op_cost_us: f64,
    /// Bandwidth consumed by the background traffic generator during a
    /// burst, bits/s (Section VI-C floods 1024 B frames via Packet_MMAP —
    /// a raw-socket sender saturates most of the link while active).
    pub bg_bps: f64,
    /// Burst duty cycle as a fraction of the bandwidth-update interval
    /// (the paper sweeps 0 / 0.25 / 0.50 / 0.75).
    pub duty_cycle: f64,

    /// Cloud-tier WAN bandwidth, bits/s. `0.0` (the default) disables the
    /// cloud tier entirely: no WAN medium, no extra placement target, no
    /// change to any event stream — edge-only runs stay byte-identical.
    pub cloud_wan_bps: f64,
    /// Cloud-tier round-trip propagation delay, ms (request up + result
    /// back, excluding the bandwidth-limited upload itself).
    pub cloud_rtt_ms: f64,
    /// Cloud service-time speedup over a four-core edge device: the
    /// default per-class cloud service time is `lp4_proc_s / speedup`
    /// (unpadded — the server tier has no Pi jitter to defend against).
    /// Classes can override with an explicit `TaskClass::cloud`.
    pub cloud_speedup: f64,

    /// Fleet cell (shard) size for the sharded placement hierarchy:
    /// devices are grouped into contiguous cells of this many slots, and
    /// schedulers descend cell → device instead of scanning the fleet
    /// ([`crate::coordinator::fleet`]). `0` (the default) sizes cells
    /// automatically: one cell for small fleets, ~√n-device cells at
    /// scale. Placement decisions are identical for every cell size —
    /// the hierarchy prunes work, never changes answers.
    pub cell_size: usize,
    /// Remote-candidate count above which RAS switches from an eager
    /// materialized shuffle to the sparse lazy shuffle (draws
    /// proportional to candidates *consumed*, not fleet size). Below
    /// the cutover the draw sequence is bit-identical to the historical
    /// eager shuffle; at any count the choice depends only on the
    /// candidate count, never on the cell layout, so sharded and flat
    /// placement stay decision-identical.
    pub lazy_shuffle_cutover: usize,

    /// Missed probe rounds before the failure detector marks a device
    /// `Suspected` and schedulers receive `DeviceSuspected`. `0` (the
    /// default) disables the detector entirely: no suspicion state, no
    /// new scheduler events, byte-identical runs.
    pub suspect_after: u32,
    /// Additional missed rounds (past `suspect_after`) before a suspected
    /// device is escalated to `Confirmed`-down (diagnostic only; the
    /// scheduler already placed around the suspicion).
    pub confirm_after: u32,
    /// Per-placement offload timeout in seconds: an offloaded low-priority
    /// placement that has not completed this long after its transfer was
    /// scheduled is cancelled and re-offered (exponential backoff doubles
    /// the window per retry, up to `retry_limit` tries). `0.0` (the
    /// default) disables timeouts and retries entirely.
    pub offload_timeout_s: f64,
    /// Maximum number of timeout-driven re-offers per task before the
    /// task is abandoned as lost.
    pub retry_limit: u32,
    /// Hedged-duplicate window in seconds: an offloaded deadline-critical
    /// placement still unfinished this long after it started gets a
    /// duplicate placed elsewhere; first completion wins, the loser is
    /// cancelled without credit. `0.0` (the default) disables hedging.
    pub hedge_timeout_s: f64,
    /// Consecutive failed probe rounds after which the bandwidth estimate
    /// is considered stale (`BandwidthEstimator::is_stale`); RAS widens
    /// its conservative windows while stale. `0` (the default) means the
    /// estimate never goes stale.
    pub bw_stale_after: u32,

    /// Deadline-pressure controller check interval, seconds: how often
    /// the engine surveys running staged low-priority tasks and offers
    /// the scheduler a `SchedEvent::Pressure` truncation decision. `0.0`
    /// (the default) disables the anytime controller entirely — no new
    /// events, no new RNG draws, byte-identical runs.
    pub pressure_check_s: f64,
    /// Queued low-priority backlog (tasks admitted but not yet placed
    /// or re-offered) at or above which a pressure check also offers
    /// *slack-positive* truncations, not just deadline-saving ones. `0`
    /// means backlog never escalates pressure (deadline/battery rescue
    /// cuts still fire whenever the controller is enabled).
    pub pressure_backlog: u32,

    /// RNG seed for trace generation, device shuffling, probe host
    /// selection and traffic bursts. Same seed ⇒ identical run.
    pub seed: u64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            n_devices: 4,
            cores_per_device: 4,
            hp_proc_s: 0.98,
            lp2_proc_s: 16.862,
            lp4_proc_s: 11.611,
            proc_padding_s: 0.35,
            proc_jitter_s: 0.5,
            hp_cores: 4,
            frame_period_s: 18.86,
            hp_deadline_s: 1.9,
            image_bytes: 1_100_000,
            link_bps: 40e6,
            control_latency_ms: 2.0,
            base_buckets: 16,
            exp_buckets: 11,
            bandwidth_interval_s: 30.0,
            ewma_alpha: 0.3,
            ping_count: 10,
            ping_bytes: 1400,
            probe_airtime_factor: 8.0,
            cost_scale: 1.0,
            op_cost_us: 200.0,
            bg_bps: 36e6,
            duty_cycle: 0.0,
            cloud_wan_bps: 0.0,
            cloud_rtt_ms: 40.0,
            cloud_speedup: 8.0,
            cell_size: 0,
            lazy_shuffle_cutover: 256,
            suspect_after: 0,
            confirm_after: 2,
            offload_timeout_s: 0.0,
            retry_limit: 2,
            hedge_timeout_s: 0.0,
            bw_stale_after: 0,
            pressure_check_s: 0.0,
            pressure_backlog: 0,
            seed: 42,
        }
    }
}

impl SystemConfig {
    /// High-priority processing time in µs.
    pub fn hp_proc(&self) -> SimDuration {
        secs(self.hp_proc_s)
    }
    /// Two-core low-priority processing time (padded) in µs.
    pub fn lp2_proc(&self) -> SimDuration {
        secs(self.lp2_proc_s + self.proc_padding_s)
    }
    /// Four-core low-priority processing time (padded) in µs.
    pub fn lp4_proc(&self) -> SimDuration {
        secs(self.lp4_proc_s + self.proc_padding_s)
    }
    /// Frame period in µs.
    pub fn frame_period(&self) -> SimDuration {
        secs(self.frame_period_s)
    }
    /// High-priority deadline budget in µs.
    pub fn hp_deadline(&self) -> SimDuration {
        secs(self.hp_deadline_s)
    }
    /// Bandwidth probe interval in µs.
    pub fn bandwidth_interval(&self) -> SimDuration {
        secs(self.bandwidth_interval_s)
    }
    /// One-way control-plane latency in µs.
    pub fn control_latency(&self) -> SimDuration {
        millis(self.control_latency_ms)
    }
    /// Image transfer time at `bps`, in µs (the discretisation unit D).
    pub fn transfer_unit(&self, bps: f64) -> SimDuration {
        let s = (self.image_bytes as f64 * 8.0) / bps.max(1.0);
        secs(s).max(1)
    }
    /// Load from a `key value` text file (see [`crate::util::kv`]);
    /// unknown keys are rejected, missing keys keep their defaults.
    pub fn from_kv_file(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_kv(&std::fs::read_to_string(path)?)
    }

    /// Parse from `key value` text.
    pub fn from_kv(text: &str) -> anyhow::Result<Self> {
        let map = crate::util::kv::parse(text);
        let mut cfg = Self::default();
        for (k, v) in &map {
            macro_rules! set {
                ($($key:ident),*) => {
                    match k.as_str() {
                        $(stringify!($key) => {
                            cfg.$key = v.parse().map_err(|_| {
                                anyhow::anyhow!("bad value for {k}: {v}")
                            })?;
                        })*
                        other => anyhow::bail!("unknown config key: {other}"),
                    }
                };
            }
            set!(
                n_devices, cores_per_device, hp_proc_s, lp2_proc_s, lp4_proc_s,
                proc_padding_s, proc_jitter_s, hp_cores, frame_period_s, hp_deadline_s,
                image_bytes, link_bps, control_latency_ms, base_buckets,
                exp_buckets, bandwidth_interval_s, ewma_alpha, ping_count,
                ping_bytes, probe_airtime_factor, cost_scale, op_cost_us, bg_bps, duty_cycle,
                cloud_wan_bps, cloud_rtt_ms, cloud_speedup, cell_size,
                lazy_shuffle_cutover, suspect_after, confirm_after,
                offload_timeout_s, retry_limit, hedge_timeout_s,
                bw_stale_after, pressure_check_s, pressure_backlog, seed
            );
        }
        Ok(cfg)
    }

    /// Render to the `key value` text format (stable, diffable).
    pub fn to_kv(&self) -> String {
        format!(
            "n_devices {}\ncores_per_device {}\nhp_proc_s {}\nlp2_proc_s {}\nlp4_proc_s {}\nproc_padding_s {}\nproc_jitter_s {}\nhp_cores {}\nframe_period_s {}\nhp_deadline_s {}\nimage_bytes {}\nlink_bps {}\ncontrol_latency_ms {}\nbase_buckets {}\nexp_buckets {}\nbandwidth_interval_s {}\newma_alpha {}\nping_count {}\nping_bytes {}\nprobe_airtime_factor {}\ncost_scale {}\nop_cost_us {}\nbg_bps {}\nduty_cycle {}\ncloud_wan_bps {}\ncloud_rtt_ms {}\ncloud_speedup {}\ncell_size {}\nlazy_shuffle_cutover {}\nsuspect_after {}\nconfirm_after {}\noffload_timeout_s {}\nretry_limit {}\nhedge_timeout_s {}\nbw_stale_after {}\npressure_check_s {}\npressure_backlog {}\nseed {}\n",
            self.n_devices, self.cores_per_device, self.hp_proc_s, self.lp2_proc_s,
            self.lp4_proc_s, self.proc_padding_s, self.proc_jitter_s, self.hp_cores, self.frame_period_s,
            self.hp_deadline_s, self.image_bytes, self.link_bps, self.control_latency_ms,
            self.base_buckets, self.exp_buckets, self.bandwidth_interval_s, self.ewma_alpha,
            self.ping_count, self.ping_bytes, self.probe_airtime_factor, self.cost_scale, self.op_cost_us,
            self.bg_bps, self.duty_cycle, self.cloud_wan_bps, self.cloud_rtt_ms, self.cloud_speedup,
            self.cell_size, self.lazy_shuffle_cutover, self.suspect_after, self.confirm_after,
            self.offload_timeout_s, self.retry_limit, self.hedge_timeout_s,
            self.bw_stale_after, self.pressure_check_s, self.pressure_backlog, self.seed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = SystemConfig::default();
        assert_eq!(c.n_devices, 4);
        assert_eq!(c.cores_per_device, 4);
        assert_eq!(c.hp_proc(), 980_000);
        assert_eq!(c.frame_period(), 18_860_000);
        assert_eq!(c.bandwidth_interval(), 30_000_000);
        assert!((c.ewma_alpha - 0.3).abs() < 1e-12);
        assert_eq!(c.ping_count, 10);
        assert_eq!(c.ping_bytes, 1400);
    }

    #[test]
    fn transfer_unit_scales_with_bandwidth() {
        let c = SystemConfig::default();
        let d40 = c.transfer_unit(40e6);
        let d20 = c.transfer_unit(20e6);
        // Halving bandwidth doubles the unit transfer time.
        assert!((d20 as f64 / d40 as f64 - 2.0).abs() < 0.01);
        // 1.1 MB at 40 Mb/s = 220 ms.
        assert_eq!(d40, 220_000);
    }

    #[test]
    fn kv_roundtrip() {
        let c = SystemConfig { seed: 99, duty_cycle: 0.25, ..Default::default() };
        let c2 = SystemConfig::from_kv(&c.to_kv()).unwrap();
        assert_eq!(c2.seed, 99);
        assert!((c2.duty_cycle - 0.25).abs() < 1e-12);
        assert_eq!(c2.n_devices, c.n_devices);
    }

    #[test]
    fn kv_partial_overrides_defaults() {
        let c = SystemConfig::from_kv("seed 7\nbandwidth_interval_s 1.5\n").unwrap();
        assert_eq!(c.seed, 7);
        assert!((c.bandwidth_interval_s - 1.5).abs() < 1e-12);
        assert_eq!(c.n_devices, 4); // default kept
    }

    #[test]
    fn cloud_tier_is_disabled_by_default_and_roundtrips() {
        let c = SystemConfig::default();
        assert_eq!(c.cloud_wan_bps, 0.0, "cloud tier must default OFF");
        let c = SystemConfig { cloud_wan_bps: 20e6, cloud_rtt_ms: 60.0, ..Default::default() };
        let c2 = SystemConfig::from_kv(&c.to_kv()).unwrap();
        assert_eq!(c2.cloud_wan_bps, 20e6);
        assert!((c2.cloud_rtt_ms - 60.0).abs() < 1e-12);
        assert!((c2.cloud_speedup - 8.0).abs() < 1e-12);
    }

    #[test]
    fn fleet_sharding_knobs_default_and_roundtrip() {
        let c = SystemConfig::default();
        assert_eq!(c.cell_size, 0, "cell sizing must default to auto");
        assert_eq!(c.lazy_shuffle_cutover, 256);
        let c = SystemConfig { cell_size: 64, lazy_shuffle_cutover: 8, ..Default::default() };
        let c2 = SystemConfig::from_kv(&c.to_kv()).unwrap();
        assert_eq!(c2.cell_size, 64);
        assert_eq!(c2.lazy_shuffle_cutover, 8);
    }

    #[test]
    fn robustness_knobs_default_off_and_roundtrip() {
        let c = SystemConfig::default();
        assert_eq!(c.suspect_after, 0, "detector must default OFF");
        assert_eq!(c.offload_timeout_s, 0.0, "offload timeouts must default OFF");
        assert_eq!(c.hedge_timeout_s, 0.0, "hedging must default OFF");
        assert_eq!(c.bw_stale_after, 0, "staleness must default OFF");
        assert_eq!(c.confirm_after, 2);
        assert_eq!(c.retry_limit, 2);
        let c = SystemConfig {
            suspect_after: 3,
            confirm_after: 1,
            offload_timeout_s: 4.5,
            retry_limit: 5,
            hedge_timeout_s: 2.25,
            bw_stale_after: 2,
            ..Default::default()
        };
        let c2 = SystemConfig::from_kv(&c.to_kv()).unwrap();
        assert_eq!(c2.suspect_after, 3);
        assert_eq!(c2.confirm_after, 1);
        assert!((c2.offload_timeout_s - 4.5).abs() < 1e-12);
        assert_eq!(c2.retry_limit, 5);
        assert!((c2.hedge_timeout_s - 2.25).abs() < 1e-12);
        assert_eq!(c2.bw_stale_after, 2);
    }

    #[test]
    fn anytime_knobs_default_off_and_roundtrip() {
        let c = SystemConfig::default();
        assert_eq!(c.pressure_check_s, 0.0, "pressure controller must default OFF");
        assert_eq!(c.pressure_backlog, 0);
        let c = SystemConfig { pressure_check_s: 2.5, pressure_backlog: 6, ..Default::default() };
        let c2 = SystemConfig::from_kv(&c.to_kv()).unwrap();
        assert!((c2.pressure_check_s - 2.5).abs() < 1e-12);
        assert_eq!(c2.pressure_backlog, 6);
    }

    #[test]
    fn kv_rejects_unknown_keys() {
        assert!(SystemConfig::from_kv("nonsense 1\n").is_err());
        assert!(SystemConfig::from_kv("seed notanumber\n").is_err());
    }
}
