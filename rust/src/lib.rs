//! # medge — deadline-constrained DNN offloading at the mobile edge
//!
//! A reproduction of *"Accuracy vs Performance: An abstraction model for
//! deadline constrained offloading at the mobile-edge"* (Cotter,
//! Castiñeiras, Cionca — CS.DC 2025) as a three-layer rust + JAX + Pallas
//! system:
//!
//! * **Layer 3 (this crate)** — the centralised controller: resource
//!   availability lists, the discretised network link, dynamic bandwidth
//!   estimation, the RAS scheduler and the WPS baseline, plus the full
//!   simulation substrate (devices, shared wireless medium, traffic
//!   generator, workload traces) and the experiment harness that
//!   regenerates every figure and table in the paper's evaluation.
//! * **Layer 2 (python/compile, build time)** — the three-stage waste
//!   classification pipeline as JAX models, AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels, build time)** — Pallas kernels for
//!   the convolution/matmul hot path, verified against pure-jnp oracles.
//!
//! The [`runtime`] module loads the AOT artifacts and executes real
//! inference from rust via PJRT — python never runs on the request path
//! (gated behind the `pjrt` cargo feature; the scheduler/simulator stack
//! never needs it).
//!
//! ## Architecture of the controller layer
//!
//! The scheduler boundary is a typed event/decision API
//! ([`coordinator::scheduler::SchedEvent`] →
//! [`coordinator::scheduler::Decision`], dispatched through
//! `Scheduler::on_event`), and experiments are composed with the
//! [`scenario`] module: a fluent [`scenario::ScenarioBuilder`] (trace,
//! fleet size/heterogeneity, churn, congestion regimes, seed, duration)
//! compiles to an engine run, and [`scenario::Sweep`] fans scenario grids
//! across worker threads. The [`experiments`] harness and the `medge`
//! CLI (including `medge sweep`) are thin layers over those two APIs.
//! The [`fault`] module adds fault injection on top — lossy links with
//! retransmission inflation, device crashes that lose in-flight work
//! (re-offered to the scheduler), and probe failure — turning the
//! happy-path reproduction into a robustness testbed.
//!
//! The [`workload::gen`] subsystem decouples load from the conveyor frame
//! clock: seeded arrival processes (Poisson, bursty MMPP, diurnal,
//! closed-loop) × a task-class catalog (per-class priority, deadline,
//! input size, per-stage cost, mix weights) compile into an open-loop
//! arrival plan the engine executes with offered-load and admission-drop
//! accounting — `ScenarioBuilder::workload(...)` and `medge loadgen` are
//! the entry points, and the conveyor trace is just the axis's default
//! value ([`workload::gen::Workload::Conveyor`], byte-identical replay).
//!
//! The [`energy`] subsystem adds the joules axis on top of all of it:
//! an optional per-device power model integrated by the engine at every
//! state transition, optional batteries whose depletion routes through
//! the crash/re-offer machinery, a WAN-attached cloud tier
//! ([`sim::netsim::CloudTier`]) as a third placement target, and an
//! energy-aware scheduler variant ([`scenario::SchedKind::Energy`]) that
//! ranks deadline-feasible placements by estimated joules — `medge
//! energy` drives the battery-constrained / cloud-burst / diurnal-drain
//! grids (see README §Energy).
//!
//! The [`obs`] subsystem is the observability layer: an optional
//! flight-recorder ring buffer of structured span events fed by the
//! engine (zero events and zero RNG draws when disabled), explainable
//! [`obs::DecisionRecord`]s emitted from inside every scheduler, and a
//! Chrome-trace/Perfetto JSON export — `medge trace --run` and
//! `ScenarioBuilder::record_trace` are the entry points (see README
//! §Observability).
//!
//! The simulation hot path is allocation-free and index-based in steady
//! state: engine tasks live in a generational slab ([`util::slab`],
//! placement staleness folded into the slot generation), the shared
//! medium advances with an O(1) drain accumulator and cached earliest
//! completion, and sweep grids share one immutable `Arc<Trace>` per
//! workload point. `medge bench --json` tracks it all in the
//! `BENCH_hotpath.json` trajectory (see README §Performance).

pub mod config;
pub mod coordinator;
pub mod energy;
pub mod experiments;
pub mod fault;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod time;
pub mod util;
pub mod workload;
