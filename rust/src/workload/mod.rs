//! Workload model: the conveyor-belt waste-classification traces that
//! drive the paper's experiments (Section V), plus the generative
//! workload subsystem ([`gen`]) — seeded arrival processes, a task-class
//! catalog, and the open-loop driver that scales the evaluation beyond
//! the conveyor.

pub mod gen;
pub mod trace;

pub use gen::{ArrivalProcess, Catalog, GenSpec, GenWorkload, TaskClass, Workload};
pub use trace::{Trace, TraceEntry, TraceSpec};
