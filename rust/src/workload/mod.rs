//! Workload model: the conveyor-belt waste-classification traces that
//! drive the experiments (Section V).

pub mod trace;

pub use trace::{Trace, TraceEntry, TraceSpec};
