//! Trace files (Section V): the experiment workload model.
//!
//! Each trace entry is one conveyor frame and holds a value per device:
//! `-1` — no object detected; `0` — a high-priority task only; `1..=4` — a
//! high-priority task followed by a low-priority request with that many
//! DNN tasks. Five distributions are used by the paper: *uniform* (1..4
//! equally likely) and *weighted X* for X in 1..4 (predominantly X tasks,
//! load increasing with X).

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock, Weak};

use crate::util::Rng;

/// Per-device value for one frame.
pub type FrameLoad = i8;

/// One frame across all devices.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEntry {
    pub loads: Vec<FrameLoad>,
}

/// The workload distributions from the paper's experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TraceSpec {
    /// 1..4 DNN tasks with equal probability.
    Uniform,
    /// Predominantly `n` tasks (n in 1..=4).
    Weighted(u8),
}

impl TraceSpec {
    pub fn label(&self) -> String {
        match self {
            TraceSpec::Uniform => "U".to_string(),
            TraceSpec::Weighted(n) => format!("{n}"),
        }
    }

    fn name(&self) -> String {
        match self {
            TraceSpec::Uniform => "uniform".to_string(),
            TraceSpec::Weighted(n) => format!("weighted{n}"),
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<TraceSpec> {
        match s {
            "uniform" => Ok(TraceSpec::Uniform),
            "weighted1" => Ok(TraceSpec::Weighted(1)),
            "weighted2" => Ok(TraceSpec::Weighted(2)),
            "weighted3" => Ok(TraceSpec::Weighted(3)),
            "weighted4" => Ok(TraceSpec::Weighted(4)),
            other => anyhow::bail!("unknown trace spec: {other}"),
        }
    }

    /// Probability weights over the frame value alphabet
    /// `[-1, 0, 1, 2, 3, 4]`. Waste items are sparse on a real conveyor
    /// (the paper: "at a given point in time a device might be handling
    /// several waste items while another device is idle"), so a
    /// substantial share of frames are empty (-1) or detector-only (0);
    /// the DNN-count mass is uniform or concentrated on the weighted
    /// target. With these weights the weighted-1 load is comfortably
    /// inside network capacity, weighted-3 is near it, and weighted-4
    /// pushes past it in bursts — matching the regimes the evaluation
    /// contrasts.
    fn weights(&self) -> [f64; 6] {
        match self {
            TraceSpec::Uniform => [0.35, 0.10, 0.1375, 0.1375, 0.1375, 0.1375],
            TraceSpec::Weighted(n) => {
                let mut w = [0.35, 0.10, 0.05, 0.05, 0.05, 0.05];
                w[(*n as usize).clamp(1, 4) + 1] = 0.40;
                w
            }
        }
    }
}

/// A complete experiment trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub spec: TraceSpec,
    pub n_devices: usize,
    pub entries: Vec<TraceEntry>,
}

impl Trace {
    /// Generate `n_frames` of workload for `n_devices`, deterministically
    /// from `seed`.
    pub fn generate(spec: TraceSpec, n_devices: usize, n_frames: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let weights = spec.weights();
        let alphabet: [FrameLoad; 6] = [-1, 0, 1, 2, 3, 4];
        let entries = (0..n_frames)
            .map(|_| TraceEntry {
                loads: (0..n_devices)
                    .map(|_| alphabet[rng.weighted_index(&weights)])
                    .collect(),
            })
            .collect();
        Self { spec, n_devices, entries }
    }

    /// Like [`Trace::generate`], but deduplicated through a process-wide
    /// registry: every scenario with the same `(spec, devices, frames,
    /// seed)` shares **one** immutable allocation (generation is
    /// deterministic, so sharing is transparent). A 1000-cell sweep grid
    /// that varies only the scheduler or fault axis holds one trace per
    /// workload point instead of one per cell. Dropped traces are evicted
    /// lazily (the registry keeps `Weak` references only).
    pub fn shared(spec: TraceSpec, n_devices: usize, n_frames: usize, seed: u64) -> Arc<Trace> {
        type Key = (TraceSpec, usize, usize, u64);
        static REGISTRY: OnceLock<Mutex<HashMap<Key, Weak<Trace>>>> = OnceLock::new();
        let registry = REGISTRY.get_or_init(|| Mutex::new(HashMap::new()));
        let key = (spec, n_devices, n_frames, seed);
        let mut map = registry.lock().expect("trace registry poisoned");
        if let Some(t) = map.get(&key).and_then(Weak::upgrade) {
            return t;
        }
        map.retain(|_, w| w.strong_count() > 0);
        let t = Arc::new(Trace::generate(spec, n_devices, n_frames, seed));
        map.insert(key, Arc::downgrade(&t));
        t
    }

    /// Serialise to the trace text format: a header, then one
    /// space-separated line of per-device loads per frame.
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.render())?;
        Ok(())
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "# medge trace v1\nspec {}\ndevices {}\nframes {}\n",
            self.spec.name(),
            self.n_devices,
            self.entries.len()
        );
        for e in &self.entries {
            let line: Vec<String> = e.loads.iter().map(|l| l.to_string()).collect();
            out.push_str(&line.join(" "));
            out.push('\n');
        }
        out
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let mut spec: Option<TraceSpec> = None;
        let mut n_devices: Option<usize> = None;
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(rest) = line.strip_prefix("spec ") {
                spec = Some(TraceSpec::parse(rest.trim())?);
            } else if let Some(rest) = line.strip_prefix("devices ") {
                n_devices = Some(rest.trim().parse()?);
            } else if line.strip_prefix("frames ").is_some() {
                // informational; entry count is authoritative
            } else {
                let loads: Result<Vec<FrameLoad>, _> =
                    line.split_whitespace().map(|t| t.parse()).collect();
                let loads = loads.map_err(|e| anyhow::anyhow!("bad trace line '{line}': {e}"))?;
                anyhow::ensure!(
                    loads.iter().all(|l| (-1..=4).contains(l)),
                    "trace load out of range in '{line}'"
                );
                entries.push(TraceEntry { loads });
            }
        }
        let spec = spec.ok_or_else(|| anyhow::anyhow!("trace missing 'spec' header"))?;
        let n_devices = n_devices.ok_or_else(|| anyhow::anyhow!("trace missing 'devices' header"))?;
        anyhow::ensure!(
            entries.iter().all(|e| e.loads.len() == n_devices),
            "trace entry width != devices header"
        );
        Ok(Self { spec, n_devices, entries })
    }

    /// Mean DNN tasks per frame per device (diagnostics; grows with the
    /// weighted level).
    pub fn mean_dnn_load(&self) -> f64 {
        let mut total = 0u64;
        let mut cells = 0u64;
        for e in &self.entries {
            for &l in &e.loads {
                total += l.max(0) as u64;
                cells += 1;
            }
        }
        total as f64 / cells.max(1) as f64
    }

    /// Take the first `n` frames (the paper's "30 min slice" of a longer
    /// scenario).
    pub fn slice(&self, n: usize) -> Trace {
        Trace {
            spec: self.spec,
            n_devices: self.n_devices,
            entries: self.entries.iter().take(n).cloned().collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = Trace::generate(TraceSpec::Weighted(3), 4, 100, 7);
        let b = Trace::generate(TraceSpec::Weighted(3), 4, 100, 7);
        assert_eq!(a.entries, b.entries);
        let c = Trace::generate(TraceSpec::Weighted(3), 4, 100, 8);
        assert_ne!(a.entries, c.entries);
    }

    #[test]
    fn shared_traces_deduplicate_identical_parameters() {
        let a = Trace::shared(TraceSpec::Weighted(2), 4, 40, 99);
        let b = Trace::shared(TraceSpec::Weighted(2), 4, 40, 99);
        assert!(Arc::ptr_eq(&a, &b), "same parameters must share one allocation");
        assert_eq!(a.entries, Trace::generate(TraceSpec::Weighted(2), 4, 40, 99).entries);
        let c = Trace::shared(TraceSpec::Weighted(2), 4, 40, 100);
        assert!(!Arc::ptr_eq(&a, &c), "different seeds must not alias");
        // Dropping every strong reference lets the registry forget the
        // entry; the next request regenerates (content-identical).
        let key_entries = a.entries.clone();
        drop((a, b));
        let d = Trace::shared(TraceSpec::Weighted(2), 4, 40, 99);
        assert_eq!(d.entries, key_entries);
    }

    #[test]
    fn weighted_distribution_concentrates_mass() {
        let t = Trace::generate(TraceSpec::Weighted(4), 4, 2000, 1);
        let fours = t
            .entries
            .iter()
            .flat_map(|e| e.loads.iter())
            .filter(|&&l| l == 4)
            .count() as f64;
        let cells = (t.entries.len() * 4) as f64;
        // 0.40 of the mass sits on the dominant value (the rest is empty /
        // detector-only / other counts).
        assert!(fours / cells > 0.33, "weighted-4 should be dominated by 4s: {}", fours / cells);
    }

    #[test]
    fn load_increases_with_weight() {
        let loads: Vec<f64> = (1..=4)
            .map(|n| Trace::generate(TraceSpec::Weighted(n), 4, 3000, 5).mean_dnn_load())
            .collect();
        for w in loads.windows(2) {
            assert!(w[0] < w[1], "mean load must grow with weighted level: {loads:?}");
        }
    }

    #[test]
    fn values_stay_in_alphabet() {
        let t = Trace::generate(TraceSpec::Uniform, 4, 500, 3);
        for e in &t.entries {
            assert_eq!(e.loads.len(), 4);
            for &l in &e.loads {
                assert!((-1..=4).contains(&l));
            }
        }
    }

    #[test]
    fn text_roundtrip() {
        let t = Trace::generate(TraceSpec::Weighted(2), 4, 50, 9);
        let t2 = Trace::parse(&t.render()).unwrap();
        assert_eq!(t.entries, t2.entries);
        assert_eq!(t2.spec, TraceSpec::Weighted(2));
        assert_eq!(t2.n_devices, 4);
    }

    #[test]
    fn save_load_roundtrip() {
        let p = std::env::temp_dir().join(format!("medge_trace_{}.txt", std::process::id()));
        let t = Trace::generate(TraceSpec::Uniform, 4, 50, 9);
        t.save(&p).unwrap();
        let t2 = Trace::load(&p).unwrap();
        let _ = std::fs::remove_file(&p);
        assert_eq!(t.entries, t2.entries);
        assert_eq!(t2.spec, TraceSpec::Uniform);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Trace::parse("devices 4\n0 0 0 0\n").is_err()); // no spec
        assert!(Trace::parse("spec uniform\n0 0\n").is_err()); // no devices
        assert!(Trace::parse("spec uniform\ndevices 4\n9 9 9 9\n").is_err()); // range
        assert!(Trace::parse("spec uniform\ndevices 4\n0 0 0\n").is_err()); // width
    }

    #[test]
    fn slice_takes_prefix() {
        let t = Trace::generate(TraceSpec::Uniform, 4, 100, 9);
        let s = t.slice(10);
        assert_eq!(s.entries.len(), 10);
        assert_eq!(s.entries[..], t.entries[..10]);
    }
}
