//! Seeded arrival processes: *when* generated work reaches the system.
//!
//! Every process expands to a concrete, time-sorted arrival stream with
//! [`ArrivalProcess::stream`] — a pure function of (spec, seed, horizon),
//! so a compiled workload is bit-identical across runs, worker-thread
//! counts, and machines. Four families cover the regimes the related
//! serving/offloading work evaluates under:
//!
//! * [`ArrivalProcess::Poisson`] — memoryless open-loop arrivals at a
//!   fixed mean rate (the classic serving benchmark).
//! * [`ArrivalProcess::Mmpp`] — a two-state Markov-modulated Poisson
//!   process (bursty on-off): quiet spells punctuated by arrival storms,
//!   the "high-volume workload" regime the abstraction model targets.
//! * [`ArrivalProcess::Diurnal`] — a sinusoidal rate curve over a
//!   configurable period (day-scale load swing), realised by thinning.
//! * [`ArrivalProcess::ClosedLoop`] — a fixed user population with
//!   exponential think times. Compiled open-loop using the catalog's
//!   nominal service time as the per-cycle estimate (the driver is
//!   open-loop by design; the population bound still shapes the stream).

use crate::time::{SimDuration, SimTime};
use crate::util::Rng;

/// Seed-domain tag for arrival streams (hex "ARRV").
const SEED_TAG: u64 = 0x4152_5256;

/// An arrival process specification. Rates are per *minute* (the natural
/// scale for the paper's 18.86 s frame period).
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at `rate_per_min`.
    Poisson { rate_per_min: f64 },
    /// Two-state bursty on-off process: arrivals at `on_rate_per_min`
    /// during bursts of mean length `mean_on_s`, at `off_rate_per_min`
    /// (often ~0) during quiet spells of mean length `mean_off_s`. Dwell
    /// times are exponential; the process starts in the ON state.
    Mmpp {
        on_rate_per_min: f64,
        off_rate_per_min: f64,
        mean_on_s: f64,
        mean_off_s: f64,
    },
    /// Sinusoidal rate curve: `rate(t) = base · (1 + amplitude·sin(2πt/period))`,
    /// clamped at zero, realised by thinning a peak-rate Poisson stream.
    Diurnal {
        base_rate_per_min: f64,
        /// Relative swing in [0, 1]: 0 = flat, 1 = rate touches zero.
        amplitude: f64,
        period_s: f64,
    },
    /// `users` independent clients, each cycling submit → (nominal
    /// service) → exponential think of mean `think_s` → submit …
    ClosedLoop { users: u32, think_s: f64 },
}

impl ArrivalProcess {
    /// Expand to concrete arrival instants over `[0, horizon_us)`,
    /// deterministically from `seed`. `nominal_service_us` is the
    /// catalog's mean service estimate (closed-loop cycle time only).
    pub fn stream(
        &self,
        seed: u64,
        horizon_us: SimDuration,
        nominal_service_us: SimDuration,
    ) -> Vec<SimTime> {
        let mut rng = Rng::seed_from_u64(seed ^ SEED_TAG);
        match *self {
            ArrivalProcess::Poisson { rate_per_min } => {
                let rate = per_us(rate_per_min);
                let mut out = Vec::new();
                let mut t = 0.0f64;
                loop {
                    t += exp_gap(&mut rng, rate);
                    if t >= horizon_us as f64 {
                        break;
                    }
                    out.push(t as SimTime);
                }
                out
            }
            ArrivalProcess::Mmpp { on_rate_per_min, off_rate_per_min, mean_on_s, mean_off_s } => {
                let rates = [per_us(on_rate_per_min), per_us(off_rate_per_min)];
                let dwell_us = [(mean_on_s * 1e6).max(1.0), (mean_off_s * 1e6).max(1.0)];
                let mut out = Vec::new();
                let mut state = 0usize; // start bursting
                let mut seg_start = 0.0f64;
                while seg_start < horizon_us as f64 {
                    let dwell = exp_gap(&mut rng, 1.0 / dwell_us[state]);
                    let seg_end = (seg_start + dwell).min(horizon_us as f64);
                    // Arrivals within the segment: exponential gaps are
                    // memoryless, so restarting the clock at the segment
                    // boundary is exact, not an approximation.
                    if rates[state] > 0.0 {
                        let mut t = seg_start;
                        loop {
                            t += exp_gap(&mut rng, rates[state]);
                            if t >= seg_end {
                                break;
                            }
                            out.push(t as SimTime);
                        }
                    }
                    seg_start = seg_end;
                    state = 1 - state;
                }
                out
            }
            ArrivalProcess::Diurnal { base_rate_per_min, amplitude, period_s } => {
                let amp = amplitude.clamp(0.0, 1.0);
                let base = per_us(base_rate_per_min);
                let peak = base * (1.0 + amp);
                let period_us = (period_s * 1e6).max(1.0);
                let mut out = Vec::new();
                let mut t = 0.0f64;
                if peak <= 0.0 {
                    return out;
                }
                loop {
                    // Thinning: candidates at the peak rate, accepted with
                    // probability rate(t)/peak.
                    t += exp_gap(&mut rng, peak);
                    if t >= horizon_us as f64 {
                        break;
                    }
                    let phase = (t / period_us) * std::f64::consts::TAU;
                    let rate = (base * (1.0 + amp * phase.sin())).max(0.0);
                    if rng.gen_f64() < rate / peak {
                        out.push(t as SimTime);
                    }
                }
                out
            }
            ArrivalProcess::ClosedLoop { users, think_s } => {
                let mut tagged: Vec<(SimTime, u32)> = Vec::new();
                let think_mean_us = (think_s * 1e6).max(1.0);
                for u in 0..users {
                    // Per-user stream from a user-derived seed: adding a
                    // user never perturbs the others' cycles.
                    let user_tag = 0x55_5345_5200 + u as u64; // "USER" + index
                    let mut urng = Rng::seed_from_u64(seed ^ SEED_TAG ^ user_tag);
                    // Stagger the first submission by one think draw.
                    let mut t = exp_gap(&mut urng, 1.0 / think_mean_us);
                    while (t as SimDuration) < horizon_us {
                        tagged.push((t as SimTime, u));
                        t += nominal_service_us as f64 + exp_gap(&mut urng, 1.0 / think_mean_us);
                    }
                }
                // Deterministic merge: time, ties broken by user index.
                tagged.sort_unstable();
                tagged.into_iter().map(|(t, _)| t).collect()
            }
        }
    }

    /// Compact label used in scenario names (`RAS_poisson6`).
    pub fn label(&self) -> String {
        match self {
            ArrivalProcess::Poisson { rate_per_min } => format!("poisson{}", trim(*rate_per_min)),
            ArrivalProcess::Mmpp { on_rate_per_min, .. } => {
                format!("mmpp{}", trim(*on_rate_per_min))
            }
            ArrivalProcess::Diurnal { base_rate_per_min, .. } => {
                format!("diurnal{}", trim(*base_rate_per_min))
            }
            ArrivalProcess::ClosedLoop { users, .. } => format!("closed{users}"),
        }
    }

    /// Parse a CLI spec:
    ///
    /// * `poisson:RATE`
    /// * `mmpp:ON_RATE:OFF_RATE:MEAN_ON_S:MEAN_OFF_S`
    /// * `diurnal:BASE_RATE:AMPLITUDE:PERIOD_S`
    /// * `closed:USERS:THINK_S`
    ///
    /// Rates are arrivals per minute. Parsing is strict: wrong field
    /// counts, non-numeric or non-finite fields, and values outside each
    /// process's domain (negative rates, zero periods, amplitude outside
    /// [0, 1], a zero-user population) are errors — never a panic and
    /// never a silently-degenerate process.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |i: usize, what: &str| -> anyhow::Result<f64> {
            let v = parts
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("arrival spec '{s}' is missing {what}"))?
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("arrival spec '{s}': bad {what}"))?;
            anyhow::ensure!(v.is_finite(), "arrival spec '{s}': {what} must be finite");
            Ok(v)
        };
        let arity = |n: usize| -> anyhow::Result<()> {
            anyhow::ensure!(
                parts.len() == n,
                "arrival spec '{s}' has {} fields, expected {n}",
                parts.len()
            );
            Ok(())
        };
        let p = match parts[0] {
            "poisson" => {
                arity(2)?;
                let rate_per_min = num(1, "rate")?;
                anyhow::ensure!(rate_per_min > 0.0, "arrival spec '{s}': rate must be > 0");
                ArrivalProcess::Poisson { rate_per_min }
            }
            "mmpp" => {
                arity(5)?;
                let on = num(1, "on rate")?;
                let off = num(2, "off rate")?;
                let mean_on_s = num(3, "mean on seconds")?;
                let mean_off_s = num(4, "mean off seconds")?;
                anyhow::ensure!(on >= 0.0 && off >= 0.0, "arrival spec '{s}': negative rate");
                anyhow::ensure!(on + off > 0.0, "arrival spec '{s}': both rates are zero");
                anyhow::ensure!(
                    mean_on_s > 0.0 && mean_off_s > 0.0,
                    "arrival spec '{s}': segment means must be > 0"
                );
                ArrivalProcess::Mmpp {
                    on_rate_per_min: on,
                    off_rate_per_min: off,
                    mean_on_s,
                    mean_off_s,
                }
            }
            "diurnal" => {
                arity(4)?;
                let base_rate_per_min = num(1, "base rate")?;
                let amplitude = num(2, "amplitude")?;
                let period_s = num(3, "period seconds")?;
                anyhow::ensure!(base_rate_per_min > 0.0, "arrival spec '{s}': base rate must be > 0");
                anyhow::ensure!(
                    (0.0..=1.0).contains(&amplitude),
                    "arrival spec '{s}': amplitude must be in [0, 1]"
                );
                anyhow::ensure!(period_s > 0.0, "arrival spec '{s}': period must be > 0");
                ArrivalProcess::Diurnal { base_rate_per_min, amplitude, period_s }
            }
            "closed" => {
                arity(3)?;
                let users = num(1, "users")?;
                let think_s = num(2, "think seconds")?;
                anyhow::ensure!(
                    users >= 1.0 && users.fract() == 0.0 && users <= u32::MAX as f64,
                    "arrival spec '{s}': users must be a positive integer"
                );
                anyhow::ensure!(think_s >= 0.0, "arrival spec '{s}': negative think time");
                ArrivalProcess::ClosedLoop { users: users as u32, think_s }
            }
            other => anyhow::bail!(
                "unknown arrival process: {other} (poisson | mmpp | diurnal | closed)"
            ),
        };
        Ok(p)
    }
}

fn per_us(rate_per_min: f64) -> f64 {
    (rate_per_min / 60e6).max(0.0)
}

/// Integer-looking floats render without the trailing `.0` (labels).
fn trim(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Exponential inter-arrival gap at `rate` (events per µs).
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    if rate <= 0.0 {
        return f64::INFINITY;
    }
    // 1 − u avoids ln(0); u ∈ [0, 1).
    -(1.0 - rng.gen_f64()).ln() / rate
}

// ---- stream statistics (property tests + diagnostics) -------------------

/// Mean arrivals per minute over the horizon.
pub fn empirical_rate_per_min(stream: &[SimTime], horizon_us: SimDuration) -> f64 {
    if horizon_us == 0 {
        return 0.0;
    }
    stream.len() as f64 / (horizon_us as f64 / 60e6)
}

/// Index of dispersion of window counts (variance / mean): ≈1 for a
/// Poisson stream, >1 for bursty streams. `window_us` buckets the
/// horizon; partial trailing windows are dropped.
pub fn index_of_dispersion(
    stream: &[SimTime],
    horizon_us: SimDuration,
    window_us: SimDuration,
) -> f64 {
    let n_windows = (horizon_us / window_us.max(1)) as usize;
    if n_windows < 2 {
        return 0.0;
    }
    let mut counts = vec![0f64; n_windows];
    for &t in stream {
        let w = (t / window_us) as usize;
        if w < n_windows {
            counts[w] += 1.0;
        }
    }
    let mean = counts.iter().sum::<f64>() / n_windows as f64;
    if mean == 0.0 {
        return 0.0;
    }
    let var = counts.iter().map(|c| (c - mean).powi(2)).sum::<f64>() / n_windows as f64;
    var / mean
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::time::secs;

    #[test]
    fn streams_are_sorted_seeded_and_distinct_across_seeds() {
        for p in [
            ArrivalProcess::Poisson { rate_per_min: 12.0 },
            ArrivalProcess::Mmpp {
                on_rate_per_min: 40.0,
                off_rate_per_min: 1.0,
                mean_on_s: 20.0,
                mean_off_s: 60.0,
            },
            ArrivalProcess::Diurnal { base_rate_per_min: 10.0, amplitude: 0.8, period_s: 300.0 },
            ArrivalProcess::ClosedLoop { users: 6, think_s: 20.0 },
        ] {
            let h = secs(1800.0);
            let a = p.stream(7, h, secs(10.0));
            let b = p.stream(7, h, secs(10.0));
            assert_eq!(a, b, "{p:?} must replay bit-identically");
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{p:?} must be time-sorted");
            assert!(a.iter().all(|&t| t < h), "{p:?} must respect the horizon");
            assert!(!a.is_empty(), "{p:?} should produce arrivals over 30 min");
            let c = p.stream(8, h, secs(10.0));
            assert_ne!(a, c, "{p:?} must vary with the seed");
        }
    }

    #[test]
    fn poisson_hits_its_mean_rate() {
        let p = ArrivalProcess::Poisson { rate_per_min: 30.0 };
        let h = secs(4.0 * 3600.0);
        let s = p.stream(3, h, 0);
        let rate = empirical_rate_per_min(&s, h);
        assert!((rate - 30.0).abs() < 2.0, "empirical rate {rate} vs spec 30");
        // Poisson window counts are ~unit-dispersed.
        let d = index_of_dispersion(&s, h, secs(60.0));
        assert!((0.6..1.6).contains(&d), "poisson dispersion {d} should be ≈1");
    }

    #[test]
    fn mmpp_is_overdispersed_and_rate_sits_between_states() {
        let p = ArrivalProcess::Mmpp {
            on_rate_per_min: 60.0,
            off_rate_per_min: 1.0,
            mean_on_s: 30.0,
            mean_off_s: 90.0,
        };
        let h = secs(4.0 * 3600.0);
        let s = p.stream(11, h, 0);
        let rate = empirical_rate_per_min(&s, h);
        assert!((1.0..60.0).contains(&rate), "mean rate {rate} must sit between the states");
        // Duty-weighted expectation: (60·30 + 1·90) / 120 ≈ 15.75/min.
        assert!((rate - 15.75).abs() < 4.0, "mean rate {rate} vs expectation 15.75");
        let d = index_of_dispersion(&s, h, secs(60.0));
        assert!(d > 2.0, "bursty on-off stream must be overdispersed, got {d}");
    }

    #[test]
    fn diurnal_peaks_and_troughs_follow_the_curve() {
        let period = 1200.0;
        let p =
            ArrivalProcess::Diurnal { base_rate_per_min: 20.0, amplitude: 0.9, period_s: period };
        let h = secs(4.0 * period);
        let s = p.stream(5, h, 0);
        // First vs third quarter of each period: sin > 0 vs sin < 0.
        let (mut peak, mut trough) = (0usize, 0usize);
        for &t in &s {
            let phase = (t as f64 / secs(period) as f64).fract();
            if phase < 0.5 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > trough as f64 * 1.5,
            "rising half-periods should dominate: peak={peak} trough={trough}"
        );
    }

    #[test]
    fn closed_loop_scales_with_population_and_respects_cycle_time() {
        let h = secs(3600.0);
        let service = secs(15.0);
        let few = ArrivalProcess::ClosedLoop { users: 4, think_s: 30.0 }.stream(9, h, service);
        let many = ArrivalProcess::ClosedLoop { users: 8, think_s: 30.0 }.stream(9, h, service);
        assert!(
            (many.len() as f64 / few.len() as f64 - 2.0).abs() < 0.35,
            "doubling users should ≈double throughput: {} vs {}",
            few.len(),
            many.len()
        );
        // Per-user cycle = service + think ⇒ ≈ users · horizon / cycle.
        let expect = 4.0 * 3600.0 / 45.0;
        assert!(
            (few.len() as f64 - expect).abs() < expect * 0.25,
            "closed-loop count {} vs expectation {expect}",
            few.len()
        );
    }

    #[test]
    fn parse_roundtrips_and_rejects_garbage() {
        assert_eq!(
            ArrivalProcess::parse("poisson:12").unwrap(),
            ArrivalProcess::Poisson { rate_per_min: 12.0 }
        );
        assert_eq!(
            ArrivalProcess::parse("mmpp:40:1:20:60").unwrap(),
            ArrivalProcess::Mmpp {
                on_rate_per_min: 40.0,
                off_rate_per_min: 1.0,
                mean_on_s: 20.0,
                mean_off_s: 60.0
            }
        );
        assert_eq!(
            ArrivalProcess::parse("diurnal:10:0.8:600").unwrap(),
            ArrivalProcess::Diurnal { base_rate_per_min: 10.0, amplitude: 0.8, period_s: 600.0 }
        );
        assert_eq!(
            ArrivalProcess::parse("closed:8:30").unwrap(),
            ArrivalProcess::ClosedLoop { users: 8, think_s: 30.0 }
        );
        assert!(ArrivalProcess::parse("poisson").is_err());
        assert!(ArrivalProcess::parse("mmpp:40:1").is_err());
        assert!(ArrivalProcess::parse("sawtooth:1").is_err());
        assert_eq!(ArrivalProcess::parse("poisson:6").unwrap().label(), "poisson6");
    }

    #[test]
    fn parse_rejects_malformed_specs_with_errors_not_panics() {
        // Every rejection is an Err — `medge loadgen --procs` surfaces
        // it as a CLI error instead of a panic or a silent no-op plan.
        for bad in [
            "",                    // no process name
            "poisson:",            // empty rate
            "poisson:abc",         // non-numeric
            "poisson:0",           // zero rate → empty plan
            "poisson:-4",          // negative rate
            "poisson:inf",         // non-finite
            "poisson:nan",         // non-finite
            "poisson:6:9",         // extra field
            "mmpp:-1:1:20:60",     // negative on rate
            "mmpp:0:0:20:60",      // both rates zero
            "mmpp:40:1:0:60",      // zero segment mean
            "mmpp:40:1:20:60:9",   // extra field
            "diurnal:0:0.5:600",   // zero base rate
            "diurnal:10:1.5:600",  // amplitude out of [0,1]
            "diurnal:10:-0.1:600", // negative amplitude
            "diurnal:10:0.5:0",    // zero period
            "closed:0:30",         // empty population
            "closed:2.5:30",       // fractional users
            "closed:-3:30",        // negative users
            "closed:3:-1",         // negative think time
        ] {
            assert!(ArrivalProcess::parse(bad).is_err(), "spec '{bad}' should be rejected");
        }
        // Boundary values that are valid stay valid.
        assert!(ArrivalProcess::parse("diurnal:10:0:600").is_ok(), "amplitude 0 is flat");
        assert!(ArrivalProcess::parse("diurnal:10:1:600").is_ok(), "amplitude 1 is full swing");
        assert!(ArrivalProcess::parse("closed:1:0").is_ok(), "one user, zero think");
        assert!(ArrivalProcess::parse("mmpp:40:0:20:60").is_ok(), "silent OFF state is fine");
    }
}
