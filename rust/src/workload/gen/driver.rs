//! The open-loop load driver: compiles an arrival process + task-class
//! catalog into the concrete arrival plan the engine's event queue
//! executes.
//!
//! A [`Workload`] is the scenario-level axis: either the paper's
//! [`Workload::Conveyor`] trace (replayed exactly — byte-identical to the
//! pre-generative engine) or a [`Workload::Generative`] spec. Compilation
//! ([`GenSpec::compile`]) is a pure function of (spec, seed, fleet,
//! horizon): every arrival instant, class draw, and source-device draw is
//! fixed before the run starts, so generative runs are as deterministic
//! as trace replays — across repeated runs *and* sweep worker threads.

use crate::config::SystemConfig;
use crate::coordinator::task::{DeviceId, Priority};
use crate::time::{SimDuration, SimTime};
use crate::util::Rng;
use crate::workload::trace::TraceSpec;

use super::arrival::ArrivalProcess;
use super::catalog::Catalog;

/// Seed-domain tag for class/source draws (hex "MIX").
const MIX_SEED_TAG: u64 = 0x4d49_58;

/// The scenario workload axis.
#[derive(Debug, Clone, PartialEq)]
pub enum Workload {
    /// The paper's conveyor-belt trace (Section V), replayed exactly.
    Conveyor(TraceSpec),
    /// Arrival process × task-class catalog, compiled open-loop.
    Generative(GenSpec),
}

impl Workload {
    /// The conveyor trace as a workload (the default axis value).
    pub fn conveyor(spec: TraceSpec) -> Self {
        Workload::Conveyor(spec)
    }

    /// A generative workload with no admission cap.
    pub fn generative(arrivals: ArrivalProcess, catalog: Catalog) -> Self {
        Workload::Generative(GenSpec { arrivals, catalog, admission_cap: 0 })
    }

    pub fn label(&self) -> String {
        match self {
            Workload::Conveyor(spec) => spec.label(),
            Workload::Generative(g) => g.arrivals.label(),
        }
    }
}

/// A generative workload specification.
#[derive(Debug, Clone, PartialEq)]
pub struct GenSpec {
    pub arrivals: ArrivalProcess,
    pub catalog: Catalog,
    /// Admission control: maximum tasks in flight (0 = unlimited). An
    /// arrival batch that would push the live count past the cap is
    /// dropped whole at admission and counted, not queued.
    pub admission_cap: usize,
}

impl GenSpec {
    pub fn admission_cap(mut self, cap: usize) -> Self {
        self.admission_cap = cap;
        self
    }

    /// Expand to the concrete plan the engine executes. Pure in
    /// (self, cfg.seed, n_devices, horizon_us).
    pub fn compile(
        &self,
        cfg: &SystemConfig,
        horizon_us: SimDuration,
    ) -> anyhow::Result<GenWorkload> {
        self.catalog.validate()?;
        let instants =
            self.arrivals.stream(cfg.seed, horizon_us, self.catalog.mean_service_us());
        let weights = self.catalog.weights();
        let mut rng = Rng::seed_from_u64(cfg.seed ^ MIX_SEED_TAG);
        let arrivals = instants
            .into_iter()
            .map(|at| {
                // One class draw + one source draw per arrival, in stream
                // order: the plan is a fixed function of the seed.
                let class = rng.weighted_index(&weights) as u16;
                let source = rng.index(cfg.n_devices);
                GenArrival { at, class, source }
            })
            .collect();
        Ok(GenWorkload {
            classes: self.catalog.classes.iter().map(|c| c.compile(cfg)).collect(),
            arrivals,
            admission_cap: self.admission_cap,
        })
    }
}

/// A compiled task class (integer µs/bytes — what the engine consumes).
#[derive(Debug, Clone, PartialEq)]
pub struct GenClass {
    pub priority: Priority,
    pub deadline_us: SimDuration,
    pub input_bytes: u64,
    /// `[two-core, four-core]` stage durations (HP: the stage duration in
    /// both entries).
    pub proc_us: [SimDuration; 2],
    /// Cloud-tier service time (0 for HP classes — see
    /// [`crate::coordinator::task::Task::cloud_us`]).
    pub cloud_us: SimDuration,
    pub batch: u32,
    /// Compiled model-variant ladder (rung 0 equals this class's own
    /// spec by construction). Empty = no explicit ladder: the class runs
    /// its single model at implicit accuracy 1.0 and never degrades.
    pub rungs: Vec<crate::coordinator::task::VariantRung>,
    /// Compiled anytime stage plans, parallel to `rungs` (entry `i`
    /// splits rung `i`; `StagePlan::NONE` for monolithic rungs). Empty
    /// whenever `rungs` is empty.
    pub stage_plans: Vec<crate::coordinator::task::StagePlan>,
}

/// One planned arrival: `batch` tasks of `class` from `source` at `at`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GenArrival {
    pub at: SimTime,
    pub class: u16,
    pub source: DeviceId,
}

/// The fully-compiled plan handed to the engine via
/// [`crate::sim::engine::RunExtras::gen`].
#[derive(Debug, Clone, PartialEq, Default)]
pub struct GenWorkload {
    pub classes: Vec<GenClass>,
    /// Time-sorted arrival plan.
    pub arrivals: Vec<GenArrival>,
    /// 0 = unlimited.
    pub admission_cap: usize,
}

impl GenWorkload {
    /// Total tasks the plan offers (admission sees them; drops subtract).
    pub fn offered_tasks(&self) -> u64 {
        self.arrivals
            .iter()
            .map(|a| self.classes[a.class as usize].batch as u64)
            .sum()
    }

    /// Last planned arrival instant (engine input horizon).
    pub fn last_arrival(&self) -> SimTime {
        self.arrivals.last().map(|a| a.at).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::time::secs;

    fn spec() -> GenSpec {
        GenSpec {
            arrivals: ArrivalProcess::Poisson { rate_per_min: 20.0 },
            catalog: Catalog::edge_serving(&SystemConfig::default()),
            admission_cap: 0,
        }
    }

    #[test]
    fn compile_is_deterministic_and_time_sorted() {
        let cfg = SystemConfig::default();
        let a = spec().compile(&cfg, secs(1800.0)).unwrap();
        let b = spec().compile(&cfg, secs(1800.0)).unwrap();
        assert_eq!(a, b);
        assert!(a.arrivals.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(!a.arrivals.is_empty());
        assert!(a.arrivals.iter().all(|x| x.source < cfg.n_devices));
        assert!(a.arrivals.iter().all(|x| (x.class as usize) < a.classes.len()));
        let mut cfg2 = cfg;
        cfg2.seed ^= 1;
        let c = spec().compile(&cfg2, secs(1800.0)).unwrap();
        assert_ne!(a, c, "plan must vary with the scenario seed");
    }

    #[test]
    fn class_mix_follows_catalog_weights() {
        let cfg = SystemConfig::default();
        let plan = spec().compile(&cfg, secs(6.0 * 3600.0)).unwrap();
        let mut counts = vec![0f64; plan.classes.len()];
        for a in &plan.arrivals {
            counts[a.class as usize] += 1.0;
        }
        // edge_serving weights 3:2:1 — the dominant class dominates.
        assert!(counts[0] > counts[1] && counts[1] > counts[2], "mix skew lost: {counts:?}");
        let total: f64 = counts.iter().sum();
        assert!((counts[0] / total - 0.5).abs() < 0.06, "interactive share: {counts:?}");
    }

    #[test]
    fn offered_tasks_accounts_for_batch_sizes() {
        let cfg = SystemConfig::default();
        let plan = spec().compile(&cfg, secs(3600.0)).unwrap();
        let by_hand: u64 = plan
            .arrivals
            .iter()
            .map(|a| plan.classes[a.class as usize].batch as u64)
            .sum();
        assert_eq!(plan.offered_tasks(), by_hand);
        assert!(plan.offered_tasks() >= plan.arrivals.len() as u64);
        assert!(plan.last_arrival() > 0);
    }

    #[test]
    fn compile_rejects_invalid_catalogs() {
        let cfg = SystemConfig::default();
        let bad = GenSpec {
            arrivals: ArrivalProcess::Poisson { rate_per_min: 5.0 },
            catalog: Catalog::new(vec![]),
            admission_cap: 0,
        };
        assert!(bad.compile(&cfg, secs(60.0)).is_err());
    }
}
