//! Model-variant ladders: the degraded-inference accuracy axis.
//!
//! Real edge serving does not run one DNN per task class — it keeps a
//! *family* of model variants (full / distilled / quantised / tiny) and
//! trades inference accuracy for latency when the deadline is at risk
//! (Fresa & Champati; Yao et al.'s imprecise-computation scheduling). A
//! [`Ladder`] is that family as an ordered list of [`ModelVariant`]s:
//! rung 0 is the full-accuracy model, every lower rung is cheaper on
//! every axis (accuracy, input size, both stage times — validated).
//!
//! The compiled form ([`VariantRung`]) flows to the schedulers through
//! [`crate::coordinator::scheduler::SchedEvent::LowPriorityBatch`]; the
//! shared degradation policy
//! ([`crate::coordinator::scheduler::place_degrading`]) tries the
//! full-accuracy rung first and steps down only when the scheduler's own
//! state says the rung is infeasible — so RAS (conservative windows) and
//! WPS (exact state) genuinely *disagree about when degradation is
//! necessary*, which is the paper's accuracy-vs-performance trade-off
//! made literal. A one-rung ladder never degrades and decides
//! bit-identically to having no ladder at all.

use crate::config::SystemConfig;
use crate::coordinator::task::{StagePlan, VariantRung, MAX_RUNGS, MAX_STAGES};
use crate::time::secs;

/// One anytime stage of a model variant, spec-level: the *incremental*
/// share of the variant's execution time this stage consumes and the
/// *incremental* accuracy credit it banks on completion (the imprecise-
/// computation split: a mandatory prefix earns most of the accuracy,
/// optional refinement stages buy the rest).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageSpec {
    /// Incremental fraction of the variant's total stage time, in (0, 1];
    /// a variant's stage fractions must sum to 1.
    pub frac: f64,
    /// Incremental accuracy credit banked when this stage completes
    /// (non-negative); a variant's credits must sum to its accuracy.
    pub credit: f64,
}

/// One model variant of a task class: the accuracy it delivers and what
/// it costs. Stage times are *benchmark means* like
/// [`crate::workload::gen::TaskClass`]'s — compilation adds the system's
/// low-priority `proc_padding_s`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelVariant {
    pub name: String,
    /// Delivered inference accuracy in (0, 1].
    pub accuracy: f64,
    /// Input transferred on offload, megabits.
    pub input_mbits: f64,
    /// Two-core stage time (benchmark mean), seconds.
    pub proc2_s: f64,
    /// Four-core stage time (benchmark mean), seconds.
    pub proc4_s: f64,
    /// Anytime stage plan: empty (the default) means monolithic
    /// execution, byte-identical to the pre-anytime system. Attach with
    /// [`ModelVariant::staged`].
    pub stages: Vec<StageSpec>,
    /// Leading stages that can never be truncated (`>= 1` whenever
    /// `stages` is non-empty).
    pub mandatory: u8,
}

impl ModelVariant {
    pub fn new(name: &str, accuracy: f64, input_mbits: f64, proc2_s: f64, proc4_s: f64) -> Self {
        Self {
            name: name.to_string(),
            accuracy,
            input_mbits,
            proc2_s,
            proc4_s,
            stages: Vec::new(),
            mandatory: 0,
        }
    }

    /// Attach an anytime stage plan: `mandatory` leading stages that can
    /// never be cut, and one `(frac, credit)` pair per stage (incremental
    /// time share / incremental accuracy credit). Validated by
    /// [`Ladder::validate`].
    pub fn staged(mut self, mandatory: usize, stages: &[(f64, f64)]) -> Self {
        self.stages = stages.iter().map(|&(frac, credit)| StageSpec { frac, credit }).collect();
        self.mandatory = mandatory as u8;
        self
    }

    /// Compiled integer form (padding in seconds, added to both stage
    /// times exactly like `TaskClass::compile` pads low-priority plans).
    pub(crate) fn compile(&self, pad_s: f64) -> VariantRung {
        VariantRung {
            accuracy: self.accuracy,
            input_bytes: (self.input_mbits * 1e6 / 8.0).round() as u64,
            proc_us: [secs(self.proc2_s + pad_s), secs(self.proc4_s + pad_s)],
        }
    }

    /// Compiled stage plan: cumulative time fractions and accuracy
    /// credits, with the final entries forced to exactly `1.0` and the
    /// variant's accuracy so an uncut staged run is indistinguishable
    /// from a monolithic one (no float-accumulation drift in the
    /// accuracy ledger). [`StagePlan::NONE`] when the variant is
    /// monolithic.
    pub(crate) fn compile_stages(&self) -> StagePlan {
        if self.stages.is_empty() {
            return StagePlan::NONE;
        }
        let mut plan = StagePlan {
            n_stages: self.stages.len() as u8,
            mandatory: self.mandatory,
            ..StagePlan::NONE
        };
        let (mut frac, mut credit) = (0.0, 0.0);
        for (i, s) in self.stages.iter().enumerate() {
            frac += s.frac;
            credit += s.credit;
            plan.cum_frac[i] = frac;
            plan.cum_accuracy[i] = credit;
        }
        let last = self.stages.len() - 1;
        plan.cum_frac[last] = 1.0;
        plan.cum_accuracy[last] = self.accuracy;
        plan
    }

    /// Per-variant stage-plan validity (called per rung by
    /// [`Ladder::validate`]).
    fn validate_stages(&self, rung: usize) -> anyhow::Result<()> {
        if self.stages.is_empty() {
            anyhow::ensure!(
                self.mandatory == 0,
                "rung {rung} ({}): mandatory prefix without a stage plan",
                self.name
            );
            return Ok(());
        }
        let n = self.stages.len();
        anyhow::ensure!(
            n <= MAX_STAGES,
            "rung {rung} ({}): {n} stages exceeds the supported maximum {MAX_STAGES}",
            self.name
        );
        anyhow::ensure!(
            (1..=n).contains(&(self.mandatory as usize)),
            "rung {rung} ({}): mandatory prefix {} must be in 1..={n}",
            self.name,
            self.mandatory
        );
        let (mut frac, mut credit) = (0.0, 0.0);
        for (i, s) in self.stages.iter().enumerate() {
            anyhow::ensure!(
                s.frac > 0.0 && s.frac <= 1.0,
                "rung {rung} ({}): stage {} time fraction must be in (0, 1], got {}",
                self.name,
                i + 1,
                s.frac
            );
            anyhow::ensure!(
                s.credit >= 0.0,
                "rung {rung} ({}): stage {} has negative accuracy credit",
                self.name,
                i + 1
            );
            frac += s.frac;
            credit += s.credit;
        }
        anyhow::ensure!(
            (frac - 1.0).abs() < 1e-9,
            "rung {rung} ({}): stage time fractions sum to {frac}, want 1",
            self.name
        );
        anyhow::ensure!(
            (credit - self.accuracy).abs() < 1e-9,
            "rung {rung} ({}): stage accuracy credits sum to {credit}, want {}",
            self.name,
            self.accuracy
        );
        Ok(())
    }
}

/// An ordered model-variant family: rung 0 = full accuracy, descending.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Ladder {
    pub rungs: Vec<ModelVariant>,
}

impl Ladder {
    pub fn new(rungs: Vec<ModelVariant>) -> Self {
        Self { rungs }
    }

    /// A one-rung ladder (degradation disabled; decisions and runs are
    /// bit-identical to having no ladder when `accuracy` is 1.0).
    pub fn single(v: ModelVariant) -> Self {
        Self { rungs: vec![v] }
    }

    pub fn depth(&self) -> usize {
        self.rungs.len()
    }

    /// The top `depth` rungs (at least one) — the frontier grids sweep
    /// ladder depth with this.
    pub fn truncated(&self, depth: usize) -> Ladder {
        let depth = depth.clamp(1, self.rungs.len().max(1));
        Ladder { rungs: self.rungs.iter().take(depth).cloned().collect() }
    }

    /// Structural validity: non-empty, bounded depth, accuracies in
    /// (0, 1], positive stage times with `proc4 ≤ proc2` per rung, and
    /// monotone descent — a lower rung is never more expensive (or more
    /// accurate) than the rung above it on *any* axis, which is what
    /// makes "step down on infeasibility" a sound policy.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.rungs.is_empty(), "ladder has no rungs");
        anyhow::ensure!(
            self.rungs.len() <= MAX_RUNGS,
            "ladder depth {} exceeds the supported maximum {MAX_RUNGS}",
            self.rungs.len()
        );
        for (i, r) in self.rungs.iter().enumerate() {
            anyhow::ensure!(
                r.accuracy > 0.0 && r.accuracy <= 1.0,
                "rung {} ({}): accuracy must be in (0, 1], got {}",
                i,
                r.name,
                r.accuracy
            );
            anyhow::ensure!(
                r.proc2_s > 0.0 && r.proc4_s > 0.0,
                "rung {} ({}): non-positive stage time",
                i,
                r.name
            );
            anyhow::ensure!(
                r.proc4_s <= r.proc2_s,
                "rung {} ({}): four-core time must not exceed two-core time",
                i,
                r.name
            );
            anyhow::ensure!(r.input_mbits >= 0.0, "rung {} ({}): negative input", i, r.name);
            r.validate_stages(i)?;
            if i > 0 {
                let up = &self.rungs[i - 1];
                anyhow::ensure!(
                    r.accuracy <= up.accuracy
                        && r.proc2_s <= up.proc2_s
                        && r.proc4_s <= up.proc4_s
                        && r.input_mbits <= up.input_mbits,
                    "rung {} ({}) must be no more accurate and no more expensive than rung {} ({})",
                    i,
                    r.name,
                    i - 1,
                    up.name
                );
            }
        }
        Ok(())
    }

    /// Compile to the integer rungs the engine and schedulers consume
    /// (low-priority padding applied to every rung's stage times).
    pub fn compile(&self, cfg: &SystemConfig) -> Vec<VariantRung> {
        self.rungs.iter().map(|v| v.compile(cfg.proc_padding_s)).collect()
    }

    /// Does any rung carry an anytime stage plan?
    pub fn has_stage_plans(&self) -> bool {
        self.rungs.iter().any(|v| !v.stages.is_empty())
    }

    /// Compile every rung's stage plan, parallel to [`Ladder::compile`]
    /// (entry `i` belongs to compiled rung `i`; [`StagePlan::NONE`] for
    /// monolithic rungs).
    pub fn compile_stage_plans(&self) -> Vec<StagePlan> {
        self.rungs.iter().map(|v| v.compile_stages()).collect()
    }

    /// A three-rung family built from the paper's stage-3 benchmark
    /// model: the full model, a distilled variant (~55 % of the compute
    /// and half the input for ~5 points of accuracy), and a tiny variant
    /// (~25 % compute, quarter input, ~19 points down). The accuracy
    /// numbers follow the usual full/distilled/tiny spread of DNN model
    /// families; the costs scale the paper's measured stage times.
    pub fn stage3_family(cfg: &SystemConfig) -> Ladder {
        let image_mbits = cfg.image_bytes as f64 * 8.0 / 1e6;
        Ladder::new(vec![
            ModelVariant::new("stage3-full", 0.97, image_mbits, cfg.lp2_proc_s, cfg.lp4_proc_s),
            ModelVariant::new(
                "stage3-distilled",
                0.92,
                image_mbits * 0.5,
                cfg.lp2_proc_s * 0.55,
                cfg.lp4_proc_s * 0.55,
            ),
            ModelVariant::new(
                "stage3-tiny",
                0.78,
                image_mbits * 0.25,
                cfg.lp2_proc_s * 0.25,
                cfg.lp4_proc_s * 0.25,
            ),
        ])
    }

    /// [`Ladder::stage3_family`] with anytime stage plans attached: the
    /// full and distilled variants split into a mandatory backbone plus
    /// optional refinement stages (the usual anytime-DNN shape — early
    /// exits bank most of the accuracy, late stages buy the last few
    /// points), while the tiny variant stays monolithic (too small to
    /// exit early). This is the anytime grid's workload.
    pub fn stage3_family_staged(cfg: &SystemConfig) -> Ladder {
        let mut fam = Ladder::stage3_family(cfg);
        fam.rungs[0] = fam.rungs[0]
            .clone()
            .staged(1, &[(0.50, 0.70), (0.30, 0.17), (0.20, 0.10)]);
        fam.rungs[1] = fam.rungs[1].clone().staged(1, &[(0.60, 0.72), (0.40, 0.20)]);
        fam
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage3_family_is_valid_and_descending() {
        let cfg = SystemConfig::default();
        let fam = Ladder::stage3_family(&cfg);
        fam.validate().unwrap();
        assert_eq!(fam.depth(), 3);
        assert!(fam.rungs.windows(2).all(|w| w[1].accuracy < w[0].accuracy));
        assert!(fam.rungs.windows(2).all(|w| w[1].proc2_s < w[0].proc2_s));
        // Rung 0 is exactly the paper's stage-3 spec.
        let compiled = fam.compile(&cfg);
        assert_eq!(compiled[0].proc_us, [cfg.lp2_proc(), cfg.lp4_proc()]);
        assert_eq!(compiled[0].input_bytes, cfg.image_bytes);
        assert!((compiled[0].accuracy - 0.97).abs() < 1e-12);
    }

    #[test]
    fn truncation_keeps_the_top_rungs() {
        let cfg = SystemConfig::default();
        let fam = Ladder::stage3_family(&cfg);
        assert_eq!(fam.truncated(1).depth(), 1);
        assert_eq!(fam.truncated(2).rungs[1], fam.rungs[1]);
        assert_eq!(fam.truncated(99).depth(), 3, "truncation clamps to the family depth");
        assert_eq!(fam.truncated(0).depth(), 1, "at least one rung always remains");
    }

    #[test]
    fn validate_rejects_malformed_ladders() {
        let mk = |acc: f64, in_mb: f64, p2: f64, p4: f64| ModelVariant::new("v", acc, in_mb, p2, p4);
        assert!(Ladder::new(vec![]).validate().is_err(), "empty");
        assert!(Ladder::single(mk(1.5, 1.0, 2.0, 1.5)).validate().is_err(), "accuracy > 1");
        assert!(Ladder::single(mk(0.9, 1.0, 2.0, 2.5)).validate().is_err(), "proc4 > proc2");
        assert!(Ladder::single(mk(0.9, 1.0, 0.0, 0.0)).validate().is_err(), "zero stage time");
        // Non-monotone descent: the lower rung is MORE accurate.
        let inverted = Ladder::new(vec![mk(0.8, 1.0, 2.0, 1.5), mk(0.9, 0.5, 1.0, 0.8)]);
        assert!(inverted.validate().is_err());
        // Non-monotone cost: the lower rung is MORE expensive.
        let pricier = Ladder::new(vec![mk(0.9, 1.0, 2.0, 1.5), mk(0.8, 1.0, 3.0, 2.0)]);
        assert!(pricier.validate().is_err());
        // Depth cap.
        let deep = Ladder::new(
            (0..MAX_RUNGS + 1)
                .map(|i| mk(0.9 - i as f64 * 0.05, 1.0, 2.0, 1.5))
                .collect(),
        );
        assert!(deep.validate().is_err());
    }

    #[test]
    fn staged_family_validates_and_compiles_cumulative_plans() {
        let cfg = SystemConfig::default();
        let fam = Ladder::stage3_family_staged(&cfg);
        fam.validate().unwrap();
        assert!(fam.has_stage_plans());
        assert!(!Ladder::stage3_family(&cfg).has_stage_plans());
        let plans = fam.compile_stage_plans();
        assert_eq!(plans.len(), fam.depth());
        // Rung 0: three stages, mandatory backbone of one.
        let p = plans[0];
        assert_eq!((p.n_stages, p.mandatory), (3, 1));
        assert!(p.cuttable());
        assert!((p.frac_after(1) - 0.50).abs() < 1e-12);
        assert!((p.accuracy_after(2) - 0.87).abs() < 1e-12);
        // Final entries are exact: an uncut staged run credits precisely
        // the rung accuracy, no float-accumulation drift.
        assert_eq!(p.frac_after(3), 1.0);
        assert_eq!(p.accuracy_after(3), fam.rungs[0].accuracy);
        // Cumulative fractions and credits are strictly increasing.
        assert!(p.cum_frac[..3].windows(2).all(|w| w[0] < w[1]));
        assert!(p.cum_accuracy[..3].windows(2).all(|w| w[0] < w[1]));
        // The tiny rung stays monolithic.
        assert_eq!(plans[2], StagePlan::NONE);
        assert!(!plans[2].is_staged());
        // Stage plans survive depth truncation (they ride on the rungs).
        assert!(fam.truncated(2).has_stage_plans());
    }

    #[test]
    fn validate_rejects_malformed_stage_plans() {
        let base = || ModelVariant::new("v", 0.9, 1.0, 2.0, 1.5);
        // Fractions must sum to 1.
        let bad = Ladder::single(base().staged(1, &[(0.5, 0.5), (0.3, 0.4)]));
        assert!(bad.validate().is_err(), "fractions sum to 0.8");
        // Credits must sum to the variant accuracy.
        let bad = Ladder::single(base().staged(1, &[(0.5, 0.5), (0.5, 0.5)]));
        assert!(bad.validate().is_err(), "credits sum to 1.0, accuracy is 0.9");
        // Mandatory prefix must cover at least one stage...
        let bad = Ladder::single(base().staged(0, &[(0.5, 0.4), (0.5, 0.5)]));
        assert!(bad.validate().is_err(), "mandatory 0");
        // ...and no more than all of them.
        let bad = Ladder::single(base().staged(3, &[(0.5, 0.4), (0.5, 0.5)]));
        assert!(bad.validate().is_err(), "mandatory 3 of 2");
        // Non-positive fractions and negative credits are rejected.
        assert!(Ladder::single(base().staged(1, &[(0.0, 0.4), (1.0, 0.5)])).validate().is_err());
        assert!(Ladder::single(base().staged(1, &[(0.5, -0.1), (0.5, 1.0)])).validate().is_err());
        // Too many stages.
        let mut many: Vec<(f64, f64)> =
            (0..MAX_STAGES + 1).map(|_| (1.0 / (MAX_STAGES + 1) as f64, 0.0)).collect();
        many[0].1 = 0.9;
        assert!(Ladder::single(base().staged(1, &many)).validate().is_err());
        // A mandatory prefix without stages is nonsense.
        let mut stray = base();
        stray.mandatory = 1;
        assert!(Ladder::single(stray).validate().is_err());
        // All-mandatory (no cut point) is legal, just never cuttable.
        let solid = Ladder::single(base().staged(2, &[(0.5, 0.4), (0.5, 0.5)]));
        solid.validate().unwrap();
        assert!(!solid.compile_stage_plans()[0].cuttable());
    }

    #[test]
    fn compile_pads_every_rung() {
        let cfg = SystemConfig::default();
        let fam = Ladder::stage3_family(&cfg);
        let compiled = fam.compile(&cfg);
        for (v, r) in fam.rungs.iter().zip(&compiled) {
            assert_eq!(r.proc_us[0], secs(v.proc2_s + cfg.proc_padding_s));
            assert_eq!(r.proc_us[1], secs(v.proc4_s + cfg.proc_padding_s));
            assert_eq!(r.input_bytes, (v.input_mbits * 1e6 / 8.0).round() as u64);
        }
    }
}
