//! Model-variant ladders: the degraded-inference accuracy axis.
//!
//! Real edge serving does not run one DNN per task class — it keeps a
//! *family* of model variants (full / distilled / quantised / tiny) and
//! trades inference accuracy for latency when the deadline is at risk
//! (Fresa & Champati; Yao et al.'s imprecise-computation scheduling). A
//! [`Ladder`] is that family as an ordered list of [`ModelVariant`]s:
//! rung 0 is the full-accuracy model, every lower rung is cheaper on
//! every axis (accuracy, input size, both stage times — validated).
//!
//! The compiled form ([`VariantRung`]) flows to the schedulers through
//! [`crate::coordinator::scheduler::SchedEvent::LowPriorityBatch`]; the
//! shared degradation policy
//! ([`crate::coordinator::scheduler::place_degrading`]) tries the
//! full-accuracy rung first and steps down only when the scheduler's own
//! state says the rung is infeasible — so RAS (conservative windows) and
//! WPS (exact state) genuinely *disagree about when degradation is
//! necessary*, which is the paper's accuracy-vs-performance trade-off
//! made literal. A one-rung ladder never degrades and decides
//! bit-identically to having no ladder at all.

use crate::config::SystemConfig;
use crate::coordinator::task::{VariantRung, MAX_RUNGS};
use crate::time::secs;

/// One model variant of a task class: the accuracy it delivers and what
/// it costs. Stage times are *benchmark means* like
/// [`crate::workload::gen::TaskClass`]'s — compilation adds the system's
/// low-priority `proc_padding_s`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelVariant {
    pub name: String,
    /// Delivered inference accuracy in (0, 1].
    pub accuracy: f64,
    /// Input transferred on offload, megabits.
    pub input_mbits: f64,
    /// Two-core stage time (benchmark mean), seconds.
    pub proc2_s: f64,
    /// Four-core stage time (benchmark mean), seconds.
    pub proc4_s: f64,
}

impl ModelVariant {
    pub fn new(name: &str, accuracy: f64, input_mbits: f64, proc2_s: f64, proc4_s: f64) -> Self {
        Self { name: name.to_string(), accuracy, input_mbits, proc2_s, proc4_s }
    }

    /// Compiled integer form (padding in seconds, added to both stage
    /// times exactly like `TaskClass::compile` pads low-priority plans).
    pub(crate) fn compile(&self, pad_s: f64) -> VariantRung {
        VariantRung {
            accuracy: self.accuracy,
            input_bytes: (self.input_mbits * 1e6 / 8.0).round() as u64,
            proc_us: [secs(self.proc2_s + pad_s), secs(self.proc4_s + pad_s)],
        }
    }
}

/// An ordered model-variant family: rung 0 = full accuracy, descending.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Ladder {
    pub rungs: Vec<ModelVariant>,
}

impl Ladder {
    pub fn new(rungs: Vec<ModelVariant>) -> Self {
        Self { rungs }
    }

    /// A one-rung ladder (degradation disabled; decisions and runs are
    /// bit-identical to having no ladder when `accuracy` is 1.0).
    pub fn single(v: ModelVariant) -> Self {
        Self { rungs: vec![v] }
    }

    pub fn depth(&self) -> usize {
        self.rungs.len()
    }

    /// The top `depth` rungs (at least one) — the frontier grids sweep
    /// ladder depth with this.
    pub fn truncated(&self, depth: usize) -> Ladder {
        let depth = depth.clamp(1, self.rungs.len().max(1));
        Ladder { rungs: self.rungs.iter().take(depth).cloned().collect() }
    }

    /// Structural validity: non-empty, bounded depth, accuracies in
    /// (0, 1], positive stage times with `proc4 ≤ proc2` per rung, and
    /// monotone descent — a lower rung is never more expensive (or more
    /// accurate) than the rung above it on *any* axis, which is what
    /// makes "step down on infeasibility" a sound policy.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.rungs.is_empty(), "ladder has no rungs");
        anyhow::ensure!(
            self.rungs.len() <= MAX_RUNGS,
            "ladder depth {} exceeds the supported maximum {MAX_RUNGS}",
            self.rungs.len()
        );
        for (i, r) in self.rungs.iter().enumerate() {
            anyhow::ensure!(
                r.accuracy > 0.0 && r.accuracy <= 1.0,
                "rung {} ({}): accuracy must be in (0, 1], got {}",
                i,
                r.name,
                r.accuracy
            );
            anyhow::ensure!(
                r.proc2_s > 0.0 && r.proc4_s > 0.0,
                "rung {} ({}): non-positive stage time",
                i,
                r.name
            );
            anyhow::ensure!(
                r.proc4_s <= r.proc2_s,
                "rung {} ({}): four-core time must not exceed two-core time",
                i,
                r.name
            );
            anyhow::ensure!(r.input_mbits >= 0.0, "rung {} ({}): negative input", i, r.name);
            if i > 0 {
                let up = &self.rungs[i - 1];
                anyhow::ensure!(
                    r.accuracy <= up.accuracy
                        && r.proc2_s <= up.proc2_s
                        && r.proc4_s <= up.proc4_s
                        && r.input_mbits <= up.input_mbits,
                    "rung {} ({}) must be no more accurate and no more expensive than rung {} ({})",
                    i,
                    r.name,
                    i - 1,
                    up.name
                );
            }
        }
        Ok(())
    }

    /// Compile to the integer rungs the engine and schedulers consume
    /// (low-priority padding applied to every rung's stage times).
    pub fn compile(&self, cfg: &SystemConfig) -> Vec<VariantRung> {
        self.rungs.iter().map(|v| v.compile(cfg.proc_padding_s)).collect()
    }

    /// A three-rung family built from the paper's stage-3 benchmark
    /// model: the full model, a distilled variant (~55 % of the compute
    /// and half the input for ~5 points of accuracy), and a tiny variant
    /// (~25 % compute, quarter input, ~19 points down). The accuracy
    /// numbers follow the usual full/distilled/tiny spread of DNN model
    /// families; the costs scale the paper's measured stage times.
    pub fn stage3_family(cfg: &SystemConfig) -> Ladder {
        let image_mbits = cfg.image_bytes as f64 * 8.0 / 1e6;
        Ladder::new(vec![
            ModelVariant::new("stage3-full", 0.97, image_mbits, cfg.lp2_proc_s, cfg.lp4_proc_s),
            ModelVariant::new(
                "stage3-distilled",
                0.92,
                image_mbits * 0.5,
                cfg.lp2_proc_s * 0.55,
                cfg.lp4_proc_s * 0.55,
            ),
            ModelVariant::new(
                "stage3-tiny",
                0.78,
                image_mbits * 0.25,
                cfg.lp2_proc_s * 0.25,
                cfg.lp4_proc_s * 0.25,
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage3_family_is_valid_and_descending() {
        let cfg = SystemConfig::default();
        let fam = Ladder::stage3_family(&cfg);
        fam.validate().unwrap();
        assert_eq!(fam.depth(), 3);
        assert!(fam.rungs.windows(2).all(|w| w[1].accuracy < w[0].accuracy));
        assert!(fam.rungs.windows(2).all(|w| w[1].proc2_s < w[0].proc2_s));
        // Rung 0 is exactly the paper's stage-3 spec.
        let compiled = fam.compile(&cfg);
        assert_eq!(compiled[0].proc_us, [cfg.lp2_proc(), cfg.lp4_proc()]);
        assert_eq!(compiled[0].input_bytes, cfg.image_bytes);
        assert!((compiled[0].accuracy - 0.97).abs() < 1e-12);
    }

    #[test]
    fn truncation_keeps_the_top_rungs() {
        let cfg = SystemConfig::default();
        let fam = Ladder::stage3_family(&cfg);
        assert_eq!(fam.truncated(1).depth(), 1);
        assert_eq!(fam.truncated(2).rungs[1], fam.rungs[1]);
        assert_eq!(fam.truncated(99).depth(), 3, "truncation clamps to the family depth");
        assert_eq!(fam.truncated(0).depth(), 1, "at least one rung always remains");
    }

    #[test]
    fn validate_rejects_malformed_ladders() {
        let mk = |acc: f64, in_mb: f64, p2: f64, p4: f64| ModelVariant::new("v", acc, in_mb, p2, p4);
        assert!(Ladder::new(vec![]).validate().is_err(), "empty");
        assert!(Ladder::single(mk(1.5, 1.0, 2.0, 1.5)).validate().is_err(), "accuracy > 1");
        assert!(Ladder::single(mk(0.9, 1.0, 2.0, 2.5)).validate().is_err(), "proc4 > proc2");
        assert!(Ladder::single(mk(0.9, 1.0, 0.0, 0.0)).validate().is_err(), "zero stage time");
        // Non-monotone descent: the lower rung is MORE accurate.
        let inverted = Ladder::new(vec![mk(0.8, 1.0, 2.0, 1.5), mk(0.9, 0.5, 1.0, 0.8)]);
        assert!(inverted.validate().is_err());
        // Non-monotone cost: the lower rung is MORE expensive.
        let pricier = Ladder::new(vec![mk(0.9, 1.0, 2.0, 1.5), mk(0.8, 1.0, 3.0, 2.0)]);
        assert!(pricier.validate().is_err());
        // Depth cap.
        let deep = Ladder::new(
            (0..MAX_RUNGS + 1)
                .map(|i| mk(0.9 - i as f64 * 0.05, 1.0, 2.0, 1.5))
                .collect(),
        );
        assert!(deep.validate().is_err());
    }

    #[test]
    fn compile_pads_every_rung() {
        let cfg = SystemConfig::default();
        let fam = Ladder::stage3_family(&cfg);
        let compiled = fam.compile(&cfg);
        for (v, r) in fam.rungs.iter().zip(&compiled) {
            assert_eq!(r.proc_us[0], secs(v.proc2_s + cfg.proc_padding_s));
            assert_eq!(r.proc_us[1], secs(v.proc4_s + cfg.proc_padding_s));
            assert_eq!(r.input_bytes, (v.input_mbits * 1e6 / 8.0).round() as u64);
        }
    }
}
