//! Task-class catalog: the per-class shape of generated work.
//!
//! The paper's evaluation knows exactly two task shapes — the
//! high-priority detector stage and the low-priority stage-3 DNN. A
//! [`TaskClass`] generalises that pair: per-class priority, relative
//! deadline, input size, per-configuration stage cost (given directly in
//! seconds or derived from a FLOP count), arrival batch size, and a mix
//! weight. A [`Catalog`] is the weighted set of classes one generator
//! draws from; [`Catalog::conveyor`] reproduces the paper's HP/LP pair so
//! the conveyor workload is just one catalog among many.

use crate::config::SystemConfig;
use crate::coordinator::task::Priority;
use crate::time::{secs, SimDuration};

use super::variants::Ladder;

/// Four-core parallel efficiency implied by the paper's benchmarks:
/// 16.862 s on two cores vs 11.611 s on four is a 1.45× speed-up for a
/// 2× core increase, i.e. ≈0.726 efficiency. [`TaskClass::from_flops`]
/// uses it to derive the four-core stage time from a FLOP count.
pub const FOUR_CORE_EFFICIENCY: f64 = 0.726;

/// One class of generated work.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskClass {
    pub name: String,
    pub priority: Priority,
    /// Relative completion deadline from arrival, seconds.
    pub deadline_s: f64,
    /// Input transferred on offload, megabits (0 for local-only classes;
    /// ignored for high-priority classes, which never offload).
    pub input_mbits: f64,
    /// Two-core stage processing time, seconds (the single stage time for
    /// high-priority classes). This is the *benchmark mean*: compilation
    /// adds the system's `proc_padding_s` to low-priority plans exactly
    /// like the conveyor pipeline does, and the engine executes
    /// mean + |N(0, σ)| — so classes keep the paper's conservative-plan
    /// semantics without each catalog hand-adding the padding.
    pub proc2_s: f64,
    /// Four-core stage processing time (benchmark mean), seconds.
    pub proc4_s: f64,
    /// Tasks per arrival (an arrival is one atomic batch request).
    /// High-priority classes must use 1 (HP placement is per-task).
    pub batch: u32,
    /// Unnormalised mix weight (chance this class is drawn per arrival).
    pub weight: f64,
    /// Cloud-tier service time, seconds. `None` (the default) derives it
    /// from the four-core time and the system's `cloud_speedup` at
    /// compile; an explicit value overrides per class (a memory-bound
    /// stage may gain less from the server tier than a compute-bound
    /// one). Ignored for high-priority classes, which never leave the
    /// edge, and irrelevant while the cloud tier is disabled.
    pub cloud_s: Option<f64>,
    /// Model-variant ladder (ordered, rung 0 = full accuracy). Empty =
    /// the class's single spec compiles to an implicit one-rung ladder
    /// at accuracy 1.0, bit-identical to the pre-ladder behaviour. Set
    /// through [`TaskClass::ladder`], which keeps the class spec synced
    /// to rung 0. Low-priority classes only — HP work never degrades.
    pub variants: Vec<super::variants::ModelVariant>,
}

impl TaskClass {
    /// A low-priority class with explicit stage times.
    pub fn low(name: &str, deadline_s: f64, input_mbits: f64, proc2_s: f64, proc4_s: f64) -> Self {
        Self {
            name: name.to_string(),
            priority: Priority::Low,
            deadline_s,
            input_mbits,
            proc2_s,
            proc4_s,
            batch: 1,
            weight: 1.0,
            cloud_s: None,
            variants: Vec::new(),
        }
    }

    /// A high-priority class (local to its source, preemption-capable).
    pub fn high(name: &str, deadline_s: f64, proc_s: f64) -> Self {
        Self {
            name: name.to_string(),
            priority: Priority::High,
            deadline_s,
            input_mbits: 0.0,
            proc2_s: proc_s,
            proc4_s: proc_s,
            batch: 1,
            weight: 1.0,
            cloud_s: None,
            variants: Vec::new(),
        }
    }

    /// Derive the stage times from a per-stage FLOP cost and a per-core
    /// throughput: `proc2 = gflops / (2 · core_gflops_s)`, four-core
    /// scaled by [`FOUR_CORE_EFFICIENCY`].
    pub fn from_flops(
        name: &str,
        deadline_s: f64,
        input_mbits: f64,
        stage_gflops: f64,
        core_gflops_s: f64,
    ) -> Self {
        let proc2 = stage_gflops / (2.0 * core_gflops_s);
        let proc4 = stage_gflops / (4.0 * core_gflops_s * FOUR_CORE_EFFICIENCY);
        Self::low(name, deadline_s, input_mbits, proc2, proc4)
    }

    pub fn batch(mut self, n: u32) -> Self {
        self.batch = n;
        self
    }

    pub fn weight(mut self, w: f64) -> Self {
        self.weight = w;
        self
    }

    /// Override the cloud-tier service time (seconds).
    pub fn cloud(mut self, secs: f64) -> Self {
        self.cloud_s = Some(secs);
        self
    }

    /// Attach a model-variant ladder. Rung 0 becomes the class's own
    /// spec (input/stage times are synced to it), so an attached ladder
    /// *replaces* the single-model cost — the class never runs a model
    /// its ladder doesn't name. Validated by [`Catalog::validate`].
    pub fn ladder(mut self, ladder: Ladder) -> Self {
        if let Some(r0) = ladder.rungs.first() {
            self.input_mbits = r0.input_mbits;
            self.proc2_s = r0.proc2_s;
            self.proc4_s = r0.proc4_s;
        }
        self.variants = ladder.rungs;
        self
    }

    /// Compiled integer form the engine consumes. Low-priority plan
    /// durations are mean + the system padding (the engine subtracts the
    /// padding back out and jitters around the mean); high-priority
    /// stages are unpadded, as in the paper.
    pub(crate) fn compile(&self, cfg: &SystemConfig) -> super::driver::GenClass {
        let pad = if self.priority == Priority::Low { cfg.proc_padding_s } else { 0.0 };
        super::driver::GenClass {
            priority: self.priority,
            deadline_us: secs(self.deadline_s),
            input_bytes: (self.input_mbits * 1e6 / 8.0).round() as u64,
            proc_us: [secs(self.proc2_s + pad), secs(self.proc4_s + pad)],
            cloud_us: if self.priority == Priority::High {
                0
            } else {
                match self.cloud_s {
                    Some(s) => secs(s).max(1), // explicit, unpadded
                    None => crate::coordinator::task::default_cloud_us(self.proc4_s, cfg),
                }
            },
            batch: self.batch.max(1),
            rungs: self.variants.iter().map(|v| v.compile(pad)).collect(),
            stage_plans: self.variants.iter().map(|v| v.compile_stages()).collect(),
        }
    }

    /// Nominal (two-core, no transfer) service time — the closed-loop
    /// generator's think-cycle estimate.
    pub(crate) fn nominal_service_us(&self) -> SimDuration {
        secs(self.proc2_s)
    }
}

/// A weighted set of task classes.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Catalog {
    pub classes: Vec<TaskClass>,
}

impl Catalog {
    pub fn new(classes: Vec<TaskClass>) -> Self {
        Self { classes }
    }

    /// The paper's pipeline as a catalog: the detector/classifier HP
    /// stage and the stage-3 DNN LP class with the benchmark times from
    /// `cfg`. LP arrivals carry the trace's mean burst of 2 tasks; the
    /// conveyor *workload* itself does not go through this catalog (it
    /// replays the trace exactly), but sweeps that want "paper-shaped
    /// work under open-loop arrivals" start here.
    pub fn conveyor(cfg: &SystemConfig) -> Self {
        Self::new(vec![
            TaskClass::high("detect", cfg.hp_deadline_s, cfg.hp_proc_s).weight(1.0),
            TaskClass::low(
                "stage3",
                cfg.frame_period_s,
                cfg.image_bytes as f64 * 8.0 / 1e6,
                cfg.lp2_proc_s,
                cfg.lp4_proc_s,
            )
            .batch(2)
            .weight(2.0),
        ])
    }

    /// A heterogeneous edge-serving mix (the regime of the related
    /// DNN-serving schedulers): latency-sensitive *interactive* queries,
    /// paper-shaped *standard* jobs, and heavy *analytics* batches with
    /// a loose deadline and a large input.
    pub fn edge_serving(cfg: &SystemConfig) -> Self {
        let image_mbits = cfg.image_bytes as f64 * 8.0 / 1e6;
        Self::new(vec![
            TaskClass::low("interactive", 6.0, image_mbits * 0.25, 3.2, 2.2).weight(3.0),
            TaskClass::low("standard", cfg.frame_period_s, image_mbits, cfg.lp2_proc_s, cfg.lp4_proc_s)
                .batch(2)
                .weight(2.0),
            TaskClass::low("analytics", 3.0 * cfg.frame_period_s, image_mbits * 2.0, 24.0, 16.5)
                .batch(6)
                .weight(1.0),
        ])
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.classes.is_empty(), "catalog has no classes");
        let total: f64 = self.classes.iter().map(|c| c.weight).sum();
        anyhow::ensure!(total > 0.0, "catalog mix weights sum to zero");
        for c in &self.classes {
            anyhow::ensure!(c.weight >= 0.0, "class {}: negative weight", c.name);
            anyhow::ensure!(c.deadline_s > 0.0, "class {}: non-positive deadline", c.name);
            anyhow::ensure!(
                c.proc2_s > 0.0 && c.proc4_s > 0.0,
                "class {}: non-positive stage time",
                c.name
            );
            anyhow::ensure!(
                c.proc4_s <= c.proc2_s,
                "class {}: four-core time must not exceed two-core time",
                c.name
            );
            anyhow::ensure!(
                c.priority == Priority::Low || c.batch == 1,
                "class {}: high-priority classes are placed per-task (batch must be 1)",
                c.name
            );
            if let Some(s) = c.cloud_s {
                anyhow::ensure!(
                    s.is_finite() && s > 0.0,
                    "class {}: cloud service time must be finite and positive",
                    c.name
                );
                anyhow::ensure!(
                    c.priority == Priority::Low,
                    "class {}: high-priority classes never run on the cloud tier",
                    c.name
                );
            }
            if !c.variants.is_empty() {
                anyhow::ensure!(
                    c.priority == Priority::Low,
                    "class {}: high-priority classes cannot carry a variant ladder",
                    c.name
                );
                Ladder::new(c.variants.clone())
                    .validate()
                    .map_err(|e| anyhow::anyhow!("class {}: {e}", c.name))?;
                let r0 = &c.variants[0];
                anyhow::ensure!(
                    r0.input_mbits == c.input_mbits
                        && r0.proc2_s == c.proc2_s
                        && r0.proc4_s == c.proc4_s,
                    "class {}: ladder rung 0 must equal the class spec \
                     (attach ladders through TaskClass::ladder, which syncs them)",
                    c.name
                );
            }
        }
        Ok(())
    }

    /// Mix weights in class order (generator sampling).
    pub(crate) fn weights(&self) -> Vec<f64> {
        self.classes.iter().map(|c| c.weight).collect()
    }

    /// Weighted mean nominal service time across the mix (closed-loop
    /// think-cycle estimate).
    pub(crate) fn mean_service_us(&self) -> SimDuration {
        let total: f64 = self.classes.iter().map(|c| c.weight).sum();
        if total <= 0.0 {
            return 1;
        }
        let mean: f64 = self
            .classes
            .iter()
            .map(|c| c.weight * c.nominal_service_us() as f64)
            .sum::<f64>()
            / total;
        (mean.round() as SimDuration).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conveyor_catalog_mirrors_the_paper_pair() {
        let cfg = SystemConfig::default();
        let cat = Catalog::conveyor(&cfg);
        cat.validate().unwrap();
        assert_eq!(cat.classes.len(), 2);
        let hp = &cat.classes[0];
        assert_eq!(hp.priority, Priority::High);
        // HP stages are unpadded, exactly like the paper.
        assert_eq!(hp.compile(&cfg).proc_us, [cfg.hp_proc(); 2]);
        assert_eq!(hp.compile(&cfg).deadline_us, cfg.hp_deadline());
        let lp = &cat.classes[1];
        assert_eq!(lp.priority, Priority::Low);
        // LP means + the system padding == the conveyor's padded plan.
        assert_eq!(lp.compile(&cfg).proc_us, [cfg.lp2_proc(), cfg.lp4_proc()]);
        assert_eq!(lp.compile(&cfg).input_bytes, cfg.image_bytes);
    }

    #[test]
    fn flop_derived_times_scale_with_cost_and_cores() {
        let a = TaskClass::from_flops("a", 20.0, 8.0, 40.0, 1.25);
        let b = TaskClass::from_flops("b", 20.0, 8.0, 80.0, 1.25);
        assert!((b.proc2_s / a.proc2_s - 2.0).abs() < 1e-9, "FLOPs double → time doubles");
        // Four cores are faster than two but sub-linear (efficiency < 1).
        assert!(a.proc4_s < a.proc2_s);
        assert!(a.proc4_s > a.proc2_s / 2.0);
    }

    #[test]
    fn validate_rejects_malformed_classes() {
        let cfg = SystemConfig::default();
        assert!(Catalog::new(vec![]).validate().is_err());
        let bad_deadline = Catalog::new(vec![TaskClass::low("x", 0.0, 1.0, 1.0, 0.8)]);
        assert!(bad_deadline.validate().is_err());
        let inverted = Catalog::new(vec![TaskClass::low("x", 10.0, 1.0, 1.0, 1.5)]);
        assert!(inverted.validate().is_err());
        let hp_batch = Catalog::new(vec![TaskClass::high("h", 2.0, 1.0).batch(3)]);
        assert!(hp_batch.validate().is_err());
        assert!(Catalog::edge_serving(&cfg).validate().is_ok());
    }

    #[test]
    fn ladder_attaches_and_syncs_rung_zero() {
        let cfg = SystemConfig::default();
        let fam = Ladder::stage3_family(&cfg);
        let class = TaskClass::low("stage3", cfg.frame_period_s, 0.0, 1.0, 0.8)
            .batch(2)
            .ladder(fam.clone());
        // Rung 0 overwrote the placeholder spec.
        assert_eq!(class.input_mbits, fam.rungs[0].input_mbits);
        assert_eq!(class.proc2_s, fam.rungs[0].proc2_s);
        assert_eq!(class.proc4_s, fam.rungs[0].proc4_s);
        let cat = Catalog::new(vec![class.clone()]);
        cat.validate().unwrap();
        // Compiled class carries compiled rungs; rung 0 equals the
        // class's own compiled spec (bit-identical by construction).
        let g = class.compile(&cfg);
        assert_eq!(g.rungs.len(), 3);
        assert_eq!(g.rungs[0].input_bytes, g.input_bytes);
        assert_eq!(g.rungs[0].proc_us, g.proc_us);
        assert!(g.rungs[2].proc_us[0] < g.rungs[0].proc_us[0]);
        // HP classes must not carry ladders.
        let mut hp = TaskClass::high("h", 2.0, 1.0);
        hp.variants = fam.rungs.clone();
        assert!(Catalog::new(vec![hp]).validate().is_err());
        // A desynced rung 0 (hand-set variants) is rejected.
        let mut desync = TaskClass::low("x", 20.0, 4.0, 8.0, 6.0);
        desync.variants = fam.rungs;
        assert!(Catalog::new(vec![desync]).validate().is_err());
    }

    #[test]
    fn stage_plans_compile_alongside_rungs() {
        let cfg = SystemConfig::default();
        let fam = Ladder::stage3_family_staged(&cfg);
        let class = TaskClass::low("stage3", cfg.frame_period_s, 0.0, 1.0, 0.8)
            .batch(2)
            .ladder(fam.clone());
        Catalog::new(vec![class.clone()]).validate().unwrap();
        let g = class.compile(&cfg);
        assert_eq!(g.stage_plans.len(), g.rungs.len());
        assert!(g.stage_plans[0].is_staged() && g.stage_plans[0].cuttable());
        let n = g.stage_plans[0].n_stages;
        assert_eq!(g.stage_plans[0].accuracy_after(n), g.rungs[0].accuracy);
        assert!(!g.stage_plans[2].is_staged());
        // Unstaged ladders compile to all-NONE plans (anytime off).
        let plain = TaskClass::low("p", cfg.frame_period_s, 0.0, 1.0, 0.8)
            .ladder(Ladder::stage3_family(&cfg))
            .compile(&cfg);
        assert!(plain.stage_plans.iter().all(|p| !p.is_staged()));
    }

    #[test]
    fn cloud_times_default_from_speedup_and_override_per_class() {
        let cfg = SystemConfig { cloud_wan_bps: 20e6, ..Default::default() };
        let derived = TaskClass::low("d", 20.0, 1.0, 3.0, 2.0).compile(&cfg);
        assert_eq!(derived.cloud_us, secs(2.0 / cfg.cloud_speedup));
        let explicit = TaskClass::low("e", 20.0, 1.0, 3.0, 2.0).cloud(0.5).compile(&cfg);
        assert_eq!(explicit.cloud_us, secs(0.5));
        // HP classes never compile a cloud time.
        assert_eq!(TaskClass::high("h", 2.0, 1.0).compile(&cfg).cloud_us, 0);
        // Validation rejects degenerate overrides (and HP overrides).
        assert!(Catalog::new(vec![TaskClass::low("x", 10.0, 1.0, 1.0, 0.8).cloud(0.0)])
            .validate()
            .is_err());
        assert!(Catalog::new(vec![TaskClass::low("x", 10.0, 1.0, 1.0, 0.8).cloud(f64::NAN)])
            .validate()
            .is_err());
        let mut hp = TaskClass::high("h", 2.0, 1.0);
        hp.cloud_s = Some(1.0);
        assert!(Catalog::new(vec![hp]).validate().is_err());
    }

    #[test]
    fn mean_service_follows_mix_weights() {
        let cat = Catalog::new(vec![
            TaskClass::low("fast", 10.0, 1.0, 1.0, 0.8).weight(1.0),
            TaskClass::low("slow", 10.0, 1.0, 3.0, 2.4).weight(1.0),
        ]);
        assert_eq!(cat.mean_service_us(), secs(2.0));
    }
}
