//! Generative workload subsystem: arrival processes, a task-class
//! catalog, and the open-loop load driver.
//!
//! The conveyor-belt trace ([`crate::workload::trace`]) couples task
//! arrivals to a fixed frame clock and to exactly two task shapes. This
//! module decouples both:
//!
//! * [`arrival::ArrivalProcess`] — *when* work arrives: Poisson, bursty
//!   MMPP on-off, diurnal rate curves, and a closed-loop (think-time)
//!   population, all expanding to seed-deterministic arrival streams.
//! * [`catalog::TaskClass`] / [`catalog::Catalog`] — *what* arrives:
//!   per-class priority, deadline, input megabits, per-stage cost
//!   (seconds or FLOPs), batch size, mix weight.
//! * [`variants::Ladder`] / [`variants::ModelVariant`] — *how well* it
//!   runs: per-class model-variant ladders (full / distilled / tiny)
//!   that let the schedulers trade inference accuracy for deadline
//!   compliance under pressure.
//! * [`driver::GenSpec`] → [`driver::GenWorkload`] — the open-loop
//!   driver: compiles (process × catalog) into the concrete arrival plan
//!   the engine's event queue executes, with offered-load and
//!   admission-drop accounting.
//!
//! [`driver::Workload`] is the scenario axis that unifies the two worlds:
//! `Workload::Conveyor(spec)` replays the paper's trace byte-identically,
//! `Workload::Generative(spec)` drives the same engine, schedulers, and
//! metrics through open-loop load. See `ScenarioBuilder::workload` and
//! the `medge loadgen` subcommand.

pub mod arrival;
pub mod catalog;
pub mod driver;
pub mod variants;

pub use arrival::{empirical_rate_per_min, index_of_dispersion, ArrivalProcess};
pub use catalog::{Catalog, TaskClass, FOUR_CORE_EFFICIENCY};
pub use driver::{GenArrival, GenClass, GenSpec, GenWorkload, Workload};
pub use variants::{Ladder, ModelVariant, StageSpec};
