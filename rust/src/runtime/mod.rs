//! Inference runtime (Layer 3 ↔ Layer 1/2 bridge): loads the AOT-compiled
//! HLO artifacts produced by `python/compile/aot.py` and executes them on
//! the PJRT CPU client. Python never runs here — the rust binary is
//! self-contained once `make artifacts` has produced the `.hlo.txt` files.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly (see
//! /opt/xla-example/README.md).
//!
//! The PJRT backend needs the offline `xla` crate closure and is gated
//! behind the `pjrt` cargo feature. Without it (the default — the crate
//! is not on crates.io) everything still compiles: [`InferenceEngine`]
//! becomes a stub whose `load` returns an error, and the whole
//! scheduling/simulation stack is unaffected.

pub mod image;

use std::path::{Path, PathBuf};

use anyhow::Result;

/// The three pipeline stages of Fig. 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Stage 1: object detector (waste present / absent).
    Detector,
    /// Stage 2: binary classifier (recyclable / non-recyclable).
    Binary,
    /// Stage 3: high-complexity four-class recyclable classifier.
    Classifier,
}

impl Stage {
    pub fn artifact_name(self) -> &'static str {
        match self {
            Stage::Detector => "detector.hlo.txt",
            Stage::Binary => "binary.hlo.txt",
            Stage::Classifier => "classifier.hlo.txt",
        }
    }

    pub fn n_classes(self) -> usize {
        match self {
            Stage::Detector => 2,
            Stage::Binary => 2,
            Stage::Classifier => 4,
        }
    }
}

/// Input image side (square RGB frames, see python/compile/model.py).
pub const IMAGE_SIDE: usize = 64;
/// Flattened input element count.
pub const IMAGE_ELEMS: usize = IMAGE_SIDE * IMAGE_SIDE * 3;

/// One inference result: per-class logits.
#[derive(Debug, Clone)]
pub struct Logits(pub Vec<f32>);

impl Logits {
    pub fn argmax(&self) -> usize {
        self.0
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Outcome of a full pipeline pass.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PipelineResult {
    pub object_present: bool,
    pub recyclable: Option<bool>,
    /// Recyclable class in 0..4 (paper: four classes of recyclable waste).
    pub class: Option<usize>,
}

/// Default artifacts directory: `$MEDGE_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("MEDGE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use super::*;
    use anyhow::Context;

    /// A compiled pipeline stage.
    pub struct CompiledStage {
        pub stage: Stage,
        exe: xla::PjRtLoadedExecutable,
    }

    /// The PJRT inference engine hosting all three stages.
    pub struct InferenceEngine {
        client: xla::PjRtClient,
        stages: Vec<CompiledStage>,
    }

    impl InferenceEngine {
        /// Load and compile every stage artifact under `artifacts_dir`.
        pub fn load(artifacts_dir: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            let mut stages = Vec::new();
            for stage in [Stage::Detector, Stage::Binary, Stage::Classifier] {
                let path = artifacts_dir.join(stage.artifact_name());
                let exe = Self::compile_one(&client, &path)
                    .with_context(|| format!("compile {}", path.display()))?;
                stages.push(CompiledStage { stage, exe });
            }
            Ok(Self { client, stages })
        }

        fn compile_one(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not UTF-8")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn compiled(&self, stage: Stage) -> &CompiledStage {
            self.stages.iter().find(|s| s.stage == stage).expect("stage loaded")
        }

        /// Run one stage on a flattened `[IMAGE_SIDE, IMAGE_SIDE, 3]` f32
        /// image in [0, 1]. Returns the per-class logits.
        pub fn infer(&self, stage: Stage, image: &[f32]) -> Result<Logits> {
            anyhow::ensure!(
                image.len() == IMAGE_ELEMS,
                "expected {IMAGE_ELEMS} elements, got {}",
                image.len()
            );
            let input = xla::Literal::vec1(image).reshape(&[
                1,
                IMAGE_SIDE as i64,
                IMAGE_SIDE as i64,
                3,
            ])?;
            let compiled = self.compiled(stage);
            let result = compiled.exe.execute::<xla::Literal>(&[input])?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True → a 1-tuple of logits.
            let out = result.to_tuple1()?;
            let logits = out.to_vec::<f32>()?;
            anyhow::ensure!(
                logits.len() == stage.n_classes(),
                "stage {stage:?}: expected {} logits, got {}",
                stage.n_classes(),
                logits.len()
            );
            Ok(Logits(logits))
        }

        /// Run the full pipeline of Fig. 1 on one frame: detector, then (if
        /// an object is present) the binary classifier, then (if recyclable)
        /// the four-class classifier. Returns what each stage decided.
        pub fn pipeline(&self, image: &[f32]) -> Result<PipelineResult> {
            let det = self.infer(Stage::Detector, image)?;
            let object_present = det.argmax() == 1;
            if !object_present {
                return Ok(PipelineResult { object_present, recyclable: None, class: None });
            }
            let bin = self.infer(Stage::Binary, image)?;
            let recyclable = bin.argmax() == 1;
            if !recyclable {
                return Ok(PipelineResult { object_present, recyclable: Some(false), class: None });
            }
            let cls = self.infer(Stage::Classifier, image)?;
            Ok(PipelineResult {
                object_present,
                recyclable: Some(true),
                class: Some(cls.argmax()),
            })
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_backend::{CompiledStage, InferenceEngine};

#[cfg(not(feature = "pjrt"))]
mod stub_backend {
    use super::*;

    enum Never {}

    /// Stub engine for builds without the `pjrt` feature: the same API
    /// surface, but `load` always fails, so no instance can exist (the
    /// other methods are statically unreachable).
    pub struct InferenceEngine {
        never: Never,
    }

    impl InferenceEngine {
        pub fn load(artifacts_dir: &Path) -> Result<Self> {
            anyhow::bail!(
                "medge was built without the `pjrt` feature; rebuild with \
                 `--features pjrt` (requires the offline xla crate closure) \
                 to load artifacts from {}",
                artifacts_dir.display()
            )
        }

        pub fn platform(&self) -> String {
            match self.never {}
        }

        pub fn infer(&self, _stage: Stage, _image: &[f32]) -> Result<Logits> {
            match self.never {}
        }

        pub fn pipeline(&self, _image: &[f32]) -> Result<PipelineResult> {
            match self.never {}
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub_backend::InferenceEngine;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logits_argmax() {
        assert_eq!(Logits(vec![0.1, 0.9]).argmax(), 1);
        assert_eq!(Logits(vec![3.0, -1.0, 2.0, 0.0]).argmax(), 0);
        assert_eq!(Logits(vec![]).argmax(), 0);
    }

    #[test]
    fn stage_metadata() {
        assert_eq!(Stage::Classifier.n_classes(), 4);
        assert_eq!(Stage::Detector.artifact_name(), "detector.hlo.txt");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_reports_missing_feature() {
        let err = InferenceEngine::load(Path::new("artifacts")).unwrap_err();
        assert!(format!("{err}").contains("pjrt"));
    }

    // Engine-loading tests live in rust/tests/runtime_inference.rs — they
    // need `make artifacts` to have run and are skipped when the artifacts
    // are absent.
}
