//! Deterministic synthetic frame generation.
//!
//! The paper feeds every DNN task the same input image (Section V: "we use
//! the same input image for each DNN task"); real deployments would crop
//! and resize the conveyor frame. We generate deterministic synthetic
//! frames — a textured background with an optional bright "waste item"
//! blob — so the end-to-end example exercises real inference with varied
//! but reproducible inputs.

use crate::runtime::{IMAGE_ELEMS, IMAGE_SIDE};

/// Generate a flattened `[IMAGE_SIDE, IMAGE_SIDE, 3]` f32 frame in [0, 1].
/// `seed` varies the texture; `with_item` stamps a bright blob (the waste
/// item) in the centre region.
pub fn synth_frame(seed: u64, with_item: bool) -> Vec<f32> {
    let mut img = vec![0.0f32; IMAGE_ELEMS];
    // Cheap deterministic texture: xorshift per pixel.
    let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    for y in 0..IMAGE_SIDE {
        for x in 0..IMAGE_SIDE {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            let noise = (s % 1000) as f32 / 1000.0;
            let base = 0.25 + 0.1 * noise; // conveyor-belt grey
            let idx = (y * IMAGE_SIDE + x) * 3;
            img[idx] = base;
            img[idx + 1] = base * 0.95;
            img[idx + 2] = base * 0.9;
        }
    }
    if with_item {
        // A bright square blob, position jittered by the seed.
        let cx = IMAGE_SIDE / 2 + (seed % 9) as usize;
        let cy = IMAGE_SIDE / 2 + (seed / 9 % 9) as usize;
        let half = IMAGE_SIDE / 6;
        for y in cy.saturating_sub(half)..(cy + half).min(IMAGE_SIDE) {
            for x in cx.saturating_sub(half)..(cx + half).min(IMAGE_SIDE) {
                let idx = (y * IMAGE_SIDE + x) * 3;
                img[idx] = 0.9;
                img[idx + 1] = 0.8 - 0.2 * ((seed % 4) as f32 / 4.0);
                img[idx + 2] = 0.3 + 0.15 * ((seed % 3) as f32 / 3.0);
            }
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_shape_and_range() {
        let f = synth_frame(1, true);
        assert_eq!(f.len(), IMAGE_ELEMS);
        assert!(f.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(synth_frame(7, true), synth_frame(7, true));
        assert_ne!(synth_frame(7, true), synth_frame(8, true));
    }

    #[test]
    fn item_brightens_centre() {
        let with = synth_frame(3, true);
        let without = synth_frame(3, false);
        let centre = (IMAGE_SIDE / 2 * IMAGE_SIDE + IMAGE_SIDE / 2) * 3;
        assert!(with[centre] > without[centre]);
    }
}
