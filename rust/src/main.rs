//! `medge` — CLI for the experiment harness.
//!
//! Regenerates every table and figure of the paper's evaluation:
//! `medge fig4 | fig5 | fig6 | fig7 | fig8 | table2 | all`, plus
//! `medge ablation` (the future-work contextual multi-scheduler) and
//! `medge trace` (trace-file tooling). Argument parsing is in-tree (the
//! offline build has no clap): `--minutes F`, `--seed N`, `--config PATH`.

use medge::config::SystemConfig;
use medge::experiments;
use medge::metrics::report;
use medge::workload::trace::{Trace, TraceSpec};

const USAGE: &str = "\
medge — deadline-constrained DNN offloading at the mobile edge (paper reproduction)

USAGE: medge <COMMAND> [--minutes F] [--seed N] [--config PATH]

COMMANDS:
  fig4     Task completion, WPS_N vs RAS_N (weighted 1..4)
  fig5     Scheduling latency by scenario, both schedulers
  fig6     LP stage-3 completion by mechanism (bandwidth-interval sweep)
  fig7     Bandwidth-interval tests: completion across categories
  fig8     Network traffic congestion tests
  table2   Core allocation mix under congestion
  all      Everything above
  ablation Contextual multi-scheduler vs WPS vs RAS (future work)
  trace    Generate a trace file: --spec S --frames N --out PATH
           (S: uniform | weighted1..weighted4)

OPTIONS:
  --minutes F   simulated experiment duration in minutes (default 30)
  --seed N      RNG seed (traces, shuffles, probe hosts, bursts)
  --config P    key-value config file overriding the paper defaults
";

struct Args {
    cmd: String,
    minutes: f64,
    seed: Option<u64>,
    config: Option<std::path::PathBuf>,
    spec: String,
    frames: usize,
    out: Option<std::path::PathBuf>,
}

fn parse_args() -> anyhow::Result<Args> {
    let mut args = Args {
        cmd: String::new(),
        minutes: 30.0,
        seed: None,
        config: None,
        spec: "weighted4".to_string(),
        frames: 96,
        out: None,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let mut value = |name: &str| -> anyhow::Result<String> {
            it.next().ok_or_else(|| anyhow::anyhow!("{name} needs a value"))
        };
        match a.as_str() {
            "--minutes" => args.minutes = value("--minutes")?.parse()?,
            "--seed" => args.seed = Some(value("--seed")?.parse()?),
            "--config" => args.config = Some(value("--config")?.into()),
            "--spec" => args.spec = value("--spec")?,
            "--frames" => args.frames = value("--frames")?.parse()?,
            "--out" => args.out = Some(value("--out")?.into()),
            "-h" | "--help" => {
                print!("{USAGE}");
                std::process::exit(0);
            }
            cmd if !cmd.starts_with('-') && args.cmd.is_empty() => args.cmd = cmd.to_string(),
            other => anyhow::bail!("unknown argument: {other}\n{USAGE}"),
        }
    }
    if args.cmd.is_empty() {
        anyhow::bail!("missing command\n{USAGE}");
    }
    Ok(args)
}

fn main() -> anyhow::Result<()> {
    let args = parse_args()?;
    let mut cfg = match &args.config {
        Some(p) => SystemConfig::from_kv_file(p)?,
        None => SystemConfig::default(),
    };
    if let Some(seed) = args.seed {
        cfg.seed = seed;
    }
    let minutes = args.minutes;

    match args.cmd.as_str() {
        "fig4" => {
            let runs = experiments::fig4_fig5(&cfg, minutes);
            print!("{}", report::fig4(&runs));
        }
        "fig5" => {
            let runs = experiments::fig4_fig5(&cfg, minutes);
            print!("{}", report::fig5(&runs));
        }
        "fig6" => {
            let runs = experiments::fig6_fig7(&cfg, minutes);
            print!("{}", report::fig6(&runs));
        }
        "fig7" => {
            let runs = experiments::fig6_fig7(&cfg, minutes);
            print!("{}", report::fig7(&runs));
        }
        "fig8" => {
            let runs = experiments::fig8_table2(&cfg, minutes);
            print!("{}", report::fig8(&runs));
        }
        "table2" => {
            let runs = experiments::fig8_table2(&cfg, minutes);
            print!("{}", report::table2(&runs));
        }
        "all" => {
            let main_runs = experiments::fig4_fig5(&cfg, minutes);
            print!("{}", report::fig4(&main_runs));
            print!("{}", report::fig5(&main_runs));
            let bit_runs = experiments::fig6_fig7(&cfg, minutes);
            print!("{}", report::fig6(&bit_runs));
            print!("{}", report::fig7(&bit_runs));
            let traffic_runs = experiments::fig8_table2(&cfg, minutes);
            print!("{}", report::fig8(&traffic_runs));
            print!("{}", report::table2(&traffic_runs));
        }
        "ablation" => {
            let runs = experiments::ablation_multi(&cfg, minutes);
            print!("{}", report::fig4(&runs));
            print!("{}", report::fig5(&runs));
        }
        "trace" => {
            let out = args.out.ok_or_else(|| anyhow::anyhow!("trace needs --out PATH"))?;
            let t = Trace::generate(TraceSpec::parse(&args.spec)?, cfg.n_devices, args.frames, cfg.seed);
            t.save(&out)?;
            println!(
                "wrote {} frames ({:.2} mean DNN load) to {}",
                args.frames,
                t.mean_dnn_load(),
                out.display()
            );
        }
        other => anyhow::bail!("unknown command: {other}\n{USAGE}"),
    }
    Ok(())
}
